//! Figure 6: aspect-ratio study at equal PE counts (the SCALE-SIM
//! configuration space of Samajdar et al.), for PE budgets 4096, 16384 and
//! 65536 — plus the SCALE-SIM-style baseline for context.
//!
//! Run: `cargo run --release --example equal_pe`

use camuy::baseline::scalesim_metrics;
use camuy::config::ArrayConfig;
use camuy::nets;
use camuy::report::figures::{fig6_equal_pe, write_fig6, FigureContext};
use camuy::sweep::grid::equal_pe_factorizations;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let ctx = FigureContext::paper();
    let out = Path::new("results/equal_pe");

    let budgets = [4096usize, 16384, 65536];
    let data: Vec<_> = budgets
        .iter()
        .map(|&b| fig6_equal_pe(b, 8, &ctx))
        .collect();
    write_fig6(&data, out)?;

    for d in &data {
        println!("PE budget {} — avg normalized E across the nine models:", d.pe_budget);
        for (i, &(h, w)) in d.shapes.iter().enumerate() {
            let bar_len = (d.average[i] * 50.0).round() as usize;
            println!(
                "  {h:>5} x {w:<5} {:<52} {:.4}",
                "#".repeat(bar_len.max(1)),
                d.average[i]
            );
        }
        println!();
    }

    // SCALE-SIM baseline context: cycles for ResNet-152 across the 16384
    // space (their never-stalling weight-stationary model).
    println!("SCALE-SIM-style baseline, ResNet-152 cycles @16384 PEs:");
    let net = nets::build("resnet152").unwrap();
    for (h, w) in equal_pe_factorizations(16384, 8) {
        let cfg = ArrayConfig::new(h, w);
        let cycles: u64 = net
            .layers
            .iter()
            .map(|l| {
                let (g, groups) = l.gemm();
                scalesim_metrics(g, &cfg).cycles * groups as u64
            })
            .sum();
        println!("  {h:>5} x {w:<5} {cycles:>15} cycles");
    }
    println!("\noutputs written to {}", out.display());
    Ok(())
}
