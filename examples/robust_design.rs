//! Section 5 of the paper: the robust configuration search — Figure 4
//! (per-model heatmap minima) and Figure 5 (Pareto over the averaged
//! min-max-normalized data movement cost and cycle count of all nine
//! models).
//!
//! Run: `cargo run --release --example robust_design [-- --smoke]`

use camuy::pareto::nsga2::Nsga2Params;
use camuy::report::figures::{fig4_heatmaps, fig5_robust, write_fig4, write_fig5, FigureContext};
use camuy::report::pareto_table;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ctx = if smoke {
        FigureContext::smoke()
    } else {
        FigureContext::paper()
    };
    let out = Path::new("results/robust");

    // Figure 4: where does each model want the array to be?
    let fig4 = fig4_heatmaps(&ctx);
    write_fig4(&fig4, out)?;
    println!("per-model optima (Figure 4):");
    println!("{:<18} {:>8} {:>8} {:>14}", "model", "height", "width", "min E");
    for d in &fig4 {
        let (h, w, e) = d.energy.min_cell();
        println!("{:<18} {:>8} {:>8} {:>14.4e}", d.network, h, w, e);
    }
    println!();

    // Figure 5: the robustness Pareto.
    let fig5 = fig5_robust(&ctx, &Nsga2Params::default());
    write_fig5(&fig5, out)?;
    println!(
        "{}",
        pareto_table(
            "Figure 5 — robust Pareto (avg normalized E vs avg normalized cycles)",
            &["avg_norm_E", "avg_norm_cyc"],
            &fig5.front
        )
    );

    // The paper's reading of the figure: the knee configurations.
    let knee: Vec<_> = fig5
        .front
        .iter()
        .filter(|s| s.objectives[0] < 0.25 && s.objectives[1] < 0.25)
        .collect();
    println!("knee (both objectives < 0.25):");
    for s in &knee {
        let ratio = s.width as f64 / s.height as f64;
        println!(
            "  ({:>3}, {:>3})  width/height = {ratio:.2}{}",
            s.height,
            s.width,
            if ratio < 1.0 { "  <- height > width" } else { "" }
        );
    }
    println!("outputs written to {}", out.display());
    Ok(())
}
