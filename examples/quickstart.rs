//! Quickstart: the 60-second tour of CAMUY.
//!
//! 1. Create an emulator instance for an array configuration.
//! 2. Functionally emulate a small GEMM (real numbers + movement counters).
//! 3. Run a full ResNet-152 inference through the analytic coordinator.
//! 4. If `make artifacts` has run, execute the same GEMM through the
//!    AOT-compiled JAX/Pallas artifact on PJRT and cross-check.
//!
//! Run: `cargo run --release --example quickstart`

use camuy::arch::{EmulationMode, Emulator};
use camuy::config::{ArrayConfig, EnergyWeights};
use camuy::coordinator::Coordinator;
use camuy::nets;
use camuy::report::kv_block;
use camuy::runtime::{default_artifact_dir, Manifest, PjrtRuntime};
use camuy::tensor::Matrix;
use camuy::util::human_count;
use camuy::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. an emulator instance, TPUv1-flavoured but 32x32 ---
    let cfg = ArrayConfig::new(32, 32);
    println!("array config: {cfg}\n");

    // --- 2. functional emulation of one GEMM ---
    let mut rng = Rng::new(42);
    let a = Matrix::random_small_int(48, 96, &mut rng);
    let w = Matrix::random_small_int(96, 64, &mut rng);
    let emu = Emulator::new(cfg.clone()).map_err(anyhow::Error::msg)?;
    let res = emu.run_gemm(&a, &w, EmulationMode::Wavefront);
    assert_eq!(res.output, a.matmul(&w), "emulator numerics are exact");
    println!(
        "{}",
        kv_block(
            "GEMM 48x96x64 on the functional emulator",
            &[
                ("cycles", human_count(res.metrics.cycles)),
                ("passes", human_count(res.metrics.passes)),
                ("MACs", human_count(res.metrics.macs)),
                ("utilization", format!("{:.3}", res.metrics.utilization(cfg.pe_count()))),
                ("M_UB", human_count(res.metrics.movements.m_ub())),
                ("M_INTER_PE", human_count(res.metrics.movements.m_inter_pe())),
                ("M_AA", human_count(res.metrics.movements.m_aa())),
                (
                    "energy E (Eq.1)",
                    format!("{:.4e}", res.metrics.energy(&EnergyWeights::paper()))
                ),
                ("numerics", "exact vs reference matmul".to_string()),
            ]
        )
    );

    // --- 3. a full network on the analytic coordinator ---
    let net = nets::build("resnet152").unwrap();
    let coord = Coordinator::new(cfg.clone()).map_err(anyhow::Error::msg)?;
    let run = coord.run_inference(&net);
    println!(
        "{}",
        kv_block(
            "ResNet-152 inference (analytic model)",
            &[
                ("layers", run.timeline.len().to_string()),
                ("cycles", human_count(run.total.cycles)),
                ("utilization", format!("{:.4}", run.utilization())),
                (
                    "energy E (Eq.1)",
                    format!("{:.4e}", run.energy(&EnergyWeights::paper()))
                ),
                ("UB bandwidth (B/cy)", format!("{:.1}", run.bandwidth.ub_total())),
            ]
        )
    );

    // --- 4. the compiled JAX/Pallas artifact, if present ---
    match Manifest::load(&default_artifact_dir()) {
        Err(_) => println!("(artifacts not built — run `make artifacts` for the PJRT leg)"),
        Ok(manifest) => {
            let entry = manifest.find("gemm_quickstart").expect("manifest entry");
            let rt = PjrtRuntime::cpu()?;
            let exe = rt.load(&entry.name, &entry.file)?;
            let a = Matrix::random_small_int(128, 128, &mut rng);
            let w = Matrix::random_small_int(128, 128, &mut rng);
            let got = exe.run_gemm(&a, &w)?;
            let diff = got.max_abs_diff(&a.matmul(&w));
            println!(
                "PJRT artifact 'gemm_quickstart' on {}: max |diff| vs reference = {diff:.2e}",
                rt.platform()
            );
            assert!(diff < 1e-3);
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
