//! Multi-array scaling study (the paper's §6 future work, implemented):
//! how do banks of 1..16 small arrays trade latency against data-movement
//! energy, and which workloads actually parallelize?
//!
//! Run: `cargo run --release --example multiarray_scaling`

use camuy::config::{ArrayConfig, EnergyWeights};
use camuy::model::multi::{network_metrics_multi, MultiArrayConfig};
use camuy::nets;
use camuy::util::human_count;

fn main() -> anyhow::Result<()> {
    let w = EnergyWeights::paper();
    let base_cfg = ArrayConfig::new(64, 64);
    println!(
        "bank scaling on {base_cfg} (speedup = makespan vs 1 array; ΔE = Eq.1 energy overhead)\n"
    );

    for name in ["resnet152", "resnext152", "mobilenetv3l", "capsnet", "bertbase-s128"] {
        let net = nets::build(name).unwrap();
        let base = network_metrics_multi(&net, &MultiArrayConfig::new(1, base_cfg.clone()));
        println!(
            "{:<16} 1x: {:>10} cycles, E {:.3e}",
            name,
            human_count(base.makespan_cycles),
            base.energy(&w)
        );
        for arrays in [2usize, 4, 8, 16] {
            let cfg = MultiArrayConfig::new(arrays, base_cfg.clone());
            let m = network_metrics_multi(&net, &cfg);
            let speedup = base.makespan_cycles as f64 / m.makespan_cycles as f64;
            let de = 100.0 * (m.energy(&w) / base.energy(&w) - 1.0);
            let eff = 100.0 * speedup / arrays as f64;
            println!(
                "  {arrays:>2} arrays: {speedup:>5.2}x speedup ({eff:>5.1}% parallel efficiency), \
                 ΔE {de:+.1}%, bank util {:.3}",
                m.utilization(&cfg)
            );
        }
        println!();
    }

    // The headline comparison: 16 arrays of 64x64 vs one 256x256 TPU — the
    // same PE count, radically different efficiency on modern nets.
    println!("same 65536 PEs, two organizations (MobileNetV3-Large):");
    let net = nets::build("mobilenetv3l").unwrap();
    let bank = network_metrics_multi(&net, &MultiArrayConfig::new(16, base_cfg));
    let tpu = net.metrics(&ArrayConfig::tpu_v1());
    println!(
        "  16 x 64x64 bank : {:>10} cycles, E {:.3e}",
        human_count(bank.makespan_cycles),
        bank.energy(&w)
    );
    println!(
        "  1 x 256x256 TPU : {:>10} cycles, E {:.3e}",
        human_count(tpu.cycles),
        tpu.energy(&w)
    );
    Ok(())
}
