//! END-TO-END DRIVER: proves all three layers of the stack compose on a
//! real small workload.
//!
//!   Layer 1 (Pallas weight-stationary matmul kernel)
//!     -> lowered inside Layer 2 (JAX conv-as-GEMM graphs)
//!     -> exported once as HLO text (`make artifacts`)
//!     -> loaded, compiled and executed here by the Layer 3 Rust
//!        coordinator through PJRT,
//! while the functional emulator runs the *same* operands and the
//! analytic model prices them — three independent numeric/metric paths
//! that must agree.
//!
//! Workload: every artifact in the manifest — real layer shapes from
//! ResNet-152 and MobileNetV3 — plus a batched request loop over the
//! quickstart GEMM reporting latency/throughput. See DESIGN.md §7.4 for
//! the verification strategy this example exercises.
//!
//! Run: `make artifacts && cargo run --release --example verify_numerics`

use camuy::config::{ArrayConfig, EnergyWeights};
use camuy::coordinator::verify::verify_gemm_artifact;
use camuy::runtime::{default_artifact_dir, Manifest, PjrtRuntime};
use camuy::tensor::Matrix;
use camuy::util::human_count;
use camuy::util::prng::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    let manifest = Manifest::load(&dir)?;
    let rt = PjrtRuntime::cpu()?;
    println!(
        "PJRT platform: {}; {} artifacts in {}\n",
        rt.platform(),
        manifest.artifacts.len(),
        dir.display()
    );

    // --- three-way verification on every GEMM artifact ---
    let cfg = ArrayConfig::new(32, 32);
    println!("three-way verification (reference = emulator = PJRT):");
    let mut all_pass = true;
    for entry in manifest.artifacts.iter().filter(|a| a.kind == "gemm") {
        let report = verify_gemm_artifact(&rt, entry, &cfg, 2026)?;
        println!("  {report}");
        all_pass &= report.pass;
    }
    anyhow::ensure!(all_pass, "verification failed");

    // --- non-GEMM artifacts: compile + execute smoke with shape checks ---
    println!("\ncompiling + executing composite artifacts:");
    let mut rng = Rng::new(7);
    for entry in manifest.artifacts.iter().filter(|a| a.kind != "gemm") {
        let exe = rt.load(&entry.name, &entry.file)?;
        let buffers: Vec<Vec<f32>> = entry
            .inputs
            .iter()
            .map(|shape| {
                let len: usize = shape.iter().product();
                (0..len)
                    .map(|_| (rng.range_usize(0, 8) as i32 - 4) as f32)
                    .collect()
            })
            .collect();
        let refs: Vec<(Vec<i64>, &[f32])> = entry
            .inputs
            .iter()
            .zip(&buffers)
            .map(|(shape, data)| {
                (
                    shape.iter().map(|&d| d as i64).collect::<Vec<i64>>(),
                    data.as_slice(),
                )
            })
            .collect();
        let arg_refs: Vec<(&[i64], &[f32])> =
            refs.iter().map(|(s, d)| (s.as_slice(), *d)).collect();
        let t0 = Instant::now();
        let out = exe.run_raw(&arg_refs)?;
        println!(
            "  {:<22} ({:<10}) -> {} outputs in {:.2?}",
            entry.name,
            entry.kind,
            human_count(out.len() as u64),
            t0.elapsed()
        );
        anyhow::ensure!(out.iter().all(|v| v.is_finite()), "non-finite output");
    }

    // --- batched request loop: latency/throughput on the served GEMM ---
    println!("\nbatched request loop (gemm_quickstart, 64 requests):");
    let entry = manifest.find("gemm_quickstart").unwrap();
    let exe = rt.load(&entry.name, &entry.file)?;
    let mut latencies = Vec::new();
    let mut checked = 0usize;
    let t_all = Instant::now();
    for i in 0..64 {
        let a = Matrix::random_small_int(128, 128, &mut rng);
        let w = Matrix::random_small_int(128, 128, &mut rng);
        let t0 = Instant::now();
        let out = exe.run_gemm(&a, &w)?;
        latencies.push(t0.elapsed().as_secs_f64());
        if i % 8 == 0 {
            // Spot-check numerics on every 8th request.
            anyhow::ensure!(out.max_abs_diff(&a.matmul(&w)) < 1e-3);
            checked += 1;
        }
    }
    let total = t_all.elapsed().as_secs_f64();
    let summary = camuy::util::stats::Summary::of(&latencies).unwrap();
    println!(
        "  p50 {:.3} ms, p95 {:.3} ms, throughput {:.1} req/s ({} spot-checked)",
        summary.median * 1e3,
        summary.p95 * 1e3,
        64.0 / total,
        checked
    );

    // --- emulator metrics for the same served workload ---
    let m = camuy::model::gemm::ws_metrics(
        camuy::model::schedule::GemmShape::new(128, 128, 128),
        &cfg,
    );
    println!(
        "  emulated on {cfg}: {} cycles/request, E = {:.3e}, utilization {:.3}",
        human_count(m.cycles),
        m.energy(&EnergyWeights::paper()),
        m.utilization(cfg.pe_count())
    );

    println!("\nE2E verification PASSED — all three layers compose.");
    Ok(())
}
