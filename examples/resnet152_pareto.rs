//! The paper's Section 4.1 case study: ResNet-152 over the 961-point
//! (height, width) grid — Figure 2 heatmaps and Figure 3 Pareto sets
//! (NSGA-II, validated against the exhaustive frontier).
//!
//! Run: `cargo run --release --example resnet152_pareto [-- --smoke]`

use camuy::pareto::nsga2::Nsga2Params;
use camuy::report::figures::{fig2_heatmaps, fig3_pareto, write_fig2, write_fig3, FigureContext};
use camuy::report::pareto_table;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ctx = if smoke {
        FigureContext::smoke()
    } else {
        FigureContext::paper()
    };
    let out = Path::new("results/resnet152");

    // Figure 2.
    let fig2 = fig2_heatmaps("resnet152", &ctx);
    write_fig2(&fig2, out)?;
    println!("{}", fig2.energy.ascii());
    println!("{}", fig2.utilization.ascii());
    let (h, w, e) = fig2.energy.min_cell();
    println!("lowest data movement cost: E = {e:.4e} at (height {h}, width {w})\n");

    // Figure 3.
    let params = Nsga2Params::default();
    let fig3 = fig3_pareto("resnet152", &ctx, &params);
    write_fig3(&fig3, out)?;
    println!(
        "{}",
        pareto_table(
            "Pareto set: data movement cost vs cycles (NSGA-II, blue dots of Fig. 3)",
            &["energy", "cycles"],
            &fig3.energy_front
        )
    );
    println!(
        "{}",
        pareto_table(
            "Pareto set: (1 - utilization) vs cycles",
            &["1-util", "cycles"],
            &fig3.utilization_front
        )
    );
    println!(
        "NSGA-II recovered {}/{} exhaustive-front points (energy objective)",
        fig3.energy_front
            .iter()
            .filter(|s| fig3
                .exhaustive_energy_front
                .iter()
                .any(|e| e.height == s.height && e.width == s.width))
            .count(),
        fig3.exhaustive_energy_front.len()
    );
    println!("outputs written to {}", out.display());
    Ok(())
}
