//! The long-lived engine behind every CLI subcommand and `camuy serve`.
//!
//! An [`Engine`] owns the three pieces of state a request needs:
//!
//! * the built-in network registry ([`crate::nets`]),
//! * the user-network store (arbitrary models ingested from layer-list
//!   JSON via [`Engine::register_network_json`]),
//! * the shared per-(shape, configuration) [`EvalCache`], so repeated
//!   queries — the same network on the same geometry, overlapping sweep
//!   cells, revisited NSGA-II grid points — hit the memo table instead of
//!   recomputing the closed form.
//!
//! All methods take `&self`; the engine is `Sync` and one instance serves
//! concurrent requests (the serve loop fans out over it directly).

use super::error::ApiError;
use super::request::{
    check_arrays, check_config, check_nsga2, EqualPeRequest, EvalRequest, GraphRequest,
    MemoryRequest, ParetoRequest, StatsRequest, SweepRequest, SweepSpec, TraceRequest,
};
use super::response::{
    EvalResponse, GraphResponse, MemoryResponse, NetworkEntry, NetworkSource, PerLayerReport,
    RegisterResponse, StatsResponse, TraceResponse,
};
use crate::config::ArrayConfig;
use crate::coordinator::Coordinator;
use crate::model::graph::NetworkGraph;
use crate::model::memory::MemoryAnalysis;
use crate::model::multi::{network_metrics_multi, MultiArrayConfig};
use crate::model::network::Network;
use crate::model::roofline;
use crate::model::workload::{EvalCache, Workload};
use crate::nets;
use crate::pareto::nsga2::Nsga2Params;
use crate::report::figures::{self, Fig2Data, Fig3Data, Fig5Data, Fig6Data};
use crate::sim::{self, SimOptions};
use crate::sweep::plan::{PlanCache, PlanCacheStats};
use crate::sweep::runner::seed_workload_planned;
use crate::telemetry::{self, ReqKind};
use crate::util::json::Json;
use std::collections::{HashMap, HashSet};
use std::sync::{OnceLock, RwLock};

/// Most user networks a long-lived engine will hold — registration past
/// this (under fresh names) is rejected so untrusted serve clients cannot
/// grow the store without bound. Re-registering an existing name always
/// succeeds.
pub const MAX_USER_NETWORKS: usize = 256;

/// Format version stamped into registry snapshots ([`Engine::snapshot_json`]).
/// Bump it when the network spec schema changes incompatibly; restore
/// rejects versions it does not understand (DESIGN.md §15).
pub const SNAPSHOT_VERSION: usize = 1;

/// The long-lived query engine. See the module docs.
#[derive(Debug, Default)]
pub struct Engine {
    user_nets: RwLock<HashMap<String, Network>>,
    /// DAG forms of user networks registered with an `edges` section.
    /// Every entry's name also exists in `user_nets` (as the chain
    /// lowering), so the store bound covers both.
    user_graphs: RwLock<HashMap<String, NetworkGraph>>,
    /// Zoo networks built once per engine; resolving a built-in model is a
    /// clone, not a reconstruction (the serving hot path).
    zoo: OnceLock<HashMap<String, Network>>,
    cache: EvalCache,
    /// Segmented sweep plans memoized per (dataflow, workload
    /// fingerprint, grid axes, accumulator capacity) — see [`PlanCache`]
    /// for the key semantics; both dataflows plan (DESIGN.md §10/§11).
    /// Sweep, Pareto, equal-PE and figure requests that replay a
    /// (workload, grid) reuse its segment tables instead of re-deriving
    /// them; batched eval seeding deliberately stays ephemeral so ad-hoc
    /// batch geometries cannot pollute the cache.
    /// Because the key embeds the exact shape histogram,
    /// [`Engine::register_network_json`] needs no invalidation hook: a
    /// re-registered network stops matching the old entries, which age
    /// out via the capacity bounds.
    plans: PlanCache,
}

impl Engine {
    pub fn new() -> Engine {
        Engine::default()
    }

    /// The shared per-(shape, configuration) memo table.
    pub fn cache(&self) -> &EvalCache {
        &self.cache
    }

    /// The shared segmented-sweep plan cache.
    pub fn plans(&self) -> &PlanCache {
        &self.plans
    }

    /// A point-in-time occupancy/traffic snapshot of the plan cache —
    /// what the serve loop logs per connection so operators can see
    /// whether sweeps are re-deriving segment tables or replaying them.
    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    /// Answer a stats request (DESIGN.md §14): a snapshot of the
    /// process-wide telemetry registry with the engine-owned sections
    /// attached — per-shard eval-cache stats, plan-cache stats, and the
    /// network-store sizes. The poll itself is counted as a request, so
    /// a monitoring loop shows up in the traffic it reports.
    pub fn stats(&self, req: &StatsRequest) -> StatsResponse {
        let timer = telemetry::Timer::start();
        let mut snapshot = telemetry::global().snapshot();
        snapshot.eval_cache = Some(self.cache.stats());
        snapshot.plan_cache = Some(self.plans.stats());
        let users = self.user_nets.read().expect("user-network store poisoned").len();
        snapshot.networks = Some((nets::ALL_MODELS.len(), users));
        timer.observe_request(ReqKind::Stats);
        StatsResponse {
            snapshot,
            buckets: req.buckets,
        }
    }

    fn zoo(&self) -> &HashMap<String, Network> {
        self.zoo.get_or_init(|| {
            nets::ALL_MODELS
                .iter()
                .map(|name| (name.to_string(), nets::build(name).expect("registered")))
                .collect()
        })
    }

    /// Resolve a network by name — user store first, then the zoo — and
    /// optionally re-batch it.
    pub fn resolve(&self, name: &str, batch: Option<usize>) -> Result<Network, ApiError> {
        if batch == Some(0) {
            return Err(ApiError::BadRequest("batch must be positive".into()));
        }
        if let Some(b) = batch {
            if b > super::request::MAX_BATCH {
                return Err(ApiError::BadRequest(format!(
                    "batch {b} exceeds the limit {}",
                    super::request::MAX_BATCH
                )));
            }
        }
        let net = {
            let store = self.user_nets.read().expect("user-network store poisoned");
            store.get(name).cloned()
        }
        .or_else(|| self.zoo().get(name).cloned())
        .ok_or_else(|| ApiError::UnknownNetwork {
            name: name.to_string(),
        })?;
        match batch {
            Some(b) => {
                let net = net.with_batch(b);
                // Re-batching composes with per-layer sizes; re-check the
                // work ceilings so the override cannot push the lowered
                // GEMMs out of exact-arithmetic range.
                for l in &net.layers {
                    l.check_work_bounds()
                        .map_err(|e| ApiError::BadRequest(format!("batch {b}: {e}")))?;
                }
                Ok(net)
            }
            None => Ok(net),
        }
    }

    /// Validate a network JSON document into the workload IR and store it
    /// under its own name. Zoo names are reserved. A document with an
    /// `edges` section is parsed as a [`NetworkGraph`] (DESIGN.md §9) and
    /// additionally stored in DAG form, so graph requests see its real
    /// connectivity; its chain lowering serves every other request kind.
    pub fn register_network_json(&self, spec: &Json) -> Result<RegisterResponse, ApiError> {
        observed(ReqKind::Register, || self.register_inner(spec))
    }

    fn register_inner(&self, spec: &Json) -> Result<RegisterResponse, ApiError> {
        // Before any lock: an injected panic here must never poison the
        // network stores (DESIGN.md §15).
        crate::faultpoint::hit("register.inner");
        // `junctions` without `edges` must reach the graph parser so it is
        // rejected loudly instead of silently dropping the junctions.
        let graph = if spec.get("edges").is_some() || spec.get("junctions").is_some() {
            Some(NetworkGraph::from_json_spec(spec).map_err(ApiError::InvalidNetwork)?)
        } else {
            None
        };
        let net = match &graph {
            Some(g) => g.to_network(),
            None => Network::from_json_spec(spec).map_err(ApiError::InvalidNetwork)?,
        };
        if self.zoo().contains_key(&net.name) {
            return Err(ApiError::InvalidNetwork(format!(
                "'{}' is a built-in zoo network; pick another name",
                net.name
            )));
        }
        let resp = RegisterResponse {
            name: net.name.clone(),
            layers: net.layers.len(),
            params: net.params(),
            macs: net.macs(),
            distinct_gemms: net.gemm_histogram().len(),
            replaced: false,
        };
        let mut store = self.user_nets.write().expect("user-network store poisoned");
        if !store.contains_key(&net.name) && store.len() >= MAX_USER_NETWORKS {
            return Err(ApiError::InvalidNetwork(format!(
                "user-network store is full ({MAX_USER_NETWORKS} networks); \
                 re-register an existing name to replace it"
            )));
        }
        // Take both stores before mutating either, so concurrent
        // re-registrations of one name can never leave its chain and DAG
        // forms out of sync. This is the only place both locks are held
        // (readers take them one at a time), so the nets→graphs order
        // cannot deadlock.
        let mut graphs = self.user_graphs.write().expect("user-graph store poisoned");
        let replaced = store.insert(net.name.clone(), net).is_some();
        match graph {
            Some(g) => {
                graphs.insert(resp.name.clone(), g);
            }
            None => {
                // A chain re-registration drops any stale graph form.
                graphs.remove(&resp.name);
            }
        }
        Ok(RegisterResponse { replaced, ..resp })
    }

    /// [`Engine::register_network_json`] from raw JSON text.
    pub fn register_network_str(&self, text: &str) -> Result<RegisterResponse, ApiError> {
        let v = Json::parse(text).map_err(ApiError::Json)?;
        self.register_network_json(&v)
    }

    /// Every known network: the zoo in registry order, then the user store
    /// sorted by name.
    pub fn list_networks(&self) -> Vec<NetworkEntry> {
        let timer = telemetry::Timer::start();
        let out = self.list_networks_inner();
        timer.observe_request(ReqKind::Zoo);
        out
    }

    fn list_networks_inner(&self) -> Vec<NetworkEntry> {
        fn entry(net: &Network, source: NetworkSource) -> NetworkEntry {
            NetworkEntry {
                name: net.name.clone(),
                source,
                params: net.params(),
                macs: net.macs(),
                layers: net.layers.len(),
                distinct_gemms: net.gemm_histogram().len(),
            }
        }
        let zoo = self.zoo();
        let mut out: Vec<NetworkEntry> = nets::ALL_MODELS
            .iter()
            .map(|name| entry(&zoo[*name], NetworkSource::Zoo))
            .collect();
        let store = self.user_nets.read().expect("user-network store poisoned");
        let mut users: Vec<&Network> = store.values().collect();
        users.sort_by(|a, b| a.name.cmp(&b.name));
        out.extend(users.into_iter().map(|n| entry(n, NetworkSource::User)));
        out
    }

    /// Export any known network as the layer-list JSON schema.
    pub fn network_spec(&self, name: &str) -> Result<Json, ApiError> {
        self.resolve(name, None).map(|n| n.to_json_spec())
    }

    /// Answer one eval request through the shared memo table.
    pub fn eval(&self, req: &EvalRequest) -> Result<EvalResponse, ApiError> {
        observed(ReqKind::Eval, || self.eval_inner(req))
    }

    fn eval_inner(&self, req: &EvalRequest) -> Result<EvalResponse, ApiError> {
        crate::robust::checkpoint();
        crate::faultpoint::hit("eval.inner");
        check_config(&req.config)?;
        check_arrays(req.arrays)?;
        let net = self.resolve(&req.net, req.batch)?;
        if req.arrays > 1 {
            let config = MultiArrayConfig::new(req.arrays, req.config.clone());
            let metrics = network_metrics_multi(&net, &config);
            return Ok(EvalResponse::Multi {
                network: net.name.clone(),
                utilization: metrics.utilization(&config),
                energy: metrics.energy(&req.weights),
                config,
                metrics,
            });
        }
        let coord = Coordinator::new(req.config.clone())
            .map_err(ApiError::Config)?
            .with_weights(req.weights);
        let run = coord.run_inference_cached(&net, &self.cache);
        let per_layer = if req.per_layer {
            let (rooflines, memory_bound_share) = roofline::network_roofline(&net, &req.config);
            Some(PerLayerReport {
                rooflines,
                memory_bound_share,
                machine_balance: roofline::machine_balance(&req.config),
            })
        } else {
            None
        };
        Ok(EvalResponse::Single {
            energy: run.energy(&req.weights),
            max_fifo_depth: sim::network_fifo_depth(&net, &req.config),
            run,
            per_layer,
        })
    }

    /// Answer a batch of eval requests: requests are grouped by workload
    /// and their distinct configurations run through the segmented sweep
    /// core once ([`seed_workload_planned`]) across `threads` workers,
    /// seeding the shared memo table; each request is then answered from
    /// the hot cache. Results align with the input order and equal
    /// [`Engine::eval`] exactly.
    pub fn eval_batch(
        &self,
        reqs: &[EvalRequest],
        threads: usize,
    ) -> Vec<Result<EvalResponse, ApiError>> {
        let mut groups: HashMap<(String, Option<usize>), Vec<ArrayConfig>> = HashMap::new();
        for r in reqs {
            if r.arrays == 1 && r.batch != Some(0) && check_config(&r.config).is_ok() {
                groups
                    .entry((r.net.clone(), r.batch))
                    .or_default()
                    .push(r.config.clone());
            }
        }
        for ((name, batch), mut cfgs) in groups {
            let Ok(net) = self.resolve(&name, batch) else {
                continue; // the per-request pass reports the error
            };
            let mut seen: HashSet<ArrayConfig> = HashSet::with_capacity(cfgs.len());
            cfgs.retain(|c| seen.insert(c.clone()));
            let workload = Workload::of(&net);
            // A config whose every shape is already memoized needs no
            // sweep — steady-state repeat batches are pure cache hits.
            cfgs.retain(|c| {
                !workload
                    .shapes
                    .iter()
                    .all(|&(shape, _)| self.cache.contains(shape, c))
            });
            if cfgs.is_empty() {
                continue;
            }
            // Ephemeral plans on purpose: a batch's ad-hoc geometry set
            // rarely recurs as a plan key (steady-state repeat batches are
            // already pure memo-table hits and skip seeding entirely via
            // the retain above), so inserting per-batch plans would only
            // pollute the shared cache and evict the long-lived sweep
            // plans it exists to retain.
            seed_workload_planned(&workload, &cfgs, threads, &self.cache, None);
        }
        // Answer from the hot cache, fanned out so the requests the
        // seeding pass could not cover (multi-array banks, per-layer
        // reports) still use the pool.
        crate::runtime::pool::parallel_map(reqs.len(), threads, |i| self.eval(&reqs[i]))
    }

    /// Run a network through the event-driven simulator (DESIGN.md §13),
    /// layer sims fanned out over the default pool budget.
    pub fn trace(&self, req: &TraceRequest) -> Result<TraceResponse, ApiError> {
        self.trace_threaded(req, crate::runtime::pool::default_threads())
    }

    /// [`Engine::trace`] with an explicit executor budget (the serve
    /// path's `--threads`). The simulated totals are cross-checked against
    /// the analytic evaluation through the shared memo table — the two are
    /// property-tested identical, so a divergence here is a bug in one of
    /// the oracles and is logged loudly rather than silently returned.
    pub fn trace_threaded(
        &self,
        req: &TraceRequest,
        threads: usize,
    ) -> Result<TraceResponse, ApiError> {
        observed(ReqKind::Trace, || self.trace_inner(req, threads))
    }

    fn trace_inner(&self, req: &TraceRequest, threads: usize) -> Result<TraceResponse, ApiError> {
        check_config(&req.config)?;
        let net = self.resolve(&req.net, req.batch)?;
        let opts = SimOptions::traced(req.max_slices);
        let run = sim::simulate_network(&net, &req.config, threads, &opts);
        let analytic = Workload::of(&net).eval_cached(&req.config, &self.cache);
        if run.total != analytic {
            log::warn!(
                "trace: simulator diverges from the analytic model on '{}' \
                 ({} vs {} cycles)",
                run.network,
                run.total.cycles,
                analytic.cycles
            );
        }
        Ok(TraceResponse {
            sim: run,
            config: req.config.clone(),
            per_layer: req.per_layer,
        })
    }

    /// Figure-2 heatmaps for one network over a grid, through the shared
    /// plan cache: a repeated sweep of the same (workload, grid) reuses
    /// its segment tables.
    pub fn sweep(&self, req: &SweepRequest) -> Result<Fig2Data, ApiError> {
        observed(ReqKind::Sweep, || self.sweep_inner(req))
    }

    fn sweep_inner(&self, req: &SweepRequest) -> Result<Fig2Data, ApiError> {
        req.spec.validate()?;
        let net = self.resolve(&req.net, None)?;
        Ok(figures::fig2_heatmaps_planned(&net, &req.spec, Some(&self.plans)))
    }

    /// Figure-3 NSGA-II Pareto fronts for one network; genome probes run
    /// through the cached segmented plan (two binary searches plus the
    /// SoA combine — no divisions).
    pub fn pareto(&self, req: &ParetoRequest) -> Result<Fig3Data, ApiError> {
        observed(ReqKind::Pareto, || self.pareto_inner(req))
    }

    fn pareto_inner(&self, req: &ParetoRequest) -> Result<Fig3Data, ApiError> {
        req.spec.validate()?;
        check_nsga2(&req.params)?;
        let net = self.resolve(&req.net, None)?;
        Ok(figures::fig3_pareto_planned(
            &net,
            &req.spec,
            &req.params,
            Some(&self.plans),
        ))
    }

    /// Figure-4 heatmaps for all paper models.
    pub fn heatmaps(&self, spec: &SweepSpec) -> Result<Vec<Fig2Data>, ApiError> {
        spec.validate()?;
        Ok(figures::fig4_heatmaps_planned(spec, Some(&self.plans)))
    }

    /// Figure-5 robust Pareto across all paper models.
    pub fn robust(&self, spec: &SweepSpec, params: &Nsga2Params) -> Result<Fig5Data, ApiError> {
        spec.validate()?;
        check_nsga2(params)?;
        Ok(figures::fig5_robust_planned(spec, params, Some(&self.plans)))
    }

    /// Figure-6 equal-PE aspect-ratio study, one entry per budget.
    pub fn equal_pe(&self, req: &EqualPeRequest) -> Result<Vec<Fig6Data>, ApiError> {
        observed(ReqKind::EqualPe, || self.equal_pe_inner(req))
    }

    fn equal_pe_inner(&self, req: &EqualPeRequest) -> Result<Vec<Fig6Data>, ApiError> {
        req.spec.validate()?;
        req.validate()?;
        let ctx = &req.spec;
        Ok(req
            .budgets
            .iter()
            .map(|&b| figures::fig6_equal_pe_planned(b, req.min_dim, ctx, Some(&self.plans)))
            .collect())
    }

    /// Per-layer UB working sets, spills and the corrected Eq.1 energy.
    /// With `graph: true` the graph-aware liveness pass runs too, and the
    /// corrected energy additionally charges long-lived edge spills.
    pub fn memory(&self, req: &MemoryRequest) -> Result<MemoryResponse, ApiError> {
        observed(ReqKind::Memory, || self.memory_inner(req))
    }

    fn memory_inner(&self, req: &MemoryRequest) -> Result<MemoryResponse, ApiError> {
        check_config(&req.config)?;
        let net = self.resolve(&req.net, req.batch)?;
        let analysis = MemoryAnalysis::of(&net, &req.config);
        let base_energy = net.metrics(&req.config).energy(&req.weights);
        let mut corrected_energy = analysis.corrected_energy(&net, &req.config, &req.weights);
        let liveness = if req.graph {
            let g = self.resolve_graph(&req.net, req.batch)?;
            let live = g.liveness(&req.config);
            corrected_energy += live.dram_energy();
            Some(live)
        } else {
            None
        };
        Ok(MemoryResponse {
            network: net.name.clone(),
            config: req.config.clone(),
            analysis,
            base_energy,
            corrected_energy,
            liveness,
        })
    }

    /// Resolve the DAG form of a network: user-registered graphs first,
    /// then the zoo graph builders (residual/dense/branch families get
    /// real junctions; everything else the trivial chain), then the chain
    /// lowering of any other resolvable user network.
    pub fn resolve_graph(&self, name: &str, batch: Option<usize>) -> Result<NetworkGraph, ApiError> {
        let g = {
            let store = self.user_graphs.read().expect("user-graph store poisoned");
            store.get(name).cloned()
        };
        let g = match g {
            Some(g) => g,
            None => {
                // Zoo names never shadow user networks: graph builders
                // cover exactly the zoo registry, so check the user store
                // first via the plain resolution path.
                let user_chain = {
                    let store = self.user_nets.read().expect("user-network store poisoned");
                    store.get(name).map(NetworkGraph::chain)
                };
                match user_chain {
                    Some(g) => g,
                    None => match nets::build_graph(name) {
                        Some(g) => g,
                        None => {
                            return Err(ApiError::UnknownNetwork {
                                name: name.to_string(),
                            })
                        }
                    },
                }
            }
        };
        match batch {
            None => Ok(g),
            Some(b) => {
                if b == 0 || b > super::request::MAX_BATCH {
                    return Err(ApiError::BadRequest(format!(
                        "batch must be in 1..={}",
                        super::request::MAX_BATCH
                    )));
                }
                let g = g.with_batch(b).map_err(ApiError::BadRequest)?;
                // Check the layer nodes in place — no need to clone the
                // whole layer list into a Network just for the bounds.
                for nd in g.nodes() {
                    if let crate::model::graph::NodeOp::Layer(l) = &nd.op {
                        l.check_work_bounds()
                            .map_err(|e| ApiError::BadRequest(format!("batch {b}: {e}")))?;
                    }
                }
                Ok(g)
            }
        }
    }

    /// Serialize the registered-network store — chains *and* DAG forms —
    /// as a versioned snapshot document (DESIGN.md §15):
    /// `{"version": 1, "kind": "camuy-registry", "networks": [spec, …]}`,
    /// networks sorted by name for byte-stable output. Graph-registered
    /// networks export their full DAG spec (edges and junctions
    /// round-trip), so a restored shard answers graph requests exactly as
    /// the original did. Zoo networks are never snapshotted — every
    /// binary rebuilds them.
    pub fn snapshot_json(&self) -> Json {
        // nets → graphs is the same order `register_inner` takes its
        // write locks, so the two read guards cannot deadlock against a
        // concurrent registration.
        let nets = self.user_nets.read().expect("user-network store poisoned");
        let graphs = self.user_graphs.read().expect("user-graph store poisoned");
        let mut names: Vec<&String> = nets.keys().collect();
        names.sort();
        let specs: Vec<Json> = names
            .into_iter()
            .map(|name| match graphs.get(name) {
                Some(g) => g.to_json_spec(),
                None => nets[name].to_json_spec(),
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(SNAPSHOT_VERSION as f64)),
            ("kind", Json::str("camuy-registry")),
            ("networks", Json::arr(specs)),
        ])
    }

    /// Re-register every network from a snapshot document produced by
    /// [`Engine::snapshot_json`]; returns how many were restored. Rejects
    /// unknown snapshot versions loudly rather than guessing — a future
    /// format bump must not half-restore a shard. Restoration goes
    /// through the same validation as wire registration but does not
    /// count in the request telemetry (a warm start is not traffic).
    pub fn restore_json(&self, doc: &Json) -> Result<usize, ApiError> {
        let version = doc.get("version").and_then(Json::as_usize);
        if version != Some(SNAPSHOT_VERSION) {
            return Err(ApiError::BadRequest(format!(
                "unsupported snapshot version {:?} (this build reads version {SNAPSHOT_VERSION})",
                doc.get("version").map(Json::to_string_compact)
            )));
        }
        let specs = doc
            .get("networks")
            .and_then(Json::as_arr)
            .ok_or_else(|| ApiError::BadRequest("snapshot has no 'networks' array".into()))?;
        for spec in specs {
            self.register_inner(spec)?;
        }
        Ok(specs.len())
    }

    /// Write the registry snapshot to `path` atomically (write to a
    /// `.tmp` sibling, then rename), so a crash mid-write can never leave
    /// a truncated snapshot where a good one stood.
    pub fn snapshot_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::faultpoint::hit("snapshot.write");
        let doc = self.snapshot_json();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, doc.to_string_pretty() + "\n")?;
        std::fs::rename(&tmp, path)?;
        telemetry::global().snapshot_writes.add(1);
        Ok(())
    }

    /// Restore the registry from a snapshot file written by
    /// [`Engine::snapshot_to`]; returns how many networks came back.
    pub fn restore_from(&self, path: &std::path::Path) -> Result<usize, ApiError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            ApiError::BadRequest(format!("cannot read snapshot {}: {e}", path.display()))
        })?;
        let doc = Json::parse(&text).map_err(ApiError::Json)?;
        self.restore_json(&doc)
    }

    /// Graph-connectivity analysis: DAG statistics, tensor liveness with
    /// the liveness-corrected energy, and the branch-parallel multi-array
    /// schedule (DESIGN.md §9). Scheduling evaluates node durations over
    /// the default pool budget; [`Engine::graph_threaded`] takes an
    /// explicit bound (the serve path's `--threads`).
    pub fn graph(&self, req: &GraphRequest) -> Result<GraphResponse, ApiError> {
        self.graph_threaded(req, crate::runtime::pool::default_threads())
    }

    /// [`Engine::graph`] with an explicit executor budget for the
    /// schedule's node-duration fan-out.
    pub fn graph_threaded(
        &self,
        req: &GraphRequest,
        threads: usize,
    ) -> Result<GraphResponse, ApiError> {
        observed(ReqKind::Graph, || self.graph_inner(req, threads))
    }

    fn graph_inner(&self, req: &GraphRequest, threads: usize) -> Result<GraphResponse, ApiError> {
        check_config(&req.config)?;
        check_arrays(req.arrays)?;
        let g = self.resolve_graph(&req.net, req.batch)?;
        let net = g.to_network();
        let metrics = Workload::of(&net).eval_cached(&req.config, &self.cache);
        let base_energy = metrics.energy(&req.weights);
        let liveness = g.liveness(&req.config);
        let layer_mem = MemoryAnalysis::of(&net, &req.config);
        let corrected_energy = base_energy + layer_mem.dram_energy() + liveness.dram_energy();
        let schedule = g.schedule_threaded(
            &MultiArrayConfig::new(req.arrays, req.config.clone()),
            &self.cache,
            threads,
        );
        Ok(GraphResponse {
            network: g.name.clone(),
            config: req.config.clone(),
            nodes: g.len(),
            layers: g.layer_count(),
            junctions: g.junction_count(),
            edges: g.edge_count(),
            is_chain: g.is_chain(),
            metrics,
            base_energy,
            liveness,
            layer_dram_words: layer_mem.total_dram_words,
            corrected_energy,
            schedule,
        })
    }
}

/// Time one engine entry point through the process-wide telemetry
/// registry (DESIGN.md §14): bump the per-kind request counter, record
/// its latency histogram, and count errors by kind on failure. With
/// telemetry disabled the timer never reads the clock, so the wrapper
/// reduces to two branches.
fn observed<T>(kind: ReqKind, f: impl FnOnce() -> Result<T, ApiError>) -> Result<T, ApiError> {
    let timer = telemetry::Timer::start();
    let out = f();
    if out.is_err() {
        telemetry::global().record_request_error(kind);
    }
    timer.observe_request(kind);
    out
}
