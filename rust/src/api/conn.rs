//! The event-loop TCP front end: one poller thread owns every socket
//! (DESIGN.md §16).
//!
//! `camuy serve --listen` used to dedicate two OS threads to every
//! connection (a blocking reader plus the serve loop), so one slow or
//! malicious client pinned a thread and the hard connection cap was the
//! only defense. Here a single poller thread multiplexes all sockets
//! through [`crate::runtime::netpoll`] (level-triggered epoll), driving a
//! per-connection state machine:
//!
//! ```text
//! read buffer → line framing → batch assembly → pool dispatch → write queue
//! ```
//!
//! Compute never blocks I/O: assembled batches are handed over a channel
//! to a small pool of dispatcher threads, which run the exact same
//! [`process_batch`](super::serve::process_batch) as the threaded front
//! end (so response streams are byte-identical) and wake the poller over
//! an eventfd when the response bytes are ready. One batch is in flight
//! per connection at a time, which preserves per-connection response
//! ordering and the register-barrier semantics for free.
//!
//! Misbehaving clients are bounded by construction:
//!
//! * **Slowloris** — a connection with no read/write progress and no
//!   batch in flight for `idle_secs` gets a structured `idle_timeout`
//!   envelope and is closed (`connections_idle_closed`).
//! * **Stalled readers** — responses queue up to `write_cap_bytes`; past
//!   the cap the queue is dropped and the client gets one `overloaded`
//!   envelope, then close (`requests_shed`). The gauge
//!   `write_queue_bytes` tracks the total queued across connections.
//! * **Vanished clients** — a reset/broken pipe cancels the connection's
//!   in-flight batch through its [`CancelToken`] so the pool stops
//!   computing answers nobody will read (`connections_aborted`).
//! * **Floods** — reads stop once a connection has `batch_max` framed
//!   requests waiting (TCP backpressure does the rest), each read event
//!   has a byte budget so one firehose cannot starve its neighbors, and
//!   connections beyond `max_concurrent` are refused with the structured
//!   `overloaded` envelope.
//!
//! SIGTERM (or [`request_drain`](super::serve::request_drain)) drains
//! gracefully: stop accepting, refuse new reads, finish every assembled
//! request, flush, close. The faultpoint sites `serve.accept`,
//! `conn.read` and `conn.write` make the failure paths deterministically
//! testable without real slow clients.

use super::engine::Engine;
use super::error::ApiError;
use super::serve::{self, Incoming, ServeOptions, ServeStats, MAX_LINE_BYTES};
use crate::robust::{Admission, CancelToken, Cancelled};
use crate::runtime::netpoll::{self, EpollEvent, Poller, Waker};
use crate::telemetry::Telemetry;
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Poller token of the accept socket.
const TOKEN_LISTENER: u64 = 0;
/// Poller token of the dispatcher-completion eventfd.
const TOKEN_WAKER: u64 = 1;
/// First token handed to a connection.
const TOKEN_FIRST_CONN: u64 = 2;

/// Most bytes pulled off one socket per readiness event, so a firehose
/// client shares the poller fairly with its neighbors (level-triggered
/// epoll re-reports the leftover immediately).
const READ_BUDGET: usize = 256 * 1024;
/// One `read(2)` worth of buffer.
const READ_CHUNK: usize = 64 * 1024;
/// Poll timeout: the cadence of idle checks, drain-flag polls and
/// periodic snapshots when no socket is active.
const POLL_MS: i32 = 100;

/// A batch handed to the dispatcher pool.
struct BatchJob {
    token: u64,
    lines: Vec<Incoming>,
    cancel: CancelToken,
}

/// A finished batch coming back from a dispatcher.
struct BatchDone {
    token: u64,
    bytes: Vec<u8>,
    stats: ServeStats,
    /// The connection's token fired mid-batch (client vanished): the
    /// bytes are partial and must not be delivered.
    aborted: bool,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    peer: String,
    /// Raw bytes read but not yet framed into a line.
    rbuf: Vec<u8>,
    /// Inside an oversized line: discard until the next newline.
    discarding: bool,
    /// Framed requests awaiting dispatch.
    inbox: VecDeque<Incoming>,
    /// Response bytes awaiting the socket; `out_pos` marks how much of
    /// the front has already been written.
    outbox: Vec<u8>,
    out_pos: usize,
    /// One batch is at the dispatchers.
    in_flight: bool,
    /// Peer half-closed (or a drain refused further reads).
    read_closed: bool,
    /// Close once the outbox flushes; no further dispatches, and reads
    /// only discard (the lingering close below).
    closing: bool,
    /// Our write side has been shut down (FIN sent).
    sent_fin: bool,
    /// Tear down now, delivering nothing further.
    aborted: bool,
    /// Cancels this connection's in-flight compute when it dies.
    cancel: CancelToken,
    stats: ServeStats,
    last_activity: Instant,
    /// Interest mask currently registered with the poller.
    interest: u32,
}

impl Conn {
    fn new(stream: TcpStream, peer: String) -> Conn {
        Conn {
            stream,
            peer,
            rbuf: Vec::new(),
            discarding: false,
            inbox: VecDeque::new(),
            outbox: Vec::new(),
            out_pos: 0,
            in_flight: false,
            read_closed: false,
            closing: false,
            sent_fin: false,
            aborted: false,
            cancel: CancelToken::manual(),
            stats: ServeStats::default(),
            last_activity: Instant::now(),
            interest: netpoll::EPOLLIN | netpoll::EPOLLRDHUP,
        }
    }

    /// Response bytes queued and not yet written.
    fn pending_out(&self) -> usize {
        self.outbox.len() - self.out_pos
    }

    /// The interest mask this state wants: read while we are willing to
    /// frame more requests (or, when closing, to drain-and-discard the
    /// peer's leftovers so closing never resets the wire), write while
    /// responses are queued.
    fn desired_interest(&self, batch_max: usize) -> u32 {
        let mut mask = netpoll::EPOLLRDHUP;
        if !self.read_closed && !self.aborted && (self.closing || self.inbox.len() < batch_max) {
            mask |= netpoll::EPOLLIN;
        }
        if self.pending_out() > 0 {
            mask |= netpoll::EPOLLOUT;
        }
        mask
    }
}

/// Shared, copyable context threaded through the loop's helpers.
#[derive(Clone, Copy)]
struct Ctx<'a> {
    engine: &'a Engine,
    opts: &'a ServeOptions,
    poller: &'a Poller,
    job_tx: &'a mpsc::Sender<BatchJob>,
    tel: &'static Telemetry,
    batch_max: usize,
}

/// Run the event-loop front end until drain or the connection budget is
/// spent. Called from [`super::serve::serve_tcp`], which has already
/// installed the SIGPIPE/SIGTERM handlers and writes the final snapshot
/// after this returns.
pub(crate) fn serve_event_loop(
    engine: &Engine,
    listener: &TcpListener,
    opts: &ServeOptions,
) -> io::Result<()> {
    listener.set_nonblocking(true)?;
    let poller = Poller::new()?;
    let waker = Waker::new()?;
    poller.add(listener.as_raw_fd(), TOKEN_LISTENER, netpoll::EPOLLIN)?;
    poller.add(waker.fd(), TOKEN_WAKER, netpoll::EPOLLIN)?;
    let admission = Admission::new(opts.admission_max);
    let (job_tx, job_rx) = mpsc::channel::<BatchJob>();
    let (done_tx, done_rx) = mpsc::channel::<BatchDone>();
    let job_rx = Mutex::new(job_rx);
    // Dispatchers bound how many connections' batches compute at once.
    // At least two, so one long-running batch (a dense sweep) can never
    // starve every other client — the CI robustness smoke depends on an
    // eval answering while a deadline-capped sweep grinds.
    let dispatchers = opts.threads.max(2);
    std::thread::scope(|scope| -> io::Result<()> {
        let admission = &admission;
        let job_rx = &job_rx;
        let waker_ref = &waker;
        for _ in 0..dispatchers {
            let done_tx = done_tx.clone();
            scope.spawn(move || dispatcher(engine, opts, admission, job_rx, done_tx, waker_ref));
        }
        let ctx = Ctx {
            engine,
            opts,
            poller: &poller,
            job_tx: &job_tx,
            tel: crate::telemetry::global(),
            batch_max: opts.batch_max.max(1),
        };
        let res = event_loop(ctx, listener, &waker, &done_rx);
        // Closing the job channel lets the dispatchers drain and exit so
        // the scope can join them.
        drop(job_tx);
        res
    })
}

/// A dispatcher thread: pull a batch, run it through the shared
/// [`process_batch`](serve::process_batch) with the connection's token
/// ambient (so a dead client's cancellation reaches the pool's
/// checkpoints), hand the bytes back, wake the poller.
fn dispatcher(
    engine: &Engine,
    opts: &ServeOptions,
    admission: &Admission,
    jobs: &Mutex<mpsc::Receiver<BatchJob>>,
    done_tx: mpsc::Sender<BatchDone>,
    waker: &Waker,
) {
    loop {
        // Holding the lock only while waiting: the first idle dispatcher
        // camps on `recv`, everyone else queues behind the mutex.
        let job = {
            let rx = match jobs.lock() {
                Ok(guard) => guard,
                Err(_) => return,
            };
            match rx.recv() {
                Ok(job) => job,
                Err(_) => return,
            }
        };
        let mut bytes: Vec<u8> = Vec::new();
        let mut stats = ServeStats::default();
        let run = catch_unwind(AssertUnwindSafe(|| {
            crate::robust::with_token(&job.cancel, || {
                serve::process_batch(engine, &job.lines, &mut bytes, opts, &mut stats, admission)
            })
        }));
        let aborted = match run {
            // Writes into a Vec cannot fail.
            Ok(_) => false,
            Err(payload) => {
                if payload.downcast_ref::<Cancelled>().is_some() {
                    // The connection died mid-batch; its partial answers
                    // have no reader.
                    true
                } else {
                    // Anything else escaping `process_batch`'s per-request
                    // isolation is an infrastructure bug: let it propagate
                    // (parity with the threaded front end, where it would
                    // unwind the connection's scoped thread).
                    resume_unwind(payload);
                }
            }
        };
        if aborted {
            bytes.clear();
        }
        let done = BatchDone {
            token: job.token,
            bytes,
            stats,
            aborted,
        };
        if done_tx.send(done).is_err() {
            return;
        }
        waker.wake();
    }
}

/// The poller loop proper.
fn event_loop(
    ctx: Ctx<'_>,
    listener: &TcpListener,
    waker: &Waker,
    done_rx: &mpsc::Receiver<BatchDone>,
) -> io::Result<()> {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = TOKEN_FIRST_CONN;
    let mut accepted = 0usize;
    let mut accepting = true;
    let mut draining = false;
    let mut last_snapshot = Instant::now();
    let mut events = vec![EpollEvent::zeroed(); 512];
    loop {
        if !draining && serve::drain_requested() {
            draining = true;
            log::info!(
                "serve: drain requested, finishing {} live connection(s)",
                conns.len()
            );
            if accepting {
                accepting = false;
                let _ = ctx.poller.delete(listener.as_raw_fd());
            }
            for conn in conns.values_mut() {
                // Refuse new reads; everything already framed still runs.
                conn.read_closed = true;
                conn.rbuf.clear();
                conn.discarding = false;
            }
        }
        if !accepting && conns.is_empty() {
            break;
        }
        let n = ctx.poller.wait(&mut events, POLL_MS)?;
        for ev in events.iter().take(n) {
            match ev.token() {
                TOKEN_LISTENER => {
                    if accepting {
                        accept_ready(ctx, listener, &mut conns, &mut next_token, &mut accepted);
                        if let Some(max) = ctx.opts.max_connections {
                            if accepted >= max {
                                accepting = false;
                                let _ = ctx.poller.delete(listener.as_raw_fd());
                            }
                        }
                    }
                }
                TOKEN_WAKER => waker.drain(),
                token => {
                    if let Some(conn) = conns.get_mut(&token) {
                        if ev.failed() {
                            // Error or full hangup (e.g. the peer reset):
                            // nothing more can be delivered.
                            conn.aborted = true;
                        } else {
                            if ev.readable() {
                                do_read(conn, ctx.batch_max, ctx.tel);
                            }
                            if ev.writable() {
                                do_write(conn, ctx.tel);
                            }
                        }
                    }
                }
            }
        }
        while let Ok(done) = done_rx.try_recv() {
            // A missing token is a connection already torn down; its
            // cancelled batch finished into the void.
            if let Some(conn) = conns.get_mut(&done.token) {
                complete_batch(conn, done, ctx);
            }
        }
        sweep(ctx, &mut conns);
        serve::maybe_snapshot(ctx.engine, ctx.opts, &mut last_snapshot);
    }
    Ok(())
}

/// Accept everything pending. Connections beyond `max_concurrent` are
/// refused with the structured `overloaded` envelope, exactly like the
/// threaded front end, and do not count against `max_connections`.
fn accept_ready(
    ctx: Ctx<'_>,
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    accepted: &mut usize,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _addr)) => stream,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) => {
                log::warn!("serve: accept failed: {e}");
                return;
            }
        };
        crate::faultpoint::hit("serve.accept");
        if conns.len() >= ctx.opts.max_concurrent.max(1) {
            log::warn!(
                "serve: shedding connection, {} already live (cap {})",
                conns.len(),
                ctx.opts.max_concurrent
            );
            serve::refuse_connection(stream);
            continue;
        }
        if let Err(e) = stream.set_nonblocking(true) {
            log::warn!("serve: could not configure connection: {e}");
            continue;
        }
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".to_string());
        let token = *next_token;
        *next_token += 1;
        let conn = Conn::new(stream, peer);
        if let Err(e) = ctx
            .poller
            .add(conn.stream.as_raw_fd(), token, conn.interest)
        {
            log::warn!("serve: {}: could not register connection: {e}", conn.peer);
            continue;
        }
        ctx.tel.serve_connections.add(1);
        ctx.tel.connections_active.inc();
        conns.insert(token, conn);
        *accepted += 1;
        if let Some(max) = ctx.opts.max_connections {
            if *accepted >= max {
                return;
            }
        }
    }
}

/// Run a faultpoint with the connection's token ambient, so an armed
/// `cancel` action aborts exactly this connection (and an armed `panic`
/// is contained to it). Returns whether the connection must abort.
fn fault_aborts(site: &'static str, cancel: &CancelToken) -> bool {
    catch_unwind(AssertUnwindSafe(|| {
        crate::robust::with_token(cancel, || crate::faultpoint::hit(site))
    }))
    .is_err()
}

/// Service a readable socket: pull bytes (within the fairness budget),
/// frame complete lines into the inbox, stop once `batch_max` requests
/// wait (TCP backpressure throttles the sender from there). A `closing`
/// connection instead reads and discards — the lingering close: dropping
/// a socket with unread input makes the kernel answer with RST, which can
/// destroy the structured close notice before the client reads it.
fn do_read(conn: &mut Conn, batch_max: usize, tel: &'static Telemetry) {
    if conn.read_closed || conn.aborted {
        return;
    }
    if fault_aborts("conn.read", &conn.cancel) {
        conn.aborted = true;
        return;
    }
    let mut buf = [0u8; READ_CHUNK];
    let mut budget = READ_BUDGET;
    loop {
        if budget == 0 || (!conn.closing && conn.inbox.len() >= batch_max) {
            return;
        }
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.read_closed = true;
                if !conn.closing {
                    flush_trailing_line(conn, tel);
                }
                return;
            }
            Ok(k) => {
                conn.last_activity = Instant::now();
                budget = budget.saturating_sub(k);
                if conn.closing {
                    continue;
                }
                conn.rbuf.extend_from_slice(&buf[..k]);
                frame_lines(conn, tel);
                if conn.read_closed {
                    // Invalid UTF-8 closed the input mid-buffer.
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                log::warn!("serve: {}: read error: {e}", conn.peer);
                conn.aborted = true;
                return;
            }
        }
    }
}

/// Split `rbuf` into framed requests. Mirrors the blocking reader's
/// semantics exactly — same oversized-line threshold and resync, same
/// blank-line skip, same treat-invalid-UTF-8-as-input-close — so the two
/// front ends stay byte-identical.
fn frame_lines(conn: &mut Conn, tel: &'static Telemetry) {
    loop {
        if conn.discarding {
            match conn.rbuf.iter().position(|&b| b == b'\n') {
                Some(p) => {
                    conn.rbuf.drain(..=p);
                    conn.discarding = false;
                }
                None => {
                    conn.rbuf.clear();
                    return;
                }
            }
            continue;
        }
        match conn.rbuf.iter().position(|&b| b == b'\n') {
            Some(p) if p as u64 >= MAX_LINE_BYTES => {
                log::warn!(
                    "serve: {}: request line exceeds {MAX_LINE_BYTES} bytes, \
                     skipping to the next newline",
                    conn.peer
                );
                conn.rbuf.drain(..=p);
                conn.inbox.push_back(Incoming::Oversized);
            }
            Some(p) => {
                let line = match std::str::from_utf8(&conn.rbuf[..p]) {
                    Ok(text) => {
                        let trimmed = text.trim();
                        if trimmed.is_empty() {
                            None
                        } else {
                            Some(trimmed.to_string())
                        }
                    }
                    Err(_) => {
                        // The blocking reader's `read_line` fails the
                        // whole input stream on invalid UTF-8; match it.
                        log::warn!("serve: {}: invalid UTF-8, closing input", conn.peer);
                        conn.read_closed = true;
                        conn.rbuf.clear();
                        return;
                    }
                };
                if let Some(text) = line {
                    tel.serve_bytes_in.add(p as u64 + 1);
                    conn.inbox.push_back(Incoming::Line(text));
                }
                conn.rbuf.drain(..=p);
            }
            None => {
                if conn.rbuf.len() as u64 > MAX_LINE_BYTES {
                    log::warn!(
                        "serve: {}: request line exceeds {MAX_LINE_BYTES} bytes, \
                         skipping to the next newline",
                        conn.peer
                    );
                    conn.rbuf.clear();
                    conn.discarding = true;
                    conn.inbox.push_back(Incoming::Oversized);
                    continue;
                }
                return;
            }
        }
    }
}

/// EOF with leftover bytes: a final unterminated line is still a request
/// (parity with `read_line`, which returns it without the newline).
fn flush_trailing_line(conn: &mut Conn, tel: &'static Telemetry) {
    if conn.discarding {
        conn.discarding = false;
        conn.rbuf.clear();
        return;
    }
    if conn.rbuf.is_empty() {
        return;
    }
    if let Ok(text) = std::str::from_utf8(&conn.rbuf) {
        let trimmed = text.trim();
        if !trimmed.is_empty() {
            tel.serve_bytes_in.add(conn.rbuf.len() as u64);
            conn.inbox.push_back(Incoming::Line(trimmed.to_string()));
        }
    }
    conn.rbuf.clear();
}

/// Push queued response bytes into the socket until it would block.
fn do_write(conn: &mut Conn, tel: &'static Telemetry) {
    if conn.aborted || conn.pending_out() == 0 {
        return;
    }
    if fault_aborts("conn.write", &conn.cancel) {
        conn.aborted = true;
        return;
    }
    loop {
        if conn.out_pos >= conn.outbox.len() {
            break;
        }
        match conn.stream.write(&conn.outbox[conn.out_pos..]) {
            Ok(0) => {
                conn.aborted = true;
                break;
            }
            Ok(k) => {
                conn.out_pos += k;
                conn.last_activity = Instant::now();
                tel.write_queue_bytes.add(-(k as i64));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                // Broken pipe / reset: the client is gone.
                log::warn!("serve: {}: write error: {e}", conn.peer);
                conn.aborted = true;
                break;
            }
        }
    }
    if conn.out_pos == conn.outbox.len() {
        conn.outbox.clear();
        conn.out_pos = 0;
    } else if conn.out_pos > READ_CHUNK {
        // Reclaim the written prefix of a long-lived queue.
        conn.outbox.drain(..conn.out_pos);
        conn.out_pos = 0;
    }
}

/// Fold a finished batch back into its connection: deliver the bytes, or
/// shed the connection if its reader has stalled past the write cap.
fn complete_batch(conn: &mut Conn, done: BatchDone, ctx: Ctx<'_>) {
    conn.in_flight = false;
    conn.last_activity = Instant::now();
    conn.stats.requests += done.stats.requests;
    conn.stats.errors += done.stats.errors;
    conn.stats.batches += done.stats.batches;
    if done.aborted {
        conn.aborted = true;
        return;
    }
    if conn.aborted || conn.closing {
        return;
    }
    ctx.tel.write_queue_bytes.add(done.bytes.len() as i64);
    conn.outbox.extend_from_slice(&done.bytes);
    // Flush into the socket first: the cap is a judgement on the *client*
    // (it stopped reading), so only bytes the kernel refused to take
    // count against it — a healthy reader taking a large batch is fine.
    do_write(conn, ctx.tel);
    if !conn.aborted && conn.pending_out() > ctx.opts.write_cap_bytes.max(1) {
        shed_stalled_reader(conn, ctx.tel);
    }
}

/// The write queue blew its cap: the client stopped reading. Drop the
/// queue, tell it why with one `overloaded` envelope, close, and cancel
/// anything it still had queued.
fn shed_stalled_reader(conn: &mut Conn, tel: &'static Telemetry) {
    log::warn!(
        "serve: {}: write queue over cap, shedding stalled reader",
        conn.peer
    );
    tel.requests_shed.add(1);
    // Drop the queue, but never mid-line: if a response was partially
    // written, keep its tail so the client's framing stays intact and
    // the refusal lands on its own line.
    let keep = match conn.outbox[conn.out_pos..].iter().position(|&b| b == b'\n') {
        Some(p) => conn.out_pos + p + 1,
        None => conn.out_pos,
    };
    tel.write_queue_bytes.add(-((conn.outbox.len() - keep) as i64));
    conn.outbox.truncate(keep);
    let refusal = serve::envelope(
        None,
        Err(ApiError::Overloaded {
            retry_after_ms: 250,
        }),
    )
    .to_string_compact();
    conn.outbox.extend_from_slice(refusal.as_bytes());
    conn.outbox.push(b'\n');
    tel.write_queue_bytes.add(refusal.len() as i64 + 1);
    conn.closing = true;
    conn.inbox.clear();
    conn.cancel.cancel();
}

/// The per-iteration pass over every connection: dispatch ready batches,
/// flush writes, enforce the idle timeout, close what is finished, and
/// reconcile poller interest with the new state.
fn sweep(ctx: Ctx<'_>, conns: &mut HashMap<u64, Conn>) {
    let idle = Duration::from_secs(ctx.opts.idle_secs);
    let tokens: Vec<u64> = conns.keys().copied().collect();
    for token in tokens {
        let conn = conns.get_mut(&token).expect("token just listed");
        if !conn.aborted && !conn.closing {
            // Lines can be waiting in `rbuf` because the inbox was full
            // when they arrived; frame them now that dispatch may have
            // drained it.
            if !conn.rbuf.is_empty() && conn.inbox.len() < ctx.batch_max {
                frame_lines(conn, ctx.tel);
            }
            if !conn.in_flight && !conn.inbox.is_empty() {
                let take = conn.inbox.len().min(ctx.batch_max);
                let lines: Vec<Incoming> = conn.inbox.drain(..take).collect();
                conn.in_flight = true;
                let job = BatchJob {
                    token,
                    lines,
                    cancel: conn.cancel.clone(),
                };
                let _ = ctx.job_tx.send(job);
            }
        }
        do_write(conn, ctx.tel);
        // A closing connection that has flushed everything sends FIN so
        // the client sees EOF right after the close notice, then lingers
        // (reads discarded) until the peer closes too — tearing it down
        // with unread input still queued would reset the wire and could
        // destroy the notice.
        if conn.closing && !conn.sent_fin && conn.pending_out() == 0 {
            let _ = conn.stream.shutdown(std::net::Shutdown::Write);
            conn.sent_fin = true;
        }
        if ctx.opts.idle_secs > 0 && !conn.in_flight && conn.last_activity.elapsed() >= idle {
            if conn.closing {
                // Second strike: it never read its close notice either.
                conn.aborted = true;
            } else if !conn.aborted {
                idle_close(conn, ctx.tel);
            }
        }
        let finished = conn.read_closed
            && !conn.in_flight
            && conn.pending_out() == 0
            && (conn.closing || conn.inbox.is_empty());
        if conn.aborted || finished {
            let conn = conns.remove(&token).expect("token just listed");
            close_conn(ctx, conn);
            continue;
        }
        let want = conn.desired_interest(ctx.batch_max);
        if want != conn.interest {
            conn.interest = want;
            let _ = ctx
                .poller
                .modify(conn.stream.as_raw_fd(), token, want);
        }
    }
}

/// Idle past the slowloris budget: structured `idle_timeout` envelope,
/// then close once it flushes (or abort on the next strike).
fn idle_close(conn: &mut Conn, tel: &'static Telemetry) {
    log::warn!(
        "serve: {}: idle timeout, closing (slowloris guard)",
        conn.peer
    );
    tel.connections_idle_closed.add(1);
    let idle_ms = conn.last_activity.elapsed().as_millis() as u64;
    let notice =
        serve::envelope(None, Err(ApiError::IdleTimeout { idle_ms })).to_string_compact();
    conn.outbox.extend_from_slice(notice.as_bytes());
    conn.outbox.push(b'\n');
    tel.write_queue_bytes.add(notice.len() as i64 + 1);
    conn.closing = true;
    conn.inbox.clear();
    do_write(conn, tel);
}

/// Tear a connection down: settle the gauges, cancel in-flight work on
/// aborts, log the summary on graceful closes, deregister, drop.
fn close_conn(ctx: Ctx<'_>, conn: Conn) {
    ctx.tel.write_queue_bytes.add(-(conn.pending_out() as i64));
    ctx.tel.connections_active.dec();
    let _ = ctx.poller.delete(conn.stream.as_raw_fd());
    if conn.aborted {
        conn.cancel.cancel();
        ctx.tel.connections_aborted.add(1);
        log::warn!(
            "serve: {}: connection aborted after {} request(s)",
            conn.peer,
            conn.stats.requests
        );
    } else {
        let summary = serve::connection_summary(ctx.engine, &conn.stats);
        log::info!("serve: {}: {summary}", conn.peer);
    }
}
