//! The batched JSON-lines server behind `camuy serve`.
//!
//! One request per input line, one response per output line, in input
//! order. The loop blocks for the first request, then drains whatever else
//! has already arrived (up to `batch_max`) into one batch — adaptive
//! batching: an interactive client sees single-request latency, a piped
//! request file rides the batched path. Within a batch:
//!
//! * eval requests without a deadline go through [`Engine::eval_batch`],
//!   which groups them by workload and runs their distinct configurations
//!   through the segmented sweep core once, seeding the engine's shared
//!   memo table;
//! * every other request kind — and any deadline-carrying eval — fans out
//!   over the process-wide persistent pool ([`crate::runtime::pool`],
//!   DESIGN.md §11) through the per-request dispatch guard;
//! * `register` requests are ordering barriers — everything before one is
//!   answered first, so a register-then-eval pipeline behaves like the
//!   sequential program it reads as.
//!
//! Responses are envelopes: `{"id": ..., "ok": true, "result": {...}}` or
//! `{"id": ..., "ok": false, "error": {"kind": ..., "message": ...}}`.
//!
//! # Operational hardening (DESIGN.md §15)
//!
//! Every request dispatch runs inside a guard ([`dispatch_guarded`]) that
//! installs the request's [`CancelToken`] when a `"deadline_ms"` field was
//! sent and catches unwinds: a cooperative-cancellation payload becomes a
//! typed `deadline_exceeded` error carrying the progress count, any other
//! panic is isolated as `internal` — the engine, its caches and the
//! connection stay healthy either way. Compute requests pass an
//! [`Admission`] gate at batch-assembly time; past its budget they are
//! shed immediately with `overloaded` + `retry_after_ms`. The TCP front
//! end installs a SIGTERM flag for graceful drain and writes periodic and
//! final registry snapshots when `--snapshot` is set.

use super::engine::Engine;
use super::error::ApiError;
use super::request::{ApiRequest, LineMeta};
use super::response::{equal_pe_json, pareto_json, sweep_json, zoo_json};
use crate::robust::{Admission, CancelToken, Cancelled};
use crate::util::json::Json;
use std::io::{self, BufRead, Read, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Serve-loop knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker pool size for non-eval requests within a batch.
    pub threads: usize,
    /// Most requests drained into one batch.
    pub batch_max: usize,
    /// TCP only: stop accepting after this many connections (`None` =
    /// serve forever). The stdin path ignores it.
    pub max_connections: Option<usize>,
    /// TCP only: most connections served *simultaneously*; one scoped
    /// thread exists per live connection, so this bounds the server's
    /// worst-case thread count at roughly `max_concurrent × host cores`
    /// (each connection runs at most one internally-parallel request at a
    /// time). Excess connections get an `overloaded` line, then close.
    pub max_concurrent: usize,
    /// Most compute requests admitted concurrently (across every
    /// connection of one TCP server) before load shedding answers
    /// `overloaded` with a `retry_after_ms` hint (DESIGN.md §15).
    pub admission_max: usize,
    /// Write the registered-network store here periodically and on
    /// graceful drain, so a restarted shard comes back warm via
    /// `--restore` (DESIGN.md §15).
    pub snapshot: Option<std::path::PathBuf>,
    /// Seconds between periodic snapshot writes.
    pub snapshot_secs: u64,
    /// TCP only: use the legacy thread-per-connection front end instead
    /// of the event loop (DESIGN.md §16). Kept as the oracle the
    /// event-loop replay tests compare against; also the only TCP path on
    /// non-Linux hosts, where `runtime::netpoll` does not exist.
    pub threaded: bool,
    /// Event loop only: close a connection after this many seconds
    /// without read or write progress and no batch in flight (the
    /// slowloris guard, DESIGN.md §16). `0` disables the timeout.
    pub idle_secs: u64,
    /// Event loop only: most response bytes queued for one connection
    /// whose client has stopped reading; past the cap the queue is
    /// dropped and the connection is shed with a structured `overloaded`
    /// close instead of growing without bound.
    pub write_cap_bytes: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            threads: crate::sweep::runner::default_threads(),
            batch_max: 64,
            max_connections: None,
            max_concurrent: 64,
            admission_max: 256,
            snapshot: None,
            snapshot_secs: 30,
            threaded: false,
            idle_secs: 60,
            write_cap_bytes: 8 << 20,
        }
    }
}

/// Counters reported when a serve loop ends.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub errors: u64,
    pub batches: u64,
}

/// One unit off the reader thread: a complete request line, or the
/// tombstone of one that blew [`MAX_LINE_BYTES`] (answered with a
/// structured error so the client's id sequence never desynchronizes).
pub(crate) enum Incoming {
    Line(String),
    Oversized,
}

/// One request per line, each at most this long — a client streaming
/// bytes without a newline cannot grow memory without bound.
pub(crate) const MAX_LINE_BYTES: u64 = 4 << 20;

/// Serve JSON-lines requests from `input` until EOF, writing one response
/// line per request to `out`. Blank lines are skipped.
pub fn serve<R, W>(
    engine: &Engine,
    input: R,
    out: &mut W,
    opts: &ServeOptions,
) -> io::Result<ServeStats>
where
    R: BufRead + Send,
    W: Write,
{
    let admission = Admission::new(opts.admission_max);
    serve_gated(engine, input, out, opts, &admission)
}

/// [`serve`] against a caller-owned admission gate — the TCP front end
/// shares one gate across every connection, so the in-flight budget is a
/// server property, not a per-connection one.
fn serve_gated<R, W>(
    engine: &Engine,
    input: R,
    out: &mut W,
    opts: &ServeOptions,
    admission: &Admission,
) -> io::Result<ServeStats>
where
    R: BufRead + Send,
    W: Write,
{
    let mut stats = ServeStats::default();
    crate::telemetry::global().serve_connections.add(1);
    let batch_max = opts.batch_max.max(1);
    let (tx, rx) = mpsc::sync_channel::<Incoming>(batch_max);
    std::thread::scope(|scope| -> io::Result<()> {
        scope.spawn(move || {
            let mut reader = input;
            let mut line = String::new();
            loop {
                line.clear();
                match reader.by_ref().take(MAX_LINE_BYTES + 1).read_line(&mut line) {
                    Ok(0) => break,
                    Ok(_) => {
                        if line.len() as u64 > MAX_LINE_BYTES {
                            // Resynchronize: discard the rest of the
                            // oversized line so the *next* line parses,
                            // and answer this one with a structured error
                            // instead of desynchronizing the connection.
                            log::warn!(
                                "serve: request line exceeds {MAX_LINE_BYTES} bytes, \
                                 skipping to the next newline"
                            );
                            let resynced =
                                line.ends_with('\n') || drain_to_newline(&mut reader);
                            if tx.send(Incoming::Oversized).is_err() || !resynced {
                                break;
                            }
                            continue;
                        }
                        let trimmed = line.trim();
                        if trimmed.is_empty() {
                            continue;
                        }
                        crate::telemetry::global().serve_bytes_in.add(line.len() as u64);
                        if tx.send(Incoming::Line(trimmed.to_string())).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        log::warn!("serve: read error, closing input: {e}");
                        break;
                    }
                }
            }
        });
        // On a write error we cannot return yet — thread::scope would
        // block joining the reader, which may sit in a blocking read.
        // Instead keep draining input (answering nothing) until the reader
        // reaches EOF, then surface the stored error.
        let mut write_err: Option<io::Error> = None;
        loop {
            // Block for the first request of a batch, then drain whatever
            // is already queued.
            let first = match rx.recv() {
                Ok(l) => l,
                Err(_) => break,
            };
            let mut lines = vec![first];
            while lines.len() < batch_max {
                match rx.try_recv() {
                    Ok(l) => lines.push(l),
                    Err(_) => break,
                }
            }
            if write_err.is_none() {
                if let Err(e) =
                    process_batch(engine, &lines, out, opts, &mut stats, admission)
                {
                    // The peer vanished mid-conversation (broken pipe /
                    // reset): the remaining answers have no reader.
                    log::warn!("serve: output error, draining remaining input: {e}");
                    crate::telemetry::global().connections_aborted.add(1);
                    write_err = Some(e);
                }
            }
        }
        match write_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;
    Ok(stats)
}

/// Discard buffered input up to and including the next newline. Returns
/// `false` on EOF or a read error (nothing left to resynchronize to).
fn drain_to_newline<R: BufRead>(reader: &mut R) -> bool {
    loop {
        let consumed = match reader.fill_buf() {
            Ok(buf) if buf.is_empty() => return false,
            Ok(buf) => match buf.iter().position(|&b| b == b'\n') {
                Some(p) => {
                    reader.consume(p + 1);
                    return true;
                }
                None => buf.len(),
            },
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        };
        reader.consume(consumed);
    }
}

/// The process-wide graceful-shutdown flag the TCP accept loop polls.
fn term_flag() -> &'static AtomicBool {
    static FLAG: AtomicBool = AtomicBool::new(false);
    &FLAG
}

/// Ask every TCP serve loop in this process to drain gracefully — the
/// programmatic equivalent of sending the process SIGTERM: stop
/// accepting, refuse new reads, finish in-flight requests, flush, write
/// the final snapshot, return. Embedders (and tests) use this to stop a
/// server they started in-process without signals.
pub fn request_drain() {
    term_flag().store(true, Ordering::SeqCst);
}

/// Whether a drain has been requested ([`request_drain`] or SIGTERM).
pub fn drain_requested() -> bool {
    term_flag().load(Ordering::SeqCst)
}

/// Re-arm after a drain, so a later [`serve_tcp`] call in the same
/// process starts accepting again. (The flag is process-global; a server
/// restarted in-process after a drain would otherwise exit immediately.)
pub fn clear_drain() {
    term_flag().store(false, Ordering::SeqCst);
}

/// Install the SIGTERM handler (raw syscall shim — the offline image
/// ships no `libc` crate, DESIGN.md §6). Storing into a static atomic is
/// async-signal-safe. Returns the flag it sets.
#[cfg(unix)]
fn install_sigterm() -> &'static AtomicBool {
    extern "C" fn on_term(_signum: i32) {
        term_flag().store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
    }
    term_flag()
}

#[cfg(not(unix))]
fn install_sigterm() -> &'static AtomicBool {
    term_flag()
}

/// Accept TCP connections against one shared engine (connections see each
/// other's registered networks and share the memo table) and one shared
/// admission gate. On Linux the default front end is the epoll event loop
/// (DESIGN.md §16) — one poller thread owns every socket, a small
/// dispatcher pool runs the batches, and misbehaving clients are bounded
/// by idle timeouts and write-queue caps; `opts.threaded` (CLI
/// `--threaded`) selects the legacy thread-per-connection loop instead,
/// which is also the only path off Linux. Either way SIGTERM (or
/// [`request_drain`]) drains gracefully: stop accepting, finish live
/// connections, write a final snapshot when `--snapshot` is set.
pub fn serve_tcp(
    engine: &Engine,
    listener: std::net::TcpListener,
    opts: &ServeOptions,
) -> io::Result<()> {
    // The CLI restores default SIGPIPE so `camuy ... | head` exits quietly,
    // but a server must not die because one client closed its socket before
    // reading the response: ignore SIGPIPE for the server's lifetime so the
    // write fails with EPIPE and only that connection's loop ends. Raw
    // syscall shim — the offline image ships no `libc` crate (DESIGN.md §6).
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGPIPE: i32 = 13;
        const SIG_IGN: usize = 1;
        unsafe {
            signal(SIGPIPE, SIG_IGN);
        }
    }
    let term = install_sigterm();
    #[cfg(target_os = "linux")]
    if !opts.threaded {
        super::conn::serve_event_loop(engine, &listener, opts)?;
        write_final_snapshot(engine, opts);
        return Ok(());
    }
    serve_tcp_threaded(engine, listener, opts, term)?;
    write_final_snapshot(engine, opts);
    Ok(())
}

/// The legacy thread-per-connection TCP front end: one scoped reader +
/// serve thread pair per live connection, blocking reads, nonblocking
/// accepts polling the drain flag. Strictly simpler than the event loop
/// and byte-identical to it on the same request stream — which is exactly
/// why it survives behind `--threaded`: it is the oracle the event-loop
/// replay tests diff against, and the fallback for non-Linux hosts.
fn serve_tcp_threaded(
    engine: &Engine,
    listener: std::net::TcpListener,
    opts: &ServeOptions,
    term: &'static AtomicBool,
) -> io::Result<()> {
    // Nonblocking accepts so the loop can poll the shutdown flag and the
    // snapshot timer between connections.
    listener.set_nonblocking(true)?;
    let admission = Admission::new(opts.admission_max);
    let mut accepted = 0usize;
    let live = AtomicUsize::new(0);
    let mut last_snapshot = Instant::now();
    std::thread::scope(|scope| {
        loop {
            if term.load(Ordering::SeqCst) {
                log::info!(
                    "serve: SIGTERM received, draining {} live connection(s)",
                    live.load(Ordering::Acquire)
                );
                break;
            }
            let stream = match listener.accept() {
                Ok((s, _addr)) => s,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    maybe_snapshot(engine, opts, &mut last_snapshot);
                    std::thread::sleep(Duration::from_millis(20));
                    continue;
                }
                Err(e) => {
                    log::warn!("serve: accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                    continue;
                }
            };
            crate::faultpoint::hit("serve.accept");
            // The listener is nonblocking for the poll loop, but each
            // connection's reader must block normally.
            if let Err(e) = stream.set_nonblocking(false) {
                log::warn!("serve: could not configure connection: {e}");
                continue;
            }
            // A scoped thread lives per connection; shed beyond the
            // concurrency cap with a structured `overloaded` line instead
            // of growing the thread count without bound.
            let live_now = live.load(Ordering::Acquire);
            if live_now >= opts.max_concurrent.max(1) {
                log::warn!(
                    "serve: shedding connection, {live_now} already live (cap {})",
                    opts.max_concurrent
                );
                refuse_connection(stream);
                continue;
            }
            live.fetch_add(1, Ordering::AcqRel);
            crate::telemetry::global().connections_active.inc();
            let conn_opts = opts.clone();
            let live_ref = &live;
            let admission_ref = &admission;
            scope.spawn(move || {
                let peer = stream
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "?".to_string());
                let reader = match stream.try_clone() {
                    Ok(s) => Some(io::BufReader::new(s)),
                    Err(e) => {
                        log::warn!("serve: {peer}: could not clone stream: {e}");
                        None
                    }
                };
                if let Some(reader) = reader {
                    let mut writer = stream;
                    match serve_gated(engine, reader, &mut writer, &conn_opts, admission_ref) {
                        Ok(stats) => {
                            let summary = connection_summary(engine, &stats);
                            log::info!("serve: {peer}: {summary}");
                        }
                        Err(e) => log::warn!("serve: {peer}: {e}"),
                    }
                }
                live_ref.fetch_sub(1, Ordering::AcqRel);
                crate::telemetry::global().connections_active.dec();
            });
            accepted += 1;
            if let Some(max) = opts.max_connections {
                if accepted >= max {
                    break;
                }
            }
        }
    });
    Ok(())
}

/// Every connection has drained; capture their registrations in the
/// final snapshot.
fn write_final_snapshot(engine: &Engine, opts: &ServeOptions) {
    if let Some(path) = &opts.snapshot {
        match engine.snapshot_to(path) {
            Ok(()) => log::info!("serve: wrote final snapshot to {}", path.display()),
            Err(e) => log::warn!("serve: final snapshot failed: {e}"),
        }
    }
}

/// Write the periodic registry snapshot when one is due.
pub(crate) fn maybe_snapshot(engine: &Engine, opts: &ServeOptions, last: &mut Instant) {
    let Some(path) = &opts.snapshot else { return };
    if last.elapsed() < Duration::from_secs(opts.snapshot_secs.max(1)) {
        return;
    }
    *last = Instant::now();
    if let Err(e) = engine.snapshot_to(path) {
        log::warn!("serve: periodic snapshot failed: {e}");
    }
}

/// Tell a shed connection why before closing it: one `overloaded`
/// envelope (no id — nothing was read), then drop.
pub(crate) fn refuse_connection(stream: std::net::TcpStream) {
    let tel = crate::telemetry::global();
    tel.requests_shed.add(1);
    let mut stream = stream;
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let refusal = envelope(
        None,
        Err(ApiError::Overloaded {
            retry_after_ms: 250,
        }),
    );
    let _ = writeln!(stream, "{}", refusal.to_string_compact());
    let _ = stream.flush();
}

/// Answer one batch of request lines, writing responses in input order.
/// Shared verbatim by every front end — the stdin loop, the threaded TCP
/// loop and the event loop's dispatcher threads — which is what makes
/// their response streams byte-identical on the same input.
pub(crate) fn process_batch<W: Write>(
    engine: &Engine,
    lines: &[Incoming],
    out: &mut W,
    opts: &ServeOptions,
    stats: &mut ServeStats,
    admission: &Admission,
) -> io::Result<()> {
    let n = lines.len();
    let parsed: Vec<(LineMeta, Result<ApiRequest, ApiError>)> = lines
        .iter()
        .map(|l| match l {
            Incoming::Line(text) => ApiRequest::parse_line(text),
            Incoming::Oversized => (
                LineMeta::default(),
                Err(ApiError::BadRequest(format!(
                    "request line exceeds {MAX_LINE_BYTES} bytes"
                ))),
            ),
        })
        .collect();
    let mut responses: Vec<Option<Json>> = vec![None; n];
    let mut pending: Vec<usize> = Vec::new();
    for i in 0..n {
        match &parsed[i].1 {
            // Decode failures answer immediately; nothing to compute.
            Err(e) => {
                stats.errors += 1;
                responses[i] = Some(envelope(parsed[i].0.id.clone(), Err(e.clone())));
            }
            // Registration is an ordering barrier. It runs through the
            // dispatch guard too: an injected or genuine panic inside the
            // spec validator must not kill the connection.
            Ok(ApiRequest::Register(_)) => {
                flush_pending(
                    engine,
                    &parsed,
                    &mut pending,
                    &mut responses,
                    opts,
                    stats,
                    admission,
                );
                let res = dispatch_guarded(engine, &parsed[i], opts.threads);
                if res.is_err() {
                    stats.errors += 1;
                }
                responses[i] = Some(envelope(parsed[i].0.id.clone(), res));
            }
            Ok(_) => pending.push(i),
        }
    }
    flush_pending(engine, &parsed, &mut pending, &mut responses, opts, stats, admission);
    let mut bytes_out = 0u64;
    for r in &responses {
        let json = r.as_ref().expect("every request answered");
        let text = json.to_string_compact();
        bytes_out += text.len() as u64 + 1; // newline
        writeln!(out, "{text}")?;
    }
    out.flush()?;
    stats.requests += n as u64;
    stats.batches += 1;
    let tel = crate::telemetry::global();
    tel.serve_bytes_out.add(bytes_out);
    tel.serve_batches.add(1);
    tel.serve_batch_size.record(n as u64);
    Ok(())
}

/// Whether a request must hold an admission permit: the compute kinds
/// that can occupy the pool. Control-plane kinds (stats, zoo, register)
/// always run — an operator must be able to inspect an overloaded server.
fn needs_permit(req: &ApiRequest) -> bool {
    !matches!(
        req,
        ApiRequest::Stats(_) | ApiRequest::Zoo | ApiRequest::Register(_)
    )
}

/// Answer the gathered non-register requests: deadline-free evals through
/// the engine's batched segmented path, everything else fanned out over
/// the shared persistent pool through the per-request dispatch guard.
fn flush_pending(
    engine: &Engine,
    parsed: &[(LineMeta, Result<ApiRequest, ApiError>)],
    pending: &mut Vec<usize>,
    responses: &mut [Option<Json>],
    opts: &ServeOptions,
    stats: &mut ServeStats,
    admission: &Admission,
) {
    if pending.is_empty() {
        return;
    }
    // Admission control happens at batch-assembly time — all permits are
    // taken before any dispatch and held until the whole flush finishes —
    // so shedding is deterministic whether the fan-out below runs pooled
    // or degenerates to the serial path (`CAMUY_THREADS=1`).
    let mut permits = Vec::new();
    let mut admitted: Vec<usize> = Vec::with_capacity(pending.len());
    for &i in pending.iter() {
        let gated = match &parsed[i].1 {
            Ok(req) => needs_permit(req),
            Err(_) => false,
        };
        if gated {
            match admission.try_admit() {
                Ok(permit) => permits.push(permit),
                Err(retry_after_ms) => {
                    stats.errors += 1;
                    crate::telemetry::global().requests_shed.add(1);
                    responses[i] = Some(envelope(
                        parsed[i].0.id.clone(),
                        Err(ApiError::Overloaded { retry_after_ms }),
                    ));
                    continue;
                }
            }
        }
        admitted.push(i);
    }
    let mut eval_idx = Vec::new();
    let mut eval_reqs = Vec::new();
    let mut rest = Vec::new();
    for &i in &admitted {
        match &parsed[i].1 {
            // Deadline-free evals keep the batched seeding path; an eval
            // with a deadline needs its own token and guard, so it rides
            // the per-request fan-out instead.
            Ok(ApiRequest::Eval(r)) if parsed[i].0.deadline_ms.is_none() => {
                eval_idx.push(i);
                eval_reqs.push(r.clone());
            }
            _ => rest.push(i),
        }
    }
    // The batched path shares one pool job across many requests, so a
    // panic inside it cannot be attributed to one request the way the
    // guarded fan-out below attributes panics. Catch it at the batch
    // level and retry each eval individually through the guard — only
    // the faulty request (if it reproduces) answers `internal`.
    match catch_unwind(AssertUnwindSafe(|| engine.eval_batch(&eval_reqs, opts.threads))) {
        Ok(results) => {
            for (i, res) in eval_idx.iter().copied().zip(results) {
                if res.is_err() {
                    stats.errors += 1;
                }
                responses[i] =
                    Some(envelope(parsed[i].0.id.clone(), res.map(|r| r.to_json())));
            }
        }
        Err(payload) => {
            // A Cancelled payload here is not a request failure: the
            // batched path only carries deadline-free evals, so the only
            // token it can inherit is an event-loop connection token —
            // i.e. the client is gone. Re-raise so the dispatcher can
            // tear the whole batch down instead of mislabeling it.
            if payload.downcast_ref::<Cancelled>().is_some() {
                std::panic::resume_unwind(payload);
            }
            crate::telemetry::global().panics_caught.add(1);
            log::error!(
                "serve: eval batch panicked (isolated): {}; retrying individually",
                panic_message(payload.as_ref())
            );
            rest.extend(eval_idx);
        }
    }
    // Sweep/pareto/equal-pe/memory requests fan out over the shared
    // persistent pool (DESIGN.md §11). Each is also parallel *inside*
    // (the sweep cores fan out through the same pool), but because every
    // fan-out in the process shares one set of workers — with nested
    // submissions executing on their submitting thread when the pool is
    // saturated — dispatching them concurrently overlaps their serial
    // phases (plan builds, JSON encoding) without multiplying threads,
    // unlike the pre-§11 per-call scoped pools this loop used to avoid.
    // The guard lives *inside* the fan-out closure: a panic or fired
    // deadline is caught per request, so it can never poison the batch's
    // own pool job.
    let rest_results = crate::runtime::pool::parallel_map(rest.len(), opts.threads, |j| {
        dispatch_guarded(engine, &parsed[rest[j]], opts.threads)
    });
    for (&i, res) in rest.iter().zip(rest_results) {
        if res.is_err() {
            stats.errors += 1;
        }
        responses[i] = Some(envelope(parsed[i].0.id.clone(), res));
    }
    drop(permits);
    pending.clear();
}

/// Render an unwind payload for the `internal` error message: panics via
/// `panic!("...")` carry strings; anything else gets a generic label.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "request panicked".to_string()
    }
}

/// Route one request through the hardening guard (DESIGN.md §15): install
/// its cancellation token when the line carried `deadline_ms`, dispatch,
/// and catch unwinds — a [`Cancelled`] payload becomes the typed
/// `deadline_exceeded` error with the progress count, anything else is
/// isolated as `internal`. Either way the engine and the connection
/// survive.
fn dispatch_guarded(
    engine: &Engine,
    parsed: &(LineMeta, Result<ApiRequest, ApiError>),
    threads: usize,
) -> Result<Json, ApiError> {
    let (meta, req) = parsed;
    let token = meta.deadline_ms.map(CancelToken::with_deadline_ms);
    let tel = crate::telemetry::global();
    let run = || {
        crate::faultpoint::hit("serve.dispatch");
        match &token {
            Some(t) => crate::robust::with_token(t, || dispatch(engine, req, threads)),
            None => dispatch(engine, req, threads),
        }
    };
    match catch_unwind(AssertUnwindSafe(run)) {
        Ok(res) => res,
        Err(payload) => {
            if let Some(c) = payload.downcast_ref::<Cancelled>() {
                // A deadline-less cancellation on a deadline-less request
                // can only come from an ambient connection token — the
                // event loop cancelling a dead client's in-flight batch.
                // That is not this request's deadline firing: re-raise so
                // the dispatcher aborts the batch. (The threaded path
                // never installs an ambient token, so `current()` is
                // `None` there and this branch is unreachable.)
                if c.deadline_ms.is_none()
                    && meta.deadline_ms.is_none()
                    && crate::robust::current().is_some()
                {
                    std::panic::resume_unwind(payload);
                }
                tel.deadline_exceeded.add(1);
                Err(ApiError::DeadlineExceeded {
                    deadline_ms: c.deadline_ms.or(meta.deadline_ms).unwrap_or(0),
                    progress: c.progress,
                })
            } else {
                tel.panics_caught.add(1);
                let msg = panic_message(payload.as_ref());
                log::error!("serve: request panicked (isolated): {msg}");
                Err(ApiError::Internal(msg))
            }
        }
    }
}

/// Route one decoded request to the engine. `threads` is the serve
/// loop's executor budget, honored by the request kinds whose fan-out is
/// not already bounded by their own spec (today: graph scheduling).
fn dispatch(
    engine: &Engine,
    req: &Result<ApiRequest, ApiError>,
    threads: usize,
) -> Result<Json, ApiError> {
    match req {
        Err(e) => Err(e.clone()),
        Ok(ApiRequest::Eval(r)) => engine.eval(r).map(|x| x.to_json()),
        Ok(ApiRequest::Register(r)) => {
            engine.register_network_json(&r.spec).map(|x| x.to_json())
        }
        Ok(ApiRequest::Zoo) => Ok(zoo_json(&engine.list_networks())),
        Ok(ApiRequest::Sweep(r)) => engine.sweep(r).map(|d| sweep_json(&d)),
        Ok(ApiRequest::Pareto(r)) => engine.pareto(r).map(|d| pareto_json(&d)),
        Ok(ApiRequest::EqualPe(r)) => engine.equal_pe(r).map(|d| equal_pe_json(&d)),
        Ok(ApiRequest::Memory(r)) => engine.memory(r).map(|x| x.to_json()),
        Ok(ApiRequest::Graph(r)) => engine.graph_threaded(r, threads).map(|x| x.to_json()),
        Ok(ApiRequest::Trace(r)) => engine.trace_threaded(r, threads).map(|x| x.to_json()),
        Ok(ApiRequest::Stats(r)) => Ok(engine.stats(r).to_json()),
    }
}

/// One human-readable line summarizing a finished serve loop: the
/// connection's own counters, the engine-wide request-latency quantiles,
/// the eval/plan cache traffic, and the hardening counters (DESIGN.md
/// §15) — the log-file rendering of the telemetry the `{"type": "stats"}`
/// request exposes as JSON. Shared by the TCP per-connection log and the
/// stdin path of `camuy serve`.
pub fn connection_summary(engine: &Engine, stats: &ServeStats) -> String {
    let tel = crate::telemetry::global().snapshot();
    let lat = tel.request_latency();
    let ec = engine.cache().stats();
    let ps = engine.plan_stats();
    format!(
        "{} request(s), {} error(s), {} batch(es); \
         request p50/p99 {:.2}/{:.2} ms; \
         eval cache: {} entr(ies), {:.0}% hit rate; \
         plan cache: {} plan(s), {} hit(s) / {} miss(es) \
         ({:.0}% hit rate), {} table word(s); \
         robust: {} shed, {} deadline-exceeded, {} panic(s) caught, \
         {} snapshot write(s)",
        stats.requests,
        stats.errors,
        stats.batches,
        lat.quantile(0.50) as f64 / 1e6,
        lat.quantile(0.99) as f64 / 1e6,
        ec.entries,
        100.0 * ec.hit_rate(),
        ps.entries,
        ps.hits,
        ps.misses,
        100.0 * ps.hit_rate(),
        ps.table_words,
        tel.robust.requests_shed,
        tel.robust.deadline_exceeded,
        tel.robust.panics_caught,
        tel.robust.snapshot_writes,
    )
}

/// The response envelope: the echoed id, the ok flag, and either the
/// result document or the structured error.
pub(crate) fn envelope(id: Option<Json>, result: Result<Json, ApiError>) -> Json {
    let mut pairs = Vec::with_capacity(3);
    if let Some(id) = id {
        pairs.push(("id", id));
    }
    match result {
        Ok(v) => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("result", v));
        }
        Err(e) => {
            crate::telemetry::global().record_error_kind(e.kind());
            pairs.push(("ok", Json::Bool(false)));
            pairs.push(("error", e.to_json()));
        }
    }
    Json::obj(pairs)
}
