//! The batched JSON-lines server behind `camuy serve`.
//!
//! One request per input line, one response per output line, in input
//! order. The loop blocks for the first request, then drains whatever else
//! has already arrived (up to `batch_max`) into one batch — adaptive
//! batching: an interactive client sees single-request latency, a piped
//! request file rides the batched path. Within a batch:
//!
//! * eval requests go through [`Engine::eval_batch`], which groups them by
//!   workload and runs their distinct configurations through the
//!   segmented sweep core once, seeding the engine's shared memo table;
//! * every other request kind fans out over the process-wide persistent
//!   pool ([`crate::runtime::pool`], DESIGN.md §11) — nested fan-outs
//!   (a sweep inside a request) share the same workers, so thread counts
//!   never multiply and a saturated pool degrades to the caller's thread;
//! * `register` requests are ordering barriers — everything before one is
//!   answered first, so a register-then-eval pipeline behaves like the
//!   sequential program it reads as.
//!
//! Responses are envelopes: `{"id": ..., "ok": true, "result": {...}}` or
//! `{"id": ..., "ok": false, "error": {"kind": ..., "message": ...}}`.

use super::engine::Engine;
use super::error::ApiError;
use super::request::ApiRequest;
use super::response::{equal_pe_json, pareto_json, sweep_json, zoo_json};
use crate::util::json::Json;
use std::io::{self, BufRead, Read, Write};
use std::sync::mpsc;

/// Serve-loop knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker pool size for non-eval requests within a batch.
    pub threads: usize,
    /// Most requests drained into one batch.
    pub batch_max: usize,
    /// TCP only: stop accepting after this many connections (`None` =
    /// serve forever). The stdin path ignores it.
    pub max_connections: Option<usize>,
    /// TCP only: most connections served *simultaneously*; one scoped
    /// thread exists per live connection, so this bounds the server's
    /// worst-case thread count at roughly `max_concurrent × host cores`
    /// (each connection runs at most one internally-parallel request at a
    /// time). Excess connections are closed immediately.
    pub max_concurrent: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            threads: crate::sweep::runner::default_threads(),
            batch_max: 64,
            max_connections: None,
            max_concurrent: 64,
        }
    }
}

/// Counters reported when a serve loop ends.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub errors: u64,
    pub batches: u64,
}

/// Serve JSON-lines requests from `input` until EOF, writing one response
/// line per request to `out`. Blank lines are skipped.
pub fn serve<R, W>(
    engine: &Engine,
    input: R,
    out: &mut W,
    opts: &ServeOptions,
) -> io::Result<ServeStats>
where
    R: BufRead + Send,
    W: Write,
{
    let mut stats = ServeStats::default();
    crate::telemetry::global().serve_connections.add(1);
    let batch_max = opts.batch_max.max(1);
    let (tx, rx) = mpsc::sync_channel::<String>(batch_max);
    std::thread::scope(|scope| -> io::Result<()> {
        let rx = rx;
        scope.spawn(move || {
            // One request per line, each at most this long — a client
            // streaming bytes without a newline cannot grow memory
            // without bound.
            const MAX_LINE_BYTES: u64 = 4 << 20;
            let mut reader = input;
            let mut line = String::new();
            loop {
                line.clear();
                match reader.by_ref().take(MAX_LINE_BYTES + 1).read_line(&mut line) {
                    Ok(0) => break,
                    Ok(_) => {
                        if line.len() as u64 > MAX_LINE_BYTES {
                            log::warn!(
                                "serve: request line exceeds {MAX_LINE_BYTES} bytes, \
                                 closing input"
                            );
                            break;
                        }
                        let trimmed = line.trim();
                        if trimmed.is_empty() {
                            continue;
                        }
                        crate::telemetry::global().serve_bytes_in.add(line.len() as u64);
                        if tx.send(trimmed.to_string()).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        log::warn!("serve: read error, closing input: {e}");
                        break;
                    }
                }
            }
        });
        // On a write error we cannot return yet — thread::scope would
        // block joining the reader, which may sit in a blocking read.
        // Instead keep draining input (answering nothing) until the reader
        // reaches EOF, then surface the stored error.
        let mut write_err: Option<io::Error> = None;
        loop {
            // Block for the first request of a batch, then drain whatever
            // is already queued.
            let first = match rx.recv() {
                Ok(l) => l,
                Err(_) => break,
            };
            let mut lines = vec![first];
            while lines.len() < batch_max {
                match rx.try_recv() {
                    Ok(l) => lines.push(l),
                    Err(_) => break,
                }
            }
            if write_err.is_none() {
                if let Err(e) = process_batch(engine, &lines, out, opts, &mut stats) {
                    log::warn!("serve: output error, draining remaining input: {e}");
                    write_err = Some(e);
                }
            }
        }
        match write_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;
    Ok(stats)
}

/// Accept TCP connections and run [`serve`] per connection, concurrently,
/// against one shared engine (connections see each other's registered
/// networks and share the memo table).
pub fn serve_tcp(
    engine: &Engine,
    listener: std::net::TcpListener,
    opts: &ServeOptions,
) -> io::Result<()> {
    // The CLI restores default SIGPIPE so `camuy ... | head` exits quietly,
    // but a server must not die because one client closed its socket before
    // reading the response: ignore SIGPIPE for the server's lifetime so the
    // write fails with EPIPE and only that connection's loop ends. Raw
    // syscall shim — the offline image ships no `libc` crate (DESIGN.md §6).
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGPIPE: i32 = 13;
        const SIG_IGN: usize = 1;
        unsafe {
            signal(SIGPIPE, SIG_IGN);
        }
    }
    let mut accepted = 0usize;
    let live = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for conn in listener.incoming() {
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    log::warn!("serve: accept failed: {e}");
                    continue;
                }
            };
            // A scoped thread lives per connection; refuse beyond the
            // concurrency cap instead of growing the thread count without
            // bound. (Dropping the stream closes it.)
            let live_now = live.load(std::sync::atomic::Ordering::Acquire);
            if live_now >= opts.max_concurrent.max(1) {
                log::warn!(
                    "serve: refusing connection, {live_now} already live (cap {})",
                    opts.max_concurrent
                );
                continue;
            }
            live.fetch_add(1, std::sync::atomic::Ordering::AcqRel);
            let conn_opts = opts.clone();
            let live_ref = &live;
            scope.spawn(move || {
                let peer = stream
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "?".to_string());
                let reader = match stream.try_clone() {
                    Ok(s) => Some(io::BufReader::new(s)),
                    Err(e) => {
                        log::warn!("serve: {peer}: could not clone stream: {e}");
                        None
                    }
                };
                if let Some(reader) = reader {
                    let mut writer = stream;
                    match serve(engine, reader, &mut writer, &conn_opts) {
                        Ok(stats) => {
                            let summary = connection_summary(engine, &stats);
                            log::info!("serve: {peer}: {summary}");
                        }
                        Err(e) => log::warn!("serve: {peer}: {e}"),
                    }
                }
                live_ref.fetch_sub(1, std::sync::atomic::Ordering::AcqRel);
            });
            accepted += 1;
            if let Some(max) = opts.max_connections {
                if accepted >= max {
                    break;
                }
            }
        }
    });
    Ok(())
}

/// Answer one batch of request lines, writing responses in input order.
fn process_batch<W: Write>(
    engine: &Engine,
    lines: &[String],
    out: &mut W,
    opts: &ServeOptions,
    stats: &mut ServeStats,
) -> io::Result<()> {
    let n = lines.len();
    let parsed: Vec<(Option<Json>, Result<ApiRequest, ApiError>)> =
        lines.iter().map(|l| ApiRequest::parse_line(l)).collect();
    let mut responses: Vec<Option<Json>> = vec![None; n];
    let mut pending: Vec<usize> = Vec::new();
    for i in 0..n {
        match &parsed[i].1 {
            // Decode failures answer immediately; nothing to compute.
            Err(e) => {
                stats.errors += 1;
                responses[i] = Some(envelope(parsed[i].0.clone(), Err(e.clone())));
            }
            // Registration is an ordering barrier.
            Ok(ApiRequest::Register(r)) => {
                flush_pending(engine, &parsed, &mut pending, &mut responses, opts, stats);
                let res = engine
                    .register_network_json(&r.spec)
                    .map(|resp| resp.to_json());
                if res.is_err() {
                    stats.errors += 1;
                }
                responses[i] = Some(envelope(parsed[i].0.clone(), res));
            }
            Ok(_) => pending.push(i),
        }
    }
    flush_pending(engine, &parsed, &mut pending, &mut responses, opts, stats);
    let mut bytes_out = 0u64;
    for r in &responses {
        let json = r.as_ref().expect("every request answered");
        let text = json.to_string_compact();
        bytes_out += text.len() as u64 + 1; // newline
        writeln!(out, "{text}")?;
    }
    out.flush()?;
    stats.requests += n as u64;
    stats.batches += 1;
    let tel = crate::telemetry::global();
    tel.serve_bytes_out.add(bytes_out);
    tel.serve_batches.add(1);
    tel.serve_batch_size.record(n as u64);
    Ok(())
}

/// Answer the gathered non-register requests: evals through the engine's
/// batched segmented path, the rest fanned out over the shared
/// persistent pool.
fn flush_pending(
    engine: &Engine,
    parsed: &[(Option<Json>, Result<ApiRequest, ApiError>)],
    pending: &mut Vec<usize>,
    responses: &mut [Option<Json>],
    opts: &ServeOptions,
    stats: &mut ServeStats,
) {
    if pending.is_empty() {
        return;
    }
    let mut eval_idx = Vec::new();
    let mut eval_reqs = Vec::new();
    let mut rest = Vec::new();
    for &i in pending.iter() {
        match &parsed[i].1 {
            Ok(ApiRequest::Eval(r)) => {
                eval_idx.push(i);
                eval_reqs.push(r.clone());
            }
            _ => rest.push(i),
        }
    }
    for (i, res) in eval_idx
        .iter()
        .copied()
        .zip(engine.eval_batch(&eval_reqs, opts.threads))
    {
        if res.is_err() {
            stats.errors += 1;
        }
        responses[i] = Some(envelope(parsed[i].0.clone(), res.map(|r| r.to_json())));
    }
    // Sweep/pareto/equal-pe/memory requests fan out over the shared
    // persistent pool (DESIGN.md §11). Each is also parallel *inside*
    // (the sweep cores fan out through the same pool), but because every
    // fan-out in the process shares one set of workers — with nested
    // submissions executing on their submitting thread when the pool is
    // saturated — dispatching them concurrently overlaps their serial
    // phases (plan builds, JSON encoding) without multiplying threads,
    // unlike the pre-§11 per-call scoped pools this loop used to avoid.
    let rest_results = crate::runtime::pool::parallel_map(rest.len(), opts.threads, |j| {
        dispatch(engine, &parsed[rest[j]].1, opts.threads)
    });
    for (&i, res) in rest.iter().zip(rest_results) {
        if res.is_err() {
            stats.errors += 1;
        }
        responses[i] = Some(envelope(parsed[i].0.clone(), res));
    }
    pending.clear();
}

/// Route one decoded request to the engine. `threads` is the serve
/// loop's executor budget, honored by the request kinds whose fan-out is
/// not already bounded by their own spec (today: graph scheduling).
fn dispatch(
    engine: &Engine,
    req: &Result<ApiRequest, ApiError>,
    threads: usize,
) -> Result<Json, ApiError> {
    match req {
        Err(e) => Err(e.clone()),
        Ok(ApiRequest::Eval(r)) => engine.eval(r).map(|x| x.to_json()),
        // Never reached from the serve loop — process_batch answers
        // registers inline as ordering barriers before anything is fanned
        // out. Kept correct for completeness should a future caller
        // dispatch one directly.
        Ok(ApiRequest::Register(r)) => {
            engine.register_network_json(&r.spec).map(|x| x.to_json())
        }
        Ok(ApiRequest::Zoo) => Ok(zoo_json(&engine.list_networks())),
        Ok(ApiRequest::Sweep(r)) => engine.sweep(r).map(|d| sweep_json(&d)),
        Ok(ApiRequest::Pareto(r)) => engine.pareto(r).map(|d| pareto_json(&d)),
        Ok(ApiRequest::EqualPe(r)) => engine.equal_pe(r).map(|d| equal_pe_json(&d)),
        Ok(ApiRequest::Memory(r)) => engine.memory(r).map(|x| x.to_json()),
        Ok(ApiRequest::Graph(r)) => engine.graph_threaded(r, threads).map(|x| x.to_json()),
        Ok(ApiRequest::Trace(r)) => engine.trace_threaded(r, threads).map(|x| x.to_json()),
        Ok(ApiRequest::Stats(r)) => Ok(engine.stats(r).to_json()),
    }
}

/// One human-readable line summarizing a finished serve loop: the
/// connection's own counters, the engine-wide request-latency quantiles,
/// and the eval/plan cache traffic — the log-file rendering of the
/// telemetry the `{"type": "stats"}` request exposes as JSON. Shared by
/// the TCP per-connection log and the stdin path of `camuy serve`.
pub fn connection_summary(engine: &Engine, stats: &ServeStats) -> String {
    let tel = crate::telemetry::global().snapshot();
    let lat = tel.request_latency();
    let ec = engine.cache().stats();
    let ps = engine.plan_stats();
    format!(
        "{} request(s), {} error(s), {} batch(es); \
         request p50/p99 {:.2}/{:.2} ms; \
         eval cache: {} entr(ies), {:.0}% hit rate; \
         plan cache: {} plan(s), {} hit(s) / {} miss(es) \
         ({:.0}% hit rate), {} table word(s)",
        stats.requests,
        stats.errors,
        stats.batches,
        lat.quantile(0.50) as f64 / 1e6,
        lat.quantile(0.99) as f64 / 1e6,
        ec.entries,
        100.0 * ec.hit_rate(),
        ps.entries,
        ps.hits,
        ps.misses,
        100.0 * ps.hit_rate(),
        ps.table_words
    )
}

/// The response envelope: the echoed id, the ok flag, and either the
/// result document or the structured error.
fn envelope(id: Option<Json>, result: Result<Json, ApiError>) -> Json {
    let mut pairs = Vec::with_capacity(3);
    if let Some(id) = id {
        pairs.push(("id", id));
    }
    match result {
        Ok(v) => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("result", v));
        }
        Err(e) => {
            crate::telemetry::global().record_error_kind(e.kind());
            pairs.push(("ok", Json::Bool(false)));
            pairs.push(("error", e.to_json()));
        }
    }
    Json::obj(pairs)
}
