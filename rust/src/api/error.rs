//! The structured error type of the typed query API. Every failure a
//! request can produce maps to a stable machine-readable `kind` plus a
//! human-readable message, so `camuy serve` clients can branch without
//! string-matching and the CLI can print the same error it would have
//! produced before the engine existed.

use crate::config::ConfigError;
use crate::util::json::{Json, JsonError};
use std::fmt;

/// Everything that can go wrong answering an API request.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// The request named a network neither the zoo nor the user-network
    /// store knows.
    UnknownNetwork { name: String },
    /// The array configuration violates a structural invariant
    /// (zero height/width/accumulator capacity, bad bitwidth, …).
    Config(ConfigError),
    /// The request document is not valid JSON at all.
    Json(JsonError),
    /// The request parsed as JSON but is malformed (missing fields, wrong
    /// types, out-of-range values, unknown request type, …).
    BadRequest(String),
    /// A network spec failed validation during registration.
    InvalidNetwork(String),
}

impl ApiError {
    /// Stable machine-readable discriminator for the wire format.
    pub fn kind(&self) -> &'static str {
        match self {
            ApiError::UnknownNetwork { .. } => "unknown_network",
            ApiError::Config(_) => "invalid_config",
            ApiError::Json(_) => "bad_json",
            ApiError::BadRequest(_) => "bad_request",
            ApiError::InvalidNetwork(_) => "invalid_network",
        }
    }

    /// The structured error object embedded in a serve response:
    /// `{"kind": ..., "message": ...}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind())),
            ("message", Json::str(self.to_string())),
        ])
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::UnknownNetwork { name } => {
                write!(f, "unknown network '{name}' (see `camuy zoo`)")
            }
            ApiError::Config(e) => write!(f, "invalid array configuration: {e}"),
            ApiError::Json(e) => write!(f, "{e}"),
            ApiError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ApiError::InvalidNetwork(msg) => write!(f, "invalid network spec: {msg}"),
        }
    }
}

impl std::error::Error for ApiError {}

impl From<ConfigError> for ApiError {
    fn from(e: ConfigError) -> ApiError {
        ApiError::Config(e)
    }
}

impl From<JsonError> for ApiError {
    fn from(e: JsonError) -> ApiError {
        ApiError::Json(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_stable_and_json_is_structured() {
        let e = ApiError::UnknownNetwork {
            name: "lenet-9000".into(),
        };
        assert_eq!(e.kind(), "unknown_network");
        let j = e.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("unknown_network"));
        assert!(j.get("message").unwrap().as_str().unwrap().contains("lenet-9000"));
    }

    #[test]
    fn config_errors_convert() {
        let e: ApiError = ConfigError::ZeroHeight.into();
        assert_eq!(e.kind(), "invalid_config");
        assert!(e.to_string().contains("height"));
    }
}
