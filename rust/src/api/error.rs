//! The structured error type of the typed query API. Every failure a
//! request can produce maps to a stable machine-readable `kind` plus a
//! human-readable message, so `camuy serve` clients can branch without
//! string-matching and the CLI can print the same error it would have
//! produced before the engine existed.

use crate::config::ConfigError;
use crate::util::json::{Json, JsonError};
use std::fmt;

/// Everything that can go wrong answering an API request.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// The request named a network neither the zoo nor the user-network
    /// store knows.
    UnknownNetwork { name: String },
    /// The array configuration violates a structural invariant
    /// (zero height/width/accumulator capacity, bad bitwidth, …).
    Config(ConfigError),
    /// The request document is not valid JSON at all.
    Json(JsonError),
    /// The request parsed as JSON but is malformed (missing fields, wrong
    /// types, out-of-range values, unknown request type, …).
    BadRequest(String),
    /// A network spec failed validation during registration.
    InvalidNetwork(String),
    /// The request's `deadline_ms` fired before the work finished
    /// (DESIGN.md §15). `progress` counts the cooperative checkpoints the
    /// request passed — pool chunks, sweep units, NSGA-II generations —
    /// before cancellation, so a client can tell "barely started" from
    /// "almost done" and size its retry deadline accordingly.
    DeadlineExceeded { deadline_ms: u64, progress: u64 },
    /// The server shed the request under load (admission queue full or
    /// connection cap reached); retry after roughly `retry_after_ms`.
    Overloaded { retry_after_ms: u64 },
    /// The connection made no read or write progress for the server's
    /// idle budget and was closed by the slowloris guard (DESIGN.md §16).
    /// `idle_ms` is how long it sat idle.
    IdleTimeout { idle_ms: u64 },
    /// The request panicked and was isolated (DESIGN.md §15); the engine
    /// and the connection stay healthy. The message is the panic payload.
    Internal(String),
}

impl ApiError {
    /// Stable machine-readable discriminator for the wire format.
    pub fn kind(&self) -> &'static str {
        match self {
            ApiError::UnknownNetwork { .. } => "unknown_network",
            ApiError::Config(_) => "invalid_config",
            ApiError::Json(_) => "bad_json",
            ApiError::BadRequest(_) => "bad_request",
            ApiError::InvalidNetwork(_) => "invalid_network",
            ApiError::DeadlineExceeded { .. } => "deadline_exceeded",
            ApiError::Overloaded { .. } => "overloaded",
            ApiError::IdleTimeout { .. } => "idle_timeout",
            ApiError::Internal(_) => "internal",
        }
    }

    /// The structured error object embedded in a serve response:
    /// `{"kind": ..., "message": ...}`, plus machine-readable detail
    /// fields for the operational kinds (`deadline_ms`/`progress` on a
    /// fired deadline, `retry_after_ms` on a shed request) so clients
    /// never parse the human message.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::str(self.kind())),
            ("message", Json::str(self.to_string())),
        ];
        match self {
            ApiError::DeadlineExceeded {
                deadline_ms,
                progress,
            } => {
                pairs.push(("deadline_ms", Json::num(*deadline_ms as f64)));
                pairs.push(("progress", Json::num(*progress as f64)));
            }
            ApiError::Overloaded { retry_after_ms } => {
                pairs.push(("retry_after_ms", Json::num(*retry_after_ms as f64)));
            }
            ApiError::IdleTimeout { idle_ms } => {
                pairs.push(("idle_ms", Json::num(*idle_ms as f64)));
            }
            _ => {}
        }
        Json::obj(pairs)
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::UnknownNetwork { name } => {
                write!(f, "unknown network '{name}' (see `camuy zoo`)")
            }
            ApiError::Config(e) => write!(f, "invalid array configuration: {e}"),
            ApiError::Json(e) => write!(f, "{e}"),
            ApiError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ApiError::InvalidNetwork(msg) => write!(f, "invalid network spec: {msg}"),
            ApiError::DeadlineExceeded {
                deadline_ms,
                progress,
            } => write!(
                f,
                "deadline of {deadline_ms} ms exceeded after {progress} checkpoint(s); \
                 partial work discarded"
            ),
            ApiError::Overloaded { retry_after_ms } => {
                write!(f, "server overloaded; retry after {retry_after_ms} ms")
            }
            ApiError::IdleTimeout { idle_ms } => {
                write!(f, "connection idle for {idle_ms} ms, closing")
            }
            ApiError::Internal(msg) => write!(f, "internal error: {msg}"),
        }
    }
}

impl std::error::Error for ApiError {}

impl From<ConfigError> for ApiError {
    fn from(e: ConfigError) -> ApiError {
        ApiError::Config(e)
    }
}

impl From<JsonError> for ApiError {
    fn from(e: JsonError) -> ApiError {
        ApiError::Json(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_stable_and_json_is_structured() {
        let e = ApiError::UnknownNetwork {
            name: "lenet-9000".into(),
        };
        assert_eq!(e.kind(), "unknown_network");
        let j = e.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("unknown_network"));
        assert!(j.get("message").unwrap().as_str().unwrap().contains("lenet-9000"));
    }

    #[test]
    fn operational_kinds_carry_structured_detail() {
        let e = ApiError::DeadlineExceeded {
            deadline_ms: 250,
            progress: 17,
        };
        assert_eq!(e.kind(), "deadline_exceeded");
        let j = e.to_json();
        assert_eq!(j.get("deadline_ms").and_then(Json::as_f64), Some(250.0));
        assert_eq!(j.get("progress").and_then(Json::as_f64), Some(17.0));

        let e = ApiError::Overloaded { retry_after_ms: 40 };
        assert_eq!(e.kind(), "overloaded");
        let j = e.to_json();
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_f64), Some(40.0));

        let e = ApiError::IdleTimeout { idle_ms: 60_000 };
        assert_eq!(e.kind(), "idle_timeout");
        let j = e.to_json();
        assert_eq!(j.get("idle_ms").and_then(Json::as_f64), Some(60_000.0));

        let e = ApiError::Internal("boom".into());
        assert_eq!(e.kind(), "internal");
        assert!(e.to_string().contains("boom"));
        assert!(e.to_json().get("retry_after_ms").is_none());
    }

    #[test]
    fn config_errors_convert() {
        let e: ApiError = ConfigError::ZeroHeight.into();
        assert_eq!(e.kind(), "invalid_config");
        assert!(e.to_string().contains("height"));
    }
}
