//! The typed query API: request/response structs, structured errors, the
//! long-lived [`Engine`], and the batched JSON-lines server.
//!
//! The paper positions CAMUY as a library other ML stacks embed; this
//! module is that embedding surface. Construct an [`Engine`] once, keep it
//! alive, and issue typed requests against it:
//!
//! ```
//! use camuy::api::{Engine, EvalRequest};
//! use camuy::config::ArrayConfig;
//!
//! let engine = Engine::new();
//! let resp = engine
//!     .eval(&EvalRequest::new("alexnet", ArrayConfig::new(64, 32)))
//!     .unwrap();
//! assert!(resp.total().cycles > 0);
//! ```
//!
//! The engine owns the network registry (zoo + user store) and the shared
//! per-(shape, configuration) evaluation cache, so repeated queries hit
//! the memo table. Arbitrary user models enter through JSON network
//! ingestion ([`Engine::register_network_json`]) — a layer-list document
//! validated into the `model::workload` IR — and become first-class
//! workloads for every request kind. `camuy serve` wraps the same engine
//! in a JSON-lines request/response loop (stdin or TCP) with adaptive
//! request batching onto the segmented sweep core ([`serve`]).
//!
//! Every CLI subcommand is a thin adapter over this module: it builds a
//! request struct, calls the engine, and formats the typed response.
//! Request schema and wire format are documented in DESIGN.md §8.

#[cfg(target_os = "linux")]
mod conn;
mod engine;
mod error;
mod request;
mod response;
mod serve;

pub use engine::{Engine, MAX_USER_NETWORKS, SNAPSHOT_VERSION};
pub use error::ApiError;
pub use request::{
    ApiRequest, EqualPeRequest, EvalRequest, GraphRequest, LineMeta, MemoryRequest, ParetoRequest,
    RegisterRequest, StatsRequest, SweepRequest, SweepSpec, TraceRequest, MAX_DEADLINE_MS,
};
pub use response::{
    equal_pe_json, liveness_json, pareto_json, schedule_json, sweep_json, zoo_json, EvalResponse,
    GraphResponse, MemoryResponse, NetworkEntry, NetworkSource, PerLayerReport, RegisterResponse,
    StatsResponse, TraceResponse,
};
pub use serve::{
    clear_drain, connection_summary, drain_requested, request_drain, serve, serve_tcp,
    ServeOptions, ServeStats,
};
