//! Typed responses and their JSON wire format.
//!
//! An eval response serializes to exactly the document `camuy emulate
//! --json` prints (it *is* the [`InferenceRun`] summary), so a serve client
//! and the CLI agree byte-for-byte on the same query.

use crate::config::ArrayConfig;
use crate::coordinator::InferenceRun;
use crate::metrics::Metrics;
use crate::model::graph::{GraphLiveness, GraphSchedule};
use crate::model::memory::MemoryAnalysis;
use crate::model::multi::{MultiArrayConfig, MultiMetrics};
use crate::model::roofline::LayerRoofline;
use crate::pareto::nsga2::Solution;
use crate::report::figures::{Fig2Data, Fig3Data, Fig6Data};
use crate::sim::NetworkSim;
use crate::util::json::Json;

/// Per-layer roofline context attached when [`super::EvalRequest::per_layer`]
/// is set.
#[derive(Debug, Clone)]
pub struct PerLayerReport {
    pub rooflines: Vec<LayerRoofline>,
    /// Fraction of layers that are memory-bound on this configuration.
    pub memory_bound_share: f64,
    /// Peak MACs/cycle over peak UB bytes/cycle.
    pub machine_balance: f64,
}

impl PerLayerReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machine_balance", Json::num(self.machine_balance)),
            ("memory_bound_share", Json::num(self.memory_bound_share)),
            (
                "layers",
                Json::arr(self.rooflines.iter().map(|r| {
                    Json::obj(vec![
                        ("layer", Json::str(r.layer.clone())),
                        ("intensity", Json::num(r.intensity)),
                        ("achieved_of_peak", Json::num(r.achieved_of_peak)),
                        (
                            "bound",
                            Json::str(match r.bound {
                                crate::model::roofline::Bound::Compute => "compute",
                                crate::model::roofline::Bound::Memory => "memory",
                            }),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// Result of an [`super::EvalRequest`].
#[derive(Debug, Clone)]
pub enum EvalResponse {
    /// One array: the full inference run (timeline, bandwidth, spills).
    Single {
        run: InferenceRun,
        /// Eq.1 energy under the request's weights (the run's own JSON
        /// always reports paper weights).
        energy: f64,
        /// Peak rows staged in the Systolic Data Setup FIFOs across the
        /// network (closed form; the simulator measures the same value).
        max_fifo_depth: usize,
        per_layer: Option<PerLayerReport>,
    },
    /// A multi-array bank (`arrays > 1`).
    Multi {
        network: String,
        config: MultiArrayConfig,
        metrics: MultiMetrics,
        utilization: f64,
        energy: f64,
    },
}

impl EvalResponse {
    /// The aggregate metrics, whichever execution model answered.
    pub fn total(&self) -> &Metrics {
        match self {
            EvalResponse::Single { run, .. } => &run.total,
            EvalResponse::Multi { metrics, .. } => &metrics.total,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            // The `camuy emulate --json` document, with the energy field
            // reflecting the *request's* weights (the run's own JSON always
            // assumes paper weights; under paper weights the two are
            // identical, so CLI/serve parity holds) and the roofline report
            // attached when the request asked for it.
            EvalResponse::Single {
                run,
                energy,
                max_fifo_depth,
                per_layer,
            } => {
                let mut j = run.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("energy".to_string(), Json::num(*energy));
                    m.insert(
                        "max_fifo_depth".to_string(),
                        Json::num(*max_fifo_depth as f64),
                    );
                    if let Some(pl) = per_layer {
                        m.insert("roofline".to_string(), pl.to_json());
                    }
                }
                j
            }
            EvalResponse::Multi {
                network,
                config,
                metrics,
                utilization,
                energy,
            } => Json::obj(vec![
                ("network", Json::str(network.clone())),
                ("arrays", Json::num(config.arrays as f64)),
                ("config", config.array.to_json()),
                ("makespan_cycles", Json::num(metrics.makespan_cycles as f64)),
                ("total", metrics.total.to_json()),
                ("utilization", Json::num(*utilization)),
                ("energy", Json::num(*energy)),
            ]),
        }
    }
}

/// Result of a [`super::TraceRequest`]: the simulated run (totals,
/// per-layer timeline, event counts) plus the Perfetto trace-event
/// document, ready to write to a file and load at <https://ui.perfetto.dev>.
#[derive(Debug)]
pub struct TraceResponse {
    pub sim: NetworkSim,
    pub config: ArrayConfig,
    /// Attach the per-layer timeline rows.
    pub per_layer: bool,
}

impl TraceResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("network", Json::str(self.sim.network.clone())),
            ("config", self.config.to_json()),
            ("cycles", Json::num(self.sim.total.cycles as f64)),
            (
                "stall_cycles",
                Json::num(self.sim.total.stall_cycles as f64),
            ),
            (
                "max_fifo_depth",
                Json::num(self.sim.max_fifo_depth as f64),
            ),
            ("events", Json::num(self.sim.events as f64)),
            ("slices", Json::num(self.sim.slice_count() as f64)),
            ("truncated", Json::Bool(self.sim.truncated())),
            ("trace", self.sim.perfetto()),
        ];
        if self.per_layer {
            pairs.push((
                "layers",
                Json::arr(self.sim.layers.iter().map(|l| {
                    Json::obj(vec![
                        ("layer", Json::str(l.name.clone())),
                        ("start_cycle", Json::num(l.start_cycle as f64)),
                        ("end_cycle", Json::num(l.end_cycle as f64)),
                        ("cycles", Json::num(l.metrics.cycles as f64)),
                        (
                            "stall_cycles",
                            Json::num(l.metrics.stall_cycles as f64),
                        ),
                        (
                            "max_fifo_depth",
                            Json::num(l.max_fifo_depth as f64),
                        ),
                        ("events", Json::num(l.events as f64)),
                    ])
                })),
            ));
        }
        Json::obj(pairs)
    }
}

/// Result of registering a user network.
#[derive(Debug, Clone)]
pub struct RegisterResponse {
    pub name: String,
    pub layers: usize,
    pub params: u64,
    pub macs: u64,
    pub distinct_gemms: usize,
    /// An earlier registration under the same name was replaced.
    pub replaced: bool,
}

impl RegisterResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("layers", Json::num(self.layers as f64)),
            ("params", Json::num(self.params as f64)),
            ("macs", Json::num(self.macs as f64)),
            ("distinct_gemms", Json::num(self.distinct_gemms as f64)),
            ("replaced", Json::Bool(self.replaced)),
        ])
    }
}

/// The stats payload: a full [`TelemetrySnapshot`] with the engine-owned
/// sections (eval cache, plan cache, network stores) attached by
/// `Engine::stats` (DESIGN.md §14).
#[derive(Debug, Clone)]
pub struct StatsResponse {
    pub snapshot: crate::telemetry::TelemetrySnapshot,
    /// Render raw histogram bucket arrays into the JSON (mirrors
    /// `StatsRequest::buckets`).
    pub buckets: bool,
}

impl StatsResponse {
    pub fn to_json(&self) -> Json {
        self.snapshot.to_json(self.buckets)
    }
}

/// Where a listed network comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetworkSource {
    Zoo,
    User,
}

impl NetworkSource {
    pub fn as_str(&self) -> &'static str {
        match self {
            NetworkSource::Zoo => "zoo",
            NetworkSource::User => "user",
        }
    }
}

/// One row of the network listing.
#[derive(Debug, Clone)]
pub struct NetworkEntry {
    pub name: String,
    pub source: NetworkSource,
    pub params: u64,
    pub macs: u64,
    pub layers: usize,
    pub distinct_gemms: usize,
}

impl NetworkEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("source", Json::str(self.source.as_str())),
            ("params", Json::num(self.params as f64)),
            ("macs", Json::num(self.macs as f64)),
            ("layers", Json::num(self.layers as f64)),
            ("distinct_gemms", Json::num(self.distinct_gemms as f64)),
        ])
    }
}

/// Result of a [`super::MemoryRequest`].
#[derive(Debug, Clone)]
pub struct MemoryResponse {
    pub network: String,
    pub config: ArrayConfig,
    pub analysis: MemoryAnalysis,
    /// Eq.1 energy assuming everything stays on chip.
    pub base_energy: f64,
    /// Eq.1 energy plus the DRAM spill overhead (per-layer spills, plus
    /// edge spills when the liveness pass ran).
    pub corrected_energy: f64,
    /// Graph-aware tensor liveness, attached when the request set
    /// `graph: true`: true peak UB residency instead of the linear-chain
    /// estimate, and DRAM traffic for long-lived skip/concat tensors.
    pub liveness: Option<GraphLiveness>,
}

impl MemoryResponse {
    /// Spilling layers, largest working set first.
    pub fn spillers(&self) -> Vec<&crate::model::memory::LayerMemory> {
        let mut out: Vec<_> = self.analysis.layers.iter().filter(|l| !l.fits).collect();
        out.sort_by(|a, b| b.working_set_bytes.cmp(&a.working_set_bytes));
        out
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("network", Json::str(self.network.clone())),
            ("config", self.config.to_json()),
            (
                "peak_working_set_bytes",
                Json::num(self.analysis.peak_working_set_bytes as f64),
            ),
            ("layers", Json::num(self.analysis.layers.len() as f64)),
            (
                "spilling_layers",
                Json::num(self.analysis.spilling_layers as f64),
            ),
            (
                "total_dram_words",
                Json::num(self.analysis.total_dram_words as f64),
            ),
            ("base_energy", Json::num(self.base_energy)),
            ("corrected_energy", Json::num(self.corrected_energy)),
            (
                "spillers",
                Json::arr(self.spillers().into_iter().take(10).map(|l| {
                    Json::obj(vec![
                        ("layer", Json::str(l.layer.clone())),
                        ("working_set_bytes", Json::num(l.working_set_bytes as f64)),
                        ("dram_words", Json::num(l.dram_words as f64)),
                    ])
                })),
            ),
        ];
        if let Some(live) = &self.liveness {
            pairs.push(("liveness", liveness_json(live)));
        }
        Json::obj(pairs)
    }
}

/// Result of a [`super::GraphRequest`]: DAG statistics, the serialized
/// metrics (byte-identical to the flat path), tensor liveness with the
/// corrected energy, and the branch-parallel schedule.
#[derive(Debug, Clone)]
pub struct GraphResponse {
    pub network: String,
    pub config: ArrayConfig,
    pub nodes: usize,
    pub layers: usize,
    pub junctions: usize,
    pub edges: usize,
    pub is_chain: bool,
    /// Serialized single-array totals — identical to the flat evaluation.
    pub metrics: Metrics,
    pub base_energy: f64,
    pub liveness: GraphLiveness,
    /// DRAM words from layers whose own working set exceeds the UB.
    pub layer_dram_words: u64,
    /// Eq.1 energy plus DRAM overhead from layer *and* edge spills.
    pub corrected_energy: f64,
    pub schedule: GraphSchedule,
}

impl GraphResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("network", Json::str(self.network.clone())),
            ("config", self.config.to_json()),
            ("nodes", Json::num(self.nodes as f64)),
            ("layers", Json::num(self.layers as f64)),
            ("junctions", Json::num(self.junctions as f64)),
            ("edges", Json::num(self.edges as f64)),
            ("is_chain", Json::Bool(self.is_chain)),
            ("metrics", self.metrics.to_json()),
            ("base_energy", Json::num(self.base_energy)),
            ("liveness", liveness_json(&self.liveness)),
            ("layer_dram_words", Json::num(self.layer_dram_words as f64)),
            ("corrected_energy", Json::num(self.corrected_energy)),
            ("schedule", schedule_json(&self.schedule)),
        ])
    }
}

/// The liveness summary embedded in graph and memory responses: peak
/// residency vs the linear-chain estimate, spill totals, and the ten
/// heaviest steps.
pub fn liveness_json(l: &GraphLiveness) -> Json {
    Json::obj(vec![
        ("peak_residency_bytes", Json::num(l.peak_bytes as f64)),
        ("chain_peak_bytes", Json::num(l.chain_peak_bytes as f64)),
        ("inflation", Json::num(l.inflation())),
        ("spilled_tensors", Json::num(l.spilled_tensors as f64)),
        ("edge_dram_words", Json::num(l.edge_dram_words as f64)),
        (
            "top_steps",
            Json::arr(l.top_steps(10).into_iter().map(|s| {
                Json::obj(vec![
                    ("node", Json::str(s.name.clone())),
                    ("own_bytes", Json::num(s.own_bytes as f64)),
                    ("held_bytes", Json::num(s.held_bytes as f64)),
                    ("total_bytes", Json::num(s.total_bytes as f64)),
                ])
            })),
        ),
    ])
}

/// The branch-parallel schedule summary of a graph response.
pub fn schedule_json(s: &GraphSchedule) -> Json {
    // Per-array busy cycles, so a client can see the load balance without
    // the full assignment list.
    let mut busy = vec![0u64; s.arrays];
    for a in &s.assignments {
        busy[a.array] += a.end_cycle - a.start_cycle;
    }
    Json::obj(vec![
        ("arrays", Json::num(s.arrays as f64)),
        ("makespan_cycles", Json::num(s.makespan_cycles as f64)),
        ("serialized_cycles", Json::num(s.serialized_cycles as f64)),
        (
            "critical_path_cycles",
            Json::num(s.critical_path_cycles as f64),
        ),
        ("speedup", Json::num(s.speedup())),
        (
            "busy_cycles_per_array",
            Json::arr(busy.iter().map(|&b| Json::num(b as f64))),
        ),
        ("scheduled_layers", Json::num(s.assignments.len() as f64)),
    ])
}

// ------------------------------------------------ figure-data wire formats

fn solution_json(s: &Solution) -> Json {
    Json::obj(vec![
        ("height", Json::num(s.height as f64)),
        ("width", Json::num(s.width as f64)),
        (
            "objectives",
            Json::arr(s.objectives.iter().map(|&x| Json::num(x))),
        ),
    ])
}

/// Serve response for a network listing.
pub fn zoo_json(entries: &[NetworkEntry]) -> Json {
    Json::obj(vec![(
        "networks",
        Json::arr(entries.iter().map(NetworkEntry::to_json)),
    )])
}

/// Serve response for a sweep: the full point cloud plus the argmin cell.
/// Request validation rejects empty grids, so a sweep response always has
/// an argmin; `Json::Null` covers the defensive corner anyway.
pub fn sweep_json(d: &Fig2Data) -> Json {
    let best = match d.sweep.argmin(|p| p.energy) {
        Some(best) => Json::obj(vec![
            ("height", Json::num(best.height as f64)),
            ("width", Json::num(best.width as f64)),
            ("energy", Json::num(best.energy)),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("network", Json::str(d.network.clone())),
        (
            "points",
            Json::arr(d.sweep.points.iter().map(|p| {
                Json::obj(vec![
                    ("height", Json::num(p.height as f64)),
                    ("width", Json::num(p.width as f64)),
                    ("energy", Json::num(p.energy)),
                    ("cycles", Json::num(p.metrics.cycles as f64)),
                    ("utilization", Json::num(p.utilization)),
                ])
            })),
        ),
        ("best_energy", best),
    ])
}

/// Serve response for a Pareto run: NSGA-II fronts for both objective
/// pairs, plus the exhaustive fronts for validation.
pub fn pareto_json(d: &Fig3Data) -> Json {
    let front = |sols: &[Solution]| Json::arr(sols.iter().map(solution_json));
    Json::obj(vec![
        ("network", Json::str(d.network.clone())),
        ("energy_front", front(&d.energy_front)),
        ("utilization_front", front(&d.utilization_front)),
        ("exhaustive_energy_front", front(&d.exhaustive_energy_front)),
        (
            "exhaustive_utilization_front",
            front(&d.exhaustive_utilization_front),
        ),
    ])
}

/// Serve response for the equal-PE study.
pub fn equal_pe_json(data: &[Fig6Data]) -> Json {
    Json::obj(vec![(
        "budgets",
        Json::arr(data.iter().map(|d| {
            Json::obj(vec![
                ("pe_budget", Json::num(d.pe_budget as f64)),
                (
                    "shapes",
                    Json::arr(d.shapes.iter().map(|&(h, w)| {
                        Json::arr(vec![Json::num(h as f64), Json::num(w as f64)])
                    })),
                ),
                (
                    "average_norm_energy",
                    Json::arr(d.average.iter().map(|&x| Json::num(x))),
                ),
            ])
        })),
    )])
}
