//! Typed request structs and their JSON wire format.
//!
//! Every request is a JSON object with a `"type"` discriminator plus an
//! optional `"id"` the server echoes back (see [`ApiRequest::parse_line`]).
//! Request construction validates eagerly — a malformed document never
//! reaches the engine, and configuration violations surface as the typed
//! [`crate::config::ConfigError`] through [`ApiError::Config`].

use super::error::ApiError;
use crate::config::{ArrayConfig, EnergyWeights};
use crate::pareto::nsga2::Nsga2Params;
use crate::report::figures::FigureContext;
use crate::sweep::grid::DimGrid;
use crate::util::json::Json;

/// Evaluate one network on one array configuration (CLI: `camuy emulate`).
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub net: String,
    /// Re-batch every layer; `None` keeps the batch the network was
    /// registered (or built) with.
    pub batch: Option<usize>,
    /// Multi-array bank size; 1 = a single array.
    pub arrays: usize,
    pub config: ArrayConfig,
    pub weights: EnergyWeights,
    /// Attach the per-layer roofline report to the response.
    pub per_layer: bool,
}

impl EvalRequest {
    pub fn new(net: impl Into<String>, config: ArrayConfig) -> EvalRequest {
        EvalRequest {
            net: net.into(),
            batch: None,
            arrays: 1,
            config,
            weights: EnergyWeights::paper(),
            per_layer: false,
        }
    }

    pub fn from_json(v: &Json) -> Result<EvalRequest, ApiError> {
        let arrays = opt_positive(v, "arrays")?.unwrap_or(1);
        check_arrays(arrays)?;
        Ok(EvalRequest {
            net: req_str(v, "net")?,
            batch: opt_positive(v, "batch")?,
            arrays,
            config: parse_config(v.get("config"), ArrayConfig::new(128, 128))?,
            weights: parse_weights(v)?,
            per_layer: v.get("per_layer").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

/// Most arrays a multi-array bank request may ask for. Together with the
/// wire-side geometry cap in [`parse_config`] this keeps `pe_count()`
/// arithmetic (arrays × height × width) far from usize overflow.
pub const MAX_ARRAYS: usize = 1 << 16;

/// The bank-size bounds every multi-array entry path shares (wire parsing
/// and the engine's programmatic surface).
pub(crate) fn check_arrays(arrays: usize) -> Result<(), ApiError> {
    if arrays == 0 {
        return Err(ApiError::BadRequest("arrays must be positive".into()));
    }
    if arrays > MAX_ARRAYS {
        return Err(ApiError::BadRequest(format!(
            "arrays {arrays} exceeds the limit {MAX_ARRAYS}"
        )));
    }
    Ok(())
}

/// Most a request (or a registered spec) may re-batch a network by —
/// matches the per-layer ingestion ceiling, so a batch override can never
/// push the GEMM lowering (`m = batch × oh × ow`) out of exact range.
/// Enforced at [`crate::api::Engine::resolve`], the choke point every
/// resolution path goes through (the re-check runs there too).
pub const MAX_BATCH: usize = 1 << 20;

/// Largest array edge any configuration may have — keeps `pe_count()`
/// (height × width, and × arrays for banks) far from usize overflow.
/// Enforced both at JSON parse time ([`parse_config`]) and at the engine
/// for programmatic and CLI callers.
pub const MAX_GEOMETRY: usize = 1 << 20;

/// Shared sweep parameters: the grid, the per-cell template configuration,
/// the Equation-1 weights and the worker count. This *is* the figure
/// pipeline's [`FigureContext`] — one definition, so the CLI's `--smoke`
/// and the API's `"grid": "smoke"` can never drift apart. The JSON and
/// validation surface lives here; construction defaults live in
/// [`crate::report::figures`].
pub type SweepSpec = FigureContext;

impl FigureContext {
    /// Parse the flattened spec fields of a request document: `"grid"`
    /// (`"paper"`, `"smoke"` or `{"lo", "hi", "step"}`), `"template"`,
    /// `"energy_model"`, `"threads"`.
    pub fn from_json(v: &Json) -> Result<SweepSpec, ApiError> {
        let grid = match v.get("grid") {
            None => DimGrid::paper(),
            Some(g) => match g.as_str() {
                Some("paper") => DimGrid::paper(),
                Some("smoke") => SweepSpec::smoke().grid,
                Some("dense") => DimGrid::dense(),
                Some(other) => {
                    return Err(ApiError::BadRequest(format!(
                        "unknown grid '{other}' (paper|smoke|dense or {{lo, hi, step}})"
                    )))
                }
                None => {
                    // Wire-surface bounds, checked before materializing
                    // anything. The grid is square (axis × axis points),
                    // so the axis cap bounds the sweep at 65536 cells —
                    // ~68 paper grids — and the response at a few MB.
                    const MAX_GRID_DIM: usize = 1 << 20;
                    const MAX_GRID_AXIS: usize = 256;
                    let lo = req_positive(g, "lo")?;
                    let hi = req_positive(g, "hi")?;
                    let step = req_positive(g, "step")?;
                    if lo > hi {
                        return Err(ApiError::BadRequest(format!(
                            "grid lo {lo} exceeds hi {hi}"
                        )));
                    }
                    if hi > MAX_GRID_DIM {
                        return Err(ApiError::BadRequest(format!(
                            "grid hi {hi} exceeds the limit {MAX_GRID_DIM}"
                        )));
                    }
                    let axis = (hi - lo) / step + 1;
                    if axis > MAX_GRID_AXIS {
                        return Err(ApiError::BadRequest(format!(
                            "grid axis has {axis} points; the limit is {MAX_GRID_AXIS}"
                        )));
                    }
                    DimGrid::coarse(lo, hi, step)
                }
            },
        };
        // `threads` is a hint, not semantics: clamp wire requests to the
        // host's core count so the product (connections × batch fan-out ×
        // per-request workers) cannot multiply into thread exhaustion.
        let cores = crate::sweep::runner::default_threads().max(1);
        let threads = opt_positive(v, "threads")?.unwrap_or(cores).min(cores);
        let spec = SweepSpec {
            grid,
            template: parse_config(v.get("template"), ArrayConfig::new(1, 1))?,
            weights: parse_weights(v)?,
            threads,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural checks shared by the JSON and the programmatic path.
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.grid.is_empty() {
            return Err(ApiError::BadRequest("sweep grid is empty".into()));
        }
        self.template.validate().map_err(ApiError::Config)?;
        Ok(())
    }
}

/// Figure-2 heatmaps for one network (CLI: `camuy sweep`).
#[derive(Debug, Clone)]
pub struct SweepRequest {
    pub net: String,
    pub spec: SweepSpec,
}

impl SweepRequest {
    pub fn from_json(v: &Json) -> Result<SweepRequest, ApiError> {
        Ok(SweepRequest {
            net: req_str(v, "net")?,
            spec: SweepSpec::from_json(v)?,
        })
    }
}

/// Figure-3 NSGA-II Pareto fronts for one network (CLI: `camuy pareto`).
#[derive(Debug, Clone)]
pub struct ParetoRequest {
    pub net: String,
    pub spec: SweepSpec,
    pub params: Nsga2Params,
}

impl ParetoRequest {
    pub fn from_json(v: &Json) -> Result<ParetoRequest, ApiError> {
        let mut params = Nsga2Params::default();
        if let Some(seed) = opt_usize(v, "seed")? {
            params.seed = seed as u64;
        }
        if let Some(p) = opt_positive(v, "population")? {
            params.population = p;
        }
        if let Some(g) = opt_positive(v, "generations")? {
            params.generations = g;
        }
        check_nsga2(&params)?;
        Ok(ParetoRequest {
            net: req_str(v, "net")?,
            spec: SweepSpec::from_json(v)?,
            params,
        })
    }
}

/// The optimizer parameters must satisfy the NSGA-II preconditions before
/// the run starts (the core asserts them).
pub(crate) fn check_nsga2(params: &Nsga2Params) -> Result<(), ApiError> {
    params.check().map_err(ApiError::BadRequest)
}

/// Figure-6 equal-PE aspect-ratio study (CLI: `camuy equal-pe`).
#[derive(Debug, Clone)]
pub struct EqualPeRequest {
    pub budgets: Vec<usize>,
    pub min_dim: usize,
    pub spec: SweepSpec,
}

impl EqualPeRequest {
    /// The paper's Figure-6 budgets — the default study everywhere (CLI
    /// fallback, `camuy figures`, and the serve API share this one list).
    pub const DEFAULT_BUDGETS: [usize; 3] = [4096, 16384, 65536];

    pub fn from_json(v: &Json) -> Result<EqualPeRequest, ApiError> {
        let budgets = match v.get("budgets") {
            None => Self::DEFAULT_BUDGETS.to_vec(),
            Some(j) => {
                let arr = j.as_arr().ok_or_else(|| {
                    ApiError::BadRequest("field 'budgets' must be an array".into())
                })?;
                let mut out = Vec::with_capacity(arr.len());
                for b in arr {
                    out.push(b.as_usize().filter(|&b| b > 0).ok_or_else(|| {
                        ApiError::BadRequest("budgets must be positive integers".into())
                    })?);
                }
                out
            }
        };
        let req = EqualPeRequest {
            budgets,
            min_dim: opt_positive(v, "min_dim")?.unwrap_or(8),
            spec: SweepSpec::from_json(v)?,
        };
        req.validate()?;
        Ok(req)
    }

    /// Most PEs one equal-PE budget may ask for — 256x the TPUv1's 65536,
    /// and small enough that every factorized geometry stays within the
    /// closed form's exact u64 range.
    pub const MAX_PE_BUDGET: usize = 1 << 24;

    /// Most budget entries per request — each one is a full nine-model
    /// study, so the list length bounds the request's total compute.
    pub const MAX_BUDGETS: usize = 16;

    /// The factorization enumeration asserts power-of-two budgets; check
    /// here so a request can never trip an assert (or demand unbounded
    /// geometry or unbounded repetition).
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.budgets.is_empty() {
            return Err(ApiError::BadRequest("budgets must be non-empty".into()));
        }
        if self.budgets.len() > Self::MAX_BUDGETS {
            return Err(ApiError::BadRequest(format!(
                "{} budgets requested; the limit is {}",
                self.budgets.len(),
                Self::MAX_BUDGETS
            )));
        }
        if !self.min_dim.is_power_of_two() {
            return Err(ApiError::BadRequest(format!(
                "min_dim must be a power of two, got {}",
                self.min_dim
            )));
        }
        if self.min_dim > 1 << 12 {
            return Err(ApiError::BadRequest(format!(
                "min_dim {} exceeds the limit {}",
                self.min_dim,
                1 << 12
            )));
        }
        for &b in &self.budgets {
            if !b.is_power_of_two() {
                return Err(ApiError::BadRequest(format!(
                    "PE budget must be a power of two, got {b}"
                )));
            }
            if b > Self::MAX_PE_BUDGET {
                return Err(ApiError::BadRequest(format!(
                    "PE budget {b} exceeds the limit {}",
                    Self::MAX_PE_BUDGET
                )));
            }
            if b < self.min_dim * self.min_dim {
                return Err(ApiError::BadRequest(format!(
                    "PE budget {b} is smaller than min_dim^2 = {}",
                    self.min_dim * self.min_dim
                )));
            }
        }
        Ok(())
    }
}

/// Per-layer UB working sets, spills and DRAM overhead (CLI: `camuy memory`).
#[derive(Debug, Clone)]
pub struct MemoryRequest {
    pub net: String,
    pub batch: Option<usize>,
    pub config: ArrayConfig,
    pub weights: EnergyWeights,
    /// Also run the graph-aware tensor-liveness pass (true peak residency
    /// instead of the linear-chain estimate) and attach it to the response.
    pub graph: bool,
}

impl MemoryRequest {
    pub fn from_json(v: &Json) -> Result<MemoryRequest, ApiError> {
        Ok(MemoryRequest {
            net: req_str(v, "net")?,
            batch: opt_positive(v, "batch")?,
            config: parse_config(v.get("config"), ArrayConfig::new(128, 128))?,
            weights: parse_weights(v)?,
            graph: v.get("graph").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

/// Graph-connectivity analysis of one network: DAG statistics, tensor
/// liveness with liveness-corrected energy, and the branch-parallel
/// multi-array schedule (CLI: `camuy graph`).
#[derive(Debug, Clone)]
pub struct GraphRequest {
    pub net: String,
    /// Re-batch every layer; `None` keeps the registered batch.
    pub batch: Option<usize>,
    /// Bank size for the branch-parallel schedule (1 = the serialized
    /// baseline).
    pub arrays: usize,
    pub config: ArrayConfig,
    pub weights: EnergyWeights,
}

impl GraphRequest {
    pub fn new(net: impl Into<String>, config: ArrayConfig) -> GraphRequest {
        GraphRequest {
            net: net.into(),
            batch: None,
            arrays: 1,
            config,
            weights: EnergyWeights::paper(),
        }
    }

    pub fn from_json(v: &Json) -> Result<GraphRequest, ApiError> {
        let arrays = opt_positive(v, "arrays")?.unwrap_or(1);
        check_arrays(arrays)?;
        Ok(GraphRequest {
            net: req_str(v, "net")?,
            batch: opt_positive(v, "batch")?,
            arrays,
            config: parse_config(v.get("config"), ArrayConfig::new(128, 128))?,
            weights: parse_weights(v)?,
        })
    }
}

/// Run a network through the event-driven simulator (DESIGN.md §13) and
/// return the Perfetto trace document (CLI: `camuy emulate --trace`).
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub net: String,
    /// Re-batch every layer; `None` keeps the registered batch.
    pub batch: Option<usize>,
    pub config: ArrayConfig,
    /// Attach per-layer rows (timeline placement, FIFO depth, events).
    pub per_layer: bool,
    /// Per-layer trace-slice budget; layers past it mark the response
    /// truncated instead of growing the document without bound.
    pub max_slices: usize,
}

impl TraceRequest {
    /// Default per-layer slice budget — enough for every zoo network's
    /// full tiling schedule while keeping the document in the tens of MB.
    pub const DEFAULT_SLICES: usize = 1 << 16;

    /// Most slices per layer a request may ask for.
    pub const MAX_SLICES: usize = 1 << 20;

    pub fn new(net: impl Into<String>, config: ArrayConfig) -> TraceRequest {
        TraceRequest {
            net: net.into(),
            batch: None,
            config,
            per_layer: false,
            max_slices: Self::DEFAULT_SLICES,
        }
    }

    pub fn from_json(v: &Json) -> Result<TraceRequest, ApiError> {
        let max_slices = opt_positive(v, "max_slices")?.unwrap_or(Self::DEFAULT_SLICES);
        if max_slices > Self::MAX_SLICES {
            return Err(ApiError::BadRequest(format!(
                "max_slices {max_slices} exceeds the limit {}",
                Self::MAX_SLICES
            )));
        }
        Ok(TraceRequest {
            net: req_str(v, "net")?,
            batch: opt_positive(v, "batch")?,
            config: parse_config(v.get("config"), ArrayConfig::new(128, 128))?,
            per_layer: v.get("per_layer").and_then(Json::as_bool).unwrap_or(false),
            max_slices,
        })
    }
}

/// Register a user network from a layer-list JSON document.
#[derive(Debug, Clone)]
pub struct RegisterRequest {
    /// The network document (see DESIGN.md §8 for the schema); parsed and
    /// validated by the engine at registration time.
    pub spec: Json,
}

impl RegisterRequest {
    pub fn from_json(v: &Json) -> Result<RegisterRequest, ApiError> {
        let spec = v.get("network").cloned().ok_or_else(|| {
            ApiError::BadRequest("register needs a 'network' object".into())
        })?;
        Ok(RegisterRequest { spec })
    }
}

/// Poll the process-wide telemetry registry (DESIGN.md §14): request
/// counts and latency quantiles per type, eval/plan-cache stats, pool
/// health. CLI adapters: `camuy stats` and `{"type":"stats"}` through
/// `camuy serve`.
#[derive(Debug, Clone, Copy, Default)]
pub struct StatsRequest {
    /// Attach the raw sparse bucket array to every histogram in the
    /// response (off by default — quantiles usually suffice).
    pub buckets: bool,
}

impl StatsRequest {
    pub fn from_json(v: &Json) -> Result<StatsRequest, ApiError> {
        let buckets = match v.get("buckets") {
            None => false,
            Some(b) => b.as_bool().ok_or_else(|| {
                ApiError::BadRequest("field 'buckets' must be a boolean".into())
            })?,
        };
        Ok(StatsRequest { buckets })
    }
}

/// One decoded request.
#[derive(Debug, Clone)]
pub enum ApiRequest {
    Eval(EvalRequest),
    Sweep(SweepRequest),
    Pareto(ParetoRequest),
    EqualPe(EqualPeRequest),
    Memory(MemoryRequest),
    Graph(GraphRequest),
    Trace(TraceRequest),
    Register(RegisterRequest),
    /// List every known network (zoo + user store).
    Zoo,
    Stats(StatsRequest),
}

impl ApiRequest {
    /// Decode a parsed JSON document by its `"type"` discriminator.
    pub fn from_json(v: &Json) -> Result<ApiRequest, ApiError> {
        let kind = req_str(v, "type")?;
        match kind.as_str() {
            "eval" => EvalRequest::from_json(v).map(ApiRequest::Eval),
            "sweep" => SweepRequest::from_json(v).map(ApiRequest::Sweep),
            "pareto" => ParetoRequest::from_json(v).map(ApiRequest::Pareto),
            "equal_pe" | "equal-pe" => EqualPeRequest::from_json(v).map(ApiRequest::EqualPe),
            "memory" => MemoryRequest::from_json(v).map(ApiRequest::Memory),
            "graph" => GraphRequest::from_json(v).map(ApiRequest::Graph),
            "trace" => TraceRequest::from_json(v).map(ApiRequest::Trace),
            "register" => RegisterRequest::from_json(v).map(ApiRequest::Register),
            "zoo" | "networks" => Ok(ApiRequest::Zoo),
            "stats" => StatsRequest::from_json(v).map(ApiRequest::Stats),
            other => Err(ApiError::BadRequest(format!(
                "unknown request type '{other}' \
                 (eval|sweep|pareto|equal_pe|memory|graph|trace|register|zoo|stats)"
            ))),
        }
    }

    /// Decode one JSON-lines request. Returns the envelope metadata — the
    /// request's `"id"` (echoed back in the response) and its optional
    /// `"deadline_ms"` budget (DESIGN.md §15) — alongside the decode
    /// result; a line that is not JSON at all has no recoverable id.
    pub fn parse_line(line: &str) -> (LineMeta, Result<ApiRequest, ApiError>) {
        match Json::parse(line) {
            Err(e) => (LineMeta::default(), Err(ApiError::Json(e))),
            Ok(v) => {
                let id = v.get("id").cloned();
                match parse_deadline(&v) {
                    Err(e) => (LineMeta { id, deadline_ms: None }, Err(e)),
                    Ok(deadline_ms) => {
                        (LineMeta { id, deadline_ms }, ApiRequest::from_json(&v))
                    }
                }
            }
        }
    }
}

/// Envelope metadata common to every wire request, decoded before the
/// per-kind body: the echoed `"id"` and the optional `"deadline_ms"`
/// cancellation budget (DESIGN.md §15).
#[derive(Debug, Clone, Default)]
pub struct LineMeta {
    pub id: Option<Json>,
    pub deadline_ms: Option<u64>,
}

/// Ceiling for the wire `deadline_ms` field — far beyond any real request
/// budget, small enough that the deadline arithmetic can never overflow.
pub const MAX_DEADLINE_MS: u64 = 86_400_000; // one day

fn parse_deadline(v: &Json) -> Result<Option<u64>, ApiError> {
    match opt_positive(v, "deadline_ms")? {
        None => Ok(None),
        Some(ms) if ms as u64 > MAX_DEADLINE_MS => Err(ApiError::BadRequest(format!(
            "deadline_ms {ms} exceeds the limit {MAX_DEADLINE_MS}"
        ))),
        Some(ms) => Ok(Some(ms as u64)),
    }
}

// ---------------------------------------------------------------- helpers

fn req_str(v: &Json, key: &str) -> Result<String, ApiError> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| ApiError::BadRequest(format!("missing or invalid string field '{key}'")))
}

fn opt_usize(v: &Json, key: &str) -> Result<Option<usize>, ApiError> {
    v.opt_usize_field(key).map_err(ApiError::BadRequest)
}

fn opt_positive(v: &Json, key: &str) -> Result<Option<usize>, ApiError> {
    match opt_usize(v, key)? {
        Some(0) => Err(ApiError::BadRequest(format!(
            "field '{key}' must be positive"
        ))),
        other => Ok(other),
    }
}

fn req_positive(v: &Json, key: &str) -> Result<usize, ApiError> {
    opt_positive(v, key)?
        .ok_or_else(|| ApiError::BadRequest(format!("missing positive integer field '{key}'")))
}

/// Parse an optional configuration object, falling back to `default`, and
/// run the shared structural + geometry checks — violations surface typed.
fn parse_config(v: Option<&Json>, default: ArrayConfig) -> Result<ArrayConfig, ApiError> {
    let cfg = match v {
        None => default,
        Some(j) => ArrayConfig::from_json(j).map_err(ApiError::BadRequest)?,
    };
    check_config(&cfg)?;
    Ok(cfg)
}

/// The configuration checks every entry path shares: structural
/// invariants (typed [`crate::config::ConfigError`]) plus the
/// [`MAX_GEOMETRY`] cap, so `pe_count()` cannot overflow no matter
/// whether a config arrived over the wire, from the CLI, or from a
/// library caller.
pub(crate) fn check_config(cfg: &ArrayConfig) -> Result<(), ApiError> {
    cfg.validate().map_err(ApiError::Config)?;
    if cfg.height > MAX_GEOMETRY || cfg.width > MAX_GEOMETRY {
        return Err(ApiError::BadRequest(format!(
            "array geometry {}x{} exceeds the limit {MAX_GEOMETRY}",
            cfg.height, cfg.width
        )));
    }
    Ok(())
}

fn parse_weights(v: &Json) -> Result<EnergyWeights, ApiError> {
    match v.get("energy_model").and_then(Json::as_str) {
        None | Some("paper") => Ok(EnergyWeights::paper()),
        Some("dally14nm") => Ok(EnergyWeights::dally_14nm()),
        Some(other) => Err(ApiError::BadRequest(format!(
            "unknown energy model '{other}' (paper|dally14nm)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_request_parses_with_defaults() {
        let v = Json::parse(r#"{"type":"eval","net":"alexnet"}"#).unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Eval(r) => {
                assert_eq!(r.net, "alexnet");
                assert_eq!(r.batch, None);
                assert_eq!(r.arrays, 1);
                assert_eq!((r.config.height, r.config.width), (128, 128));
                assert!(!r.per_layer);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn eval_request_rejects_zero_geometry_typed() {
        let v = Json::parse(r#"{"type":"eval","net":"alexnet","config":{"height":0,"width":8}}"#)
            .unwrap();
        match ApiRequest::from_json(&v) {
            Err(ApiError::Config(crate::config::ConfigError::ZeroHeight)) => {}
            other => panic!("expected typed config error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_type_and_missing_fields_are_bad_requests() {
        for bad in [
            r#"{"type":"frobnicate"}"#,
            r#"{"net":"alexnet"}"#,
            r#"{"type":"eval"}"#,
            r#"{"type":"eval","net":"alexnet","batch":0}"#,
            r#"{"type":"register"}"#,
            r#"{"type":"sweep","net":"alexnet","grid":"bogus"}"#,
            r#"{"type":"equal_pe","budgets":[1000]}"#,
            r#"{"type":"pareto","net":"alexnet","population":3}"#,
            // resource-bound rejections: arrays, geometry, grid, threads,
            // optimizer size
            r#"{"type":"eval","net":"alexnet","arrays":1000000000000000000}"#,
            r#"{"type":"graph"}"#,
            r#"{"type":"graph","net":"alexnet","arrays":0}"#,
            r#"{"type":"graph","net":"alexnet","arrays":1000000000000000000}"#,
            r#"{"type":"eval","net":"alexnet","config":{"height":2000000,"width":8}}"#,
            r#"{"type":"sweep","net":"alexnet","grid":{"lo":1,"hi":4000000000,"step":1}}"#,
            r#"{"type":"sweep","net":"alexnet","grid":{"lo":1,"hi":1000000,"step":1}}"#,
            r#"{"type":"pareto","net":"alexnet","generations":1000000000000}"#,
            r#"{"type":"equal_pe","budgets":[4611686018427387904]}"#,
            r#"{"type":"equal_pe","budgets":[4096,4096,4096,4096,4096,4096,4096,4096,4096,4096,4096,4096,4096,4096,4096,4096,4096]}"#,
            r#"{"type":"equal_pe","budgets":[]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(
                matches!(ApiRequest::from_json(&v), Err(ApiError::BadRequest(_))),
                "not rejected as bad request: {bad}"
            );
        }
    }

    #[test]
    fn trace_request_parses_and_bounds_slices() {
        let v = Json::parse(r#"{"type":"trace","net":"alexnet","per_layer":true}"#).unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Trace(r) => {
                assert_eq!(r.net, "alexnet");
                assert_eq!(r.max_slices, TraceRequest::DEFAULT_SLICES);
                assert!(r.per_layer);
            }
            other => panic!("wrong request: {other:?}"),
        }
        for bad in [
            r#"{"type":"trace"}"#,
            r#"{"type":"trace","net":"alexnet","max_slices":0}"#,
            r#"{"type":"trace","net":"alexnet","max_slices":10000000}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(
                matches!(ApiRequest::from_json(&v), Err(ApiError::BadRequest(_))),
                "not rejected as bad request: {bad}"
            );
        }
    }

    #[test]
    fn graph_request_parses_with_defaults() {
        let v = Json::parse(r#"{"type":"graph","net":"resnet50","arrays":4}"#).unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Graph(r) => {
                assert_eq!(r.net, "resnet50");
                assert_eq!(r.arrays, 4);
                assert_eq!(r.batch, None);
                assert_eq!((r.config.height, r.config.width), (128, 128));
            }
            other => panic!("wrong request: {other:?}"),
        }
        let v = Json::parse(r#"{"type":"memory","net":"resnet50","graph":true}"#).unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Memory(r) => assert!(r.graph),
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn stats_request_parses_and_validates_buckets() {
        let v = Json::parse(r#"{"type":"stats"}"#).unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Stats(r) => assert!(!r.buckets),
            other => panic!("wrong request: {other:?}"),
        }
        let v = Json::parse(r#"{"type":"stats","buckets":true}"#).unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Stats(r) => assert!(r.buckets),
            other => panic!("wrong request: {other:?}"),
        }
        let v = Json::parse(r#"{"type":"stats","buckets":1}"#).unwrap();
        let err = ApiRequest::from_json(&v);
        assert!(matches!(err, Err(ApiError::BadRequest(_))));
    }

    #[test]
    fn sweep_spec_parses_custom_grid() {
        let v = Json::parse(
            r#"{"type":"sweep","net":"alexnet","grid":{"lo":8,"hi":24,"step":8},"threads":1}"#,
        )
        .unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Sweep(r) => {
                assert_eq!(r.spec.grid.heights, vec![8, 16, 24]);
                assert_eq!(r.spec.threads, 1);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn sweep_spec_parses_dense_grid() {
        let v = Json::parse(r#"{"type":"sweep","net":"alexnet","grid":"dense","threads":1}"#)
            .unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Sweep(r) => {
                assert_eq!(r.spec.grid.heights.len(), 241);
                assert_eq!(r.spec.grid.heights[0], 16);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn wire_threads_clamp_to_host_cores() {
        let v = Json::parse(r#"{"type":"sweep","net":"alexnet","threads":1000000}"#).unwrap();
        match ApiRequest::from_json(&v).unwrap() {
            ApiRequest::Sweep(r) => {
                assert!(r.spec.threads <= crate::sweep::runner::default_threads().max(1));
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn parse_line_recovers_id() {
        let (meta, req) = ApiRequest::parse_line(r#"{"id":42,"type":"zoo"}"#);
        assert_eq!(meta.id.unwrap().as_usize(), Some(42));
        assert_eq!(meta.deadline_ms, None);
        assert!(matches!(req, Ok(ApiRequest::Zoo)));
        let (meta, req) = ApiRequest::parse_line("not json");
        assert!(meta.id.is_none());
        assert!(matches!(req, Err(ApiError::Json(_))));
    }

    #[test]
    fn parse_line_decodes_the_deadline_budget() {
        let (meta, req) =
            ApiRequest::parse_line(r#"{"id":7,"type":"eval","net":"alexnet","deadline_ms":250}"#);
        assert_eq!(meta.deadline_ms, Some(250));
        assert!(req.is_ok());
        // Invalid budgets reject the whole request but keep the id so the
        // error envelope routes back to the right client call.
        for bad in [
            r#"{"id":7,"type":"zoo","deadline_ms":0}"#,
            r#"{"id":7,"type":"zoo","deadline_ms":-3}"#,
            r#"{"id":7,"type":"zoo","deadline_ms":"fast"}"#,
            r#"{"id":7,"type":"zoo","deadline_ms":99999999999}"#,
        ] {
            let (meta, req) = ApiRequest::parse_line(bad);
            assert_eq!(meta.id.clone().unwrap().as_usize(), Some(7), "{bad}");
            assert!(matches!(req, Err(ApiError::BadRequest(_))), "{bad}");
        }
    }
}
