//! Deterministic fault injection at named sites (DESIGN.md §15).
//!
//! Compute cores and the serve tier call [`hit`] at the places failures
//! matter: `"serve.dispatch"`, `"register.inner"`, `"eval.inner"`,
//! `"sweep.unit"`, `"graph.schedule"`, `"nsga2.generation"`,
//! `"sim.layer"`, `"snapshot.write"`, plus the connection lifecycle of
//! the TCP front ends (DESIGN.md §16): `"serve.accept"` after a
//! connection is accepted, `"conn.read"`/`"conn.write"` on the event
//! loop's socket-service paths (where a `cancel` action aborts exactly
//! that connection — the deterministic stand-in for a vanished client).
//! A disarmed site costs one relaxed atomic load — the production path
//! pays nothing measurable.
//!
//! Tests arm sites programmatically ([`arm`]); CI and ad-hoc runs arm
//! them through the environment:
//!
//! ```text
//! CAMUY_FAULTPOINTS="sweep.unit=delay:2*100000,nsga2.generation=panic"
//! ```
//!
//! Comma-separated `site=action` entries, where an action is `panic`,
//! `delay:MS`, or `cancel`, optionally suffixed `*N` for a fire budget
//! (default 1 — the point disarms after firing N times). `panic` unwinds
//! with a plain string payload, so the serve tier's panic isolation
//! answers `internal`; `cancel` fires the ambient
//! [`CancelToken`](crate::robust::CancelToken) and checkpoints, so the
//! deadline path answers `deadline_exceeded`; `delay` sleeps, turning a
//! fast request into a slow one without changing its result — the
//! hardware-independent way to test deadlines against "slow" work.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// What an armed faultpoint does when execution reaches it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Unwind with a string payload (exercises panic isolation).
    Panic,
    /// Sleep this long, then continue (makes fast work slow).
    Delay(Duration),
    /// Cancel the ambient [`CancelToken`](crate::robust::CancelToken)
    /// and checkpoint (exercises the deadline path). A no-op beyond the
    /// checkpoint when no token is installed.
    Cancel,
}

#[derive(Debug)]
struct Armed {
    site: String,
    action: Action,
    remaining: usize,
    fired: usize,
}

/// Sites currently armed with a nonzero fire budget. [`hit`]'s fast path
/// is a single relaxed load of this.
static ARMED: AtomicUsize = AtomicUsize::new(0);

fn table() -> &'static Mutex<Vec<Armed>> {
    static TABLE: OnceLock<Mutex<Vec<Armed>>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let armed = match std::env::var("CAMUY_FAULTPOINTS") {
            Ok(spec) => match parse_spec(&spec) {
                Ok(entries) => entries,
                Err(e) => {
                    log::warn!("faultpoint: ignoring CAMUY_FAULTPOINTS: {e}");
                    Vec::new()
                }
            },
            Err(_) => Vec::new(),
        };
        ARMED.store(armed.len(), Ordering::SeqCst);
        Mutex::new(armed)
    })
}

fn parse_spec(spec: &str) -> Result<Vec<Armed>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (site, rest) = entry
            .split_once('=')
            .ok_or_else(|| format!("'{entry}' is not site=action"))?;
        let (action, count) = match rest.rsplit_once('*') {
            Some((a, n)) => {
                let n: usize =
                    n.parse().map_err(|_| format!("'{entry}': bad fire count '{n}'"))?;
                (a, n)
            }
            None => (rest, 1),
        };
        let action = if action == "panic" {
            Action::Panic
        } else if action == "cancel" {
            Action::Cancel
        } else if let Some(ms) = action.strip_prefix("delay:") {
            let ms: u64 = ms.parse().map_err(|_| format!("'{entry}': bad delay '{ms}'"))?;
            Action::Delay(Duration::from_millis(ms))
        } else {
            return Err(format!("'{entry}': unknown action '{action}' (panic|delay:MS|cancel)"));
        };
        if count == 0 {
            return Err(format!("'{entry}': fire count must be positive"));
        }
        out.push(Armed {
            site: site.to_string(),
            action,
            remaining: count,
            fired: 0,
        });
    }
    Ok(out)
}

/// The injection point: a no-op unless `site` is armed, in which case the
/// armed action fires (outside the table lock, so an injected panic can
/// never poison the harness itself) and its budget decrements.
#[inline]
pub fn hit(site: &str) {
    let t = table(); // first call applies CAMUY_FAULTPOINTS
    if ARMED.load(Ordering::Relaxed) == 0 {
        return;
    }
    let action = {
        let mut armed = t.lock().unwrap_or_else(|e| e.into_inner());
        let Some(entry) = armed.iter_mut().find(|a| a.site == site && a.remaining > 0) else {
            return;
        };
        entry.remaining -= 1;
        entry.fired += 1;
        if entry.remaining == 0 {
            ARMED.fetch_sub(1, Ordering::SeqCst);
        }
        entry.action
    };
    log::info!("faultpoint '{site}': injecting {action:?}");
    match action {
        Action::Panic => panic!("faultpoint '{site}': injected panic"),
        Action::Delay(d) => std::thread::sleep(d),
        Action::Cancel => {
            if let Some(t) = crate::robust::current() {
                t.cancel();
            }
            crate::robust::checkpoint();
        }
    }
}

/// Arm `site` to run `action` the next `count` times [`hit`] reaches it.
/// Stacks with (rather than replaces) an existing arming of the same
/// site; the oldest entry with budget fires first.
pub fn arm(site: &str, action: Action, count: usize) {
    if count == 0 {
        return;
    }
    let mut armed = table().lock().unwrap_or_else(|e| e.into_inner());
    armed.push(Armed {
        site: site.to_string(),
        action,
        remaining: count,
        fired: 0,
    });
    ARMED.fetch_add(1, Ordering::SeqCst);
}

/// Disarm every site and forget fire counts.
pub fn disarm_all() {
    let mut armed = table().lock().unwrap_or_else(|e| e.into_inner());
    armed.clear();
    ARMED.store(0, Ordering::SeqCst);
}

/// How many times `site` has fired since the last [`disarm_all`] (summed
/// across stacked armings). Test observability.
pub fn fired(site: &str) -> usize {
    let armed = table().lock().unwrap_or_else(|e| e.into_inner());
    armed.iter().filter(|a| a.site == site).map(|a| a.fired).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// The table is process-global; tests that arm sites serialize here
    /// so parallel test threads cannot see each other's armings.
    static TABLE_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disarmed_sites_are_no_ops() {
        let _g = lock();
        disarm_all();
        hit("nonexistent.site"); // must not panic or sleep
    }

    #[test]
    fn panic_fires_exactly_count_times_then_disarms() {
        let _g = lock();
        disarm_all();
        arm("t.panic", Action::Panic, 2);
        for i in 0..2 {
            let r = catch_unwind(AssertUnwindSafe(|| hit("t.panic")));
            assert!(r.is_err(), "fire {i} must panic");
        }
        hit("t.panic"); // budget exhausted: no-op
        assert_eq!(fired("t.panic"), 2);
        disarm_all();
    }

    #[test]
    fn cancel_fires_the_ambient_token() {
        let _g = lock();
        disarm_all();
        arm("t.cancel", Action::Cancel, 1);
        let token = crate::robust::CancelToken::manual();
        let r = catch_unwind(AssertUnwindSafe(|| {
            crate::robust::with_token(&token, || hit("t.cancel"))
        }));
        let payload = r.expect_err("cancel must unwind through the checkpoint");
        assert!(payload.downcast_ref::<crate::robust::Cancelled>().is_some());
        assert!(token.fired());
        disarm_all();
    }

    #[test]
    fn spec_parsing_round_trips_every_action() {
        let entries =
            parse_spec("a=panic, b=delay:250*3 ,c=cancel*2").expect("valid spec");
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].action, Action::Panic);
        assert_eq!(entries[0].remaining, 1);
        assert_eq!(entries[1].action, Action::Delay(Duration::from_millis(250)));
        assert_eq!(entries[1].remaining, 3);
        assert_eq!(entries[2].action, Action::Cancel);
        assert_eq!(entries[2].remaining, 2);
        assert!(parse_spec("a").is_err());
        assert!(parse_spec("a=explode").is_err());
        assert!(parse_spec("a=delay:xx").is_err());
        assert!(parse_spec("a=panic*0").is_err());
        assert!(parse_spec("").unwrap().is_empty());
    }
}
