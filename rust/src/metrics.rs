//! Metric types shared by the analytic model (`model/`) and the functional
//! emulator (`arch/`). Both produce the exact same counter set; property
//! tests assert bit-exact equality between the two (DESIGN.md §7).
//!
//! Both [`MovementCounters`] and [`Metrics`] form a commutative monoid
//! under `+` with `Default` as identity, and support scalar scaling by a
//! `u64` multiplicity (`m * 3 == m + m + m`, exactly — all fields are
//! integer counters). Every aggregation in the crate — layers over groups,
//! networks over layers, workloads over shape multiplicities — is expressed
//! through this algebra instead of field-by-field summation (DESIGN.md §2).

use crate::config::EnergyWeights;
use crate::util::json::Json;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Mul, MulAssign};

/// Every class of data movement the emulator distinguishes. All values are
/// *access counts* (one word moved = one count); bitwidths convert these to
/// bytes only in bandwidth reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MovementCounters {
    /// Unified Buffer reads serving activation streaming (SDS fetches).
    pub ub_act_reads: u64,
    /// Unified Buffer reads serving weight-tile fetches.
    pub ub_weight_reads: u64,
    /// Unified Buffer writes of final output activations.
    pub ub_out_writes: u64,
    /// Activation register reads from the left neighbour (horizontal hops).
    pub inter_pe_act: u64,
    /// Partial-sum register reads from the upper neighbour (vertical hops).
    pub inter_pe_psum: u64,
    /// Weight shift-down hops during (double-buffered) tile loads.
    pub inter_pe_weight: u64,
    /// Register accesses inside a PE (MAC operand reads/writes, weight
    /// register writes including the shadow copy).
    pub intra_pe: u64,
    /// Partial sums leaving the bottom PE row into the accumulator array.
    pub aa_writes: u64,
    /// Accumulator reads when draining a finished chunk back to the UB.
    pub aa_reads: u64,
}

impl MovementCounters {
    /// Total Unified Buffer traffic, `M_UB` in the paper's Equation 1.
    pub fn m_ub(&self) -> u64 {
        self.ub_act_reads + self.ub_weight_reads + self.ub_out_writes
    }

    /// Total inter-PE traffic, `M_INTER_PE`.
    pub fn m_inter_pe(&self) -> u64 {
        self.inter_pe_act + self.inter_pe_psum + self.inter_pe_weight
    }

    /// Total accumulator-array traffic, `M_AA`.
    pub fn m_aa(&self) -> u64 {
        self.aa_writes + self.aa_reads
    }

    /// `M_INTRA_PE`.
    pub fn m_intra_pe(&self) -> u64 {
        self.intra_pe
    }

    /// The paper's Equation 1:
    /// `E = 6·M_UB + 2·(M_INTER_PE + M_AA) + M_INTRA_PE`
    /// with the weights taken from `w` so technology ablations can rescale.
    pub fn energy(&self, w: &EnergyWeights) -> f64 {
        w.unified_buffer * self.m_ub() as f64
            + w.inter_pe * self.m_inter_pe() as f64
            + w.accumulator * self.m_aa() as f64
            + w.intra_pe * self.m_intra_pe() as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ub_act_reads", Json::num(self.ub_act_reads as f64)),
            ("ub_weight_reads", Json::num(self.ub_weight_reads as f64)),
            ("ub_out_writes", Json::num(self.ub_out_writes as f64)),
            ("inter_pe_act", Json::num(self.inter_pe_act as f64)),
            ("inter_pe_psum", Json::num(self.inter_pe_psum as f64)),
            ("inter_pe_weight", Json::num(self.inter_pe_weight as f64)),
            ("intra_pe", Json::num(self.intra_pe as f64)),
            ("aa_writes", Json::num(self.aa_writes as f64)),
            ("aa_reads", Json::num(self.aa_reads as f64)),
        ])
    }
}

impl Add for MovementCounters {
    type Output = MovementCounters;
    fn add(self, rhs: MovementCounters) -> MovementCounters {
        MovementCounters {
            ub_act_reads: self.ub_act_reads + rhs.ub_act_reads,
            ub_weight_reads: self.ub_weight_reads + rhs.ub_weight_reads,
            ub_out_writes: self.ub_out_writes + rhs.ub_out_writes,
            inter_pe_act: self.inter_pe_act + rhs.inter_pe_act,
            inter_pe_psum: self.inter_pe_psum + rhs.inter_pe_psum,
            inter_pe_weight: self.inter_pe_weight + rhs.inter_pe_weight,
            intra_pe: self.intra_pe + rhs.intra_pe,
            aa_writes: self.aa_writes + rhs.aa_writes,
            aa_reads: self.aa_reads + rhs.aa_reads,
        }
    }
}

impl AddAssign for MovementCounters {
    fn add_assign(&mut self, rhs: MovementCounters) {
        *self = *self + rhs;
    }
}

impl Mul<u64> for MovementCounters {
    type Output = MovementCounters;
    fn mul(self, s: u64) -> MovementCounters {
        MovementCounters {
            ub_act_reads: self.ub_act_reads * s,
            ub_weight_reads: self.ub_weight_reads * s,
            ub_out_writes: self.ub_out_writes * s,
            inter_pe_act: self.inter_pe_act * s,
            inter_pe_psum: self.inter_pe_psum * s,
            inter_pe_weight: self.inter_pe_weight * s,
            intra_pe: self.intra_pe * s,
            aa_writes: self.aa_writes * s,
            aa_reads: self.aa_reads * s,
        }
    }
}

impl MulAssign<u64> for MovementCounters {
    fn mul_assign(&mut self, s: u64) {
        *self = *self * s;
    }
}

impl Sum for MovementCounters {
    fn sum<I: Iterator<Item = MovementCounters>>(iter: I) -> MovementCounters {
        iter.fold(MovementCounters::default(), |a, b| a + b)
    }
}

/// Complete metric record for one workload (a GEMM, a layer, or a whole
/// network) on one array configuration.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Metrics {
    /// Total cycles including fill/drain, exposed weight loads and stalls.
    pub cycles: u64,
    /// Cycles lost waiting for weight loads the double buffer couldn't hide.
    pub stall_cycles: u64,
    /// Useful multiply-accumulate operations performed.
    pub macs: u64,
    /// Number of tile passes executed.
    pub passes: u64,
    /// Movement counters.
    pub movements: MovementCounters,
}

impl Metrics {
    /// PE utilization: useful MAC-cycles over available PE-cycles.
    pub fn utilization(&self, pe_count: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / (pe_count as f64 * self.cycles as f64)
    }

    /// Equation 1 energy under the given weights.
    pub fn energy(&self, w: &EnergyWeights) -> f64 {
        self.movements.energy(w)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cycles", Json::num(self.cycles as f64)),
            ("stall_cycles", Json::num(self.stall_cycles as f64)),
            ("macs", Json::num(self.macs as f64)),
            ("passes", Json::num(self.passes as f64)),
            ("movements", self.movements.to_json()),
        ])
    }
}

impl Add for Metrics {
    type Output = Metrics;
    fn add(self, rhs: Metrics) -> Metrics {
        Metrics {
            cycles: self.cycles + rhs.cycles,
            stall_cycles: self.stall_cycles + rhs.stall_cycles,
            macs: self.macs + rhs.macs,
            passes: self.passes + rhs.passes,
            movements: self.movements + rhs.movements,
        }
    }
}

impl AddAssign for Metrics {
    fn add_assign(&mut self, rhs: Metrics) {
        *self = *self + rhs;
    }
}

/// Scalar scaling by a multiplicity: `k` identical GEMMs run back-to-back
/// cost exactly `one * k` (cycles serialize, counters add — the identity
/// the workload IR's deduplicated evaluation relies on).
impl Mul<u64> for Metrics {
    type Output = Metrics;
    fn mul(self, s: u64) -> Metrics {
        Metrics {
            cycles: self.cycles * s,
            stall_cycles: self.stall_cycles * s,
            macs: self.macs * s,
            passes: self.passes * s,
            movements: self.movements * s,
        }
    }
}

impl MulAssign<u64> for Metrics {
    fn mul_assign(&mut self, s: u64) {
        *self = *self * s;
    }
}

impl Sum for Metrics {
    fn sum<I: Iterator<Item = Metrics>>(iter: I) -> Metrics {
        iter.fold(Metrics::default(), |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MovementCounters {
        MovementCounters {
            ub_act_reads: 10,
            ub_weight_reads: 20,
            ub_out_writes: 30,
            inter_pe_act: 1,
            inter_pe_psum: 2,
            inter_pe_weight: 3,
            intra_pe: 100,
            aa_writes: 5,
            aa_reads: 7,
        }
    }

    #[test]
    fn aggregates() {
        let c = sample();
        assert_eq!(c.m_ub(), 60);
        assert_eq!(c.m_inter_pe(), 6);
        assert_eq!(c.m_aa(), 12);
        assert_eq!(c.m_intra_pe(), 100);
    }

    #[test]
    fn equation_1() {
        let c = sample();
        let e = c.energy(&EnergyWeights::paper());
        // 6*60 + 2*(6 + 12) + 100 = 360 + 36 + 100
        assert_eq!(e, 496.0);
    }

    #[test]
    fn counters_add() {
        let c = sample() + sample();
        assert_eq!(c.m_ub(), 120);
        assert_eq!(c.intra_pe, 200);
    }

    #[test]
    fn utilization_bounds() {
        let m = Metrics {
            cycles: 100,
            macs: 1600,
            ..Default::default()
        };
        // 16 PEs * 100 cycles = 1600 PE-cycles, fully used.
        assert_eq!(m.utilization(16), 1.0);
        assert_eq!(Metrics::default().utilization(16), 0.0);
    }

    #[test]
    fn metrics_add() {
        let a = Metrics {
            cycles: 10,
            stall_cycles: 1,
            macs: 100,
            passes: 2,
            movements: sample(),
        };
        let s = a + a;
        assert_eq!(s.cycles, 20);
        assert_eq!(s.passes, 4);
        assert_eq!(s.movements.aa_reads, 14);
    }

    #[test]
    fn scalar_scaling_equals_repeated_addition() {
        let m = Metrics {
            cycles: 10,
            stall_cycles: 1,
            macs: 100,
            passes: 2,
            movements: sample(),
        };
        let mut by_add = Metrics::default();
        for _ in 0..5 {
            by_add += m;
        }
        assert_eq!(m * 5, by_add);
        assert_eq!(m * 1, m);
        assert_eq!(m * 0, Metrics::default());
        let mut assigned = m;
        assigned *= 5;
        assert_eq!(assigned, by_add);
    }

    #[test]
    fn scaling_distributes_over_addition() {
        let a = Metrics {
            cycles: 3,
            stall_cycles: 0,
            macs: 7,
            passes: 1,
            movements: sample(),
        };
        let b = Metrics {
            cycles: 11,
            stall_cycles: 2,
            macs: 13,
            passes: 4,
            movements: sample() + sample(),
        };
        assert_eq!((a + b) * 6, a * 6 + b * 6);
        assert_eq!(a * (4 * 5), (a * 4) * 5);
    }

    #[test]
    fn sum_collects_iterators() {
        let a = Metrics {
            cycles: 2,
            macs: 4,
            ..Default::default()
        };
        let total: Metrics = [a, a, a].into_iter().sum();
        assert_eq!(total, a * 3);
        let counters: MovementCounters = [sample(), sample()].into_iter().sum();
        assert_eq!(counters, sample() * 2);
        assert_eq!(Vec::<Metrics>::new().into_iter().sum::<Metrics>(), Metrics::default());
    }
}
