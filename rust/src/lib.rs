//! # CAMUY — Configurable Accelerator Modeling for Understanding and Analysis
//!
//! A reproduction of *"On the Difficulty of Designing Processor Arrays for
//! Deep Neural Networks"* (Stehle, Schindler, Fröning, 2020): a lightweight
//! model of a weight-stationary systolic array for fast design-space
//! exploration of array dimensions against deep neural network workloads.
//!
//! The crate provides:
//!
//! * [`arch`] — a functional, cycle-level emulator of the array (computes
//!   real GEMMs, counts every data movement);
//! * [`model`] — the closed-form analytic model the sweeps run on,
//!   property-tested to agree with the emulator exactly;
//! * [`nets`] — the CNN model zoo of the paper's evaluation;
//! * [`sweep`], [`pareto`] — the design-space exploration engine and the
//!   multi-objective (NSGA-II) optimizer behind Figures 2–6;
//! * [`runtime`], [`coordinator`] — the PJRT bridge that executes the
//!   AOT-compiled JAX/Pallas artifacts and cross-checks the emulator;
//! * [`report`] — heatmaps, tables and figure regeneration.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod arch;
pub mod baseline;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod model;
pub mod nets;
pub mod pareto;
pub mod report;
pub mod runtime;
pub mod sweep;
pub mod tensor;
pub mod util;
