//! Pareto dominance primitives: dominance tests, exhaustive front
//! extraction (exact on the 961-point paper grid), fast non-dominated
//! sorting and crowding distance (Deb et al. 2002) for NSGA-II.

/// `a` dominates `b` iff a <= b in every objective and a < b in at least
/// one (all objectives minimized).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the exact Pareto front (non-dominated points). O(n²·d).
pub fn pareto_front_indices<T: AsRef<[f64]>>(points: &[T]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && dominates(q.as_ref(), p.as_ref()) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Fast non-dominated sort: returns fronts of indices, best first.
pub fn fast_non_dominated_sort<T: AsRef<[f64]>>(points: &[T]) -> Vec<Vec<usize>> {
    let n = points.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // S_p
    let mut dom_count = vec![0usize; n]; // n_p
    let mut fronts: Vec<Vec<usize>> = vec![Vec::new()];

    for p in 0..n {
        for q in 0..n {
            if p == q {
                continue;
            }
            if dominates(points[p].as_ref(), points[q].as_ref()) {
                dominated_by[p].push(q);
            } else if dominates(points[q].as_ref(), points[p].as_ref()) {
                dom_count[p] += 1;
            }
        }
        if dom_count[p] == 0 {
            fronts[0].push(p);
        }
    }

    let mut i = 0;
    while !fronts[i].is_empty() {
        let mut next = Vec::new();
        for &p in &fronts[i] {
            for &q in &dominated_by[p] {
                dom_count[q] -= 1;
                if dom_count[q] == 0 {
                    next.push(q);
                }
            }
        }
        i += 1;
        fronts.push(next);
    }
    fronts.pop(); // drop the trailing empty front
    fronts
}

/// Crowding distances of the given front members (Deb et al. 2002):
/// boundary points get infinity; interior points the normalized cuboid
/// perimeter contribution.
pub fn crowding_distance<T: AsRef<[f64]>>(points: &[T], front: &[usize]) -> Vec<f64> {
    let m = if front.is_empty() { 0 } else { points[front[0]].as_ref().len() };
    let mut dist = vec![0.0f64; front.len()];
    if front.len() <= 2 {
        for d in &mut dist {
            *d = f64::INFINITY;
        }
        return dist;
    }
    for obj in 0..m {
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&a, &b| {
            points[front[a]].as_ref()[obj]
                .partial_cmp(&points[front[b]].as_ref()[obj])
                .unwrap()
        });
        let lo = points[front[order[0]]].as_ref()[obj];
        let hi = points[front[*order.last().unwrap()]].as_ref()[obj];
        dist[order[0]] = f64::INFINITY;
        dist[*order.last().unwrap()] = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for w in order.windows(3) {
            let (prev, cur, next) = (w[0], w[1], w[2]);
            dist[cur] +=
                (points[front[next]].as_ref()[obj] - points[front[prev]].as_ref()[obj]) / span;
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal
    }

    #[test]
    fn exhaustive_front() {
        let pts = vec![
            vec![1.0, 4.0], // front
            vec![2.0, 2.0], // front
            vec![4.0, 1.0], // front
            vec![3.0, 3.0], // dominated by (2,2)
            vec![2.0, 2.0], // duplicate of front point (kept: not dominated)
        ];
        let f = pareto_front_indices(&pts);
        assert_eq!(f, vec![0, 1, 2, 4]);
    }

    #[test]
    fn nds_fronts_are_ordered() {
        let pts = vec![
            vec![1.0, 1.0], // front 0 (dominates everything)
            vec![2.0, 2.0], // front 1
            vec![3.0, 3.0], // front 3 (dominated by (2,3) too)
            vec![2.0, 3.0], // front 2 (dominated by (2,2), dominates (3,3))
        ];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts[0], vec![0]);
        assert_eq!(fronts[1], vec![1]);
        assert_eq!(fronts[2], vec![3]);
        assert_eq!(fronts[3], vec![2]);
    }

    #[test]
    fn nds_front0_equals_exhaustive() {
        // Random-ish cloud: front 0 of NDS must equal the exhaustive front.
        let mut pts = Vec::new();
        let mut x = 7u64;
        for _ in 0..200 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = ((x >> 33) % 1000) as f64;
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let b = ((x >> 33) % 1000) as f64;
            pts.push(vec![a, b]);
        }
        let mut f0 = fast_non_dominated_sort(&pts)[0].clone();
        f0.sort_unstable();
        let mut ex = pareto_front_indices(&pts);
        ex.sort_unstable();
        assert_eq!(f0, ex);
    }

    #[test]
    fn crowding_boundaries_infinite() {
        let pts = vec![
            vec![0.0, 3.0],
            vec![1.0, 2.0],
            vec![2.0, 1.0],
            vec![3.0, 0.0],
        ];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&pts, &front);
        assert_eq!(d[0], f64::INFINITY);
        assert_eq!(d[3], f64::INFINITY);
        assert!(d[1].is_finite() && d[1] > 0.0);
        // Interior symmetric points have equal crowding.
        assert!((d[1] - d[2]).abs() < 1e-12);
    }

    #[test]
    fn crowding_tiny_fronts_all_infinite() {
        let pts = vec![vec![1.0, 2.0], vec![2.0, 1.0]];
        let d = crowding_distance(&pts, &[0, 1]);
        assert!(d.iter().all(|x| x.is_infinite()));
    }
}
