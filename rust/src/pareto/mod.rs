//! Multi-objective optimization: dominance primitives, exact front
//! extraction, and NSGA-II (the algorithm the paper uses for Figures 3/5).

pub mod dominance;
pub mod nsga2;

pub use dominance::{crowding_distance, dominates, fast_non_dominated_sort, pareto_front_indices};
pub use nsga2::{nsga2, nsga2_par, nsga2_workload, Nsga2Params, Solution, WorkloadObjective};
