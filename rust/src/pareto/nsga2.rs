//! NSGA-II (Deb, Pratap, Agarwal, Meyarivan, IEEE TEC 2002) over discrete
//! (height, width) grids — the multi-objective optimizer the paper uses to
//! compute its Pareto sets (Figures 3 and 5).
//!
//! Genomes are index pairs into the grid axes; variation uses uniform
//! coordinate crossover and step/reset mutation (the integer-lattice
//! analogue of SBX + polynomial mutation). Because the paper's space is
//! only 961 points, the exhaustive front is computable and the tests
//! require NSGA-II to recover it exactly.

use crate::config::{ArrayConfig, EnergyWeights};
use crate::model::workload::{EvalCache, Workload};
use crate::pareto::dominance::{crowding_distance, fast_non_dominated_sort};
use crate::sweep::grid::DimGrid;
use crate::sweep::plan::{SegmentedOsPlan, SegmentedWsPlan};
use crate::util::prng::Rng;

/// NSGA-II parameters.
#[derive(Debug, Clone)]
pub struct Nsga2Params {
    pub population: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    pub seed: u64,
}

impl Default for Nsga2Params {
    fn default() -> Self {
        Nsga2Params {
            population: 120,
            generations: 80,
            crossover_prob: 0.9,
            mutation_prob: 0.25,
            seed: 0xCA_0001,
        }
    }
}

impl Nsga2Params {
    /// Ceilings for wire-supplied parameters: far above any useful setting
    /// on a 961-point space, small enough that one request cannot demand
    /// unbounded compute.
    pub const MAX_POPULATION: usize = 8192;
    pub const MAX_GENERATIONS: usize = 16384;

    /// The preconditions [`nsga2`] asserts — plus the resource ceilings —
    /// as a checkable result. The API engine validates request parameters
    /// with this so a malformed request can never trip an assert (or pin a
    /// serve worker indefinitely).
    pub fn check(&self) -> Result<(), String> {
        if self.population < 4 || self.population % 2 != 0 {
            return Err(format!(
                "population must be an even number >= 4, got {}",
                self.population
            ));
        }
        if self.population > Self::MAX_POPULATION {
            return Err(format!(
                "population {} exceeds the limit {}",
                self.population,
                Self::MAX_POPULATION
            ));
        }
        if self.generations == 0 {
            return Err("generations must be positive".to_string());
        }
        if self.generations > Self::MAX_GENERATIONS {
            return Err(format!(
                "generations {} exceeds the limit {}",
                self.generations,
                Self::MAX_GENERATIONS
            ));
        }
        Ok(())
    }
}

/// A returned non-dominated solution.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub height: usize,
    pub width: usize,
    pub objectives: Vec<f64>,
}

#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct Genome {
    hi: usize,
    wi: usize,
}

/// How a generation's batch of distinct unseen genomes is evaluated:
/// serially through a stateful closure, or fanned out over the
/// process-wide pool ([`crate::runtime::pool`]) for pure evaluators.
/// Results are identical either way (parallel results are collected in
/// submission order), so the two modes are interchangeable per run.
enum GenomeEval<'a> {
    Serial(&'a mut dyn FnMut(usize, usize) -> Vec<f64>),
    Parallel {
        f: &'a (dyn Fn(usize, usize) -> Vec<f64> + Sync),
        threads: usize,
    },
}

impl GenomeEval<'_> {
    /// Evaluate `(height, width)` points, preserving order.
    fn eval_batch(&mut self, points: &[(usize, usize)]) -> Vec<Vec<f64>> {
        match self {
            GenomeEval::Serial(f) => points.iter().map(|&(h, w)| f(h, w)).collect(),
            GenomeEval::Parallel { f, threads } => {
                let func: &(dyn Fn(usize, usize) -> Vec<f64> + Sync) = *f;
                crate::runtime::pool::parallel_map(points.len(), *threads, |i| {
                    func(points[i].0, points[i].1)
                })
            }
        }
    }
}

/// The memoized objective store: each distinct genome is evaluated once
/// per run, generations reference stored vectors by index. A whole
/// population's unseen genomes are batched through one
/// [`GenomeEval::eval_batch`] call (first-appearance order, so the serial
/// mode calls the closure in exactly the pre-§11 order).
struct ObjectiveStore {
    store: Vec<Vec<f64>>,
    index: std::collections::HashMap<Genome, usize>,
}

impl ObjectiveStore {
    fn new() -> ObjectiveStore {
        ObjectiveStore {
            store: Vec::new(),
            index: std::collections::HashMap::new(),
        }
    }

    /// Evaluate every unseen genome of `genomes` in one batch, then
    /// return each genome's store index, aligned with the input.
    fn indices(&mut self, genomes: &[Genome], grid: &DimGrid, eval: &mut GenomeEval) -> Vec<usize> {
        let mut fresh: Vec<Genome> = Vec::new();
        for &g in genomes {
            if !self.index.contains_key(&g) {
                // Reserve the slot now so duplicates within the batch
                // stay distinct-once; the objectives land below.
                self.index.insert(g, self.store.len());
                self.store.push(Vec::new());
                fresh.push(g);
            }
        }
        if !fresh.is_empty() {
            let points: Vec<(usize, usize)> = fresh
                .iter()
                .map(|g| (grid.heights[g.hi], grid.widths[g.wi]))
                .collect();
            let objs = eval.eval_batch(&points);
            for (g, o) in fresh.iter().zip(objs) {
                self.store[self.index[g]] = o;
            }
        }
        genomes.iter().map(|g| self.index[g]).collect()
    }

    fn objs(&self, idx: &[usize]) -> Vec<&[f64]> {
        idx.iter().map(|&i| self.store[i].as_slice()).collect()
    }
}

/// Run NSGA-II minimizing `eval(height, width) -> objectives`.
pub fn nsga2(
    grid: &DimGrid,
    params: &Nsga2Params,
    mut eval: impl FnMut(usize, usize) -> Vec<f64>,
) -> Vec<Solution> {
    nsga2_core(grid, params, GenomeEval::Serial(&mut eval))
}

/// [`nsga2`] with each generation's distinct unseen genomes probed in
/// parallel over the shared pool (DESIGN.md §11). Requires a pure
/// (`Fn + Sync`) evaluator; returns exactly what [`nsga2`] would — the
/// genome sequence is driven by the seeded RNG alone, and objective
/// values are order-independent.
pub fn nsga2_par(
    grid: &DimGrid,
    params: &Nsga2Params,
    threads: usize,
    eval: impl Fn(usize, usize) -> Vec<f64> + Sync,
) -> Vec<Solution> {
    nsga2_core(grid, params, GenomeEval::Parallel { f: &eval, threads })
}

fn nsga2_core(grid: &DimGrid, params: &Nsga2Params, mut eval: GenomeEval) -> Vec<Solution> {
    assert!(!grid.is_empty());
    assert!(params.population >= 4 && params.population % 2 == 0);
    let mut rng = Rng::new(params.seed);
    let hmax = grid.heights.len() - 1;
    let wmax = grid.widths.len() - 1;

    // Objective store + cache: the expensive evaluation runs once per
    // distinct genome across the whole run, and generations reference the
    // stored vectors instead of cloning them (§Perf iteration 2). Each
    // generation's unseen genomes go through one batched probe, which the
    // parallel mode fans out over the pool (§Perf iteration 4).
    let mut store = ObjectiveStore::new();

    // --- initial population ---
    let mut pop: Vec<Genome> = (0..params.population)
        .map(|_| Genome {
            hi: rng.range_usize(0, hmax),
            wi: rng.range_usize(0, wmax),
        })
        .collect();

    // Rank and crowding of the current population. Computed once here and
    // then carried over from each environmental-selection sort (Deb's
    // original formulation — §Perf iteration 3 removed a redundant
    // per-generation re-sort).
    let (mut rank, mut crowd) = {
        let idx = store.indices(&pop, grid, &mut eval);
        let objs = store.objs(&idx);
        rank_and_crowd(&objs)
    };

    for _gen in 0..params.generations {
        // Cancellation granularity is one generation; the faultpoint lets
        // tests inject a panic mid-search (DESIGN.md §15).
        crate::robust::checkpoint();
        crate::faultpoint::hit("nsga2.generation");
        let tournament = |rng: &mut Rng| -> usize {
            let a = rng.range_usize(0, pop.len() - 1);
            let b = rng.range_usize(0, pop.len() - 1);
            if rank[a] < rank[b] || (rank[a] == rank[b] && crowd[a] > crowd[b]) {
                a
            } else {
                b
            }
        };

        // --- offspring ---
        let mut offspring = Vec::with_capacity(params.population);
        while offspring.len() < params.population {
            let p1 = pop[tournament(&mut rng)];
            let p2 = pop[tournament(&mut rng)];
            let (mut c1, mut c2) = if rng.chance(params.crossover_prob) {
                // Uniform coordinate crossover.
                if rng.chance(0.5) {
                    (Genome { hi: p1.hi, wi: p2.wi }, Genome { hi: p2.hi, wi: p1.wi })
                } else {
                    (p1, p2)
                }
            } else {
                (p1, p2)
            };
            for c in [&mut c1, &mut c2] {
                if rng.chance(params.mutation_prob) {
                    mutate(c, hmax, wmax, &mut rng);
                }
            }
            offspring.push(c1);
            offspring.push(c2);
        }

        // --- environmental selection over parents + offspring ---
        // One batched probe evaluates the generation's distinct unseen
        // genomes (parents are always already memoized).
        let mut union = pop.clone();
        union.extend_from_slice(&offspring);
        let union_idx = store.indices(&union, grid, &mut eval);
        let union_objs = store.objs(&union_idx);
        let fronts = fast_non_dominated_sort(&union_objs);
        let mut next: Vec<Genome> = Vec::with_capacity(params.population);
        let mut next_rank: Vec<usize> = Vec::with_capacity(params.population);
        let mut next_crowd: Vec<f64> = Vec::with_capacity(params.population);
        for (r, front) in fronts.iter().enumerate() {
            let d = crowding_distance(&union_objs, front);
            if next.len() + front.len() <= params.population {
                for (&i, &di) in front.iter().zip(&d) {
                    next.push(union[i]);
                    next_rank.push(r);
                    next_crowd.push(di);
                }
            } else {
                // Fill by descending crowding distance.
                let mut order: Vec<usize> = (0..front.len()).collect();
                order.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap());
                for &oi in &order {
                    if next.len() == params.population {
                        break;
                    }
                    next.push(union[front[oi]]);
                    next_rank.push(r);
                    next_crowd.push(d[oi]);
                }
            }
            if next.len() == params.population {
                break;
            }
        }
        pop = next;
        rank = next_rank;
        crowd = next_crowd;
    }

    // --- extract the final non-dominated set, deduplicated ---
    let mut seen = std::collections::HashSet::new();
    let uniq: Vec<Genome> = pop.into_iter().filter(|g| seen.insert(*g)).collect();
    let idx = store.indices(&uniq, grid, &mut eval);
    let objs = store.objs(&idx);
    let front0 = &fast_non_dominated_sort(&objs)[0];
    let mut out: Vec<Solution> = front0
        .iter()
        .map(|&i| Solution {
            height: grid.heights[uniq[i].hi],
            width: grid.widths[uniq[i].wi],
            objectives: objs[i].to_vec(),
        })
        .collect();
    out.sort_by(|a, b| {
        a.objectives[0]
            .partial_cmp(&b.objectives[0])
            .unwrap()
            .then(a.height.cmp(&b.height))
    });
    out
}

/// Objective pairs selectable for workload-driven runs, both minimized:
/// Figure 3's (E, cycles) and (1 − utilization, cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadObjective {
    EnergyCycles,
    InverseUtilizationCycles,
}

/// Run NSGA-II directly over a [`Workload`]: each genome's configuration
/// is evaluated through the shared [`EvalCache`], so per-(shape, config)
/// metrics are computed once across all generations — and across *runs*
/// when callers reuse the cache for several objective pairs on the same
/// workload (as Figure 3 does). Each generation's distinct unseen
/// genomes are probed in one parallel batch over `threads` executors
/// (`threads = 1` is exactly the serial run — the probe is pure, so the
/// returned solutions are identical either way).
#[allow(clippy::too_many_arguments)]
pub fn nsga2_workload(
    grid: &DimGrid,
    params: &Nsga2Params,
    workload: &Workload,
    template: &ArrayConfig,
    weights: &EnergyWeights,
    cache: &EvalCache,
    objective: WorkloadObjective,
    threads: usize,
) -> Vec<Solution> {
    nsga2_par(grid, params, threads, |h, w| {
        let mut cfg = template.clone();
        cfg.height = h;
        cfg.width = w;
        let m = workload.eval_cached(&cfg, cache);
        match objective {
            WorkloadObjective::EnergyCycles => vec![m.energy(weights), m.cycles as f64],
            WorkloadObjective::InverseUtilizationCycles => {
                vec![1.0 - m.utilization(cfg.pe_count()), m.cycles as f64]
            }
        }
    })
}

/// [`nsga2_workload`] with genome evaluation routed through a
/// [`SegmentedWsPlan`] (DESIGN.md §10): when the template runs the WS
/// dataflow on the plan's accumulator capacity, a genome probe is two
/// binary searches on the plan axes plus the SoA cell combine — no
/// divisions, no per-class loop, and no memo-table locking. Anything the
/// plan cannot cover (non-WS templates, off-axis probes) falls back to the
/// direct closed form, which is byte-identical by construction, so the
/// returned solutions always match [`nsga2_workload`] exactly. Generation
/// batches fan out over `threads` executors as in [`nsga2_workload`].
#[allow(clippy::too_many_arguments)]
pub fn nsga2_workload_planned(
    grid: &DimGrid,
    params: &Nsga2Params,
    workload: &Workload,
    template: &ArrayConfig,
    weights: &EnergyWeights,
    plan: &SegmentedWsPlan,
    objective: WorkloadObjective,
    threads: usize,
) -> Vec<Solution> {
    let planned = template.dataflow == crate::config::Dataflow::WeightStationary
        && template.acc_capacity == plan.acc_capacity();
    nsga2_par(grid, params, threads, |h, w| {
        let mut cfg = template.clone();
        cfg.height = h;
        cfg.width = w;
        let m = if planned {
            plan.probe(h, w).unwrap_or_else(|| workload.eval(&cfg))
        } else {
            workload.eval(&cfg)
        };
        match objective {
            WorkloadObjective::EnergyCycles => vec![m.energy(weights), m.cycles as f64],
            WorkloadObjective::InverseUtilizationCycles => {
                vec![1.0 - m.utilization(cfg.pe_count()), m.cycles as f64]
            }
        }
    })
}

/// [`nsga2_workload_planned`] for output-stationary templates: genome
/// probes route through a [`SegmentedOsPlan`] (DESIGN.md §11) — two
/// binary searches plus the two-dot-product cell combine. Non-OS
/// templates and off-axis probes fall back to the direct closed form,
/// byte-identical by construction, so the returned solutions always
/// match [`nsga2_workload`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn nsga2_workload_planned_os(
    grid: &DimGrid,
    params: &Nsga2Params,
    workload: &Workload,
    template: &ArrayConfig,
    weights: &EnergyWeights,
    plan: &SegmentedOsPlan,
    objective: WorkloadObjective,
    threads: usize,
) -> Vec<Solution> {
    let planned = template.dataflow == crate::config::Dataflow::OutputStationary;
    nsga2_par(grid, params, threads, |h, w| {
        let mut cfg = template.clone();
        cfg.height = h;
        cfg.width = w;
        let m = if planned {
            plan.probe(h, w).unwrap_or_else(|| workload.eval(&cfg))
        } else {
            workload.eval(&cfg)
        };
        match objective {
            WorkloadObjective::EnergyCycles => vec![m.energy(weights), m.cycles as f64],
            WorkloadObjective::InverseUtilizationCycles => {
                vec![1.0 - m.utilization(cfg.pe_count()), m.cycles as f64]
            }
        }
    })
}

/// Rank + crowding of a whole point set (used once, for generation 0).
fn rank_and_crowd(objs: &[&[f64]]) -> (Vec<usize>, Vec<f64>) {
    let fronts = fast_non_dominated_sort(objs);
    let mut rank = vec![0usize; objs.len()];
    let mut crowd = vec![0.0f64; objs.len()];
    for (r, front) in fronts.iter().enumerate() {
        let d = crowding_distance(objs, front);
        for (&i, &di) in front.iter().zip(&d) {
            rank[i] = r;
            crowd[i] = di;
        }
    }
    (rank, crowd)
}

fn mutate(g: &mut Genome, hmax: usize, wmax: usize, rng: &mut Rng) {
    // Half the time take a +-1 lattice step; otherwise reset a coordinate.
    if rng.chance(0.5) {
        let step = |v: usize, max: usize, rng: &mut Rng| -> usize {
            if max == 0 {
                return 0;
            }
            if v == 0 {
                v + 1
            } else if v == max {
                v - 1
            } else if rng.chance(0.5) {
                v + 1
            } else {
                v - 1
            }
        };
        if rng.chance(0.5) {
            g.hi = step(g.hi, hmax, rng);
        } else {
            g.wi = step(g.wi, wmax, rng);
        }
    } else if rng.chance(0.5) {
        g.hi = rng.range_usize(0, hmax);
    } else {
        g.wi = rng.range_usize(0, wmax);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::dominance::pareto_front_indices;

    /// A synthetic bi-objective landscape with a known trade-off:
    /// f1 = h + w (cost grows with size), f2 = 1/h + 1/w (quality needs
    /// size). The true front is the whole diagonal family.
    fn toy_eval(h: usize, w: usize) -> Vec<f64> {
        vec![(h + w) as f64, 1.0 / h as f64 + 1.0 / w as f64]
    }

    fn exhaustive_front(grid: &DimGrid) -> Vec<(usize, usize)> {
        let pairs = grid.pairs();
        let objs: Vec<Vec<f64>> = pairs.iter().map(|&(h, w)| toy_eval(h, w)).collect();
        let mut front: Vec<(usize, usize)> = pareto_front_indices(&objs)
            .into_iter()
            .map(|i| pairs[i])
            .collect();
        front.sort_unstable();
        front.dedup();
        front
    }

    #[test]
    fn recovers_exhaustive_front_on_toy_landscape() {
        let grid = DimGrid::coarse(16, 128, 16);
        let sols = nsga2(&grid, &Nsga2Params::default(), toy_eval);
        let mut got: Vec<(usize, usize)> = sols.iter().map(|s| (s.height, s.width)).collect();
        got.sort_unstable();
        got.dedup();
        let want = exhaustive_front(&grid);
        // Every returned solution must be truly non-dominated...
        for g in &got {
            assert!(want.contains(g), "{g:?} is not on the true front");
        }
        // ...and coverage must be substantial (the toy front is small).
        assert!(
            got.len() * 2 >= want.len(),
            "found {} of {} front points",
            got.len(),
            want.len()
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let grid = DimGrid::coarse(8, 64, 8);
        let a = nsga2(&grid, &Nsga2Params::default(), toy_eval);
        let b = nsga2(&grid, &Nsga2Params::default(), toy_eval);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_generation_probes_match_serial_exactly() {
        // The genome sequence is RNG-driven and the evaluator is pure, so
        // fanning each generation's batch over the pool must change
        // nothing — same fronts, same objective values, bit for bit.
        for grid in [DimGrid::coarse(16, 128, 16), DimGrid::coarse(8, 24, 8)] {
            let serial = nsga2(&grid, &Nsga2Params::default(), toy_eval);
            for threads in [1, 2, 8] {
                let parallel = nsga2_par(&grid, &Nsga2Params::default(), threads, toy_eval);
                assert_eq!(serial, parallel, "threads={threads} diverged");
            }
        }
    }

    #[test]
    fn single_objective_degenerates_to_min() {
        let grid = DimGrid::coarse(8, 64, 8);
        let sols = nsga2(&grid, &Nsga2Params::default(), |h, w| vec![(h * w) as f64]);
        assert_eq!(sols.len(), 1);
        assert_eq!((sols[0].height, sols[0].width), (8, 8));
    }

    #[test]
    fn solutions_sorted_by_first_objective() {
        let grid = DimGrid::coarse(16, 96, 16);
        let sols = nsga2(&grid, &Nsga2Params::default(), toy_eval);
        for w in sols.windows(2) {
            assert!(w[0].objectives[0] <= w[1].objectives[0]);
        }
    }

    #[test]
    fn workload_runs_share_the_eval_cache_across_objectives() {
        use crate::model::layer::{Layer, SpatialDims};
        use crate::model::network::Network;
        let net = Network::new(
            "n",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
            ],
        );
        let wl = Workload::of(&net);
        let grid = DimGrid::coarse(8, 32, 8);
        let template = ArrayConfig::new(1, 1);
        let weights = EnergyWeights::paper();
        let cache = EvalCache::new();
        let params = Nsga2Params {
            population: 16,
            generations: 10,
            ..Default::default()
        };
        // threads = 1: the serial path keeps the miss accounting below
        // exact (parallel probes may benignly double-compute a racing
        // miss).
        let energy_front = nsga2_workload(
            &grid,
            &params,
            &wl,
            &template,
            &weights,
            &cache,
            WorkloadObjective::EnergyCycles,
            1,
        );
        assert!(!energy_front.is_empty());
        // The cache can never hold more than shapes x grid points…
        let ceiling = (wl.distinct() * grid.len()) as u64;
        assert!(cache.len() as u64 <= ceiling);
        // …and a second objective over the same workload is served from the
        // shared memo table wherever the first run already visited (the
        // identical seed makes generation 0 a guaranteed overlap).
        let hits_before = cache.hits();
        let util_front = nsga2_workload(
            &grid,
            &params,
            &wl,
            &template,
            &weights,
            &cache,
            WorkloadObjective::InverseUtilizationCycles,
            1,
        );
        assert!(!util_front.is_empty());
        assert!(cache.hits() > hits_before);
        assert!(cache.misses() <= ceiling);
        // Objectives agree with a direct evaluation.
        for s in &energy_front {
            let mut cfg = template.clone();
            cfg.height = s.height;
            cfg.width = s.width;
            let m = wl.eval(&cfg);
            assert_eq!(s.objectives[0], m.energy(&weights));
            assert_eq!(s.objectives[1], m.cycles as f64);
        }
    }

    #[test]
    fn planned_genome_probes_match_the_cached_path() {
        use crate::model::layer::{Layer, SpatialDims};
        use crate::model::network::Network;
        let net = Network::new(
            "n",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(7), 32, 48, 3, 1, 1, 1),
            ],
        );
        let wl = Workload::of(&net);
        let grid = DimGrid::coarse(8, 40, 8);
        let template = ArrayConfig::new(1, 1).with_acc_capacity(256);
        let weights = EnergyWeights::paper();
        let params = Nsga2Params {
            population: 16,
            generations: 12,
            ..Default::default()
        };
        let plan =
            SegmentedWsPlan::new(&wl, &grid.heights, &grid.widths, template.acc_capacity);
        for objective in [
            WorkloadObjective::EnergyCycles,
            WorkloadObjective::InverseUtilizationCycles,
        ] {
            let cached = nsga2_workload(
                &grid,
                &params,
                &wl,
                &template,
                &weights,
                &EvalCache::new(),
                objective,
                2,
            );
            let planned = nsga2_workload_planned(
                &grid, &params, &wl, &template, &weights, &plan, objective, 2,
            );
            assert_eq!(cached, planned, "objective {objective:?} diverged");
        }
        // A plan for a different accumulator capacity falls back to the
        // direct closed form and still agrees exactly.
        let mismatched = SegmentedWsPlan::new(&wl, &grid.heights, &grid.widths, 4096);
        let via_fallback = nsga2_workload_planned(
            &grid,
            &params,
            &wl,
            &template,
            &weights,
            &mismatched,
            WorkloadObjective::EnergyCycles,
            2,
        );
        let cached = nsga2_workload(
            &grid,
            &params,
            &wl,
            &template,
            &weights,
            &EvalCache::new(),
            WorkloadObjective::EnergyCycles,
            1,
        );
        assert_eq!(via_fallback, cached);
    }

    #[test]
    fn os_planned_genome_probes_match_the_cached_path() {
        use crate::model::layer::{Layer, SpatialDims};
        use crate::model::network::Network;
        let net = Network::new(
            "n",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(7), 32, 48, 3, 1, 1, 1),
            ],
        );
        let wl = Workload::of(&net);
        let grid = DimGrid::coarse(8, 40, 8);
        let template = ArrayConfig::new(1, 1)
            .with_dataflow(crate::config::Dataflow::OutputStationary);
        let weights = EnergyWeights::paper();
        let params = Nsga2Params {
            population: 16,
            generations: 12,
            ..Default::default()
        };
        let plan = SegmentedOsPlan::new(&wl, &grid.heights, &grid.widths);
        for objective in [
            WorkloadObjective::EnergyCycles,
            WorkloadObjective::InverseUtilizationCycles,
        ] {
            let cached = nsga2_workload(
                &grid,
                &params,
                &wl,
                &template,
                &weights,
                &EvalCache::new(),
                objective,
                1,
            );
            let planned = nsga2_workload_planned_os(
                &grid, &params, &wl, &template, &weights, &plan, objective, 2,
            );
            assert_eq!(cached, planned, "objective {objective:?} diverged");
        }
    }

    #[test]
    #[should_panic]
    fn odd_population_rejected() {
        let grid = DimGrid::coarse(8, 16, 8);
        let params = Nsga2Params {
            population: 5,
            ..Default::default()
        };
        let _ = nsga2(&grid, &params, toy_eval);
    }

    #[test]
    fn check_mirrors_the_asserted_preconditions() {
        assert!(Nsga2Params::default().check().is_ok());
        for bad in [
            Nsga2Params { population: 5, ..Default::default() },
            Nsga2Params { population: 2, ..Default::default() },
            Nsga2Params { generations: 0, ..Default::default() },
            Nsga2Params { population: Nsga2Params::MAX_POPULATION + 2, ..Default::default() },
            Nsga2Params { generations: Nsga2Params::MAX_GENERATIONS + 1, ..Default::default() },
        ] {
            assert!(bad.check().is_err());
        }
    }
}
