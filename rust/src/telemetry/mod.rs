//! Engine-wide telemetry: the process-global metrics registry behind
//! `{"type":"stats"}` and `camuy stats` (DESIGN.md §14).
//!
//! Three primitives, all wait-free on the hot path:
//!
//! * [`Counter`] — a monotone count striped across 16 cache-line-padded
//!   cells, indexed by a per-thread stripe, so concurrent increments
//!   never contend on one line. Reads sum the stripes.
//! * [`Gauge`] — the same striping over a signed delta (queue depth,
//!   parked workers). Gauges are *not* gated on the enable flag: an
//!   inc/dec pair split across a mid-flight [`set_enabled`] toggle would
//!   skew the level forever.
//! * [`Histogram`](hist::Histogram) — log-bucketed latency distribution
//!   with exact-bound p50/p95/p99 (see [`hist`]).
//!
//! The registry mirrors the [`TraceSink`](crate::sim::trace::TraceSink)
//! zero-cost pattern: when disabled (`CAMUY_TELEMETRY=0` or
//! [`set_enabled`]`(false)`) every counter add and histogram record is
//! one relaxed boolean load, and [`Timer`] never reads the clock. The
//! api bench gates the enabled-path overhead at ≤3% on the memo-hot
//! serve path (`benches/api_engine.rs`).
//!
//! [`Telemetry::snapshot`] copies every metric into a plain
//! [`TelemetrySnapshot`]; `Engine::stats` attaches the engine-owned
//! sections (eval cache, plan cache, network stores) and the result
//! renders to JSON or to a Perfetto counter trace
//! ([`TelemetrySnapshot::perfetto_counters`]) that loads side by side
//! with simulator traces in ui.perfetto.dev.

pub mod hist;

pub use hist::{Histogram, HistogramSnapshot};

use crate::model::workload::EvalCacheStats;
use crate::sweep::plan::PlanCacheStats;
use crate::util::json::Json;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Stripes per counter/gauge. Power of two; one cache line each.
const STRIPES: usize = 16;

static ENABLED: AtomicBool = AtomicBool::new(true);
static ENV_INIT: OnceLock<()> = OnceLock::new();

/// Whether the registry is recording. One relaxed load — this is the
/// branch every hot-path hook pays when telemetry is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off at runtime (the bench harness measures both
/// sides of this switch). Gauges keep tracking either way.
pub fn set_enabled(on: bool) {
    init_from_env();
    ENABLED.store(on, Ordering::Relaxed);
}

/// Apply the `CAMUY_TELEMETRY=0` environment opt-out exactly once, so a
/// later explicit [`set_enabled`] can never be overwritten by it.
fn init_from_env() {
    ENV_INIT.get_or_init(|| {
        if std::env::var("CAMUY_TELEMETRY").is_ok_and(|v| v.trim() == "0") {
            ENABLED.store(false, Ordering::Relaxed);
        }
    });
}

/// This thread's stripe: assigned round-robin on first use, so threads
/// spread across the [`STRIPES`] cells instead of hashing to collisions.
fn stripe_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static STRIPE: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    STRIPE.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            s.set(v);
        }
        v & (STRIPES - 1)
    })
}

/// One stripe, padded to a cache line so neighbours never false-share.
#[repr(align(64))]
#[derive(Debug)]
struct PadU64(AtomicU64);

#[repr(align(64))]
#[derive(Debug)]
struct PadI64(AtomicI64);

/// A monotone counter striped across padded cells. `add` is one relaxed
/// `fetch_add` on this thread's stripe when enabled; `get` sums stripes.
#[derive(Debug)]
pub struct Counter {
    stripes: [PadU64; STRIPES],
}

impl Counter {
    fn new() -> Counter {
        Counter {
            stripes: std::array::from_fn(|_| PadU64(AtomicU64::new(0))),
        }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.stripes[stripe_index()].0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Point-in-time total. Monotone between calls on a quiet registry.
    pub fn get(&self) -> u64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// A signed level (queue depth, parked workers) with the same striping.
/// Never gated on [`enabled`]: see the module docs on inc/dec pairing.
#[derive(Debug)]
pub struct Gauge {
    stripes: [PadI64; STRIPES],
}

impl Gauge {
    fn new() -> Gauge {
        Gauge {
            stripes: std::array::from_fn(|_| PadI64(AtomicI64::new(0))),
        }
    }

    #[inline]
    pub fn add(&self, delta: i64) {
        self.stripes[stripe_index()].0.fetch_add(delta, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn dec(&self) {
        self.add(-1);
    }

    /// Point-in-time level (sum of stripes). A snapshot racing an inc on
    /// one stripe and its dec on another can transiently read -1 or +1
    /// off; snapshots clamp at zero for display.
    pub fn get(&self) -> i64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Number of wire request kinds ([`ReqKind::ALL`]).
const REQ_KINDS: usize = 10;

/// Every request kind the API answers, in wire-name order. One latency
/// histogram and one count/error counter pair per kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqKind {
    Eval,
    Sweep,
    Pareto,
    EqualPe,
    Memory,
    Graph,
    Trace,
    Register,
    Zoo,
    Stats,
}

impl ReqKind {
    pub const ALL: [ReqKind; REQ_KINDS] = [
        ReqKind::Eval,
        ReqKind::Sweep,
        ReqKind::Pareto,
        ReqKind::EqualPe,
        ReqKind::Memory,
        ReqKind::Graph,
        ReqKind::Trace,
        ReqKind::Register,
        ReqKind::Zoo,
        ReqKind::Stats,
    ];

    /// The wire `"type"` string for this kind.
    pub fn name(self) -> &'static str {
        match self {
            ReqKind::Eval => "eval",
            ReqKind::Sweep => "sweep",
            ReqKind::Pareto => "pareto",
            ReqKind::EqualPe => "equal_pe",
            ReqKind::Memory => "memory",
            ReqKind::Graph => "graph",
            ReqKind::Trace => "trace",
            ReqKind::Register => "register",
            ReqKind::Zoo => "zoo",
            ReqKind::Stats => "stats",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// The `ApiError::kind()` strings the wire-error counters track, plus a
/// catch-all. Keep in sync with [`crate::api::ApiError::kind`].
const ERROR_KINDS: [&str; 10] = [
    "unknown_network",
    "invalid_config",
    "bad_json",
    "bad_request",
    "invalid_network",
    "deadline_exceeded",
    "overloaded",
    "idle_timeout",
    "internal",
    "other",
];

/// The process-global registry. Obtain it with [`global`]; every field
/// is safe to hit from any thread without coordination.
#[derive(Debug)]
pub struct Telemetry {
    start: Instant,
    req_count: [Counter; REQ_KINDS],
    req_errors: [Counter; REQ_KINDS],
    req_latency: [Histogram; REQ_KINDS],
    /// Raw request bytes read off the serve wire (newline included).
    pub serve_bytes_in: Counter,
    /// Response bytes written back (newline included).
    pub serve_bytes_out: Counter,
    /// Batches flushed through the adaptive batcher.
    pub serve_batches: Counter,
    /// TCP connections accepted.
    pub serve_connections: Counter,
    /// TCP connections currently open (accepted and not yet closed).
    pub connections_active: Gauge,
    /// Connections closed by the slowloris idle timeout (DESIGN.md §16).
    pub connections_idle_closed: Counter,
    /// Connections torn down because the client vanished (broken pipe /
    /// reset), cancelling any in-flight batch.
    pub connections_aborted: Counter,
    /// Response bytes queued for clients that have not read them yet,
    /// summed across connections (event loop only; bounded per
    /// connection by the write cap).
    pub write_queue_bytes: Gauge,
    /// Requests per flushed batch.
    pub serve_batch_size: Histogram,
    wire_errors: [Counter; ERROR_KINDS.len()],
    /// Jobs submitted through the persistent pool (pooled path only —
    /// the serial fast path never queues).
    pub pool_jobs: Counter,
    /// Chunks claimed by executors (workers and submitting callers).
    pub pool_chunks: Counter,
    /// Jobs picked up by a worker off the shared queue.
    pub pool_steals: Counter,
    /// Jobs currently submitted and not yet complete.
    pub pool_queue_depth: Gauge,
    /// Workers currently blocked on the work condvar.
    pub pool_workers_parked: Gauge,
    /// Wall-clock per pooled job, submit to completion (nanoseconds).
    pub pool_job_latency: Histogram,
    /// Sweep cells evaluated through the segmented production cores.
    pub sweep_cells: Counter,
    /// Requests shed by admission control or the connection cap
    /// (answered `overloaded`, DESIGN.md §15).
    pub requests_shed: Counter,
    /// Requests cancelled by their own `deadline_ms`.
    pub deadline_exceeded: Counter,
    /// Request panics caught and isolated by the serve dispatch guard.
    pub panics_caught: Counter,
    /// Registered-network snapshots written (periodic + drain).
    pub snapshot_writes: Counter,
    /// Compute requests currently holding an admission permit.
    pub admission_depth: Gauge,
}

impl Telemetry {
    fn new() -> Telemetry {
        Telemetry {
            start: Instant::now(),
            req_count: std::array::from_fn(|_| Counter::new()),
            req_errors: std::array::from_fn(|_| Counter::new()),
            req_latency: std::array::from_fn(|_| Histogram::new()),
            serve_bytes_in: Counter::new(),
            serve_bytes_out: Counter::new(),
            serve_batches: Counter::new(),
            serve_connections: Counter::new(),
            connections_active: Gauge::new(),
            connections_idle_closed: Counter::new(),
            connections_aborted: Counter::new(),
            write_queue_bytes: Gauge::new(),
            serve_batch_size: Histogram::new(),
            wire_errors: std::array::from_fn(|_| Counter::new()),
            pool_jobs: Counter::new(),
            pool_chunks: Counter::new(),
            pool_steals: Counter::new(),
            pool_queue_depth: Gauge::new(),
            pool_workers_parked: Gauge::new(),
            pool_job_latency: Histogram::new(),
            sweep_cells: Counter::new(),
            requests_shed: Counter::new(),
            deadline_exceeded: Counter::new(),
            panics_caught: Counter::new(),
            snapshot_writes: Counter::new(),
            admission_depth: Gauge::new(),
        }
    }

    /// Count one answered request of `kind` and record its latency.
    #[inline]
    pub fn observe_request(&self, kind: ReqKind, latency: Duration) {
        let i = kind.index();
        self.req_count[i].add(1);
        self.req_latency[i].record(latency.as_nanos() as u64);
    }

    /// Count one failed request of `kind` (the request is still counted
    /// in `observe_request` — errors are a subset, not a disjoint set).
    pub fn record_request_error(&self, kind: ReqKind) {
        self.req_errors[kind.index()].add(1);
    }

    /// Count one wire-level error by its `ApiError::kind()` string.
    /// Unknown strings land in the `"other"` catch-all.
    pub fn record_error_kind(&self, kind: &str) {
        let known = ERROR_KINDS.iter().position(|&k| k == kind);
        self.wire_errors[known.unwrap_or(ERROR_KINDS.len() - 1)].add(1);
    }

    /// Time since the registry was first touched.
    pub fn uptime(&self) -> Duration {
        self.start.elapsed()
    }

    /// Copy every metric into a plain snapshot. The engine-owned
    /// sections (`eval_cache`, `plan_cache`, `networks`) stay `None`
    /// here; `Engine::stats` fills them.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let requests = ReqKind::ALL
            .iter()
            .map(|&k| RequestStats {
                kind: k.name(),
                count: self.req_count[k.index()].get(),
                errors: self.req_errors[k.index()].get(),
                latency: self.req_latency[k.index()].snapshot(),
            })
            .collect();
        let mut errors = Vec::new();
        for (k, c) in ERROR_KINDS.iter().zip(&self.wire_errors) {
            errors.push((*k, c.get()));
        }
        TelemetrySnapshot {
            enabled: enabled(),
            uptime: self.uptime(),
            requests,
            bytes_in: self.serve_bytes_in.get(),
            bytes_out: self.serve_bytes_out.get(),
            batches: self.serve_batches.get(),
            connections: self.serve_connections.get(),
            connections_active: self.connections_active.get().max(0),
            connections_idle_closed: self.connections_idle_closed.get(),
            connections_aborted: self.connections_aborted.get(),
            write_queue_bytes: self.write_queue_bytes.get().max(0),
            batch_size: self.serve_batch_size.snapshot(),
            errors,
            pool: PoolStats {
                workers: crate::runtime::pool::global().workers(),
                jobs: self.pool_jobs.get(),
                chunks: self.pool_chunks.get(),
                steals: self.pool_steals.get(),
                queue_depth: self.pool_queue_depth.get().max(0),
                workers_parked: self.pool_workers_parked.get().max(0),
                job_latency: self.pool_job_latency.snapshot(),
            },
            sweep_cells: self.sweep_cells.get(),
            robust: RobustStats {
                requests_shed: self.requests_shed.get(),
                deadline_exceeded: self.deadline_exceeded.get(),
                panics_caught: self.panics_caught.get(),
                snapshot_writes: self.snapshot_writes.get(),
                admission_depth: self.admission_depth.get().max(0),
            },
            eval_cache: None,
            plan_cache: None,
            networks: None,
        }
    }
}

/// The process-wide registry. First use applies the `CAMUY_TELEMETRY`
/// environment opt-out and starts the uptime clock.
pub fn global() -> &'static Telemetry {
    static REGISTRY: OnceLock<Telemetry> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        init_from_env();
        Telemetry::new()
    })
}

/// Times one hot-path interval. When telemetry is disabled at `start`,
/// the clock is never read — the whole timer is two branches.
#[derive(Debug)]
pub struct Timer(Option<Instant>);

impl Timer {
    #[inline]
    pub fn start() -> Timer {
        if enabled() {
            Timer(Some(Instant::now()))
        } else {
            Timer(None)
        }
    }

    /// Record the elapsed interval as one answered request of `kind`.
    #[inline]
    pub fn observe_request(self, kind: ReqKind) {
        if let Some(t0) = self.0 {
            global().observe_request(kind, t0.elapsed());
        }
    }

    /// Record the elapsed interval (nanoseconds) into `hist`.
    #[inline]
    pub fn observe_into(self, hist: &Histogram) {
        if let Some(t0) = self.0 {
            hist.record(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// One request kind's traffic in a snapshot.
#[derive(Debug, Clone)]
pub struct RequestStats {
    pub kind: &'static str,
    pub count: u64,
    pub errors: u64,
    pub latency: HistogramSnapshot,
}

/// Operational-hardening traffic in a snapshot (DESIGN.md §15): shed,
/// deadline-cancelled and panic-isolated requests, snapshot writes, and
/// the live admission-queue depth (clamped at zero for display).
#[derive(Debug, Clone, Copy, Default)]
pub struct RobustStats {
    pub requests_shed: u64,
    pub deadline_exceeded: u64,
    pub panics_caught: u64,
    pub snapshot_writes: u64,
    pub admission_depth: i64,
}

/// Pool health in a snapshot (gauges clamped at zero for display).
#[derive(Debug, Clone)]
pub struct PoolStats {
    pub workers: usize,
    pub jobs: u64,
    pub chunks: u64,
    pub steals: u64,
    pub queue_depth: i64,
    pub workers_parked: i64,
    pub job_latency: HistogramSnapshot,
}

/// A point-in-time copy of the whole registry, plus the engine-owned
/// sections `Engine::stats` attaches (`None` for a bare registry
/// snapshot). This is the payload of a `StatsResponse`.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub enabled: bool,
    pub uptime: Duration,
    /// One entry per [`ReqKind::ALL`] member, in that order.
    pub requests: Vec<RequestStats>,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub batches: u64,
    pub connections: u64,
    /// Open connections right now (clamped at zero for display).
    pub connections_active: i64,
    /// Connections closed by the slowloris idle timeout.
    pub connections_idle_closed: u64,
    /// Connections torn down mid-conversation (client vanished).
    pub connections_aborted: u64,
    /// Undelivered response bytes queued across connections (clamped).
    pub write_queue_bytes: i64,
    pub batch_size: HistogramSnapshot,
    /// Wire-level error counts, one per [`ApiError::kind`] string.
    ///
    /// [`ApiError::kind`]: crate::api::ApiError::kind
    pub errors: Vec<(&'static str, u64)>,
    pub pool: PoolStats,
    pub sweep_cells: u64,
    pub robust: RobustStats,
    pub eval_cache: Option<EvalCacheStats>,
    pub plan_cache: Option<PlanCacheStats>,
    /// (zoo, user-registered) network-store sizes.
    pub networks: Option<(usize, usize)>,
}

impl TelemetrySnapshot {
    /// Traffic for one request kind.
    pub fn request(&self, kind: ReqKind) -> &RequestStats {
        &self.requests[kind.index()]
    }

    /// Total answered requests across every kind.
    pub fn total_requests(&self) -> u64 {
        self.requests.iter().map(|r| r.count).sum()
    }

    /// Every kind's latency histogram merged into one process-wide
    /// request-latency distribution.
    pub fn request_latency(&self) -> HistogramSnapshot {
        let mut merged = HistogramSnapshot::default();
        for r in &self.requests {
            merged.merge(&r.latency);
        }
        merged
    }

    /// Render the snapshot as the stats JSON document (DESIGN.md §14).
    /// With `include_buckets`, every histogram carries its raw sparse
    /// bucket array.
    pub fn to_json(&self, include_buckets: bool) -> Json {
        let mut requests = Vec::new();
        for r in &self.requests {
            let fields = vec![
                ("count", Json::num(r.count as f64)),
                ("errors", Json::num(r.errors as f64)),
                ("latency", r.latency.to_json(include_buckets)),
            ];
            requests.push((r.kind, Json::obj(fields)));
        }
        let mut errors = Vec::new();
        for &(k, n) in &self.errors {
            errors.push((k, Json::num(n as f64)));
        }
        let serve = Json::obj(vec![
            ("bytes_in", Json::num(self.bytes_in as f64)),
            ("bytes_out", Json::num(self.bytes_out as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("connections", Json::num(self.connections as f64)),
            ("connections_active", Json::num(self.connections_active as f64)),
            (
                "connections_idle_closed",
                Json::num(self.connections_idle_closed as f64),
            ),
            ("connections_aborted", Json::num(self.connections_aborted as f64)),
            ("write_queue_bytes", Json::num(self.write_queue_bytes as f64)),
            ("batch_size", self.batch_size.to_json(include_buckets)),
            ("errors", Json::obj(errors)),
        ]);
        let pool = Json::obj(vec![
            ("workers", Json::num(self.pool.workers as f64)),
            ("jobs", Json::num(self.pool.jobs as f64)),
            ("chunks", Json::num(self.pool.chunks as f64)),
            ("steals", Json::num(self.pool.steals as f64)),
            ("queue_depth", Json::num(self.pool.queue_depth as f64)),
            ("workers_parked", Json::num(self.pool.workers_parked as f64)),
            ("job_latency", self.pool.job_latency.to_json(include_buckets)),
        ]);
        let sweep = Json::obj(vec![("cells_evaluated", Json::num(self.sweep_cells as f64))]);
        let robust = Json::obj(vec![
            ("requests_shed", Json::num(self.robust.requests_shed as f64)),
            ("deadline_exceeded", Json::num(self.robust.deadline_exceeded as f64)),
            ("panics_caught", Json::num(self.robust.panics_caught as f64)),
            ("snapshot_writes", Json::num(self.robust.snapshot_writes as f64)),
            ("admission_depth", Json::num(self.robust.admission_depth as f64)),
        ]);
        let mut pairs = vec![
            ("enabled", Json::Bool(self.enabled)),
            ("uptime_seconds", Json::num(self.uptime.as_secs_f64())),
            ("requests", Json::obj(requests)),
            ("request_latency", self.request_latency().to_json(include_buckets)),
            ("serve", serve),
            ("pool", pool),
            ("sweep", sweep),
            ("robust", robust),
        ];
        if let Some(ec) = &self.eval_cache {
            pairs.push(("eval_cache", eval_cache_json(ec)));
        }
        if let Some(pc) = &self.plan_cache {
            pairs.push(("plan_cache", plan_cache_json(pc)));
        }
        if let Some((zoo, user)) = self.networks {
            let fields = vec![("zoo", Json::num(zoo as f64)), ("user", Json::num(user as f64))];
            pairs.push(("networks", Json::obj(fields)));
        }
        Json::obj(pairs)
    }

    /// Export the snapshot as a Perfetto counter-track document, built
    /// by the same writer the event-driven simulator uses, so engine
    /// health loads side by side with hardware traces in
    /// ui.perfetto.dev.
    pub fn perfetto_counters(&self) -> Json {
        perfetto_counters_from_json(&self.to_json(false), self.uptime)
    }
}

fn eval_cache_json(s: &EvalCacheStats) -> Json {
    let mut shards = Vec::new();
    for sh in &s.shards {
        shards.push(Json::obj(vec![
            ("entries", Json::num(sh.entries as f64)),
            ("hits", Json::num(sh.hits as f64)),
            ("misses", Json::num(sh.misses as f64)),
            ("evictions", Json::num(sh.evictions as f64)),
            ("hit_rate", Json::num(sh.hit_rate())),
        ]));
    }
    Json::obj(vec![
        ("entries", Json::num(s.entries as f64)),
        ("capacity", Json::num(s.capacity as f64)),
        ("hits", Json::num(s.hits as f64)),
        ("misses", Json::num(s.misses as f64)),
        ("evictions", Json::num(s.evictions as f64)),
        ("hit_rate", Json::num(s.hit_rate())),
        ("shards", Json::arr(shards)),
    ])
}

fn plan_cache_json(s: &PlanCacheStats) -> Json {
    Json::obj(vec![
        ("entries", Json::num(s.entries as f64)),
        ("table_words", Json::num(s.table_words as f64)),
        ("hits", Json::num(s.hits as f64)),
        ("misses", Json::num(s.misses as f64)),
        ("hit_rate", Json::num(s.hit_rate())),
    ])
}

/// Flatten any stats JSON document into a Perfetto counter trace: one
/// `"C"` track per numeric leaf, named by its dotted path, sampled at
/// t=0 and t=uptime. Shared by the local snapshot export and `camuy
/// stats --connect --perfetto` (which only ever holds the remote JSON).
/// Arrays (raw histogram buckets, per-shard lists) are skipped — they
/// are distributions, not levels.
pub fn perfetto_counters_from_json(doc: &Json, uptime: Duration) -> Json {
    let mut samples: Vec<(String, f64)> = Vec::new();
    flatten_numeric(doc, "", &mut samples);
    crate::sim::trace::perfetto_counter_doc("camuy engine", uptime.as_micros() as u64, &samples)
}

fn flatten_numeric(v: &Json, path: &str, out: &mut Vec<(String, f64)>) {
    match v {
        Json::Obj(map) => {
            for (k, val) in map {
                let p = if path.is_empty() {
                    k.clone()
                } else {
                    format!("{path}.{k}")
                };
                flatten_numeric(val, &p, out);
            }
        }
        Json::Num(x) => out.push((path.to_string(), *x)),
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that read or toggle the process-global enable flag hold
    /// this lock so a concurrently running toggle test cannot drop
    /// their increments (the test harness runs tests in parallel).
    static FLAG_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn lock_flag() -> std::sync::MutexGuard<'static, ()> {
        FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn req_kind_table_is_consistent() {
        assert_eq!(ReqKind::ALL.len(), REQ_KINDS);
        for (i, k) in ReqKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "{} out of order", k.name());
        }
        let names: std::collections::HashSet<&str> =
            ReqKind::ALL.iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), REQ_KINDS, "duplicate wire names");
    }

    #[test]
    fn counters_sum_across_threads_without_losing_increments() {
        let _g = lock_flag();
        let c = Counter::new();
        set_enabled(true);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = &c;
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.add(1);
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn gauge_pairs_return_to_zero() {
        let g = Gauge::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let g = &g;
                s.spawn(move || {
                    for _ in 0..1_000 {
                        g.inc();
                        g.dec();
                    }
                });
            }
        });
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn snapshot_reflects_observed_requests() {
        let _g = lock_flag();
        let t = Telemetry::new();
        set_enabled(true);
        t.observe_request(ReqKind::Eval, Duration::from_micros(100));
        t.observe_request(ReqKind::Eval, Duration::from_micros(200));
        t.observe_request(ReqKind::Sweep, Duration::from_millis(5));
        t.record_request_error(ReqKind::Sweep);
        t.record_error_kind("bad_json");
        t.record_error_kind("no_such_kind");
        let s = t.snapshot();
        assert_eq!(s.request(ReqKind::Eval).count, 2);
        assert_eq!(s.request(ReqKind::Eval).errors, 0);
        assert_eq!(s.request(ReqKind::Sweep).count, 1);
        assert_eq!(s.request(ReqKind::Sweep).errors, 1);
        assert_eq!(s.total_requests(), 3);
        let merged = s.request_latency();
        assert_eq!(merged.count, 3);
        assert!(merged.quantile(0.99) >= 5_000_000);
        let errs: std::collections::BTreeMap<&str, u64> = s.errors.iter().copied().collect();
        assert_eq!(errs["bad_json"], 1);
        assert_eq!(errs["other"], 1);
    }

    #[test]
    fn stats_json_has_the_documented_shape() {
        let _g = lock_flag();
        let t = Telemetry::new();
        set_enabled(true);
        t.observe_request(ReqKind::Eval, Duration::from_micros(50));
        let mut snap = t.snapshot();
        snap.eval_cache = Some(EvalCacheStats::default());
        snap.plan_cache = Some(PlanCacheStats::default());
        snap.networks = Some((12, 0));
        let j = snap.to_json(false);
        let eval = j.get("requests").and_then(|r| r.get("eval")).unwrap();
        assert_eq!(eval.get("count").and_then(Json::as_f64), Some(1.0));
        assert!(eval.get("latency").and_then(|l| l.get("p99")).is_some());
        let merged = j.get("request_latency").unwrap();
        assert!(merged.get("p50").is_some());
        assert!(j.get("pool").and_then(|p| p.get("queue_depth")).is_some());
        let serve = j.get("serve").unwrap();
        assert!(serve.get("errors").is_some());
        for key in [
            "connections_active",
            "connections_idle_closed",
            "connections_aborted",
            "write_queue_bytes",
        ] {
            assert!(serve.get(key).and_then(Json::as_f64).is_some(), "serve.{key}");
        }
        let errs = serve.get("errors").unwrap();
        assert!(errs.get("idle_timeout").is_some(), "idle_timeout error kind");
        let robust = j.get("robust").unwrap();
        for key in [
            "requests_shed",
            "deadline_exceeded",
            "panics_caught",
            "snapshot_writes",
            "admission_depth",
        ] {
            assert!(robust.get(key).and_then(Json::as_f64).is_some(), "robust.{key}");
        }
        let ec = j.get("eval_cache").unwrap();
        assert!(ec.get("hit_rate").is_some());
        let pc = j.get("plan_cache").unwrap();
        assert!(pc.get("entries").is_some());
        let zoo = j.get("networks").and_then(|n| n.get("zoo"));
        assert_eq!(zoo.and_then(Json::as_f64), Some(12.0));
    }

    #[test]
    fn perfetto_export_tracks_every_numeric_leaf() {
        let _g = lock_flag();
        let t = Telemetry::new();
        set_enabled(true);
        t.observe_request(ReqKind::Eval, Duration::from_micros(50));
        let doc = t.snapshot().perfetto_counters();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let is_counter = |e: &Json| e.get("ph").and_then(Json::as_str) == Some("C");
        let name_of = |e: &Json| e.get("name").and_then(Json::as_str).unwrap_or("").to_string();
        let names: Vec<String> = events.iter().filter(|e| is_counter(e)).map(name_of).collect();
        assert!(names.iter().any(|n| n == "requests.eval.count"), "{names:?}");
        assert!(names.iter().any(|n| n == "pool.queue_depth"));
        assert!(names.iter().any(|n| n == "uptime_seconds"));
        // Counter values ride in args.value, the shape the simulator's
        // counter tracks use, so both documents load identically.
        let ev = events.iter().find(|e| is_counter(e)).unwrap();
        assert!(ev.get("args").and_then(|a| a.get("value")).is_some());
    }

    #[test]
    fn disabling_telemetry_stops_counters_but_not_gauges() {
        let _g = lock_flag();
        let t = Telemetry::new();
        set_enabled(false);
        t.observe_request(ReqKind::Graph, Duration::from_micros(1));
        t.pool_jobs.add(1);
        t.pool_queue_depth.inc();
        let s = t.snapshot();
        set_enabled(true);
        assert_eq!(s.request(ReqKind::Graph).count, 0);
        assert_eq!(s.pool.jobs, 0);
        assert_eq!(s.pool.queue_depth, 1);
        assert!(!s.enabled);
    }
}
