//! Log-linear latency histograms with exact-bound quantile extraction.
//!
//! Values (nanoseconds for latency, plain counts for size distributions)
//! land in log-spaced buckets: each power-of-two octave is split into
//! `1 << SUB_BITS` linear sub-buckets, so a bucket's width never exceeds
//! 1/4 of its lower bound. Quantiles are therefore *exact bounds*: the
//! reported p99 is the upper edge of the bucket holding the rank-⌈0.99·n⌉
//! sample, within 25% of the true order statistic, with no sampling and
//! no allocation. Recording is one relaxed `fetch_add` per field —
//! wait-free, safe from any thread, and gated on the global
//! [`enabled`](super::enabled) flag so a disabled registry costs one
//! branch (the [`TraceSink`](crate::sim::trace::TraceSink) pattern).

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution: every power-of-two octave splits into
/// `1 << SUB_BITS` linear sub-buckets, bounding relative bucket width
/// (and therefore quantile error) at `1 / (1 << SUB_BITS)` = 25%.
const SUB_BITS: u32 = 2;
const SUBS: u64 = 1 << SUB_BITS;

/// Bucket count covering the full `u64` range: values below [`SUBS`] get
/// one exact bucket each, then four sub-buckets per remaining octave.
pub const BUCKETS: usize = ((64 - SUB_BITS as usize) << SUB_BITS) + SUBS as usize;

/// The bucket holding `v`. Values below [`SUBS`] map to themselves;
/// larger values index by (octave, linear sub-bucket within the octave).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros();
    let sub = ((v >> (octave - SUB_BITS)) & (SUBS - 1)) as usize;
    (((octave - SUB_BITS + 1) as usize) << SUB_BITS) + sub
}

/// Smallest value that lands in bucket `index` (inverse of
/// [`bucket_index`] at the bucket's lower edge).
pub fn bucket_lo(index: usize) -> u64 {
    if index < SUBS as usize {
        return index as u64;
    }
    let group = (index >> SUB_BITS) as u32;
    let sub = (index & (SUBS as usize - 1)) as u64;
    let octave = group + SUB_BITS - 1;
    (1u64 << octave) + (sub << (octave - SUB_BITS))
}

/// Largest value that lands in bucket `index`. The final bucket absorbs
/// everything up to `u64::MAX` (its upper edge would be `1 << 64`).
pub fn bucket_hi(index: usize) -> u64 {
    if index + 1 >= BUCKETS {
        u64::MAX
    } else {
        bucket_lo(index + 1) - 1
    }
}

/// A concurrent log-bucketed histogram: 252 relaxed counters plus sum,
/// min, and max. Everything a snapshot needs is derivable from a plain
/// load of each field, so readers never block writers.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value when telemetry is enabled. Wait-free: four
    /// relaxed atomic ops, no locks, no allocation.
    #[inline]
    pub fn record(&self, v: u64) {
        if super::enabled() {
            self.record_always(v);
        }
    }

    /// Record regardless of the global enable flag. Used by unit tests
    /// (so a concurrently running enabled-toggle test cannot starve
    /// them) and by callers that manage their own gating.
    pub fn record_always(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// A point-in-time copy. Concurrent recording may tear between
    /// fields (count vs sum), which is acceptable for monitoring.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = buckets.iter().sum();
        let raw_min = self.min.load(Ordering::Relaxed);
        HistogramSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { raw_min },
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// An owned copy of a [`Histogram`] at one instant, with quantile and
/// JSON rendering. `buckets` is empty for a default (never-merged)
/// snapshot and `BUCKETS` long otherwise.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub buckets: Vec<u64>,
}

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact-bound quantile: the upper edge of the bucket holding the
    /// rank-⌈q·count⌉ sample, clamped into `[min, max]` so p0 and p100
    /// are the true extremes. Returns 0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_hi(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold `other` into `self` bucket-wise. Used to merge the per-kind
    /// request latency histograms into one process-wide distribution.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if other.count == 0 {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
        }
        for (a, &b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        if self.count == 0 {
            self.min = other.min;
        } else {
            self.min = self.min.min(other.min);
        }
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Render as `{count, mean, min, max, p50, p95, p99}` (latency
    /// histograms record nanoseconds). With `include_buckets`, append a
    /// sparse `[[bucket_lo, count], ...]` array of non-empty buckets.
    pub fn to_json(&self, include_buckets: bool) -> Json {
        let mut pairs = vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("min", Json::num(self.min as f64)),
            ("max", Json::num(self.max as f64)),
            ("p50", Json::num(self.quantile(0.50) as f64)),
            ("p95", Json::num(self.quantile(0.95) as f64)),
            ("p99", Json::num(self.quantile(0.99) as f64)),
        ];
        if include_buckets {
            let mut rows = Vec::new();
            for (i, &c) in self.buckets.iter().enumerate().filter(|&(_, &c)| c > 0) {
                let row = vec![Json::num(bucket_lo(i) as f64), Json::num(c as f64)];
                rows.push(Json::arr(row));
            }
            pairs.push(("buckets", Json::arr(rows)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_tile_the_u64_range_without_gaps() {
        assert_eq!(BUCKETS, 252);
        for i in 0..BUCKETS - 1 {
            assert_eq!(bucket_lo(i + 1), bucket_hi(i) + 1, "gap after bucket {i}");
        }
        assert_eq!(bucket_lo(0), 0);
        assert_eq!(bucket_hi(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn bucket_index_inverts_bucket_edges() {
        for i in 0..BUCKETS {
            assert_eq!(bucket_index(bucket_lo(i)), i, "lo edge of {i}");
            assert_eq!(bucket_index(bucket_hi(i)), i, "hi edge of {i}");
        }
    }

    #[test]
    fn bucket_width_is_at_most_a_quarter_of_its_lower_bound() {
        for i in SUBS as usize..BUCKETS - 1 {
            let width = bucket_hi(i) - bucket_lo(i) + 1;
            assert!(bucket_lo(i) / width >= 4, "bucket {i} wider than 25%");
        }
    }

    #[test]
    fn values_land_in_brackets_that_contain_them() {
        let probes = [0, 1, 3, 4, 7, 8, 100, 999, 1 << 20, (1 << 20) + 1, u64::MAX];
        for &v in &probes {
            let i = bucket_index(v);
            assert!(bucket_lo(i) <= v && v <= bucket_hi(i), "v={v} bucket={i}");
        }
    }

    #[test]
    fn quantiles_bracket_a_known_bimodal_distribution() {
        // 900 samples at 1 ms, 100 at 64 ms: p50 sits in the 1 ms bucket,
        // p99 in the 64 ms one, and clamping pins both to exact values
        // because each mode is a bucket lower edge.
        let h = Histogram::new();
        for _ in 0..900 {
            h.record_always(1_000_000);
        }
        for _ in 0..100 {
            h.record_always(64_000_000);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        let p50 = s.quantile(0.50);
        assert!((1_000_000..1_250_000).contains(&p50), "p50={p50}");
        assert_eq!(s.quantile(0.99), 64_000_000);
        assert_eq!(s.min, 1_000_000);
        assert_eq!(s.max, 64_000_000);
        assert!(s.quantile(0.50) <= s.quantile(0.95));
        assert!(s.quantile(0.95) <= s.quantile(0.99));
    }

    #[test]
    fn quantile_of_single_sample_is_that_sample() {
        let h = Histogram::new();
        h.record_always(12_345);
        let s = h.snapshot();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.quantile(q), 12_345);
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 0);
        assert_eq!(s.quantile(0.99), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn merge_combines_counts_and_extremes() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record_always(10);
        a.record_always(20);
        b.record_always(5);
        b.record_always(40_000);
        let mut m = HistogramSnapshot::default();
        m.merge(&a.snapshot());
        m.merge(&b.snapshot());
        m.merge(&HistogramSnapshot::default());
        assert_eq!(m.count, 4);
        assert_eq!(m.sum, 40_035);
        assert_eq!(m.min, 5);
        assert_eq!(m.max, 40_000);
        assert!(m.quantile(0.99) >= 40_000);
    }

    #[test]
    fn json_rendering_exposes_quantiles_and_sparse_buckets() {
        let h = Histogram::new();
        for _ in 0..10 {
            h.record_always(1_000);
        }
        let s = h.snapshot();
        let j = s.to_json(true);
        assert_eq!(j.get("count").and_then(Json::as_f64), Some(10.0));
        assert_eq!(j.get("p50").and_then(Json::as_f64), Some(1_000.0));
        let rows = j.get("buckets").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(s.to_json(false).get("buckets").is_none());
    }
}
