//! Baseline cost models CAMUY is compared against (SCALE-SIM-style
//! never-stalling weight-stationary array).

pub mod scalesim;

pub use scalesim::scalesim_metrics;
