//! A SCALE-SIM-style analytic comparator (Samajdar et al. 2018): a
//! never-stalling weight-stationary array with *serialized* (not double
//! buffered) weight loads and an unconstrained accumulator. The paper
//! compares its Figure 6 aspect-ratio findings against SCALE-SIM's
//! weight-stationary investigation; this module provides that reference
//! point and doubles as the ablation baseline for CAMUY's double buffering
//! and accumulator-capacity modeling.

use crate::config::ArrayConfig;
use crate::metrics::{Metrics, MovementCounters};
use crate::model::schedule::GemmShape;
use crate::util::ceil_div;

/// SCALE-SIM-like weight-stationary cycles and traffic for one GEMM.
///
/// Per (row-tile, col-tile) fold: load k_t cycles (exposed — no double
/// buffering), then stream M rows through the skewed array:
/// `k_t + M + n_t - 2` cycles. SRAM traffic counts each operand word once
/// per fold touch (no accumulator-capacity amplification).
pub fn scalesim_metrics(gemm: GemmShape, cfg: &ArrayConfig) -> Metrics {
    if gemm.is_empty() {
        return Metrics::default();
    }
    let (big_m, big_k, big_n) = (gemm.m as u64, gemm.k as u64, gemm.n as u64);
    let h = cfg.height as u64;
    let w = cfg.width as u64;
    let tr = ceil_div(gemm.k, cfg.height) as u64;
    let tc = ceil_div(gemm.n, cfg.width) as u64;
    let k_tail = big_k - (tr - 1) * h;
    let n_tail = big_n - (tc - 1) * w;

    let mut cycles = 0u64;
    let mut exposed_loads = 0u64;
    let mut mv = MovementCounters::default();
    for &(kt, kc) in &[(h, tr - 1), (k_tail, 1)] {
        for &(nt, nc) in &[(w, tc - 1), (n_tail, 1)] {
            let folds = kc * nc;
            if folds == 0 {
                continue;
            }
            // Exposed load + skewed stream, per fold.
            exposed_loads += folds * kt;
            cycles += folds * (kt + big_m + kt + nt - 2);
            mv.ub_act_reads += folds * big_m * kt;
            mv.ub_weight_reads += folds * kt * nt;
            mv.inter_pe_act += folds * big_m * kt * (nt - 1);
            mv.inter_pe_psum += folds * big_m * nt * (kt - 1);
            mv.inter_pe_weight += folds * nt * kt * (kt - 1) / 2;
            mv.intra_pe += folds * (5 * big_m * kt * nt + 2 * kt * nt);
            mv.aa_writes += folds * big_m * nt;
        }
    }
    mv.aa_reads = big_m * big_n;
    mv.ub_out_writes = big_m * big_n;

    Metrics {
        cycles,
        // Every load is exposed (no double buffering) — reported as stall.
        stall_cycles: exposed_loads,
        macs: gemm.macs(),
        passes: tr * tc,
        movements: mv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gemm::ws_metrics;

    fn cfg(h: usize, w: usize) -> ArrayConfig {
        ArrayConfig::new(h, w)
    }

    #[test]
    fn single_fold_by_hand() {
        let g = GemmShape::new(10, 4, 4);
        let m = scalesim_metrics(g, &cfg(4, 4));
        // load 4 + (4 + 10 + 4 - 2) = 20 cycles.
        assert_eq!(m.cycles, 20);
        assert_eq!(m.passes, 1);
        assert_eq!(m.movements.ub_weight_reads, 16);
    }

    #[test]
    fn never_rereads_weights() {
        // Unlike CAMUY with a small accumulator, SCALE-SIM touches each
        // weight exactly once regardless of M.
        let g = GemmShape::new(100_000, 64, 64);
        let m = scalesim_metrics(g, &cfg(16, 16));
        assert_eq!(m.movements.ub_weight_reads, 64 * 64);
    }

    #[test]
    fn camuy_double_buffering_beats_serial_loads() {
        // With a roomy accumulator the two models move the same data, but
        // CAMUY hides loads behind compute: strictly fewer cycles whenever
        // there is more than one fold.
        let g = GemmShape::new(256, 64, 64);
        let c = cfg(16, 16).with_acc_capacity(1 << 30);
        let camuy = ws_metrics(g, &c);
        let scale = scalesim_metrics(g, &c);
        assert!(camuy.cycles < scale.cycles);
        assert_eq!(
            camuy.movements.ub_weight_reads,
            scale.movements.ub_weight_reads
        );
    }

    #[test]
    fn empty_gemm_zero() {
        assert_eq!(
            scalesim_metrics(GemmShape::new(0, 4, 4), &cfg(4, 4)),
            Metrics::default()
        );
    }

    #[test]
    fn aspect_ratio_u_shape() {
        // At a fixed PE budget, extreme ratios pay fold overheads: cycles
        // at 4x1024 and 1024x4 both exceed the 64x64 square for a big
        // square GEMM (Samajdar et al.'s finding).
        let g = GemmShape::new(512, 512, 512);
        let sq = scalesim_metrics(g, &cfg(64, 64)).cycles;
        let tall = scalesim_metrics(g, &cfg(1024, 4)).cycles;
        let flat = scalesim_metrics(g, &cfg(4, 1024)).cycles;
        assert!(tall > sq, "tall {tall} vs sq {sq}");
        assert!(flat > sq, "flat {flat} vs sq {sq}");
    }
}
