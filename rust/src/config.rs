//! Central configuration types: systolic array geometry, memory
//! provisioning, operand bitwidths, dataflow selection and the data-movement
//! energy weights of Equation 1.
//!
//! These mirror the knobs the paper's wrapper library exposes when it
//! "dynamically creates emulator instances of certain configurations (bit
//! widths for weights, input and output activations, array dimensions, and
//! accumulator array size)".

use crate::util::json::Json;
use std::fmt;

/// Which dataflow the array implements. The paper's experiments use
/// weight-stationary (TPUv1-like); output-stationary is implemented as the
/// paper's named future-work extension and used in ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    WeightStationary,
    OutputStationary,
}

impl Dataflow {
    pub fn as_str(&self) -> &'static str {
        match self {
            Dataflow::WeightStationary => "weight-stationary",
            Dataflow::OutputStationary => "output-stationary",
        }
    }

    pub fn parse(s: &str) -> Option<Dataflow> {
        match s {
            "ws" | "weight-stationary" => Some(Dataflow::WeightStationary),
            "os" | "output-stationary" => Some(Dataflow::OutputStationary),
            _ => None,
        }
    }
}

impl fmt::Display for Dataflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Structural violation of an [`ArrayConfig`] invariant — the typed error
/// the validation path (and the `camuy::api` request surface) reports
/// instead of letting a zero dimension reach a division downstream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigError {
    ZeroHeight,
    ZeroWidth,
    ZeroAccCapacity,
    ZeroUnifiedBuffer,
    BadBitwidth { field: &'static str, bits: u32 },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::ZeroHeight => write!(f, "array height must be positive"),
            ConfigError::ZeroWidth => write!(f, "array width must be positive"),
            ConfigError::ZeroAccCapacity => write!(f, "accumulator capacity must be positive"),
            ConfigError::ZeroUnifiedBuffer => {
                write!(f, "unified buffer capacity must be positive")
            }
            ConfigError::BadBitwidth { field, bits } => {
                write!(f, "{field} must be in 1..=64, got {bits}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Geometry and provisioning of one emulated processor array instance.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArrayConfig {
    /// Array height `m`: rows, mapped to the GEMM reduction dimension K.
    /// Activations enter rows from the left (via the SDS FIFOs).
    pub height: usize,
    /// Array width `n`: columns, mapped to the GEMM output dimension N.
    /// Partial sums exit the bottom row into the accumulator array.
    pub width: usize,
    /// Total accumulator-array capacity in *entries* (shared across the
    /// active columns of a pass; TPUv1 provisioned 4096 per column but the
    /// paper treats it as one sizing knob — see DESIGN.md §3.1).
    pub acc_capacity: usize,
    /// Unified Buffer capacity in bytes. CAMUY keeps weights *and*
    /// activations on chip (its stated departure from TPUv1); layers whose
    /// working set exceeds this are flagged by the coordinator (TPUv1's
    /// activation buffer was 24 MiB — the default here).
    pub ub_bytes: usize,
    /// Operand bitwidths. They scale byte-bandwidth reports; the
    /// access-count metrics of Equation 1 are bitwidth-independent.
    pub weight_bits: u32,
    pub act_bits: u32,
    pub out_bits: u32,
    /// Dataflow concept of the array.
    pub dataflow: Dataflow,
}

impl ArrayConfig {
    /// The paper's default instance: weight-stationary, TPUv1-style
    /// provisioning, int8 operands with int32 accumulation.
    pub fn new(height: usize, width: usize) -> Self {
        Self {
            height,
            width,
            acc_capacity: 4096,
            ub_bytes: 24 * 1024 * 1024,
            weight_bits: 8,
            act_bits: 8,
            out_bits: 32,
            dataflow: Dataflow::WeightStationary,
        }
    }

    /// Validated construction: [`ArrayConfig::new`] defaults with the
    /// geometry checked up front, so a degenerate array never reaches the
    /// tiling math.
    pub fn try_new(height: usize, width: usize) -> Result<Self, ConfigError> {
        let cfg = Self::new(height, width);
        cfg.validate()?;
        Ok(cfg)
    }

    /// The commercially deployed TPUv1 geometry the paper compares against.
    pub fn tpu_v1() -> Self {
        Self::new(256, 256)
    }

    pub fn with_acc_capacity(mut self, cap: usize) -> Self {
        self.acc_capacity = cap;
        self
    }

    pub fn with_ub_bytes(mut self, bytes: usize) -> Self {
        self.ub_bytes = bytes;
        self
    }

    pub fn with_dataflow(mut self, df: Dataflow) -> Self {
        self.dataflow = df;
        self
    }

    pub fn with_bits(mut self, weight: u32, act: u32, out: u32) -> Self {
        self.weight_bits = weight;
        self.act_bits = act;
        self.out_bits = out;
        self
    }

    /// Number of processing elements.
    pub fn pe_count(&self) -> usize {
        self.height * self.width
    }

    /// Validate invariants; returns a typed [`ConfigError`] on violation.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.height == 0 {
            return Err(ConfigError::ZeroHeight);
        }
        if self.width == 0 {
            return Err(ConfigError::ZeroWidth);
        }
        if self.acc_capacity == 0 {
            return Err(ConfigError::ZeroAccCapacity);
        }
        if self.ub_bytes == 0 {
            return Err(ConfigError::ZeroUnifiedBuffer);
        }
        for (name, bits) in [
            ("weight_bits", self.weight_bits),
            ("act_bits", self.act_bits),
            ("out_bits", self.out_bits),
        ] {
            if bits == 0 || bits > 64 {
                return Err(ConfigError::BadBitwidth { field: name, bits });
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("height", Json::num(self.height as f64)),
            ("width", Json::num(self.width as f64)),
            ("acc_capacity", Json::num(self.acc_capacity as f64)),
            ("ub_bytes", Json::num(self.ub_bytes as f64)),
            ("weight_bits", Json::num(self.weight_bits as f64)),
            ("act_bits", Json::num(self.act_bits as f64)),
            ("out_bits", Json::num(self.out_bits as f64)),
            ("dataflow", Json::str(self.dataflow.as_str())),
        ])
    }

    /// Parse the JSON object form. Optional fields default when *absent*
    /// but error when present and malformed — this is a wire surface, and
    /// silently substituting a default for a typo'd field would answer a
    /// question the client did not ask. Structural parsing only — callers
    /// run [`ArrayConfig::validate`] to get the typed [`ConfigError`] (the
    /// `camuy::api` request path does exactly that).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let req_usize = |k: &str| -> Result<usize, String> {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("missing or invalid field '{k}'"))
        };
        let opt_usize = |k: &str, default: usize| -> Result<usize, String> {
            Ok(v.opt_usize_field(k)?.unwrap_or(default))
        };
        let opt_bits = |k: &str, default: u32| -> Result<u32, String> {
            match v.opt_usize_field(k)? {
                None => Ok(default),
                Some(x) => u32::try_from(x)
                    .map_err(|_| format!("field '{k}' must be a small non-negative integer")),
            }
        };
        let cfg = Self {
            height: req_usize("height")?,
            width: req_usize("width")?,
            acc_capacity: opt_usize("acc_capacity", 4096)?,
            ub_bytes: opt_usize("ub_bytes", 24 * 1024 * 1024)?,
            weight_bits: opt_bits("weight_bits", 8)?,
            act_bits: opt_bits("act_bits", 8)?,
            out_bits: opt_bits("out_bits", 32)?,
            dataflow: v
                .get("dataflow")
                .and_then(Json::as_str)
                .map(|s| Dataflow::parse(s).ok_or_else(|| format!("bad dataflow '{s}'")))
                .transpose()?
                .unwrap_or(Dataflow::WeightStationary),
        };
        Ok(cfg)
    }
}

impl fmt::Display for ArrayConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}x{} {} acc={} w{}a{}o{}",
            self.height,
            self.width,
            self.dataflow,
            self.acc_capacity,
            self.weight_bits,
            self.act_bits,
            self.out_bits
        )
    }
}

/// Weights of the normalized data-movement energy model, Equation 1:
/// `E = 6·M_UB + 2·(M_INTER_PE + M_AA) + M_INTRA_PE`, derived by the paper
/// from Eyeriss' energy hierarchy (Chen et al. 2016).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyWeights {
    pub unified_buffer: f64,
    pub inter_pe: f64,
    pub accumulator: f64,
    pub intra_pe: f64,
}

impl EnergyWeights {
    /// Equation 1 of the paper.
    pub const fn paper() -> Self {
        Self {
            unified_buffer: 6.0,
            inter_pe: 2.0,
            accumulator: 2.0,
            intra_pe: 1.0,
        }
    }

    /// 14 nm technology re-weighting after Dally, Turakhia & Han,
    /// "Domain-specific hardware accelerators" (CACM 2020): on-chip SRAM
    /// access grows relative to register traffic as wires dominate. The
    /// paper names this re-weighting as future work; used in ablations.
    pub const fn dally_14nm() -> Self {
        Self {
            unified_buffer: 10.0,
            inter_pe: 2.0,
            accumulator: 3.0,
            intra_pe: 1.0,
        }
    }
}

impl Default for EnergyWeights {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_tpu_like_int8() {
        let c = ArrayConfig::new(128, 64);
        assert_eq!(c.pe_count(), 8192);
        assert_eq!(c.acc_capacity, 4096);
        assert_eq!((c.weight_bits, c.act_bits, c.out_bits), (8, 8, 32));
        assert_eq!(c.dataflow, Dataflow::WeightStationary);
        c.validate().unwrap();
    }

    #[test]
    fn tpu_v1_geometry() {
        let c = ArrayConfig::tpu_v1();
        assert_eq!((c.height, c.width), (256, 256));
        assert_eq!(c.pe_count(), 65536);
    }

    #[test]
    fn validate_rejects_degenerate() {
        assert!(ArrayConfig::new(0, 8).validate().is_err());
        assert!(ArrayConfig::new(8, 0).validate().is_err());
        assert!(ArrayConfig::new(8, 8).with_acc_capacity(0).validate().is_err());
        assert!(ArrayConfig::new(8, 8).with_bits(0, 8, 32).validate().is_err());
        assert!(ArrayConfig::new(8, 8).with_bits(8, 128, 32).validate().is_err());
    }

    #[test]
    fn validation_errors_are_typed() {
        assert_eq!(ArrayConfig::new(0, 8).validate(), Err(ConfigError::ZeroHeight));
        assert_eq!(ArrayConfig::new(8, 0).validate(), Err(ConfigError::ZeroWidth));
        assert_eq!(
            ArrayConfig::new(8, 8).with_acc_capacity(0).validate(),
            Err(ConfigError::ZeroAccCapacity)
        );
        assert_eq!(
            ArrayConfig::new(8, 8).with_ub_bytes(0).validate(),
            Err(ConfigError::ZeroUnifiedBuffer)
        );
        assert_eq!(
            ArrayConfig::new(8, 8).with_bits(8, 0, 32).validate(),
            Err(ConfigError::BadBitwidth { field: "act_bits", bits: 0 })
        );
    }

    #[test]
    fn try_new_validates_up_front() {
        assert_eq!(ArrayConfig::try_new(0, 8), Err(ConfigError::ZeroHeight));
        assert_eq!(ArrayConfig::try_new(16, 8).unwrap(), ArrayConfig::new(16, 8));
    }

    #[test]
    fn json_roundtrip() {
        let c = ArrayConfig::new(48, 96)
            .with_acc_capacity(2048)
            .with_bits(16, 8, 32)
            .with_dataflow(Dataflow::OutputStationary);
        let back = ArrayConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn json_defaults_fill_in() {
        let v = Json::parse(r#"{"height": 32, "width": 16}"#).unwrap();
        let c = ArrayConfig::from_json(&v).unwrap();
        assert_eq!((c.height, c.width), (32, 16));
        assert_eq!(c.acc_capacity, 4096);
    }

    #[test]
    fn json_rejects_present_but_malformed_optional_fields() {
        // A typo'd optional field must error, not silently take the default.
        for bad in [
            r#"{"height":32,"width":32,"ub_bytes":"1048576"}"#,
            r#"{"height":32,"width":32,"acc_capacity":-4}"#,
            r#"{"height":32,"width":32,"acc_capacity":2.5}"#,
            r#"{"height":32,"width":32,"act_bits":4294967296}"#,
            r#"{"height":32,"width":32,"dataflow":"sideways"}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(ArrayConfig::from_json(&v).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn dataflow_parsing() {
        assert_eq!(Dataflow::parse("ws"), Some(Dataflow::WeightStationary));
        assert_eq!(Dataflow::parse("output-stationary"), Some(Dataflow::OutputStationary));
        assert_eq!(Dataflow::parse("nope"), None);
    }

    #[test]
    fn display_is_compact() {
        let c = ArrayConfig::new(16, 8);
        assert_eq!(format!("{c}"), "16x8 weight-stationary acc=4096 w8a8o32");
    }

    #[test]
    fn energy_weights_match_equation_1() {
        let w = EnergyWeights::paper();
        assert_eq!(w.unified_buffer, 6.0);
        assert_eq!(w.inter_pe, 2.0);
        assert_eq!(w.accumulator, 2.0);
        assert_eq!(w.intra_pe, 1.0);
    }
}
