//! A minimal row-major f32 matrix used by the functional emulator, the
//! PJRT runtime bridge, and the verification paths. Values are plain f32;
//! the emulator's exactness tests use small integral values so floating
//! summation order cannot introduce disagreement.

use crate::util::prng::Rng;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Fill with a deterministic function of the index (for fixtures).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    /// Random small-integer matrix in `[-8, 8]`: exact under f32 chains of
    /// the sizes the emulator tests use.
    pub fn random_small_int(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| (rng.range_usize(0, 16) as i32 - 8) as f32)
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Naive reference matmul (K ascending — matches the emulator's
    /// accumulation order so integral inputs compare exactly).
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "inner dims mismatch");
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        for r in 0..self.rows {
            for c in 0..rhs.cols {
                let mut acc = 0.0f32;
                for k in 0..self.cols {
                    acc += self[(r, k)] * rhs[(k, c)];
                }
                out[(r, c)] = acc;
            }
        }
        out
    }

    /// Max absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl fmt::Display for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{}:", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            let row: Vec<String> = (0..self.cols.min(8)).map(|c| format!("{:7.2}", self[(r, c)])).collect();
            writeln!(f, "  [{}{}]", row.join(" "), if self.cols > 8 { " …" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.data()[5], 5.0);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(3, 3, |r, c| (r * 3 + c) as f32);
        let i = Matrix::from_fn(3, 3, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn random_small_int_bounds() {
        let mut rng = Rng::new(3);
        let m = Matrix::random_small_int(10, 10, &mut rng);
        for &v in m.data() {
            assert!((-8.0..=8.0).contains(&v));
            assert_eq!(v.fract(), 0.0);
        }
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_shape_check() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
