//! Layer-3 coordination: the inference driver that runs networks through
//! the emulator (timeline, per-layer metrics, bandwidth) and the
//! three-way verification path (reference ⇔ emulator ⇔ PJRT artifact).

pub mod schedule;
pub mod verify;

pub use schedule::{Coordinator, InferenceRun, TimelineEntry};
pub use verify::{verify_gemm_artifact, VerifyReport, PJRT_TOL};
