//! Cross-layer verification: run the same GEMM through (1) the plain
//! matmul reference, (2) the functional emulator, and (3) the AOT-compiled
//! XLA artifact on the PJRT runtime — and require all three to agree.
//! This is the proof that the three-layer stack composes (DESIGN.md §7.4).

use crate::arch::{EmulationMode, Emulator};
use crate::config::ArrayConfig;
use crate::metrics::Metrics;
use crate::runtime::artifact::ArtifactEntry;
use crate::runtime::client::PjrtRuntime;
use crate::tensor::Matrix;
use crate::util::prng::Rng;
use anyhow::Result;

/// The outcome of one three-way check.
#[derive(Debug)]
pub struct VerifyReport {
    pub artifact: String,
    pub gemm: (usize, usize, usize),
    /// max |emulator - reference|; exact 0 for the integral fixtures.
    pub emulator_vs_reference: f32,
    /// max |pjrt - reference|.
    pub pjrt_vs_reference: f32,
    /// Emulator metrics for the workload (what the coordinator reports
    /// alongside the numerics).
    pub metrics: Metrics,
    pub pass: bool,
}

/// Tolerance for the PJRT path (f32 reduction order differs).
pub const PJRT_TOL: f32 = 1e-3;

/// Verify a GEMM-kind artifact end to end.
pub fn verify_gemm_artifact(
    runtime: &PjrtRuntime,
    entry: &ArtifactEntry,
    cfg: &ArrayConfig,
    seed: u64,
) -> Result<VerifyReport> {
    anyhow::ensure!(entry.kind == "gemm", "artifact {} is not a gemm", entry.name);
    anyhow::ensure!(
        entry.inputs.len() == 2 && entry.inputs[0].len() == 2 && entry.inputs[1].len() == 2,
        "unexpected operand ranks for {}",
        entry.name
    );
    let (m, k) = (entry.inputs[0][0], entry.inputs[0][1]);
    let (k2, n) = (entry.inputs[1][0], entry.inputs[1][1]);
    anyhow::ensure!(k == k2, "operand mismatch in manifest for {}", entry.name);

    let mut rng = Rng::new(seed);
    let a = Matrix::random_small_int(m, k, &mut rng);
    let w = Matrix::random_small_int(k, n, &mut rng);
    let reference = a.matmul(&w);

    // Functional emulator (numerics + metrics).
    let emu = Emulator::new(cfg.clone()).map_err(anyhow::Error::msg)?;
    let emu_res = emu.run_gemm(&a, &w, EmulationMode::Wavefront);

    // PJRT execution of the compiled JAX/Pallas artifact.
    let compiled = runtime.load(&entry.name, &entry.file)?;
    let pjrt_out = compiled.run_gemm(&a, &w)?;

    let d_emu = emu_res.output.max_abs_diff(&reference);
    let d_pjrt = pjrt_out.max_abs_diff(&reference);
    Ok(VerifyReport {
        artifact: entry.name.clone(),
        gemm: (m, k, n),
        emulator_vs_reference: d_emu,
        pjrt_vs_reference: d_pjrt,
        metrics: emu_res.metrics,
        pass: d_emu == 0.0 && d_pjrt <= PJRT_TOL,
    })
}

impl std::fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (m, k, n) = self.gemm;
        write!(
            f,
            "{:<24} GEMM {m}x{k}x{n}: emu|ref diff {:.1e}, pjrt|ref diff {:.1e}, \
             cycles {}, E {:.3e} -> {}",
            self.artifact,
            self.emulator_vs_reference,
            self.pjrt_vs_reference,
            self.metrics.cycles,
            self.metrics
                .energy(&crate::config::EnergyWeights::paper()),
            if self.pass { "PASS" } else { "FAIL" }
        )
    }
}
