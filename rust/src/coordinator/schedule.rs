//! The inference coordinator: drives a whole network through the emulator
//! layer by layer — the role the paper's TensorFlow-wrapped emulator
//! instances play — producing a timeline, per-layer metrics, bandwidth
//! requirements, and aggregate results. Optionally spot-checks layer
//! numerics against AOT artifacts (see `verify.rs`).

use crate::config::{ArrayConfig, ConfigError, EnergyWeights};
use crate::metrics::Metrics;
use crate::model::bandwidth::BandwidthReport;
use crate::model::network::Network;
use crate::util::json::Json;

/// One layer's slot in the inference timeline.
#[derive(Debug, Clone)]
pub struct TimelineEntry {
    pub layer: String,
    pub start_cycle: u64,
    pub end_cycle: u64,
    pub metrics: Metrics,
    pub utilization: f64,
    pub energy: f64,
}

/// A completed inference run.
#[derive(Debug, Clone)]
pub struct InferenceRun {
    pub network: String,
    pub config: ArrayConfig,
    pub timeline: Vec<TimelineEntry>,
    pub total: Metrics,
    pub bandwidth: BandwidthReport,
    /// Layers whose UB working set exceeds `config.ub_bytes` (they would
    /// spill to DRAM on the modeled chip).
    pub ub_violations: Vec<String>,
}

/// The coordinator the CLI/examples instantiate.
#[derive(Debug, Clone)]
pub struct Coordinator {
    pub config: ArrayConfig,
    pub weights: EnergyWeights,
}

impl Coordinator {
    pub fn new(config: ArrayConfig) -> Result<Coordinator, ConfigError> {
        config.validate()?;
        Ok(Coordinator {
            config,
            weights: EnergyWeights::paper(),
        })
    }

    pub fn with_weights(mut self, w: EnergyWeights) -> Coordinator {
        self.weights = w;
        self
    }

    /// Run one inference of `net`, serialized layer by layer (the array
    /// processes a single layer's GEMMs at a time, as in the paper). The
    /// timeline stays per-layer, but repeated GEMM shapes are costed once
    /// through a per-run workload evaluation cache.
    pub fn run_inference(&self, net: &Network) -> InferenceRun {
        self.run_inference_cached(net, &crate::model::workload::EvalCache::new())
    }

    /// Like [`Coordinator::run_inference`], with per-(shape, configuration)
    /// metrics memoized in a caller-owned cache. The long-lived
    /// [`crate::api::Engine`] shares one cache across requests so repeated
    /// queries hit the memo table instead of recomputing.
    pub fn run_inference_cached(
        &self,
        net: &Network,
        cache: &crate::model::workload::EvalCache,
    ) -> InferenceRun {
        let mut timeline = Vec::with_capacity(net.layers.len());
        let mut clock: u64 = 0;
        let mut total = Metrics::default();
        let mut ub_violations = Vec::new();
        for layer in &net.layers {
            if !crate::model::bandwidth::fits_unified_buffer(layer, &self.config) {
                ub_violations.push(layer.name.clone());
            }
            let m = layer.metrics_cached(&self.config, cache);
            let entry = TimelineEntry {
                layer: layer.name.clone(),
                start_cycle: clock,
                end_cycle: clock + m.cycles,
                utilization: m.utilization(self.config.pe_count()),
                energy: m.energy(&self.weights),
                metrics: m,
            };
            clock = entry.end_cycle;
            total += m;
            timeline.push(entry);
        }
        let bandwidth = BandwidthReport::from_metrics(&total, &self.config);
        InferenceRun {
            network: net.name.clone(),
            config: self.config.clone(),
            timeline,
            total,
            bandwidth,
            ub_violations,
        }
    }
}

impl InferenceRun {
    pub fn utilization(&self) -> f64 {
        self.total.utilization(self.config.pe_count())
    }

    pub fn energy(&self, w: &EnergyWeights) -> f64 {
        self.total.energy(w)
    }

    /// The `k` layers with the largest cycle share (hot-spot report).
    pub fn top_layers_by_cycles(&self, k: usize) -> Vec<&TimelineEntry> {
        let mut sorted: Vec<&TimelineEntry> = self.timeline.iter().collect();
        sorted.sort_by(|a, b| b.metrics.cycles.cmp(&a.metrics.cycles));
        sorted.truncate(k);
        sorted
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("network", Json::str(self.network.clone())),
            ("config", self.config.to_json()),
            ("total", self.total.to_json()),
            ("utilization", Json::num(self.utilization())),
            (
                "energy",
                Json::num(self.energy(&EnergyWeights::paper())),
            ),
            (
                "layers",
                Json::arr(self.timeline.iter().map(|t| {
                    Json::obj(vec![
                        ("layer", Json::str(t.layer.clone())),
                        ("start", Json::num(t.start_cycle as f64)),
                        ("end", Json::num(t.end_cycle as f64)),
                        ("utilization", Json::num(t.utilization)),
                        ("energy", Json::num(t.energy)),
                    ])
                })),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, SpatialDims};

    fn net() -> Network {
        Network::new(
            "n",
            vec![
                Layer::conv("c1", SpatialDims::square(8), 4, 8, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(8), 8, 8, 3, 1, 1, 1),
                Layer::linear("fc", 512, 10),
            ],
        )
    }

    #[test]
    fn ub_violations_reported() {
        let c = Coordinator::new(ArrayConfig::new(16, 16).with_ub_bytes(64)).unwrap();
        let run = c.run_inference(&net());
        // With a 64-byte UB every layer spills.
        assert_eq!(run.ub_violations.len(), 3);
        let roomy = Coordinator::new(ArrayConfig::new(16, 16)).unwrap();
        assert!(roomy.run_inference(&net()).ub_violations.is_empty());
    }

    #[test]
    fn timeline_is_contiguous_and_total_consistent() {
        let c = Coordinator::new(ArrayConfig::new(16, 16)).unwrap();
        let run = c.run_inference(&net());
        assert_eq!(run.timeline.len(), 3);
        assert_eq!(run.timeline[0].start_cycle, 0);
        for w in run.timeline.windows(2) {
            assert_eq!(w[0].end_cycle, w[1].start_cycle);
        }
        assert_eq!(
            run.timeline.last().unwrap().end_cycle,
            run.total.cycles
        );
        assert_eq!(run.total, net().metrics(&c.config));
    }

    #[test]
    fn top_layers_sorted_desc() {
        let c = Coordinator::new(ArrayConfig::new(8, 8)).unwrap();
        let run = c.run_inference(&net());
        let top = run.top_layers_by_cycles(2);
        assert_eq!(top.len(), 2);
        assert!(top[0].metrics.cycles >= top[1].metrics.cycles);
    }

    #[test]
    fn rejects_invalid_config() {
        assert_eq!(
            Coordinator::new(ArrayConfig::new(0, 8)).unwrap_err(),
            crate::config::ConfigError::ZeroHeight
        );
    }

    #[test]
    fn shared_cache_run_matches_fresh_run() {
        let c = Coordinator::new(ArrayConfig::new(16, 16)).unwrap();
        let cache = crate::model::workload::EvalCache::new();
        let a = c.run_inference_cached(&net(), &cache);
        let misses = cache.misses();
        // A second run over the same network is served from the memo table.
        let b = c.run_inference_cached(&net(), &cache);
        assert_eq!(cache.misses(), misses);
        assert!(cache.hits() >= misses);
        assert_eq!(a.total, b.total);
        assert_eq!(a.total, c.run_inference(&net()).total);
    }

    #[test]
    fn json_summary_roundtrips() {
        let c = Coordinator::new(ArrayConfig::new(8, 8)).unwrap();
        let run = c.run_inference(&net());
        let j = run.to_json();
        let text = j.to_string_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.get("network").unwrap().as_str().unwrap(), "n");
        assert_eq!(back.get("layers").unwrap().as_arr().unwrap().len(), 3);
    }
}
