//! AlexNet (Krizhevsky et al., NIPS 2012) — the classic straight-forward
//! CNN of the paper's evaluation. The original two-GPU split is kept as
//! grouped convolutions (g=2) on conv2/4/5, which is part of the operand
//! diversity story.

use crate::model::layer::SpatialDims;
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// AlexNet over 227x227 RGB input (the stride-4 11x11 stem yields 55x55).
pub fn alexnet() -> Network {
    let mut s = Stack::new("alexnet", SpatialDims::square(227), 3);
    s.conv(96, 11, 4, 0) // conv1: 55x55x96
        .pool(3, 2, 0) // 27x27
        .conv_g(256, 5, 1, 2, 2) // conv2 (grouped)
        .pool(3, 2, 0) // 13x13
        .conv(384, 3, 1, 1) // conv3
        .conv_g(384, 3, 1, 1, 2) // conv4 (grouped)
        .conv_g(256, 3, 1, 1, 2) // conv5 (grouped)
        .pool(3, 2, 0) // 6x6
        .linear(4096)
        .linear(4096)
        .linear(1000);
    Network::new("alexnet", s.layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 5 convs + 3 FCs.
        assert_eq!(alexnet().layers.len(), 8);
    }

    #[test]
    fn parameter_count_matches_published() {
        // ~60.9M weights (we count no biases: 60.95M -> ~60.9M).
        let p = alexnet().params() as f64 / 1e6;
        assert!((60.0..62.0).contains(&p), "params {p}M");
    }

    #[test]
    fn mac_count_matches_published() {
        // ~715M MACs for 227x227 single-crop inference (grouped conv).
        let m = alexnet().macs() as f64 / 1e6;
        assert!((650.0..780.0).contains(&m), "macs {m}M");
    }

    #[test]
    fn fc6_sees_6x6x256() {
        let net = alexnet();
        let fc6 = net
            .layers
            .iter()
            .find(|l| matches!(l.kind, crate::model::layer::LayerKind::Linear { .. }))
            .unwrap();
        match &fc6.kind {
            crate::model::layer::LayerKind::Linear { in_features, .. } => {
                assert_eq!(*in_features, 6 * 6 * 256)
            }
            _ => unreachable!(),
        }
    }
}
