//! Transformer encoder workloads — the paper's §6 future work ("we plan to
//! study the impact of emerging ... architectures, such as transformers
//! ... on systolic arrays"). Implemented here as an extension: a BERT-style
//! encoder's GEMM-bearing operators per layer, with attention score/context
//! matmuls expressed as per-head grouped GEMMs (they serialize on a single
//! array exactly like group convolutions).

use crate::model::layer::Layer;
use crate::model::network::Network;

/// Encoder configuration.
#[derive(Debug, Clone)]
pub struct TransformerSpec {
    pub name: String,
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

/// Build the encoder's GEMM stream for one forward pass.
///
/// Per layer: Q/K/V/O projections (seq x d_model x d_model), the per-head
/// attention matmuls QK^T (seq x d_head x seq) and AV (seq x seq x d_head)
/// — modelled as `heads` serialized GEMMs via the grouped-conv mechanism —
/// and the two FFN projections.
pub fn transformer_encoder(spec: &TransformerSpec) -> Network {
    assert!(spec.d_model % spec.heads == 0);
    let d_head = spec.d_model / spec.heads;
    let s = spec.seq_len;
    let mut layers: Vec<Layer> = Vec::new();

    for l in 0..spec.layers {
        let p = |op: &str| format!("{}.l{:02}.{}", spec.name, l, op);
        // Projections: X[s, d] * W[d, d].
        for op in ["q", "k", "v", "o"] {
            layers.push(Layer::linear(p(op), spec.d_model, spec.d_model).with_batch(s));
        }
        // Attention scores per head: [s, d_head] x [d_head, s], h heads.
        layers.push(attention_gemm(p("qk"), s, d_head, s, spec.heads));
        // Context per head: [s, s] x [s, d_head].
        layers.push(attention_gemm(p("av"), s, s, d_head, spec.heads));
        // FFN.
        layers.push(Layer::linear(p("ffn1"), spec.d_model, spec.d_ff).with_batch(s));
        layers.push(Layer::linear(p("ffn2"), spec.d_ff, spec.d_model).with_batch(s));
    }
    Network::new(spec.name.clone(), layers)
}

/// A batch of `heads` serialized (m x k x n) GEMMs, encoded as a grouped
/// 1x1 "conv" so the group-serialization machinery applies unchanged.
fn attention_gemm(name: String, m: usize, k: usize, n: usize, heads: usize) -> Layer {
    let mut l = Layer::conv(
        name,
        crate::model::layer::SpatialDims { h: m, w: 1 },
        k * heads,
        n * heads,
        1,
        1,
        0,
        heads,
    );
    l.batch = 1;
    l
}

/// BERT-Base as the canonical instance (12 layers, d=768, 12 heads,
/// ffn 3072) at sequence length 128.
pub fn bert_base_seq128() -> Network {
    transformer_encoder(&TransformerSpec {
        name: "bertbase-s128".into(),
        layers: 12,
        d_model: 768,
        heads: 12,
        d_ff: 3072,
        seq_len: 128,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_base_params() {
        // Encoder GEMM weights: 12 * (4 * 768^2 + 2 * 768 * 3072) = 85.0M.
        // (Attention matmuls are weightless only in reality; our grouped
        //  encoding carries pseudo-weights we must exclude from the check.)
        let net = bert_base_seq128();
        let proj_params: u64 = net
            .layers
            .iter()
            .filter(|l| !l.name.contains(".qk") && !l.name.contains(".av"))
            .map(|l| l.params())
            .sum();
        assert_eq!(proj_params, 12 * (4 * 768 * 768 + 2 * 768 * 3072));
    }

    #[test]
    fn attention_macs_scale_with_seq_squared() {
        let short = transformer_encoder(&TransformerSpec {
            name: "t".into(),
            layers: 1,
            d_model: 64,
            heads: 4,
            d_ff: 128,
            seq_len: 32,
        });
        let long = transformer_encoder(&TransformerSpec {
            name: "t".into(),
            layers: 1,
            d_model: 64,
            heads: 4,
            d_ff: 128,
            seq_len: 64,
        });
        let qk = |n: &Network| {
            n.layers
                .iter()
                .find(|l| l.name.contains(".qk"))
                .unwrap()
                .macs()
        };
        // QK^T MACs = s^2 * d_model: 4x for 2x sequence length.
        assert_eq!(qk(&long), 4 * qk(&short));
    }

    #[test]
    fn per_head_gemm_shape() {
        let net = bert_base_seq128();
        let qk = net.layers.iter().find(|l| l.name.contains(".qk")).unwrap();
        let (g, heads) = qk.gemm();
        assert_eq!(heads, 12);
        assert_eq!((g.m, g.k, g.n), (128, 64, 128));
    }

    #[test]
    fn layer_count() {
        // 8 GEMM ops per encoder layer.
        assert_eq!(bert_base_seq128().layers.len(), 12 * 8);
    }
}
