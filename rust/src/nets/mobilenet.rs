//! MobileNetV3-Large (Howard et al., ICCV 2019): the depthwise-separable
//! representative (g = c_in, i.e. per-channel GEMMs of K = k*k, N = 1 —
//! the extreme of the paper's group-convolution serialization effect).
//! Squeeze-and-Excitation blocks contribute small FC GEMMs.

use crate::model::layer::SpatialDims;
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// One inverted-residual block row of the V3-Large table:
/// (kernel, expanded channels, out channels, SE?, stride).
struct Block {
    k: usize,
    exp: usize,
    out: usize,
    se: bool,
    stride: usize,
}

/// Divisible-by-8 rounding used by the reference implementation for SE
/// squeeze widths.
fn make_divisible(v: usize) -> usize {
    let d = 8;
    let new_v = ((v + d / 2) / d) * d;
    // Do not round down by more than 10%.
    if (new_v as f64) < 0.9 * v as f64 {
        new_v + d
    } else {
        new_v.max(d)
    }
}

/// MobileNetV3-Large over 224x224 input.
pub fn mobilenet_v3_large() -> Network {
    // The published table (paper Table 1).
    let blocks = [
        Block { k: 3, exp: 16, out: 16, se: false, stride: 1 },
        Block { k: 3, exp: 64, out: 24, se: false, stride: 2 },
        Block { k: 3, exp: 72, out: 24, se: false, stride: 1 },
        Block { k: 5, exp: 72, out: 40, se: true, stride: 2 },
        Block { k: 5, exp: 120, out: 40, se: true, stride: 1 },
        Block { k: 5, exp: 120, out: 40, se: true, stride: 1 },
        Block { k: 3, exp: 240, out: 80, se: false, stride: 2 },
        Block { k: 3, exp: 200, out: 80, se: false, stride: 1 },
        Block { k: 3, exp: 184, out: 80, se: false, stride: 1 },
        Block { k: 3, exp: 184, out: 80, se: false, stride: 1 },
        Block { k: 3, exp: 480, out: 112, se: true, stride: 1 },
        Block { k: 3, exp: 672, out: 112, se: true, stride: 1 },
        Block { k: 5, exp: 672, out: 160, se: true, stride: 2 },
        Block { k: 5, exp: 960, out: 160, se: true, stride: 1 },
        Block { k: 5, exp: 960, out: 160, se: true, stride: 1 },
    ];

    let mut s = Stack::new("mobilenetv3l", SpatialDims::square(224), 3);
    s.conv(16, 3, 2, 1); // stem -> 112x112

    for b in &blocks {
        let in_c = s.at().1;
        if b.exp != in_c {
            s.conv_1x1(b.exp); // expand
        }
        s.conv_dw(b.k, b.stride, b.k / 2); // depthwise
        if b.se {
            s.se_block(make_divisible(b.exp / 4));
        }
        s.conv_1x1(b.out); // project
    }

    s.conv_1x1(960); // head conv
    s.global_pool();
    s.linear(1280).linear(1000);
    Network::new("mobilenetv3l", s.layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::LayerKind;

    #[test]
    fn params_match_published() {
        // 5.48M in torchvision (incl. BN/bias); weights-only ~5.4M.
        let p = mobilenet_v3_large().params() as f64 / 1e6;
        assert!((5.0..5.8).contains(&p), "params {p}M");
    }

    #[test]
    fn macs_match_published() {
        // ~219 MMACs at 224x224.
        let m = mobilenet_v3_large().macs() as f64 / 1e6;
        assert!((200.0..240.0).contains(&m), "macs {m}M");
    }

    #[test]
    fn depthwise_layers_are_per_channel_gemms() {
        let net = mobilenet_v3_large();
        let dw = net
            .layers
            .iter()
            .find(|l| l.name.contains("conv3x3g64"))
            .expect("depthwise with 64 groups");
        let (g, groups) = dw.gemm();
        assert_eq!(groups, 64);
        assert_eq!((g.k, g.n), (9, 1));
    }

    #[test]
    fn first_block_skips_expansion() {
        // exp == in_c for block 1, so no expand conv: stem then depthwise.
        let net = mobilenet_v3_large();
        match &net.layers[1].kind {
            LayerKind::Conv2d { groups, c_in, .. } => {
                assert_eq!(*groups, 16);
                assert_eq!(*c_in, 16);
            }
            _ => panic!("expected depthwise after stem"),
        }
    }

    #[test]
    fn se_blocks_present() {
        let net = mobilenet_v3_large();
        let se_fcs = net
            .layers
            .iter()
            .filter(|l| l.name.contains(".se."))
            .count();
        // 8 SE blocks x 2 FCs.
        assert_eq!(se_fcs, 16);
    }

    #[test]
    fn make_divisible_behaviour() {
        // 18 rounds to 16, but 16 < 0.9*18 so it bumps to 24.
        assert_eq!(make_divisible(18), 24);
        assert_eq!(make_divisible(30), 32);
        assert_eq!(make_divisible(240 / 4), 64);
        assert_eq!(make_divisible(4), 8);
    }

    #[test]
    fn final_geometry() {
        // 224 / 32 = 7 at the head conv.
        let net = mobilenet_v3_large();
        let head = net
            .layers
            .iter()
            .rev()
            .find(|l| matches!(l.kind, LayerKind::Conv2d { .. }))
            .unwrap();
        assert_eq!(head.input, SpatialDims::square(7));
        assert_eq!(head.c_out(), 960);
    }
}
