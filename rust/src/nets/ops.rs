//! The layer-stack builder DSL the model zoo is written in. It tracks the
//! current spatial dims and channel count so each architecture module reads
//! like its paper's table, and it auto-names layers for the per-layer
//! reports.
//!
//! Only GEMM-bearing operators become [`Layer`]s; pooling and activation
//! update the tracked geometry but move no matrix operands (they are
//! metric-neutral in the paper's model).

use crate::model::layer::{Layer, SpatialDims};

/// A sequential stack under construction.
#[derive(Debug, Clone)]
pub struct Stack {
    pub net_name: String,
    pub layers: Vec<Layer>,
    pub dims: SpatialDims,
    pub channels: usize,
    idx: usize,
}

impl Stack {
    pub fn new(net_name: impl Into<String>, input: SpatialDims, channels: usize) -> Stack {
        Stack {
            net_name: net_name.into(),
            layers: Vec::new(),
            dims: input,
            channels,
            idx: 0,
        }
    }

    fn next_name(&mut self, op: &str) -> String {
        self.idx += 1;
        format!("{}.{:03}.{}", self.net_name, self.idx, op)
    }

    /// Standard convolution; updates dims and channels.
    pub fn conv(&mut self, c_out: usize, k: usize, stride: usize, pad: usize) -> &mut Self {
        self.conv_g(c_out, k, stride, pad, 1)
    }

    /// Grouped convolution.
    pub fn conv_g(
        &mut self,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> &mut Self {
        let name = self.next_name(&format!("conv{k}x{k}g{groups}"));
        let l = Layer::conv(name, self.dims, self.channels, c_out, k, stride, pad, groups);
        self.dims = l.output_dims();
        self.channels = c_out;
        self.layers.push(l);
        self
    }

    /// Depthwise convolution (groups == channels, channel-preserving).
    pub fn conv_dw(&mut self, k: usize, stride: usize, pad: usize) -> &mut Self {
        let c = self.channels;
        self.conv_g(c, k, stride, pad, c)
    }

    /// Pointwise 1x1 convolution.
    pub fn conv_1x1(&mut self, c_out: usize) -> &mut Self {
        self.conv(c_out, 1, 1, 0)
    }

    /// Max/avg pooling: geometry only.
    pub fn pool(&mut self, k: usize, stride: usize, pad: usize) -> &mut Self {
        let probe = Layer::conv("pool-probe", self.dims, 1, 1, k, stride, pad, 1);
        self.dims = probe.output_dims();
        self
    }

    /// Pooling with torch-style `ceil_mode=True` (GoogLeNet, DenseNet
    /// transitions use it). Output = ceil((in + 2p - k) / s) + 1.
    pub fn pool_ceil(&mut self, k: usize, stride: usize, pad: usize) -> &mut Self {
        let out = |i: usize| (i + 2 * pad - k + stride - 1) / stride + 1;
        self.dims = SpatialDims {
            h: out(self.dims.h),
            w: out(self.dims.w),
        };
        self
    }

    /// Global average pooling: dims to 1x1.
    pub fn global_pool(&mut self) -> &mut Self {
        self.dims = SpatialDims { h: 1, w: 1 };
        self
    }

    /// Fully-connected layer over the flattened feature map.
    pub fn linear(&mut self, out_features: usize) -> &mut Self {
        let in_features = self.channels * self.dims.h * self.dims.w;
        let name = self.next_name("fc");
        self.layers.push(Layer::linear(name, in_features, out_features));
        self.dims = SpatialDims { h: 1, w: 1 };
        self.channels = out_features;
        self
    }

    /// Squeeze-and-Excitation block: global pool + two 1x1 FCs (the GEMMs)
    /// + channel-wise rescale. Spatial dims are untouched.
    pub fn se_block(&mut self, squeeze_channels: usize) -> &mut Self {
        let c = self.channels;
        let n1 = self.next_name("se.squeeze");
        let n2 = self.next_name("se.expand");
        self.layers.push(Layer::linear(n1, c, squeeze_channels));
        self.layers.push(Layer::linear(n2, squeeze_channels, c));
        self
    }

    /// Override the tracked channel count (after a concat computed by the
    /// caller, e.g. inception modules / dense blocks).
    pub fn set_channels(&mut self, c: usize) -> &mut Self {
        self.channels = c;
        self
    }

    /// Snapshot of (dims, channels) for branch construction.
    pub fn at(&self) -> (SpatialDims, usize) {
        (self.dims, self.channels)
    }

    /// Append a branch: runs `f` on a fork of the stack sharing geometry,
    /// collects its layers, and returns the branch's resulting channels.
    /// The caller is responsible for `set_channels` with the concat total.
    pub fn branch(&mut self, tag: &str, f: impl FnOnce(&mut Stack)) -> usize {
        let mut fork = Stack {
            net_name: format!("{}.{}", self.net_name, tag),
            layers: Vec::new(),
            dims: self.dims,
            channels: self.channels,
            idx: 0,
        };
        f(&mut fork);
        let out_c = fork.channels;
        self.layers.extend(fork.layers);
        out_c
    }

    /// Like `branch` but also asserts the branch ends at the given spatial
    /// dims (concat requires all branches to agree).
    pub fn branch_expect(
        &mut self,
        tag: &str,
        expect: SpatialDims,
        f: impl FnOnce(&mut Stack),
    ) -> usize {
        let mut fork = Stack {
            net_name: format!("{}.{}", self.net_name, tag),
            layers: Vec::new(),
            dims: self.dims,
            channels: self.channels,
            idx: 0,
        };
        f(&mut fork);
        assert_eq!(
            fork.dims, expect,
            "branch '{tag}' of {} ends at {:?}, concat expects {:?}",
            self.net_name, fork.dims, expect
        );
        let out_c = fork.channels;
        self.layers.extend(fork.layers);
        out_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::LayerKind;

    #[test]
    fn sequential_tracking() {
        let mut s = Stack::new("t", SpatialDims::square(224), 3);
        s.conv(64, 7, 2, 3).pool(3, 2, 1).conv(128, 3, 1, 1);
        assert_eq!(s.dims, SpatialDims::square(56));
        assert_eq!(s.channels, 128);
        assert_eq!(s.layers.len(), 2); // pool emits no layer
    }

    #[test]
    fn pool_ceil_rounds_up() {
        let mut s = Stack::new("t", SpatialDims::square(112), 64);
        // floor: (112 - 3)/2 + 1 = 55; ceil: 56.
        s.pool_ceil(3, 2, 0);
        assert_eq!(s.dims, SpatialDims::square(56));
    }

    #[test]
    fn depthwise_preserves_channels() {
        let mut s = Stack::new("t", SpatialDims::square(14), 96);
        s.conv_dw(3, 1, 1);
        assert_eq!(s.channels, 96);
        match &s.layers[0].kind {
            LayerKind::Conv2d { groups, .. } => assert_eq!(*groups, 96),
            _ => panic!("not a conv"),
        }
    }

    #[test]
    fn linear_flattens() {
        let mut s = Stack::new("t", SpatialDims::square(7), 512);
        s.linear(4096);
        match &s.layers[0].kind {
            LayerKind::Linear { in_features, .. } => assert_eq!(*in_features, 512 * 49),
            _ => panic!("not linear"),
        }
        assert_eq!(s.channels, 4096);
    }

    #[test]
    fn se_block_emits_two_fcs() {
        let mut s = Stack::new("t", SpatialDims::square(14), 96);
        s.se_block(24);
        assert_eq!(s.layers.len(), 2);
        assert_eq!(s.channels, 96);
        assert_eq!(s.dims, SpatialDims::square(14));
    }

    #[test]
    fn branches_concat() {
        let mut s = Stack::new("t", SpatialDims::square(28), 192);
        let dims = s.dims;
        let mut total = 0;
        total += s.branch_expect("b1", dims, |b| {
            b.conv_1x1(64);
        });
        total += s.branch_expect("b2", dims, |b| {
            b.conv_1x1(96).conv(128, 3, 1, 1);
        });
        s.set_channels(total);
        assert_eq!(s.channels, 192);
        assert_eq!(s.layers.len(), 3);
        // Geometry untouched by branches.
        assert_eq!(s.dims, dims);
    }

    #[test]
    #[should_panic(expected = "concat expects")]
    fn branch_dim_mismatch_is_caught() {
        let mut s = Stack::new("t", SpatialDims::square(28), 64);
        let dims = s.dims;
        s.branch_expect("bad", dims, |b| {
            b.conv(32, 3, 2, 1); // stride 2 halves dims -> mismatch
        });
    }

    #[test]
    fn names_are_unique_and_prefixed() {
        let mut s = Stack::new("net", SpatialDims::square(8), 3);
        s.conv(8, 3, 1, 1).conv(8, 3, 1, 1);
        assert_ne!(s.layers[0].name, s.layers[1].name);
        assert!(s.layers[0].name.starts_with("net."));
    }
}
