//! DAG forms of the zoo networks (DESIGN.md §9).
//!
//! The paper's "advanced connectivity" families — residual (ResNet /
//! ResNeXt), dense (DenseNet) and multi-branch (GoogLeNet / BN-Inception)
//! — get real [`NetworkGraph`]s with `Add`/`Concat` junction nodes; every
//! other registry model lowers to the trivial chain. The graph builders
//! re-walk the same block structure as the flat `Vec<Layer>` builders and
//! wire connectivity *over the exact layers those builders produce*, so
//! `build_graph(name).to_network()` reproduces `build(name)` layer for
//! layer (tested across the registry) and the metrics stay byte-identical.

use crate::model::graph::{GraphNode, NetworkGraph, NodeId, NodeOp};
use crate::model::layer::Layer;
use crate::model::network::Network;
use crate::nets::densenet::{DENSENET121_BLOCKS, DENSENET201_BLOCKS, GROWTH};
use crate::nets::resnet::{BottleneckSpec, RESNET34_BLOCKS};

/// Construct the DAG form of a registry network. Chain-only architectures
/// (AlexNet, VGG, MobileNet, EfficientNet, the transformers, CapsNet)
/// return the degenerate linear lowering; returns `None` for unknown
/// names.
pub fn build_graph(name: &str) -> Option<NetworkGraph> {
    Some(match name {
        "resnet34" => basic_graph("resnet34", RESNET34_BLOCKS),
        "resnet50" => bottleneck_graph(&BottleneckSpec::resnet50()),
        "resnet152" => bottleneck_graph(&BottleneckSpec::resnet152()),
        "resnext152" => bottleneck_graph(&BottleneckSpec::resnext152()),
        "densenet121" => densenet_graph("densenet121", GROWTH, &DENSENET121_BLOCKS),
        "densenet201" => densenet_graph("densenet201", GROWTH, &DENSENET201_BLOCKS),
        "googlenet" => googlenet_graph(),
        "bninception" => bn_inception_graph(),
        other => NetworkGraph::chain(&crate::nets::build(other)?),
    })
}

/// Wires connectivity over the layers of an already-built chain network,
/// consuming them in push order — a graph builder re-walks the same loop
/// structure as its `Vec<Layer>` builder, so the lowered layer list is
/// identical by construction.
struct Assembler {
    layers: std::vec::IntoIter<Layer>,
    nodes: Vec<GraphNode>,
}

impl Assembler {
    fn new(net: Network) -> Assembler {
        Assembler {
            layers: net.layers.into_iter(),
            nodes: Vec::new(),
        }
    }

    /// Append the next chain layer as a node reading `input` (`None` =
    /// the network input).
    fn layer(&mut self, input: Option<NodeId>) -> NodeId {
        let l = self
            .layers
            .next()
            .expect("graph builder consumed more layers than the chain builder produced");
        let id = NodeId(self.nodes.len());
        self.nodes.push(GraphNode {
            name: l.name.clone(),
            op: NodeOp::Layer(l),
            inputs: input.into_iter().collect(),
        });
        id
    }

    fn junction(&mut self, name: String, op: NodeOp, inputs: Vec<NodeId>) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.nodes.push(GraphNode { name, op, inputs });
        id
    }

    fn add(&mut self, name: String, inputs: Vec<NodeId>) -> NodeId {
        self.junction(name, NodeOp::Add, inputs)
    }

    fn concat(&mut self, name: String, inputs: Vec<NodeId>) -> NodeId {
        self.junction(name, NodeOp::Concat, inputs)
    }

    fn finish(mut self, name: &str) -> NetworkGraph {
        assert!(
            self.layers.next().is_none(),
            "graph builder left chain layers unwired"
        );
        NetworkGraph::new(name, self.nodes).expect("zoo graph wiring is valid")
    }
}

/// Bottleneck ResNet/ResNeXt DAG: per block, a projection (first block of
/// each stage) or identity skip joins the 1x1–3x3–1x1 chain at an `Add`.
fn bottleneck_graph(spec: &BottleneckSpec) -> NetworkGraph {
    let net = crate::nets::resnet::bottleneck_net(spec);
    let name = net.name.clone();
    let mut a = Assembler::new(net);
    let mut cursor = a.layer(None); // stem conv (max-pool elided)
    for (stage, &blocks) in spec.stage_blocks.iter().enumerate() {
        for b in 0..blocks {
            let block_in = cursor;
            let skip = if b == 0 {
                a.layer(Some(block_in)) // projection shortcut
            } else {
                block_in // identity skip
            };
            let x = a.layer(Some(block_in)); // 1x1 reduce
            let x = a.layer(Some(x)); // 3x3 (grouped for ResNeXt)
            let x = a.layer(Some(x)); // 1x1 expand
            cursor = a.add(format!("{}.s{}b{}.add", name, stage + 1, b), vec![skip, x]);
        }
    }
    a.layer(Some(cursor)); // classifier (global pool elided)
    a.finish(&name)
}

/// Basic-block ResNet DAG (ResNet-18/34 family): two 3x3 convs per block,
/// projection only where geometry or channels change.
fn basic_graph(name: &str, stage_blocks: [usize; 4]) -> NetworkGraph {
    let net = crate::nets::resnet::basic_net(name, stage_blocks);
    let mut a = Assembler::new(net);
    let mut cursor = a.layer(None); // stem
    let mut in_c = 64usize;
    for (stage, &blocks) in stage_blocks.iter().enumerate() {
        let out_c = 64 << stage;
        for b in 0..blocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let block_in = cursor;
            let skip = if b == 0 && (stride != 1 || in_c != out_c) {
                a.layer(Some(block_in))
            } else {
                block_in
            };
            let x = a.layer(Some(block_in));
            let x = a.layer(Some(x));
            cursor = a.add(format!("{}.s{}b{}.add", name, stage + 1, b), vec![skip, x]);
            in_c = out_c;
        }
    }
    a.layer(Some(cursor));
    a.finish(name)
}

/// DenseNet-BC DAG with *faithful* dense connectivity: every dense
/// layer's bottleneck reads the concatenation of the block input and all
/// previous growth outputs, so each growth tensor stays live until the
/// block's final concatenation — the structure that makes DenseNet's
/// memory behaviour interesting.
fn densenet_graph(name: &str, growth: usize, block_layers: &[usize]) -> NetworkGraph {
    let net = crate::nets::densenet::densenet(name, growth, block_layers);
    let mut a = Assembler::new(net);
    let mut block_in = a.layer(None); // stem conv (max-pool elided)
    for (bi, &layers) in block_layers.iter().enumerate() {
        let mut feats: Vec<NodeId> = vec![block_in];
        for li in 0..layers {
            let input = if feats.len() == 1 {
                feats[0]
            } else {
                a.concat(
                    format!("{}.b{}l{}.cat", name, bi + 1, li + 1),
                    feats.clone(),
                )
            };
            let b = a.layer(Some(input)); // 1x1 bottleneck over the concat
            let g = a.layer(Some(b)); // 3x3 to `growth`
            feats.push(g);
        }
        let out = a.concat(format!("{}.b{}.out.cat", name, bi + 1), feats);
        block_in = if bi + 1 < block_layers.len() {
            a.layer(Some(out)) // transition 1x1 (avg-pool elided)
        } else {
            out
        };
    }
    a.layer(Some(block_in)); // classifier
    a.finish(name)
}

/// GoogLeNet DAG: each inception module fans the previous concat into four
/// branches (1x1 / 3x3 / 5x5 / pool-proj) merged by a `Concat`.
fn googlenet_graph() -> NetworkGraph {
    let net = crate::nets::inception::googlenet();
    let mut a = Assembler::new(net);
    let c = a.layer(None);
    let c = a.layer(Some(c));
    let mut cursor = a.layer(Some(c));
    for tag in ["3a", "3b", "4a", "4b", "4c", "4d", "4e", "5a", "5b"] {
        let b1 = a.layer(Some(cursor));
        let b3 = a.layer(Some(cursor));
        let b3 = a.layer(Some(b3));
        let b5 = a.layer(Some(cursor));
        let b5 = a.layer(Some(b5));
        let bp = a.layer(Some(cursor)); // pool (elided) + 1x1 projection
        cursor = a.concat(format!("googlenet.{tag}.cat"), vec![b1, b3, b5, bp]);
    }
    a.layer(Some(cursor)); // classifier
    a.finish("googlenet")
}

/// BN-Inception DAG. Regular modules have four branches (1x1, 3x3,
/// double-3x3, pool-proj); the stride-2 reduction modules drop the 1x1
/// branch and pass the *unprojected* pooled input straight into the
/// concat — a feature-map tensor the flat model cannot represent.
fn bn_inception_graph() -> NetworkGraph {
    let net = crate::nets::inception::bn_inception();
    let mut a = Assembler::new(net);
    let c = a.layer(None);
    let c = a.layer(Some(c));
    let mut cursor = a.layer(Some(c));
    let modules: [(&str, bool); 10] = [
        ("3a", false),
        ("3b", false),
        ("3c", true),
        ("4a", false),
        ("4b", false),
        ("4c", false),
        ("4d", false),
        ("4e", true),
        ("5a", false),
        ("5b", false),
    ];
    for (tag, reduce) in modules {
        cursor = if reduce {
            let b3 = a.layer(Some(cursor));
            let b3 = a.layer(Some(b3));
            let bd = a.layer(Some(cursor));
            let bd = a.layer(Some(bd));
            let bd = a.layer(Some(bd));
            // The max-pool branch passes the module input through.
            a.concat(format!("bninception.{tag}.cat"), vec![b3, bd, cursor])
        } else {
            let b1 = a.layer(Some(cursor));
            let b3 = a.layer(Some(cursor));
            let b3 = a.layer(Some(b3));
            let bd = a.layer(Some(cursor));
            let bd = a.layer(Some(bd));
            let bd = a.layer(Some(bd));
            let bp = a.layer(Some(cursor));
            a.concat(format!("bninception.{tag}.cat"), vec![b1, b3, bd, bp])
        };
    }
    a.layer(Some(cursor)); // classifier
    a.finish("bninception")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArrayConfig;
    use crate::model::memory::MemoryAnalysis;
    use crate::model::multi::MultiArrayConfig;
    use crate::model::workload::EvalCache;
    use crate::nets::{build, ALL_MODELS};

    #[test]
    fn every_model_has_a_graph_whose_lowering_is_exact() {
        for name in ALL_MODELS {
            let g = build_graph(name).unwrap_or_else(|| panic!("{name} missing"));
            let flat = build(name).unwrap();
            assert_eq!(g.name, name);
            assert_eq!(g.to_network().layers, flat.layers, "{name} layer parity");
            assert_eq!(g.params(), flat.params(), "{name} params");
            assert_eq!(g.macs(), flat.macs(), "{name} macs");
        }
        assert!(build_graph("lenet-9000").is_none());
    }

    #[test]
    fn graph_metrics_are_byte_identical_to_the_flat_path() {
        let cfg = ArrayConfig::new(96, 48);
        for name in ALL_MODELS {
            let g = build_graph(name).unwrap();
            let flat = build(name).unwrap();
            assert_eq!(g.metrics(&cfg), flat.metrics(&cfg), "{name}");
        }
    }

    #[test]
    fn connectivity_families_have_their_junction_counts() {
        for (name, junctions) in [
            ("resnet34", 16),
            ("resnet50", 16),
            ("resnet152", 50),
            ("resnext152", 50),
            ("densenet121", 58),
            ("densenet201", 98),
            ("googlenet", 9),
            ("bninception", 10),
        ] {
            let g = build_graph(name).unwrap();
            assert_eq!(g.junction_count(), junctions, "{name}");
            assert!(!g.is_chain(), "{name} should be a DAG");
        }
        for name in ["alexnet", "vgg16", "mobilenetv3l", "efficientnetb0", "bertbase-s128"] {
            assert!(
                build_graph(name).unwrap().is_chain(),
                "{name} should lower to a chain"
            );
        }
    }

    #[test]
    fn resnet50_peak_residency_exceeds_the_linear_estimate() {
        // Acceptance: skip tensors held across bottleneck blocks push the
        // true peak strictly above the per-layer maximum.
        let g = build_graph("resnet50").unwrap();
        let cfg = ArrayConfig::new(128, 128);
        let live = g.liveness(&cfg);
        let linear = MemoryAnalysis::of(&build("resnet50").unwrap(), &cfg);
        assert_eq!(live.chain_peak_bytes, linear.peak_working_set_bytes);
        assert!(
            live.peak_bytes > linear.peak_working_set_bytes,
            "graph peak {} should exceed linear estimate {}",
            live.peak_bytes,
            linear.peak_working_set_bytes
        );
    }

    #[test]
    fn densenet_keeps_a_whole_block_of_growth_tensors_live() {
        // Dense connectivity holds many small tensors at once (a block's
        // growth outputs plus its input); residual nets hold one skip.
        let cfg = ArrayConfig::new(128, 128);
        let dense = build_graph("densenet121").unwrap().liveness(&cfg);
        let res = build_graph("resnet50").unwrap().liveness(&cfg);
        let max_held = |l: &crate::model::graph::GraphLiveness| {
            l.steps.iter().map(|s| s.held_tensors).max().unwrap()
        };
        // Block 3 has 24 dense layers: its tail holds the block input plus
        // >20 growth tensors; ResNet never holds more than a couple.
        assert!(max_held(&dense) >= 20, "densenet held {}", max_held(&dense));
        assert!(max_held(&res) <= 4, "resnet held {}", max_held(&res));
        // And the dense peak strictly exceeds the linear-chain estimate.
        assert!(dense.peak_bytes > dense.chain_peak_bytes);
    }

    #[test]
    fn zoo_makespans_never_exceed_serialized() {
        // Acceptance: branch-parallel multi-array makespan ≤ serialized on
        // every zoo net, with equality on pure chains (and on one array).
        let cache = EvalCache::new();
        for name in ALL_MODELS {
            let g = build_graph(name).unwrap();
            for arrays in [1usize, 2, 4] {
                let cfg = MultiArrayConfig::new(arrays, ArrayConfig::new(32, 32));
                let s = g.schedule(&cfg, &cache);
                assert!(
                    s.makespan_cycles <= s.serialized_cycles,
                    "{name} on {arrays} arrays: {} > {}",
                    s.makespan_cycles,
                    s.serialized_cycles
                );
                assert!(
                    s.makespan_cycles >= s.critical_path_cycles,
                    "{name} on {arrays} arrays beats its critical path"
                );
                if arrays == 1 || g.is_chain() {
                    assert_eq!(
                        s.makespan_cycles, s.serialized_cycles,
                        "{name} on {arrays} arrays"
                    );
                }
            }
        }
    }

    #[test]
    fn branch_parallelism_pays_off_on_inception() {
        // GoogLeNet's four-way branches actually overlap on a bank.
        let g = build_graph("googlenet").unwrap();
        let cache = EvalCache::new();
        let s1 = g.schedule(&MultiArrayConfig::new(1, ArrayConfig::new(32, 32)), &cache);
        let s4 = g.schedule(&MultiArrayConfig::new(4, ArrayConfig::new(32, 32)), &cache);
        assert!(s4.makespan_cycles < s1.makespan_cycles);
        assert!(s4.speedup() > 1.0);
        // Movements are conserved — no weight duplication.
        assert_eq!(s1.total, s4.total);
    }

    #[test]
    fn dag_specs_round_trip_through_json() {
        for name in ["resnet50", "densenet121", "googlenet", "bninception", "alexnet"] {
            let g = build_graph(name).unwrap();
            let spec = g.to_json_spec();
            let back = NetworkGraph::from_json_spec(&spec).unwrap();
            assert_eq!(
                back.to_json_spec().to_string_compact(),
                spec.to_string_compact(),
                "{name}"
            );
            assert_eq!(back.to_network().layers, g.to_network().layers, "{name}");
        }
    }
}
