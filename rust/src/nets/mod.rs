//! The DNN model zoo of the paper's evaluation (Section 4.2): classic
//! straight-forward CNNs (AlexNet, VGG-16), multi-receptive-field models
//! (GoogLeNet, BN-Inception), advanced-connectivity models (ResNet-152,
//! DenseNet-201), and group-convolution models (ResNeXt-152 g=32,
//! MobileNetV3-Large, EfficientNet-B0) — plus transformer encoders as the
//! paper's named future-work extension.
//!
//! Architectures are generated from their block specifications (not
//! hard-coded layer tables) and sanity-checked against published parameter
//! and MAC counts.

pub mod alexnet;
pub mod capsnet;
pub mod densenet;
pub mod efficientnet;
pub mod graph;
pub mod inception;
pub mod mobilenet;
pub mod ops;
pub mod resnet;
pub mod transformer;
pub mod vgg;
pub mod zoo;

pub use graph::build_graph;
pub use zoo::{build, paper_models, ALL_MODELS, PAPER_MODELS};
