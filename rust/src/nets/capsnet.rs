//! CapsNet (Sabour, Frosst, Hinton, NIPS 2017) — the paper's §6 names
//! capsule networks among the emerging architectures to study on systolic
//! arrays. The interesting systolic property: the prediction step
//! (û_{j|i} = W_{ij} u_i) is thousands of *tiny* independent matrix
//! products (8x16 per capsule pair), the most extreme serialized-GEMM
//! workload in the zoo — encoded here through the grouped-GEMM machinery.

use crate::model::layer::{Layer, LayerKind, SpatialDims};
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// CapsNet over 28x28x1 MNIST input (encoder only; the reconstruction
/// decoder is a training-time auxiliary).
pub fn capsnet_mnist() -> Network {
    let mut s = Stack::new("capsnet", SpatialDims::square(28), 1);
    // conv1: 9x9, 256 channels, stride 1, valid padding -> 20x20.
    s.conv(256, 9, 1, 0);
    // PrimaryCaps: 9x9 conv stride 2 -> 6x6, 32 capsules x 8D = 256 ch.
    s.conv(256, 9, 2, 0);

    let mut layers = s.layers;
    // DigitCaps routing predictions: 1152 input capsules (32*6*6), each
    // mapped to 10 classes through its own 8->16 weight matrix:
    // 11520 independent GEMMs of (1, 8, 16), encoded as one grouped layer.
    let caps_in = 32 * 6 * 6;
    let classes = 10;
    layers.push(Layer {
        name: "capsnet.digitcaps.uhat".into(),
        kind: LayerKind::Conv2d {
            c_in: 8 * caps_in * classes,
            c_out: 16 * caps_in * classes,
            kernel: (1, 1),
            stride: (1, 1),
            padding: (0, 0),
            dilation: (1, 1),
            groups: caps_in * classes,
        },
        input: SpatialDims { h: 1, w: 1 },
        batch: 1,
    });
    // Routing agreement updates (3 iterations): s_j = sum_i c_ij u_hat —
    // per class a (1 x 1152) x (1152 x 16) product, 3 rounds.
    for round in 0..3 {
        layers.push(Layer {
            name: format!("capsnet.routing{round}"),
            kind: LayerKind::Conv2d {
                c_in: caps_in * classes,
                c_out: 16 * classes,
                kernel: (1, 1),
                stride: (1, 1),
                padding: (0, 0),
                dilation: (1, 1),
                groups: classes,
            },
            input: SpatialDims { h: 1, w: 1 },
            batch: 1,
        });
    }
    Network::new("capsnet", layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrayConfig, EnergyWeights};

    #[test]
    fn params_match_published_encoder() {
        // conv1 9*9*1*256 = 20.7k; primarycaps 9*9*256*256 = 5.31M;
        // W_ij: 1152*10*8*16 = 1.47M  -> ~6.8M encoder weights.
        let net = capsnet_mnist();
        let p = net.params() as f64 / 1e6;
        assert!((6.5..7.5).contains(&p), "params {p}M");
    }

    #[test]
    fn uhat_is_an_extreme_grouped_workload() {
        let net = capsnet_mnist();
        let uhat = net
            .layers
            .iter()
            .find(|l| l.name.contains("uhat"))
            .unwrap();
        let (g, groups) = uhat.gemm();
        assert_eq!(groups, 11520);
        assert_eq!((g.m, g.k, g.n), (1, 8, 16));
    }

    #[test]
    fn tiny_gemms_crater_utilization_on_big_arrays() {
        // The paper's future-work motivation quantified: a 128x128 array
        // achieves essentially zero utilization on the routing workload.
        let net = capsnet_mnist();
        let uhat = net
            .layers
            .iter()
            .find(|l| l.name.contains("uhat"))
            .unwrap();
        let big = uhat.metrics(&ArrayConfig::new(128, 128));
        let small = uhat.metrics(&ArrayConfig::new(8, 16));
        assert!(big.utilization(128 * 128) < 0.001);
        // Even a snug 8x16 array caps out around 3% (fill/drain dominates
        // M=1 passes), but that is still two orders of magnitude better.
        assert!(small.utilization(8 * 16) > 50.0 * big.utilization(128 * 128));
        let w = EnergyWeights::paper();
        // Full-array propagation makes the oversized array ~2.7x costlier.
        assert!(big.energy(&w) > 2.0 * small.energy(&w));
    }

    #[test]
    fn registered_in_zoo() {
        let net = crate::nets::build("capsnet").expect("capsnet registered");
        assert_eq!(net.name, "capsnet");
        assert!(net.macs() > 0);
    }
}
