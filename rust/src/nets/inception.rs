//! GoogLeNet (Inception-v1, Szegedy et al. 2015) and BN-Inception
//! (Inception-v2, Ioffe & Szegedy 2015). Multi-receptive-field branches
//! (1x1 / 3x3 / 5x5 / double-3x3) over the same input increase the
//! variance of GEMM operand dimensions — the paper's second architecture
//! family.
//!
//! Channel tables follow the published architectures (GoogLeNet Table 1;
//! BN-Inception as replicated by the reference Caffe/pretrained-models
//! implementations). Auxiliary classifiers are omitted: they are
//! train-time only and the paper evaluates inference.

use crate::model::layer::SpatialDims;
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// GoogLeNet inception module: (#1x1, #3x3red, #3x3, #5x5red, #5x5, pool-proj).
fn inception_v1(s: &mut Stack, tag: &str, c: (usize, usize, usize, usize, usize, usize)) {
    let (c1, c3r, c3, c5r, c5, cp) = c;
    let dims = s.at().0;
    let mut total = 0;
    total += s.branch_expect(&format!("{tag}.b1"), dims, |b| {
        b.conv_1x1(c1);
    });
    total += s.branch_expect(&format!("{tag}.b3"), dims, |b| {
        b.conv_1x1(c3r).conv(c3, 3, 1, 1);
    });
    total += s.branch_expect(&format!("{tag}.b5"), dims, |b| {
        b.conv_1x1(c5r).conv(c5, 5, 1, 2);
    });
    total += s.branch_expect(&format!("{tag}.bp"), dims, |b| {
        b.pool(3, 1, 1).conv_1x1(cp);
    });
    s.set_channels(total);
}

/// GoogLeNet over 224x224 input.
pub fn googlenet() -> Network {
    let mut s = Stack::new("googlenet", SpatialDims::square(224), 3);
    s.conv(64, 7, 2, 3); // 112
    s.pool_ceil(3, 2, 0); // 56
    s.conv_1x1(64).conv(192, 3, 1, 1);
    s.pool_ceil(3, 2, 0); // 28

    inception_v1(&mut s, "3a", (64, 96, 128, 16, 32, 32)); // 256
    inception_v1(&mut s, "3b", (128, 128, 192, 32, 96, 64)); // 480
    s.pool_ceil(3, 2, 0); // 14
    inception_v1(&mut s, "4a", (192, 96, 208, 16, 48, 64)); // 512
    inception_v1(&mut s, "4b", (160, 112, 224, 24, 64, 64)); // 512
    inception_v1(&mut s, "4c", (128, 128, 256, 24, 64, 64)); // 512
    inception_v1(&mut s, "4d", (112, 144, 288, 32, 64, 64)); // 528
    inception_v1(&mut s, "4e", (256, 160, 320, 32, 128, 128)); // 832
    s.pool_ceil(3, 2, 0); // 7
    inception_v1(&mut s, "5a", (256, 160, 320, 32, 128, 128)); // 832
    inception_v1(&mut s, "5b", (384, 192, 384, 48, 128, 128)); // 1024
    s.global_pool().linear(1000);
    Network::new("googlenet", s.layers)
}

/// BN-Inception module with the double-3x3 branch:
/// (#1x1, #3x3red, #3x3, #d3x3red, #d3x3, pool-proj, avg?).
fn inception_v2(
    s: &mut Stack,
    tag: &str,
    c: (usize, usize, usize, usize, usize, usize),
) {
    let (c1, c3r, c3, cdr, cd, cp) = c;
    let dims = s.at().0;
    let mut total = 0;
    total += s.branch_expect(&format!("{tag}.b1"), dims, |b| {
        b.conv_1x1(c1);
    });
    total += s.branch_expect(&format!("{tag}.b3"), dims, |b| {
        b.conv_1x1(c3r).conv(c3, 3, 1, 1);
    });
    total += s.branch_expect(&format!("{tag}.bd"), dims, |b| {
        b.conv_1x1(cdr).conv(cd, 3, 1, 1).conv(cd, 3, 1, 1);
    });
    total += s.branch_expect(&format!("{tag}.bp"), dims, |b| {
        b.pool(3, 1, 1).conv_1x1(cp);
    });
    s.set_channels(total);
}

/// BN-Inception stride-2 (grid reduction) module: no 1x1 branch, pooling
/// branch passes channels through unprojected.
fn inception_v2_reduce(s: &mut Stack, tag: &str, c: (usize, usize, usize, usize)) {
    let (c3r, c3, cdr, cd) = c;
    let in_c = s.at().1;
    let out_dims = {
        // 3x3 stride-2 pad-1 geometry.
        let d = s.at().0;
        SpatialDims {
            h: (d.h + 2 - 3) / 2 + 1,
            w: (d.w + 2 - 3) / 2 + 1,
        }
    };
    let mut total = 0;
    total += s.branch_expect(&format!("{tag}.b3"), out_dims, |b| {
        b.conv_1x1(c3r).conv(c3, 3, 2, 1);
    });
    total += s.branch_expect(&format!("{tag}.bd"), out_dims, |b| {
        b.conv_1x1(cdr).conv(cd, 3, 1, 1).conv(cd, 3, 2, 1);
    });
    // Max-pool branch: stride-2, channels pass through.
    total += in_c;
    s.pool(3, 2, 1);
    s.set_channels(total);
}

/// BN-Inception (Inception-v2) over 224x224 input.
pub fn bn_inception() -> Network {
    let mut s = Stack::new("bninception", SpatialDims::square(224), 3);
    s.conv(64, 7, 2, 3); // 112
    s.pool_ceil(3, 2, 0); // 56
    s.conv_1x1(64).conv(192, 3, 1, 1);
    s.pool_ceil(3, 2, 0); // 28

    inception_v2(&mut s, "3a", (64, 64, 64, 64, 96, 32)); // 256
    inception_v2(&mut s, "3b", (64, 64, 96, 64, 96, 64)); // 320
    inception_v2_reduce(&mut s, "3c", (128, 160, 64, 96)); // 576 @ 14
    inception_v2(&mut s, "4a", (224, 64, 96, 96, 128, 128)); // 576
    inception_v2(&mut s, "4b", (192, 96, 128, 96, 128, 128)); // 576
    inception_v2(&mut s, "4c", (160, 128, 160, 128, 160, 96)); // 576
    inception_v2(&mut s, "4d", (96, 128, 192, 160, 192, 96)); // 576
    inception_v2_reduce(&mut s, "4e", (128, 192, 192, 256)); // 1024 @ 7
    inception_v2(&mut s, "5a", (352, 192, 320, 160, 224, 128)); // 1024
    inception_v2(&mut s, "5b", (352, 192, 320, 192, 224, 128)); // 1024
    s.global_pool().linear(1000);
    Network::new("bninception", s.layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::LayerKind;

    #[test]
    fn googlenet_params_match_published() {
        // ~7M weights (the GoogLeNet paper's often-quoted figure; the
        // 5x5 branches and pool projections account for the spread across
        // published reimplementations).
        let p = googlenet().params() as f64 / 1e6;
        assert!((6.4..7.4).contains(&p), "params {p}M");
    }

    #[test]
    fn googlenet_macs_match_published() {
        // ~1.5 GMACs at 224x224.
        let g = googlenet().macs() as f64 / 1e9;
        assert!((1.3..1.7).contains(&g), "macs {g}G");
    }

    #[test]
    fn googlenet_module_output_channels() {
        // The classifier must see 1024 channels.
        let net = googlenet();
        match &net.layers.last().unwrap().kind {
            LayerKind::Linear { in_features, .. } => assert_eq!(*in_features, 1024),
            _ => panic!("classifier missing"),
        }
    }

    #[test]
    fn googlenet_layer_count() {
        // Stem 3 convs + 9 modules x 6 convs + fc = 58.
        assert_eq!(googlenet().layers.len(), 58);
    }

    #[test]
    fn bninception_params_match_published() {
        // ~10.9M weights (reference implementations: 11.3M incl. BN).
        let p = bn_inception().params() as f64 / 1e6;
        assert!((10.0..12.0).contains(&p), "params {p}M");
    }

    #[test]
    fn bninception_macs_match_published() {
        // ~2.0 GMACs at 224x224.
        let g = bn_inception().macs() as f64 / 1e9;
        assert!((1.7..2.3).contains(&g), "macs {g}G");
    }

    #[test]
    fn bninception_classifier_sees_1024() {
        match &bn_inception().layers.last().unwrap().kind {
            LayerKind::Linear { in_features, .. } => assert_eq!(*in_features, 1024),
            _ => panic!("classifier missing"),
        }
    }

    #[test]
    fn reduce_modules_halve_dims() {
        // After 3c the grid is 14x14; after 4e it is 7x7 — verified by the
        // input dims of the following modules' convs.
        let net = bn_inception();
        let four_a = net
            .layers
            .iter()
            .find(|l| l.name.contains("4a.b1"))
            .unwrap();
        assert_eq!(four_a.input, SpatialDims::square(14));
        let five_a = net
            .layers
            .iter()
            .find(|l| l.name.contains("5a.b1"))
            .unwrap();
        assert_eq!(five_a.input, SpatialDims::square(7));
    }

    #[test]
    fn operand_diversity_exceeds_plain_models() {
        // Inception's signature: more distinct GEMM shapes than VGG.
        let g_count = googlenet().gemm_histogram().len();
        let v_count = crate::nets::vgg::vgg16().gemm_histogram().len();
        assert!(g_count > v_count, "googlenet {g_count} vs vgg {v_count}");
    }
}
