//! ResNet (He et al., CVPR 2016) and ResNeXt (Xie et al., CVPR 2017)
//! bottleneck architectures. Residual connectivity itself moves no GEMM
//! operands, but it shapes them: bottlenecks make layers *thin* (the
//! reduced operand dimensions the paper discusses), and ResNeXt adds
//! cardinality — grouped 3x3 convolutions.

use crate::model::layer::SpatialDims;
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// Bottleneck-family configuration.
#[derive(Debug, Clone)]
pub struct BottleneckSpec {
    pub name: String,
    /// Blocks per stage (ResNet-152: [3, 8, 36, 3]).
    pub stage_blocks: [usize; 4],
    /// Grouped-conv cardinality for the 3x3 (1 = plain ResNet).
    pub cardinality: usize,
    /// Width of the 3x3 per stage, stage 1 value (doubles per stage).
    /// ResNet: 64; ResNeXt 32x4d: 128 (32 groups x 4d).
    pub base_width: usize,
}

impl BottleneckSpec {
    /// ResNet-50 (blocks [3, 4, 6, 3]).
    pub fn resnet50() -> BottleneckSpec {
        BottleneckSpec {
            name: "resnet50".into(),
            stage_blocks: [3, 4, 6, 3],
            cardinality: 1,
            base_width: 64,
        }
    }

    /// ResNet-152 (blocks [3, 8, 36, 3]) — the paper's case study.
    pub fn resnet152() -> BottleneckSpec {
        BottleneckSpec {
            name: "resnet152".into(),
            stage_blocks: [3, 8, 36, 3],
            cardinality: 1,
            base_width: 64,
        }
    }

    /// ResNeXt-152 32x4d — the paper's grouped representative.
    pub fn resnext152() -> BottleneckSpec {
        BottleneckSpec {
            name: "resnext152".into(),
            stage_blocks: [3, 8, 36, 3],
            cardinality: 32,
            base_width: 128,
        }
    }
}

/// ResNet-34's basic-block stage table.
pub const RESNET34_BLOCKS: [usize; 4] = [3, 4, 6, 3];

/// Build a bottleneck network over 224x224 input.
pub fn bottleneck_net(spec: &BottleneckSpec) -> Network {
    let mut s = Stack::new(spec.name.clone(), SpatialDims::square(224), 3);
    s.conv(64, 7, 2, 3); // stem -> 112x112
    s.pool(3, 2, 1); // -> 56x56

    let expansion = 4;
    let mut in_c = 64;
    for (stage, &blocks) in spec.stage_blocks.iter().enumerate() {
        let width = spec.base_width << stage; // 3x3 width this stage
        let out_c = (64 << stage) * expansion; // block output channels
        for b in 0..blocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            // Projection shortcut when geometry or channels change.
            if b == 0 {
                let (dims, _) = s.at();
                let proj = crate::model::layer::Layer::conv(
                    format!("{}.s{}b{}.proj", spec.name, stage + 1, b),
                    dims,
                    in_c,
                    out_c,
                    1,
                    stride,
                    0,
                    1,
                );
                s.layers.push(proj);
            }
            s.conv_1x1(width); // reduce
            s.conv_g(width, 3, stride, 1, spec.cardinality); // spatial
            s.conv_1x1(out_c); // expand
            in_c = out_c;
        }
    }
    s.global_pool().linear(1000);
    Network::new(spec.name.clone(), s.layers)
}

/// Basic-block ResNet (two 3x3 convs per block; ResNet-18/34 family) —
/// the pre-bottleneck design, used by ablations to contrast operand
/// shapes against the bottleneck models.
pub fn basic_net(name: &str, stage_blocks: [usize; 4]) -> Network {
    let mut s = Stack::new(name.to_string(), SpatialDims::square(224), 3);
    s.conv(64, 7, 2, 3);
    s.pool(3, 2, 1);
    let mut in_c = 64;
    for (stage, &blocks) in stage_blocks.iter().enumerate() {
        let out_c = 64 << stage;
        for b in 0..blocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            if b == 0 && (stride != 1 || in_c != out_c) {
                let (dims, _) = s.at();
                s.layers.push(crate::model::layer::Layer::conv(
                    format!("{}.s{}b{}.proj", name, stage + 1, b),
                    dims,
                    in_c,
                    out_c,
                    1,
                    stride,
                    0,
                    1,
                ));
            }
            s.conv(out_c, 3, stride, 1);
            s.conv(out_c, 3, 1, 1);
            in_c = out_c;
        }
    }
    s.global_pool().linear(1000);
    Network::new(name.to_string(), s.layers)
}

/// ResNet-34 (basic blocks [3, 4, 6, 3]).
pub fn resnet34() -> Network {
    basic_net("resnet34", RESNET34_BLOCKS)
}

/// ResNet-152: the paper's case-study model (Section 4.1).
pub fn resnet152() -> Network {
    bottleneck_net(&BottleneckSpec::resnet152())
}

/// ResNet-50 (used by ablations; same family).
pub fn resnet50() -> Network {
    bottleneck_net(&BottleneckSpec::resnet50())
}

/// ResNeXt-152 with cardinality 32 (32x4d widths), the paper's grouped
/// representative.
pub fn resnext152() -> Network {
    bottleneck_net(&BottleneckSpec::resnext152())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet152_layer_count() {
        // Stem + per block 3 convs + 4 projections + fc:
        // 1 + 3*(3+8+36+3) + 4 + 1 = 156.
        assert_eq!(resnet152().layers.len(), 156);
    }

    #[test]
    fn resnet152_params_match_published() {
        // 60.2M (torchvision, incl. BN/bias ~0.15M).
        let p = resnet152().params() as f64 / 1e6;
        assert!((59.0..61.0).contains(&p), "params {p}M");
    }

    #[test]
    fn resnet152_macs_match_published() {
        // ~11.5 GMACs at 224x224.
        let g = resnet152().macs() as f64 / 1e9;
        assert!((11.0..12.0).contains(&g), "macs {g}G");
    }

    #[test]
    fn resnet34_params_match_published() {
        // 21.8M in torchvision.
        let p = resnet34().params() as f64 / 1e6;
        assert!((21.0..22.5).contains(&p), "params {p}M");
        // ~3.6 GMACs.
        let g = resnet34().macs() as f64 / 1e9;
        assert!((3.3..3.9).contains(&g), "macs {g}G");
    }

    #[test]
    fn basic_blocks_have_fatter_3x3_operands_than_bottlenecks() {
        // ResNet-34's 3x3 convs reduce over K = 9*C at full width; the
        // bottleneck 3x3 sees a 4x thinner C. Compare stage-4 shapes.
        let b34 = resnet34();
        let l34 = b34
            .layers
            .iter()
            .rev()
            .find(|l| l.name.contains("conv3x3"))
            .unwrap();
        let (g34, _) = l34.gemm();
        assert_eq!(g34.k, 512 * 9);
        let b152 = resnet152();
        let l152 = b152
            .layers
            .iter()
            .rev()
            .find(|l| l.name.contains("conv3x3"))
            .unwrap();
        let (g152, _) = l152.gemm();
        assert_eq!(g152.k, 512 * 9); // stage-4 bottleneck width is 512 too
        // but the bottleneck net's N is the thin width, not the 4x output
        assert_eq!(g152.n, 512);
        assert_eq!(g34.n, 512);
    }

    #[test]
    fn resnet50_params_match_published() {
        // 25.56M in torchvision.
        let p = resnet50().params() as f64 / 1e6;
        assert!((25.0..26.0).contains(&p), "params {p}M");
    }

    #[test]
    fn resnext152_uses_grouped_convs() {
        let net = resnext152();
        let grouped = net
            .layers
            .iter()
            .filter(|l| match &l.kind {
                crate::model::layer::LayerKind::Conv2d { groups, .. } => *groups == 32,
                _ => false,
            })
            .count();
        assert_eq!(grouped, 3 + 8 + 36 + 3);
    }

    #[test]
    fn resnext_thinner_gemms_than_resnet() {
        // The grouped 3x3 has K and N divided by cardinality vs. a plain
        // conv of the same width.
        let rn = resnext152();
        let l = rn
            .layers
            .iter()
            .find(|l| l.name.contains("conv3x3g32"))
            .unwrap();
        let (g, groups) = l.gemm();
        assert_eq!(groups, 32);
        assert_eq!(g.k, (128 / 32) * 9);
        assert_eq!(g.n, 128 / 32);
    }

    #[test]
    fn stage_geometry() {
        // After the stem + pool we are at 56x56; stages end at 7x7.
        let net = resnet152();
        let last_conv = net
            .layers
            .iter()
            .rev()
            .find(|l| matches!(l.kind, crate::model::layer::LayerKind::Conv2d { .. }))
            .unwrap();
        assert_eq!(last_conv.input, SpatialDims::square(7));
    }
}
