//! DenseNet (Huang et al., CVPR 2017). Dense connectivity concatenates
//! all previous features, so the 1x1 bottleneck's K dimension grows
//! linearly with depth — the "high diversity in the operand's dimensions"
//! the paper attributes to dense connections.

use crate::model::layer::SpatialDims;
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// Generic DenseNet-BC. Each dense layer: 1x1 bottleneck to `4*growth`,
/// then 3x3 to `growth`, input channels += growth. Transitions halve
/// channels (compression 0.5) and avg-pool stride 2.
pub fn densenet(name: &str, growth: usize, block_layers: &[usize]) -> Network {
    let mut s = Stack::new(name.to_string(), SpatialDims::square(224), 3);
    let init = 2 * growth;
    s.conv(init, 7, 2, 3); // 112x112
    s.pool(3, 2, 1); // 56x56

    let mut channels = init;
    for (bi, &layers) in block_layers.iter().enumerate() {
        for _ in 0..layers {
            // Bottleneck reads the full concatenation.
            s.set_channels(channels);
            s.conv_1x1(4 * growth);
            s.conv(growth, 3, 1, 1);
            channels += growth;
        }
        if bi + 1 < block_layers.len() {
            // Transition: 1x1 compress to half, then 2x2 avg-pool s2.
            s.set_channels(channels);
            channels /= 2;
            s.conv_1x1(channels);
            s.pool(2, 2, 0);
        }
    }
    s.set_channels(channels);
    s.global_pool().linear(1000);
    Network::new(name.to_string(), s.layers)
}

/// The standard DenseNet-BC growth rate.
pub const GROWTH: usize = 32;

/// DenseNet-201's dense-block table.
pub const DENSENET201_BLOCKS: [usize; 4] = [6, 12, 48, 32];

/// DenseNet-121's dense-block table.
pub const DENSENET121_BLOCKS: [usize; 4] = [6, 12, 24, 16];

/// DenseNet-201 (growth 32, blocks 6/12/48/32) — the dense representative.
pub fn densenet201() -> Network {
    densenet("densenet201", GROWTH, &DENSENET201_BLOCKS)
}

/// DenseNet-121 for ablations.
pub fn densenet121() -> Network {
    densenet("densenet121", GROWTH, &DENSENET121_BLOCKS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::LayerKind;

    #[test]
    fn densenet201_layer_count() {
        // Stem + 2 per dense layer * (6+12+48+32) + 3 transitions + fc
        // = 1 + 196 + 3 + 1 = 201 GEMM layers (hence the name modulo BN).
        assert_eq!(densenet201().layers.len(), 201);
    }

    #[test]
    fn densenet201_params_match_published() {
        // 20.0M in torchvision (incl. BN); weights-only slightly lower.
        let p = densenet201().params() as f64 / 1e6;
        assert!((18.5..20.5).contains(&p), "params {p}M");
    }

    #[test]
    fn densenet201_macs_match_published() {
        // ~4.3 GMACs at 224x224.
        let g = densenet201().macs() as f64 / 1e9;
        assert!((4.0..4.7).contains(&g), "macs {g}G");
    }

    #[test]
    fn densenet121_params_match_published() {
        // 7.98M in torchvision.
        let p = densenet121().params() as f64 / 1e6;
        assert!((7.4..8.2).contains(&p), "params {p}M");
    }

    #[test]
    fn bottleneck_k_grows_with_depth() {
        // The 1x1 bottlenecks' input channels must increase by `growth`
        // within a block: the operand-diversity signature of DenseNet.
        let net = densenet201();
        let bottleneck_k: Vec<usize> = net
            .layers
            .iter()
            .filter_map(|l| match &l.kind {
                LayerKind::Conv2d {
                    c_in,
                    kernel: (1, 1),
                    c_out,
                    ..
                } if *c_out == 128 => Some(*c_in),
                _ => None,
            })
            .collect();
        // First block: 64, 96, 128, ... step 32.
        assert_eq!(&bottleneck_k[..4], &[64, 96, 128, 160]);
        // Operand diversity: many distinct K values.
        let mut uniq = bottleneck_k.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() > 40, "distinct bottleneck widths: {}", uniq.len());
    }

    #[test]
    fn final_channels_are_1920() {
        let net = densenet201();
        let fc = net.layers.last().unwrap();
        match &fc.kind {
            LayerKind::Linear { in_features, .. } => assert_eq!(*in_features, 1920),
            _ => panic!("last layer should be the classifier"),
        }
    }
}
