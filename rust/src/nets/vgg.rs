//! VGG-16 (Simonyan & Zisserman, ICLR 2015): configuration D. Uniform
//! 3x3 convolutions — the paper's example of a model whose GEMM operand
//! dimensions depend only on filter count and receptive field.

use crate::model::layer::SpatialDims;
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// VGG-16 over 224x224 RGB input.
pub fn vgg16() -> Network {
    let mut s = Stack::new("vgg16", SpatialDims::square(224), 3);
    for (reps, c) in [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)] {
        for _ in 0..reps {
            s.conv(c, 3, 1, 1);
        }
        s.pool(2, 2, 0);
    }
    s.linear(4096).linear(4096).linear(1000);
    Network::new("vgg16", s.layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 13 convs + 3 FCs.
        assert_eq!(vgg16().layers.len(), 16);
    }

    #[test]
    fn parameter_count_matches_published() {
        // 138.3M with biases; ~138.3M weights-only is ~138.3 - 0.05M.
        let p = vgg16().params() as f64 / 1e6;
        assert!((136.0..140.0).contains(&p), "params {p}M");
    }

    #[test]
    fn mac_count_matches_published() {
        // ~15.5 GMACs at 224x224.
        let g = vgg16().macs() as f64 / 1e9;
        assert!((15.0..16.0).contains(&g), "macs {g}G");
    }

    #[test]
    fn fc1_dominates_params() {
        let net = vgg16();
        let fc1 = net.layers.iter().find(|l| l.name.ends_with("fc")).unwrap();
        // 7x7x512 x 4096 = 102.76M.
        assert_eq!(fc1.params(), 7 * 7 * 512 * 4096);
    }
}
