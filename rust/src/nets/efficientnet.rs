//! EfficientNet-B0 (Tan & Le, ICML 2019): MBConv (inverted residual with
//! depthwise conv + SE) backbone found by NAS — the paper's second
//! depthwise representative.

use crate::model::layer::SpatialDims;
use crate::model::network::Network;
use crate::nets::ops::Stack;

/// One MBConv stage of the B0 table:
/// (expansion factor, out channels, repeats, stride of first, kernel).
struct Stage {
    e: usize,
    c: usize,
    r: usize,
    s: usize,
    k: usize,
}

/// EfficientNet-B0 over 224x224 input.
pub fn efficientnet_b0() -> Network {
    let stages = [
        Stage { e: 1, c: 16, r: 1, s: 1, k: 3 },
        Stage { e: 6, c: 24, r: 2, s: 2, k: 3 },
        Stage { e: 6, c: 40, r: 2, s: 2, k: 5 },
        Stage { e: 6, c: 80, r: 3, s: 2, k: 3 },
        Stage { e: 6, c: 112, r: 3, s: 1, k: 5 },
        Stage { e: 6, c: 192, r: 4, s: 2, k: 5 },
        Stage { e: 6, c: 320, r: 1, s: 1, k: 3 },
    ];

    let mut s = Stack::new("efficientnetb0", SpatialDims::square(224), 3);
    s.conv(32, 3, 2, 1); // stem -> 112x112

    for st in &stages {
        for rep in 0..st.r {
            let stride = if rep == 0 { st.s } else { 1 };
            let in_c = s.at().1;
            let exp_c = in_c * st.e;
            if st.e != 1 {
                s.conv_1x1(exp_c); // expand
            }
            s.conv_dw(st.k, stride, st.k / 2); // depthwise
            // SE squeeze ratio 0.25 of the block *input* channels.
            s.se_block(((in_c as f64) * 0.25).max(1.0) as usize);
            s.conv_1x1(st.c); // project
        }
    }

    s.conv_1x1(1280); // head
    s.global_pool().linear(1000);
    Network::new("efficientnetb0", s.layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::LayerKind;

    #[test]
    fn params_match_published() {
        // 5.29M in the paper (incl. BN); weights-only ~5.2M.
        let p = efficientnet_b0().params() as f64 / 1e6;
        assert!((4.8..5.5).contains(&p), "params {p}M");
    }

    #[test]
    fn macs_match_published() {
        // ~390 MMACs at 224x224 (0.39B FLOPs/2).
        let m = efficientnet_b0().macs() as f64 / 1e6;
        assert!((360.0..420.0).contains(&m), "macs {m}M");
    }

    #[test]
    fn block_count() {
        // 16 MBConv blocks in B0.
        let net = efficientnet_b0();
        let dw = net
            .layers
            .iter()
            .filter(|l| match &l.kind {
                LayerKind::Conv2d { groups, c_in, .. } => groups == c_in,
                _ => false,
            })
            .count();
        assert_eq!(dw, 16);
    }

    #[test]
    fn every_block_has_se() {
        let net = efficientnet_b0();
        let se_fcs = net
            .layers
            .iter()
            .filter(|l| l.name.contains(".se."))
            .count();
        assert_eq!(se_fcs, 32); // 16 blocks x 2 FCs
    }

    #[test]
    fn head_sees_7x7() {
        let net = efficientnet_b0();
        let head = net
            .layers
            .iter()
            .rev()
            .find(|l| matches!(l.kind, LayerKind::Conv2d { .. }))
            .unwrap();
        assert_eq!(head.input, SpatialDims::square(7));
        assert_eq!(head.c_out(), 1280);
    }

    #[test]
    fn depthwise_operands_are_tiny() {
        // The 5x5 depthwise on 672 channels is 672 serialized 25x1 GEMMs:
        // the worst case for any large array.
        let net = efficientnet_b0();
        let dw = net
            .layers
            .iter()
            .find(|l| l.name.contains("conv5x5g672"))
            .expect("5x5 depthwise at 672ch");
        let (g, groups) = dw.gemm();
        assert_eq!(groups, 672);
        assert_eq!((g.k, g.n), (25, 1));
    }
}
