//! The model-zoo registry: lookup by name and the paper's evaluation set.

use crate::model::network::Network;

/// Names of the nine CNN models of the paper's Figure 4, in paper order.
pub const PAPER_MODELS: [&str; 9] = [
    "alexnet",
    "vgg16",
    "googlenet",
    "bninception",
    "resnet152",
    "densenet201",
    "resnext152",
    "mobilenetv3l",
    "efficientnetb0",
];

/// All registered model names (paper set + extensions).
pub const ALL_MODELS: [&str; 15] = [
    "alexnet",
    "vgg16",
    "googlenet",
    "bninception",
    "resnet152",
    "densenet201",
    "resnext152",
    "mobilenetv3l",
    "efficientnetb0",
    // extensions / ablation helpers
    "resnet34",
    "resnet50",
    "densenet121",
    "bertbase-s128",
    "bertbase-s512",
    "capsnet",
];

/// Construct a network by registry name.
pub fn build(name: &str) -> Option<Network> {
    Some(match name {
        "alexnet" => super::alexnet::alexnet(),
        "vgg16" => super::vgg::vgg16(),
        "googlenet" => super::inception::googlenet(),
        "bninception" => super::inception::bn_inception(),
        "resnet152" => super::resnet::resnet152(),
        "resnet34" => super::resnet::resnet34(),
        "resnet50" => super::resnet::resnet50(),
        "densenet201" => super::densenet::densenet201(),
        "densenet121" => super::densenet::densenet121(),
        "resnext152" => super::resnet::resnext152(),
        "mobilenetv3l" => super::mobilenet::mobilenet_v3_large(),
        "efficientnetb0" => super::efficientnet::efficientnet_b0(),
        "bertbase-s128" => super::transformer::bert_base_seq128(),
        "capsnet" => super::capsnet::capsnet_mnist(),
        "bertbase-s512" => super::transformer::transformer_encoder(
            &super::transformer::TransformerSpec {
                name: "bertbase-s512".into(),
                layers: 12,
                d_model: 768,
                heads: 12,
                d_ff: 3072,
                seq_len: 512,
            },
        ),
        _ => return None,
    })
}

/// Export a zoo network as the layer-list JSON document the `camuy::api`
/// ingestion path consumes — dump a built-in model, tweak it, re-register
/// it under a new name (`camuy zoo --net NAME`).
pub fn spec_json(name: &str) -> Option<crate::util::json::Json> {
    build(name).map(|n| n.to_json_spec())
}

/// The paper's nine evaluation models.
pub fn paper_models() -> Vec<Network> {
    PAPER_MODELS
        .iter()
        .map(|n| build(n).expect("registered"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_name_builds() {
        for name in ALL_MODELS {
            let net = build(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(net.name, name);
            assert!(!net.layers.is_empty(), "{name} has no layers");
            assert!(net.macs() > 0, "{name} has zero MACs");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(build("lenet-9000").is_none());
        assert!(spec_json("lenet-9000").is_none());
    }

    #[test]
    fn spec_json_reconstructs_every_model() {
        // The JSON export is lossless: params, MACs and the GEMM histogram
        // survive a dump → parse round trip for the entire registry.
        for name in ALL_MODELS {
            let orig = build(name).unwrap();
            let back =
                crate::model::network::Network::from_json_spec(&spec_json(name).unwrap())
                    .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(back.params(), orig.params(), "{name} params");
            assert_eq!(back.macs(), orig.macs(), "{name} macs");
            assert_eq!(back.gemm_histogram(), orig.gemm_histogram(), "{name} histogram");
        }
    }

    #[test]
    fn spec_json_round_trips_exactly_for_every_model() {
        // Stronger than the aggregate check above: the round trip must
        // reproduce every network *structurally* — names, layer order,
        // kinds, geometry, batch — across the whole registry.
        for name in ALL_MODELS {
            let orig = build(name).unwrap();
            let back = crate::model::network::Network::from_json_spec(&orig.to_json_spec())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(back, orig, "{name} round trip is not exact");
        }
    }

    #[test]
    fn paper_set_is_nine() {
        let nets = paper_models();
        assert_eq!(nets.len(), 9);
    }

    #[test]
    fn every_layer_shape_is_consistent() {
        // Each layer's GEMM must be well-formed (no zero dims) and groups
        // divide channels — catches any table typo in the zoo.
        for name in ALL_MODELS {
            let net = build(name).unwrap();
            for l in &net.layers {
                let (g, groups) = l.gemm();
                assert!(groups >= 1, "{name}/{}", l.name);
                assert!(
                    g.m > 0 && g.k > 0 && g.n > 0,
                    "{name}/{} degenerate GEMM {g:?}",
                    l.name
                );
            }
        }
    }

    #[test]
    fn relative_model_sizes_are_sane() {
        let p = |n: &str| build(n).unwrap().params();
        // VGG-16 is the largest of the paper set; MobileNet/EfficientNet
        // the smallest.
        assert!(p("vgg16") > p("resnet152"));
        assert!(p("resnet152") > p("densenet201"));
        assert!(p("densenet201") > p("mobilenetv3l"));
        let m = |n: &str| build(n).unwrap().macs();
        // VGG has the most MACs; MobileNetV3 the fewest.
        for other in PAPER_MODELS {
            if other != "vgg16" {
                assert!(m("vgg16") > m(other), "vgg16 vs {other}");
            }
            if other != "mobilenetv3l" {
                assert!(m("mobilenetv3l") < m(other), "mobilenet vs {other}");
            }
        }
    }
}
