//! Off-chip memory analysis. The paper's CAMUY deliberately keeps weights
//! and activations in the on-chip Unified Buffer and Equation 1 therefore
//! has no DRAM term — but several zoo layers (VGG-16's fc1 weights alone
//! are ~98 MiB at int8) cannot fit any plausible UB. This module makes the
//! simplification visible and quantifiable: per-layer working sets, spill
//! classification, the DRAM traffic a spilling layer would generate, and
//! the energy overhead at the Eyeriss/Horowitz-style DRAM cost ratio
//! (~200x a register access; Chen et al. 2016, Horowitz 2014).

use crate::config::{ArrayConfig, EnergyWeights};
use crate::model::bandwidth::ub_working_set_bytes;
use crate::model::layer::Layer;
use crate::model::network::Network;

/// Relative energy of one DRAM word access in Equation-1 units
/// (register access = 1). Eyeriss reports ~200x.
pub const DRAM_COST: f64 = 200.0;

/// Per-layer memory classification.
#[derive(Debug, Clone)]
pub struct LayerMemory {
    pub layer: String,
    pub working_set_bytes: u64,
    pub fits: bool,
    /// Words that must stream from DRAM when the layer spills. Model: the
    /// weight matrix streams once per accumulator M-chunk re-read (it no
    /// longer persists in the UB), activations and outputs stream once.
    pub dram_words: u64,
}

/// Whole-network memory report.
#[derive(Debug, Clone)]
pub struct MemoryAnalysis {
    pub layers: Vec<LayerMemory>,
    pub peak_working_set_bytes: u64,
    pub spilling_layers: usize,
    pub total_dram_words: u64,
}

impl MemoryAnalysis {
    pub fn of(net: &Network, cfg: &ArrayConfig) -> MemoryAnalysis {
        let mut layers = Vec::with_capacity(net.layers.len());
        let mut peak = 0u64;
        let mut spills = 0usize;
        let mut dram_total = 0u64;
        for l in &net.layers {
            let ws = ub_working_set_bytes(l, cfg);
            peak = peak.max(ws);
            let fits = ws <= cfg.ub_bytes as u64;
            let dram_words = if fits { 0 } else { spill_words(l, cfg) };
            if !fits {
                spills += 1;
                dram_total += dram_words;
            }
            layers.push(LayerMemory {
                layer: l.name.clone(),
                working_set_bytes: ws,
                fits,
                dram_words,
            });
        }
        MemoryAnalysis {
            layers,
            peak_working_set_bytes: peak,
            spilling_layers: spills,
            total_dram_words: dram_total,
        }
    }

    /// Energy overhead of the spills in Equation-1 units: words x 200.
    pub fn dram_energy(&self) -> f64 {
        self.total_dram_words as f64 * DRAM_COST
    }

    /// Eq.1 energy including the DRAM overhead — how much the paper's
    /// on-chip-only assumption undercounts for this (network, config).
    pub fn corrected_energy(&self, net: &Network, cfg: &ArrayConfig, w: &EnergyWeights) -> f64 {
        net.metrics(cfg).energy(w) + self.dram_energy()
    }
}

/// DRAM words streamed by a spilling layer: every UB weight re-read misses
/// (the working set exceeded the buffer, so weights cannot persist across
/// M-chunks), plus one pass of activations in and outputs out.
fn spill_words(layer: &Layer, cfg: &ArrayConfig) -> u64 {
    let m = layer.metrics(cfg);
    let (gemm, groups) = layer.gemm();
    let g = groups as u64;
    m.movements.ub_weight_reads // weight streams (already counts chunk re-reads)
        + gemm.m as u64 * gemm.k as u64 * g // activations in
        + gemm.m as u64 * gemm.n as u64 * g // outputs out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::SpatialDims;

    fn cfg() -> ArrayConfig {
        ArrayConfig::new(64, 64)
    }

    #[test]
    fn small_net_never_spills() {
        let net = Network::new(
            "s",
            vec![Layer::conv("c", SpatialDims::square(8), 4, 8, 3, 1, 1, 1)],
        );
        let a = MemoryAnalysis::of(&net, &cfg());
        assert_eq!(a.spilling_layers, 0);
        assert_eq!(a.total_dram_words, 0);
        assert_eq!(a.dram_energy(), 0.0);
        assert!(a.peak_working_set_bytes > 0);
    }

    #[test]
    fn vgg16_fc_layers_spill_a_24mib_ub() {
        let net = crate::nets::build("vgg16").unwrap();
        let a = MemoryAnalysis::of(&net, &cfg());
        // Early 3x3 convs spill through im2col activation amplification
        // (224^2 x 576 patches ≈ 29 MB) and fc1's 25088x4096 = ~98 MiB
        // weight matrix definitely spills.
        assert!(a.spilling_layers >= 2, "spills: {}", a.spilling_layers);
        let fc1 = a
            .layers
            .iter()
            .find(|l| l.layer.ends_with("fc") && l.working_set_bytes > 90 << 20)
            .expect("fc1 in the report");
        assert!(!fc1.fits);
        assert!(fc1.dram_words >= 25088 * 4096);
        // The corrected energy strictly exceeds the on-chip-only figure.
        let w = EnergyWeights::paper();
        assert!(a.corrected_energy(&net, &cfg(), &w) > net.metrics(&cfg()).energy(&w));
    }

    #[test]
    fn resnet152_stays_on_chip() {
        // Bottleneck layers are small; nothing exceeds 24 MiB.
        let net = crate::nets::build("resnet152").unwrap();
        let a = MemoryAnalysis::of(&net, &cfg());
        assert_eq!(a.spilling_layers, 0, "unexpected spills");
    }

    #[test]
    fn peak_tracks_the_largest_layer() {
        let net = crate::nets::build("vgg16").unwrap();
        let a = MemoryAnalysis::of(&net, &cfg());
        let max = a.layers.iter().map(|l| l.working_set_bytes).max().unwrap();
        assert_eq!(a.peak_working_set_bytes, max);
    }

    #[test]
    fn bigger_ub_removes_spills() {
        let net = crate::nets::build("vgg16").unwrap();
        let roomy = ArrayConfig::new(64, 64).with_ub_bytes(1 << 30);
        let a = MemoryAnalysis::of(&net, &roomy);
        assert_eq!(a.spilling_layers, 0);
    }
}
