//! Bandwidth requirements for stall-free execution. The paper reports
//! "resulting bandwidth requirements for a stall-free execution" and the
//! weight-update concurrency; this module converts access counts and the
//! schedule structure into bytes/cycle figures using the configured
//! operand bitwidths.

use crate::config::ArrayConfig;
use crate::metrics::Metrics;

/// Average sustained bandwidths over a run, in bytes per cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandwidthReport {
    /// Unified Buffer activation read port.
    pub ub_act_read: f64,
    /// Unified Buffer weight read port (Weight Fetcher).
    pub ub_weight_read: f64,
    /// Unified Buffer output write port.
    pub ub_out_write: f64,
    /// Array -> accumulator port.
    pub accumulator: f64,
    /// Peak concurrent weight-tile updates needed for stall-free execution
    /// (1 when double buffering hides all loads; 2 when any load was
    /// exposed, i.e. the schedule stalled).
    pub weight_update_concurrency: u32,
}

impl BandwidthReport {
    pub fn from_metrics(m: &Metrics, cfg: &ArrayConfig) -> BandwidthReport {
        let cyc = m.cycles.max(1) as f64;
        let wb = cfg.weight_bits as f64 / 8.0;
        let ab = cfg.act_bits as f64 / 8.0;
        let ob = cfg.out_bits as f64 / 8.0;
        BandwidthReport {
            ub_act_read: m.movements.ub_act_reads as f64 * ab / cyc,
            ub_weight_read: m.movements.ub_weight_reads as f64 * wb / cyc,
            ub_out_write: m.movements.ub_out_writes as f64 * ob / cyc,
            accumulator: m.movements.aa_writes as f64 * ob / cyc,
            weight_update_concurrency: if m.stall_cycles > 0 { 2 } else { 1 },
        }
    }

    /// Total Unified Buffer port pressure.
    pub fn ub_total(&self) -> f64 {
        self.ub_act_read + self.ub_weight_read + self.ub_out_write
    }
}

/// Unified Buffer working set of one layer in bytes: input activations +
/// weights + output activations at the configured widths. CAMUY holds all
/// three on chip (paper §3), so a layer only runs without DRAM spills when
/// this fits `cfg.ub_bytes`.
pub fn ub_working_set_bytes(layer: &crate::model::layer::Layer, cfg: &ArrayConfig) -> u64 {
    let (gemm, groups) = layer.gemm();
    let g = groups as u64;
    let acts = gemm.m as u64 * gemm.k as u64 * g * cfg.act_bits as u64;
    let weights = gemm.k as u64 * gemm.n as u64 * g * cfg.weight_bits as u64;
    let outs = gemm.m as u64 * gemm.n as u64 * g * cfg.out_bits as u64;
    (acts + weights + outs) / 8
}

/// Does the layer's working set fit the Unified Buffer?
pub fn fits_unified_buffer(layer: &crate::model::layer::Layer, cfg: &ArrayConfig) -> bool {
    ub_working_set_bytes(layer, cfg) <= cfg.ub_bytes as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gemm::ws_metrics;
    use crate::model::layer::{Layer, SpatialDims};
    use crate::model::schedule::GemmShape;

    #[test]
    fn bytes_scale_with_bitwidths() {
        let g = GemmShape::new(64, 32, 32);
        let cfg8 = ArrayConfig::new(16, 16);
        let cfg16 = ArrayConfig::new(16, 16).with_bits(16, 16, 32);
        let m = ws_metrics(g, &cfg8);
        let b8 = BandwidthReport::from_metrics(&m, &cfg8);
        let b16 = BandwidthReport::from_metrics(&m, &cfg16);
        assert!((b16.ub_act_read / b8.ub_act_read - 2.0).abs() < 1e-12);
        assert!((b16.ub_weight_read / b8.ub_weight_read - 2.0).abs() < 1e-12);
        // Output bits unchanged.
        assert!((b16.ub_out_write - b8.ub_out_write).abs() < 1e-12);
    }

    #[test]
    fn concurrency_tracks_stalls() {
        let cfg = ArrayConfig::new(64, 4);
        // The WS schedule is structurally stall-free (full-height drains
        // always cover the k_t-cycle loads): single concurrent update.
        let smooth = ws_metrics(GemmShape::new(512, 64, 4), &cfg);
        assert_eq!(smooth.stall_cycles, 0);
        assert_eq!(
            BandwidthReport::from_metrics(&smooth, &cfg).weight_update_concurrency,
            1
        );
        // A synthetic stalled metric (e.g. from the SCALE-SIM baseline,
        // which exposes every load) flags double concurrency.
        let mut stalled = smooth;
        stalled.stall_cycles = 10;
        assert_eq!(
            BandwidthReport::from_metrics(&stalled, &cfg).weight_update_concurrency,
            2
        );
    }

    #[test]
    fn working_set_arithmetic() {
        // conv 3x3, 4->8 ch on 8x8 (out 8x8): acts 64*36, w 36*8, out 64*8
        // at w8 a8 o32 bits.
        let l = Layer::conv("c", SpatialDims::square(8), 4, 8, 3, 1, 1, 1);
        let cfg = ArrayConfig::new(8, 8);
        let expect = (64 * 36 * 8 + 36 * 8 * 8 + 64 * 8 * 32) / 8;
        assert_eq!(ub_working_set_bytes(&l, &cfg), expect);
        assert!(fits_unified_buffer(&l, &cfg));
    }

    #[test]
    fn oversized_layer_flagged() {
        // VGG-16 fc1 (25088x4096 weights = ~98 MiB at 8 bits) cannot fit a
        // 24 MiB UB.
        let fc1 = Layer::linear("fc1", 25088, 4096);
        let cfg = ArrayConfig::new(128, 128);
        assert!(!fits_unified_buffer(&fc1, &cfg));
        // But it fits a hypothetical 128 MiB buffer.
        assert!(fits_unified_buffer(
            &fc1,
            &ArrayConfig::new(128, 128).with_ub_bytes(128 << 20)
        ));
    }

    #[test]
    fn ub_total_sums_ports() {
        let cfg = ArrayConfig::new(8, 8);
        let m = ws_metrics(GemmShape::new(32, 16, 16), &cfg);
        let b = BandwidthReport::from_metrics(&m, &cfg);
        assert!((b.ub_total() - (b.ub_act_read + b.ub_weight_read + b.ub_out_write)).abs() < 1e-12);
        assert!(b.ub_total() > 0.0);
    }
}
