//! The tile schedule of the weight-stationary array — the single source of
//! truth shared by the analytic model (`model/gemm.rs`) and the functional
//! emulator (`arch/control.rs`). Both consume the same pass stream, so
//! their counters agree by construction and their cycle counts are checked
//! against each other by property tests.
//!
//! Schedule (Main Control Unit semantics, DESIGN.md §3):
//!
//! ```text
//! for each col-tile j (width extent n_t):
//!   row budget R_j = max(1, acc_capacity / n_t)   # shared accumulator
//!   for each M-chunk c (Mc rows, Mc <= R_j):
//!     for each row-tile i (height extent k_t):
//!       PASS: stream the chunk's Mc skewed activation rows through the
//!             stationary k_t x n_t weight tile, accumulating into the AA
//!     writeback: drain Mc x n_t finished outputs from the AA to the UB
//! ```
//!
//! Weight loads are double buffered: the Weight Fetcher starts loading pass
//! p's tile the moment pass p-1 begins computing (its shadow registers are
//! free from then on) and needs `k_t` cycles (one weight row pushed down per
//! cycle). Pass p starts at `max(end(p-1), start(p-1) + load(p))`; the first
//! pass exposes its full load.

use crate::config::ArrayConfig;
use crate::util::ceil_div;

/// One GEMM `C[M,N] += A[M,K] * W[K,N]` as seen by the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    /// Output rows M (batch x output pixels for a conv layer).
    pub m: usize,
    /// Reduction depth K (receptive field x input channels / groups).
    pub k: usize,
    /// Output columns N (filters / groups).
    pub n: usize,
}

impl GemmShape {
    pub fn new(m: usize, k: usize, n: usize) -> Self {
        Self { m, k, n }
    }

    pub fn macs(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0 || self.k == 0 || self.n == 0
    }
}

/// One pass of the schedule: a chunk of activation rows streamed through
/// one stationary weight tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pass {
    /// Col-tile index and active width extent.
    pub j: usize,
    pub n_t: usize,
    /// M-chunk index, first row, and row count.
    pub c: usize,
    pub row_start: usize,
    pub mc: usize,
    /// Row-tile index and active height extent.
    pub i: usize,
    pub k_t: usize,
    /// Full array height: partial sums must descend through the whole
    /// column (the array has no row-skipping path), so drain latency and
    /// vertical hop counts use this, not `k_t`.
    pub array_height: usize,
    /// Full array width: activations propagate through every column's
    /// registers (no clock gating in the modeled array), so horizontal hop
    /// counts use this, not `n_t`.
    pub array_width: usize,
    /// True when this is the last row-tile of its (j, c) — the accumulator
    /// chunk is complete and drains to the UB after this pass.
    pub writeback_after: bool,
}

impl Pass {
    /// Compute duration: skewed fill + stream + full-height drain
    /// (DESIGN.md §3): `Mc + m + n_t - 2` cycles, where `m` is the *array*
    /// height — partial tiles still drain through the idle rows below.
    /// The 1x1x1 pass on a 1x1 array takes exactly 1 cycle.
    pub fn compute_cycles(&self) -> u64 {
        (self.mc + self.array_height + self.n_t - 2) as u64
    }

    /// Weight-load duration: one weight row per cycle.
    pub fn load_cycles(&self) -> u64 {
        self.k_t as u64
    }
}

/// The fully-expanded schedule parameters for one (GEMM, array) pair.
#[derive(Debug, Clone)]
pub struct WsSchedule {
    pub gemm: GemmShape,
    pub height: usize,
    pub width: usize,
    pub acc_capacity: usize,
    /// Row tiles over K.
    pub tr: usize,
    /// Col tiles over N.
    pub tc: usize,
}

impl WsSchedule {
    pub fn new(gemm: GemmShape, cfg: &ArrayConfig) -> Self {
        assert!(!gemm.is_empty(), "schedule of an empty GEMM");
        Self {
            gemm,
            height: cfg.height,
            width: cfg.width,
            acc_capacity: cfg.acc_capacity,
            tr: ceil_div(gemm.k, cfg.height),
            tc: ceil_div(gemm.n, cfg.width),
        }
    }

    /// Active width of col-tile `j`.
    pub fn n_t(&self, j: usize) -> usize {
        debug_assert!(j < self.tc);
        (self.gemm.n - j * self.width).min(self.width)
    }

    /// Active height of row-tile `i`.
    pub fn k_t(&self, i: usize) -> usize {
        debug_assert!(i < self.tr);
        (self.gemm.k - i * self.height).min(self.height)
    }

    /// Accumulator row budget for col-tile `j`: how many output rows the
    /// shared accumulator array can buffer while `n_t(j)` columns are live.
    pub fn row_budget(&self, j: usize) -> usize {
        (self.acc_capacity / self.n_t(j)).max(1)
    }

    /// Number of M-chunks for col-tile `j`.
    pub fn chunks(&self, j: usize) -> usize {
        ceil_div(self.gemm.m, self.row_budget(j))
    }

    /// Rows in chunk `c` of col-tile `j`.
    pub fn chunk_rows(&self, j: usize, c: usize) -> usize {
        let r = self.row_budget(j);
        debug_assert!(c < self.chunks(j));
        (self.gemm.m - c * r).min(r)
    }

    /// Total number of passes.
    pub fn pass_count(&self) -> u64 {
        (0..self.tc)
            .map(|j| self.chunks(j) as u64 * self.tr as u64)
            .sum()
    }

    /// Iterate all passes in execution order.
    pub fn passes(&self) -> impl Iterator<Item = Pass> + '_ {
        (0..self.tc).flat_map(move |j| {
            let n_t = self.n_t(j);
            let r = self.row_budget(j);
            (0..self.chunks(j)).flat_map(move |c| {
                let mc = self.chunk_rows(j, c);
                (0..self.tr).map(move |i| Pass {
                    j,
                    n_t,
                    c,
                    row_start: c * r,
                    mc,
                    i,
                    k_t: self.k_t(i),
                    array_height: self.height,
                    array_width: self.width,
                    writeback_after: i == self.tr - 1,
                })
            })
        })
    }
}

/// One output-stationary tile: an `(mt x nt)` block of C pinned in the
/// PEs while A and W stream through for the full reduction depth K.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OsTile {
    /// Row-tile index and first output row.
    pub i: usize,
    pub row_start: usize,
    pub mt: usize,
    /// Col-tile index and first output column.
    pub j: usize,
    pub col_start: usize,
    pub nt: usize,
    /// Full reduction depth — OS tiles never split K.
    pub k: usize,
    /// Full array dims: streams propagate through all `array_width`
    /// columns and the finished tile drains down all `array_height` rows,
    /// exactly as in the WS model (no clock gating).
    pub array_height: usize,
    pub array_width: usize,
}

impl OsTile {
    /// Skewed stream (`K + mt + nt - 2`) plus the full-height drain (`h`).
    /// Tiles serialize — the drain is not overlapped, so it is part of the
    /// tile's cycle count (matching `os_metrics`).
    pub fn compute_cycles(&self) -> u64 {
        (self.k + self.mt + self.nt - 2 + self.array_height) as u64
    }
}

/// The output-stationary tiling of one (GEMM, array) pair: C is covered by
/// `tm x tc` tiles, walked row-major. The accumulator capacity plays no
/// role — outputs live *in* the PEs, the AA is only crossed once per tile
/// on the way out.
#[derive(Debug, Clone)]
pub struct OsSchedule {
    pub gemm: GemmShape,
    pub height: usize,
    pub width: usize,
    /// Row tiles over M.
    pub tm: usize,
    /// Col tiles over N.
    pub tc: usize,
}

impl OsSchedule {
    pub fn new(gemm: GemmShape, cfg: &ArrayConfig) -> Self {
        assert!(!gemm.is_empty(), "schedule of an empty GEMM");
        Self {
            gemm,
            height: cfg.height,
            width: cfg.width,
            tm: ceil_div(gemm.m, cfg.height),
            tc: ceil_div(gemm.n, cfg.width),
        }
    }

    /// Active height of row-tile `i`.
    pub fn m_t(&self, i: usize) -> usize {
        debug_assert!(i < self.tm);
        (self.gemm.m - i * self.height).min(self.height)
    }

    /// Active width of col-tile `j`.
    pub fn n_t(&self, j: usize) -> usize {
        debug_assert!(j < self.tc);
        (self.gemm.n - j * self.width).min(self.width)
    }

    pub fn tile_count(&self) -> u64 {
        self.tm as u64 * self.tc as u64
    }

    /// Iterate all tiles in execution order (row-major over C).
    pub fn tiles(&self) -> impl Iterator<Item = OsTile> + '_ {
        (0..self.tm).flat_map(move |i| {
            let mt = self.m_t(i);
            (0..self.tc).map(move |j| OsTile {
                i,
                row_start: i * self.height,
                mt,
                j,
                col_start: j * self.width,
                nt: self.n_t(j),
                k: self.gemm.k,
                array_height: self.height,
                array_width: self.width,
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(h: usize, w: usize, acc: usize) -> ArrayConfig {
        ArrayConfig::new(h, w).with_acc_capacity(acc)
    }

    #[test]
    fn exact_fit_single_pass() {
        let s = WsSchedule::new(GemmShape::new(5, 8, 4), &cfg(8, 4, 4096));
        assert_eq!((s.tr, s.tc), (1, 1));
        let passes: Vec<Pass> = s.passes().collect();
        assert_eq!(passes.len(), 1);
        let p = passes[0];
        assert_eq!((p.k_t, p.n_t, p.mc), (8, 4, 5));
        assert!(p.writeback_after);
        // Full-height drain: array height 8 == k_t here.
        assert_eq!(p.compute_cycles(), 5 + 8 + 4 - 2);
    }

    #[test]
    fn partial_tiles() {
        // K=10 on height 8 -> tiles of 8 and 2; N=6 on width 4 -> 4 and 2.
        let s = WsSchedule::new(GemmShape::new(3, 10, 6), &cfg(8, 4, 4096));
        assert_eq!((s.tr, s.tc), (2, 2));
        assert_eq!(s.k_t(0), 8);
        assert_eq!(s.k_t(1), 2);
        assert_eq!(s.n_t(0), 4);
        assert_eq!(s.n_t(1), 2);
        assert_eq!(s.pass_count(), 4);
    }

    #[test]
    fn accumulator_chunking() {
        // acc=8 entries, col-tile width 4 -> budget 2 rows; M=5 -> chunks 2,2,1.
        let s = WsSchedule::new(GemmShape::new(5, 4, 4), &cfg(4, 4, 8));
        assert_eq!(s.row_budget(0), 2);
        assert_eq!(s.chunks(0), 3);
        assert_eq!(s.chunk_rows(0, 0), 2);
        assert_eq!(s.chunk_rows(0, 2), 1);
        let passes: Vec<Pass> = s.passes().collect();
        assert_eq!(passes.len(), 3);
        assert_eq!(passes[2].row_start, 4);
        assert_eq!(passes[2].mc, 1);
    }

    #[test]
    fn narrow_tail_gets_bigger_budget() {
        // N=6 on width 4: tail tile is 2 wide -> budget doubles.
        let s = WsSchedule::new(GemmShape::new(100, 4, 6), &cfg(4, 4, 8));
        assert_eq!(s.row_budget(0), 2);
        assert_eq!(s.row_budget(1), 4);
        assert_eq!(s.chunks(0), 50);
        assert_eq!(s.chunks(1), 25);
    }

    #[test]
    fn budget_clamps_to_one_row() {
        // Accumulator smaller than the active width: degrade to 1 row.
        let s = WsSchedule::new(GemmShape::new(3, 4, 16), &cfg(4, 16, 8));
        assert_eq!(s.row_budget(0), 1);
        assert_eq!(s.chunks(0), 3);
    }

    #[test]
    fn pass_order_is_j_c_i() {
        let s = WsSchedule::new(GemmShape::new(2, 10, 6), &cfg(8, 4, 4096));
        let order: Vec<(usize, usize, usize)> = s.passes().map(|p| (p.j, p.c, p.i)).collect();
        assert_eq!(order, vec![(0, 0, 0), (0, 0, 1), (1, 0, 0), (1, 0, 1)]);
    }

    #[test]
    fn writeback_flags_on_last_row_tile_only() {
        let s = WsSchedule::new(GemmShape::new(2, 10, 4), &cfg(8, 4, 4096));
        let flags: Vec<bool> = s.passes().map(|p| p.writeback_after).collect();
        assert_eq!(flags, vec![false, true]);
    }

    #[test]
    fn pass_count_matches_iterator() {
        let s = WsSchedule::new(GemmShape::new(37, 29, 23), &cfg(8, 4, 32));
        assert_eq!(s.pass_count(), s.passes().count() as u64);
    }

    #[test]
    fn single_mac_pass_is_one_cycle() {
        let p = Pass {
            j: 0,
            n_t: 1,
            c: 0,
            row_start: 0,
            mc: 1,
            i: 0,
            k_t: 1,
            array_height: 1,
            array_width: 1,
            writeback_after: true,
        };
        assert_eq!(p.compute_cycles(), 1);
        assert_eq!(p.load_cycles(), 1);
    }

    #[test]
    fn os_tiles_cover_c_row_major() {
        // M=10 on height 4 -> 4,4,2; N=6 on width 4 -> 4,2.
        let s = OsSchedule::new(GemmShape::new(10, 3, 6), &cfg(4, 4, 8));
        assert_eq!((s.tm, s.tc), (3, 2));
        let tiles: Vec<OsTile> = s.tiles().collect();
        assert_eq!(tiles.len() as u64, s.tile_count());
        assert_eq!(
            tiles
                .iter()
                .map(|t| (t.i, t.j, t.mt, t.nt))
                .collect::<Vec<_>>(),
            vec![
                (0, 0, 4, 4),
                (0, 1, 4, 2),
                (1, 0, 4, 4),
                (1, 1, 4, 2),
                (2, 0, 2, 4),
                (2, 1, 2, 2)
            ]
        );
        // Covered output elements == M*N exactly.
        let covered: usize = tiles.iter().map(|t| t.mt * t.nt).sum();
        assert_eq!(covered, 60);
        // Tail tile still pays the full-height drain.
        assert_eq!(tiles[4].compute_cycles(), (3 + 2 + 4 - 2 + 4) as u64);
    }

    #[test]
    fn os_schedule_ignores_accumulator_capacity() {
        let a = OsSchedule::new(GemmShape::new(9, 5, 7), &cfg(4, 4, 1));
        let b = OsSchedule::new(GemmShape::new(9, 5, 7), &cfg(4, 4, 4096));
        assert_eq!(a.tile_count(), b.tile_count());
    }

    #[test]
    fn partial_tile_still_drains_full_height() {
        // K=2 on a height-8 array: the pass must still pay the 8-row
        // descent to the accumulators at the bottom edge.
        let s = WsSchedule::new(GemmShape::new(4, 2, 4), &cfg(8, 4, 4096));
        let p = s.passes().next().unwrap();
        assert_eq!(p.k_t, 2);
        assert_eq!(p.array_height, 8);
        assert_eq!(p.compute_cycles(), (4 + 8 + 4 - 2) as u64);
    }
}
