//! The workload IR: the deduplicated GEMM-shape histogram every evaluating
//! layer of CAMUY consumes (DESIGN.md §2).
//!
//! A [`Workload`] reduces a network to its distinct [`GemmShape`]s with
//! multiplicities (groups × occurrences). DenseNet-201's 201 layers
//! collapse to ~120 distinct GEMMs, ResNet-152's 156 to ~40 — and because
//! per-shape metrics are configuration-deterministic, evaluating the
//! histogram and scaling by multiplicity (the [`Metrics`] algebra's scalar
//! `Mul`) is *exactly* equal to evaluating layer by layer. The network
//! model, the sweep engine, NSGA-II, the coordinator and the figure
//! pipeline all route through this one representation.
//!
//! [`EvalCache`] adds a thread-safe memo table over (shape, configuration)
//! pairs, so overlapping evaluations — NSGA-II generations revisiting grid
//! points, the two Pareto objectives of Figure 3, repeated layers inside
//! one inference — pay for each distinct GEMM once.

use crate::config::{ArrayConfig, Dataflow};
use crate::metrics::Metrics;
use crate::model::gemm::gemm_metrics;
use crate::model::network::Network;
use crate::model::schedule::GemmShape;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// The deduplicated workload of a network: distinct shapes with
/// multiplicity, in deterministic first-seen layer order.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    /// (shape, groups × occurrences) — first-seen order over the layers.
    pub shapes: Vec<(GemmShape, u64)>,
    /// Total useful MACs of one inference.
    pub macs: u64,
}

impl Workload {
    /// Deduplicate a network's GEMMs. Linear in the layer count: the
    /// histogram is keyed on [`GemmShape`] through a `HashMap` index while
    /// the output vector preserves first-seen order. (`net.macs()` equals
    /// the recomputed Σ shape.macs() × multiplicity exactly, since
    /// `layer.macs() == gemm.macs() * groups`.)
    pub fn of(net: &Network) -> Workload {
        Workload::from_shapes(
            net.name.clone(),
            net.gemm_histogram()
                .into_iter()
                .map(|(shape, groups, count)| (shape, (groups * count) as u64))
                .collect(),
        )
    }

    /// Build directly from (shape, multiplicity) pairs (tests, synthetic
    /// workloads). Pairs are deduplicated preserving first-seen order.
    pub fn from_shapes(name: impl Into<String>, pairs: Vec<(GemmShape, u64)>) -> Workload {
        let mut shapes: Vec<(GemmShape, u64)> = Vec::new();
        let mut index: HashMap<GemmShape, usize> = HashMap::new();
        let mut macs = 0u64;
        for (shape, mult) in pairs {
            macs += shape.macs() * mult;
            match index.get(&shape) {
                Some(&i) => shapes[i].1 += mult,
                None => {
                    index.insert(shape, shapes.len());
                    shapes.push((shape, mult));
                }
            }
        }
        Workload {
            name: name.into(),
            shapes,
            macs,
        }
    }

    /// Number of distinct GEMM shapes.
    pub fn distinct(&self) -> usize {
        self.shapes.len()
    }

    /// Total GEMM invocations (Σ multiplicities).
    pub fn total_gemms(&self) -> u64 {
        self.shapes.iter().map(|&(_, m)| m).sum()
    }

    /// Evaluate on one configuration: Σ multiplicity × per-shape metrics.
    pub fn eval(&self, cfg: &ArrayConfig) -> Metrics {
        self.shapes
            .iter()
            .map(|&(shape, mult)| gemm_metrics(shape, cfg) * mult)
            .sum()
    }

    /// Like [`Workload::eval`], but per-shape metrics are memoized in
    /// `cache` and reused across calls (and across workloads sharing the
    /// cache).
    pub fn eval_cached(&self, cfg: &ArrayConfig, cache: &EvalCache) -> Metrics {
        self.shapes
            .iter()
            .map(|&(shape, mult)| cache.gemm_metrics(shape, cfg) * mult)
            .sum()
    }
}

/// The configuration fields that determine [`Metrics`] (bitwidths and UB
/// provisioning scale bandwidth reports, not access counts).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct CfgKey {
    height: usize,
    width: usize,
    acc_capacity: usize,
    dataflow: Dataflow,
}

impl CfgKey {
    fn of(cfg: &ArrayConfig) -> CfgKey {
        CfgKey {
            height: cfg.height,
            width: cfg.width,
            acc_capacity: cfg.acc_capacity,
            dataflow: cfg.dataflow,
        }
    }
}

/// A thread-safe memo table of per-(shape, configuration) metrics. Shared
/// by NSGA-II across generations and objectives, by the coordinator
/// across repeated layers of one inference, and by the long-lived API
/// engine across requests.
#[derive(Debug, Default)]
pub struct EvalCache {
    map: RwLock<HashMap<(GemmShape, CfgKey), Metrics>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Entry cap for [`EvalCache`]. On overflow the table is flushed wholesale
/// — it is a memo table, not state, so a flush only costs recomputation.
/// This bounds a long-lived server's memory even against a client that
/// iterates arbitrary (shape, configuration) pairs forever.
pub const EVAL_CACHE_CAPACITY: usize = 1 << 18;

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Memoized [`gemm_metrics`].
    pub fn gemm_metrics(&self, shape: GemmShape, cfg: &ArrayConfig) -> Metrics {
        let key = (shape, CfgKey::of(cfg));
        if let Some(m) = self.map.read().expect("eval cache poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *m;
        }
        let m = gemm_metrics(shape, cfg);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.write().expect("eval cache poisoned");
        if map.len() >= EVAL_CACHE_CAPACITY {
            map.clear();
        }
        map.insert(key, m);
        m
    }

    /// Insert a precomputed per-(shape, configuration) result. The
    /// segmented sweep core seeds batch results through this
    /// ([`crate::sweep::runner::seed_workload`]) so follow-up
    /// per-request evaluations are pure memo-table hits. Counts as neither
    /// a hit nor a miss.
    pub fn seed(&self, shape: GemmShape, cfg: &ArrayConfig, m: Metrics) {
        let mut map = self.map.write().expect("eval cache poisoned");
        if map.len() >= EVAL_CACHE_CAPACITY {
            map.clear();
        }
        map.insert((shape, CfgKey::of(cfg)), m);
    }

    /// Whether a per-(shape, configuration) entry is currently memoized.
    pub fn contains(&self, shape: GemmShape, cfg: &ArrayConfig) -> bool {
        self.map
            .read()
            .expect("eval cache poisoned")
            .contains_key(&(shape, CfgKey::of(cfg)))
    }

    /// Distinct (shape, configuration) pairs evaluated so far.
    pub fn len(&self) -> usize {
        self.map.read().expect("eval cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the memo table.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to evaluate the closed form.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, SpatialDims};

    fn small_net() -> Network {
        Network::new(
            "s",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
                Layer::conv("c3", SpatialDims::square(14), 32, 32, 3, 1, 1, 1), // dup of c2
                Layer::conv("g", SpatialDims::square(14), 32, 32, 3, 1, 1, 4),
            ],
        )
    }

    #[test]
    fn workload_deduplicates() {
        let w = Workload::of(&small_net());
        // c2 and c3 share a shape; the grouped layer is distinct.
        assert_eq!(w.distinct(), 3);
        let dup = w.shapes.iter().find(|(s, _)| s.k == 32 * 9).unwrap();
        assert_eq!(dup.1, 2);
        let grouped = w.shapes.iter().find(|(s, _)| s.k == 8 * 9).unwrap();
        assert_eq!(grouped.1, 4);
        assert_eq!(w.total_gemms(), 1 + 2 + 4);
        assert_eq!(w.macs, small_net().macs());
    }

    #[test]
    fn dedup_preserves_first_seen_order() {
        let w = Workload::of(&small_net());
        // c1's shape first, then the shared c2/c3 shape, then the grouped.
        assert_eq!(w.shapes[0].0.k, 16 * 9);
        assert_eq!(w.shapes[1].0.k, 32 * 9);
        assert_eq!(w.shapes[2].0.k, 8 * 9);
    }

    #[test]
    fn workload_eval_equals_network_metrics() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfg = ArrayConfig::new(16, 8);
        assert_eq!(w.eval(&cfg), net.metrics(&cfg));
    }

    #[test]
    fn from_shapes_merges_duplicates() {
        let a = GemmShape::new(4, 8, 16);
        let b = GemmShape::new(2, 2, 2);
        let w = Workload::from_shapes("syn", vec![(a, 3), (b, 1), (a, 2)]);
        assert_eq!(w.shapes, vec![(a, 5), (b, 1)]);
        assert_eq!(w.macs, a.macs() * 5 + b.macs());
    }

    #[test]
    fn eval_is_linear_in_multiplicity() {
        let a = GemmShape::new(5, 17, 9);
        let once = Workload::from_shapes("x1", vec![(a, 1)]);
        let thrice = Workload::from_shapes("x3", vec![(a, 3)]);
        let cfg = ArrayConfig::new(8, 4).with_acc_capacity(32);
        assert_eq!(thrice.eval(&cfg), once.eval(&cfg) * 3);
    }

    #[test]
    fn cache_returns_identical_metrics_and_counts_hits() {
        let net = small_net();
        let w = Workload::of(&net);
        let cache = EvalCache::new();
        let cfg_a = ArrayConfig::new(16, 8);
        let cfg_b = ArrayConfig::new(8, 16);
        assert_eq!(w.eval_cached(&cfg_a, &cache), w.eval(&cfg_a));
        assert_eq!(cache.misses(), w.distinct() as u64);
        assert_eq!(cache.hits(), 0);
        // Second evaluation of the same config is served entirely from the
        // memo table; a different geometry misses again.
        assert_eq!(w.eval_cached(&cfg_a, &cache), w.eval(&cfg_a));
        assert_eq!(cache.hits(), w.distinct() as u64);
        assert_eq!(w.eval_cached(&cfg_b, &cache), w.eval(&cfg_b));
        assert_eq!(cache.len(), 2 * w.distinct());
    }

    #[test]
    fn seeded_entries_serve_as_hits() {
        let shape = GemmShape::new(7, 13, 5);
        let cfg = ArrayConfig::new(8, 4);
        let cache = EvalCache::new();
        let m = crate::model::gemm::gemm_metrics(shape, &cfg);
        cache.seed(shape, &cfg, m);
        assert_eq!(cache.len(), 1);
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
        assert_eq!(cache.gemm_metrics(shape, &cfg), m);
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
    }

    #[test]
    fn cache_capacity_is_bounded() {
        let cache = EvalCache::new();
        let cfg = ArrayConfig::new(8, 8);
        let m = crate::model::gemm::gemm_metrics(GemmShape::new(1, 1, 1), &cfg);
        for i in 1..=EVAL_CACHE_CAPACITY + 10 {
            cache.seed(GemmShape::new(i, 1, 1), &cfg, m);
        }
        assert!(cache.len() <= EVAL_CACHE_CAPACITY);
        // The flushed cache still answers correctly (recomputes on miss).
        let shape = GemmShape::new(1, 1, 1);
        assert_eq!(
            cache.gemm_metrics(shape, &cfg),
            crate::model::gemm::gemm_metrics(shape, &cfg)
        );
    }

    #[test]
    fn cache_distinguishes_metric_relevant_config_fields() {
        let shape = GemmShape::new(10, 20, 30);
        let cache = EvalCache::new();
        let base = ArrayConfig::new(8, 8);
        let small_acc = ArrayConfig::new(8, 8).with_acc_capacity(8);
        let m1 = cache.gemm_metrics(shape, &base);
        let m2 = cache.gemm_metrics(shape, &small_acc);
        assert_ne!(m1, m2);
        assert_eq!(cache.len(), 2);
        // Bitwidths do not affect access counts: same cache entry.
        let rebit = ArrayConfig::new(8, 8).with_bits(16, 16, 32);
        assert_eq!(cache.gemm_metrics(shape, &rebit), m1);
        assert_eq!(cache.len(), 2);
    }
}
