//! The workload IR: the deduplicated GEMM-shape histogram every evaluating
//! layer of CAMUY consumes (DESIGN.md §2).
//!
//! A [`Workload`] reduces a network to its distinct [`GemmShape`]s with
//! multiplicities (groups × occurrences). DenseNet-201's 201 layers
//! collapse to ~120 distinct GEMMs, ResNet-152's 156 to ~40 — and because
//! per-shape metrics are configuration-deterministic, evaluating the
//! histogram and scaling by multiplicity (the [`Metrics`] algebra's scalar
//! `Mul`) is *exactly* equal to evaluating layer by layer. The network
//! model, the sweep engine, NSGA-II, the coordinator and the figure
//! pipeline all route through this one representation.
//!
//! [`EvalCache`] adds a thread-safe memo table over (shape, configuration)
//! pairs, so overlapping evaluations — NSGA-II generations revisiting grid
//! points, the two Pareto objectives of Figure 3, repeated layers inside
//! one inference — pay for each distinct GEMM once.

use crate::config::{ArrayConfig, Dataflow};
use crate::metrics::Metrics;
use crate::model::gemm::gemm_metrics;
use crate::model::network::Network;
use crate::model::schedule::GemmShape;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// The deduplicated workload of a network: distinct shapes with
/// multiplicity, in deterministic first-seen layer order.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    /// (shape, groups × occurrences) — first-seen order over the layers.
    pub shapes: Vec<(GemmShape, u64)>,
    /// Total useful MACs of one inference.
    pub macs: u64,
}

impl Workload {
    /// Deduplicate a network's GEMMs. Linear in the layer count: the
    /// histogram is keyed on [`GemmShape`] through a `HashMap` index while
    /// the output vector preserves first-seen order. (`net.macs()` equals
    /// the recomputed Σ shape.macs() × multiplicity exactly, since
    /// `layer.macs() == gemm.macs() * groups`.)
    pub fn of(net: &Network) -> Workload {
        Workload::from_shapes(
            net.name.clone(),
            net.gemm_histogram()
                .into_iter()
                .map(|(shape, groups, count)| (shape, (groups * count) as u64))
                .collect(),
        )
    }

    /// Build directly from (shape, multiplicity) pairs (tests, synthetic
    /// workloads). Pairs are deduplicated preserving first-seen order.
    pub fn from_shapes(name: impl Into<String>, pairs: Vec<(GemmShape, u64)>) -> Workload {
        let mut shapes: Vec<(GemmShape, u64)> = Vec::new();
        let mut index: HashMap<GemmShape, usize> = HashMap::new();
        let mut macs = 0u64;
        for (shape, mult) in pairs {
            macs += shape.macs() * mult;
            match index.get(&shape) {
                Some(&i) => shapes[i].1 += mult,
                None => {
                    index.insert(shape, shapes.len());
                    shapes.push((shape, mult));
                }
            }
        }
        Workload {
            name: name.into(),
            shapes,
            macs,
        }
    }

    /// Number of distinct GEMM shapes.
    pub fn distinct(&self) -> usize {
        self.shapes.len()
    }

    /// Total GEMM invocations (Σ multiplicities).
    pub fn total_gemms(&self) -> u64 {
        self.shapes.iter().map(|&(_, m)| m).sum()
    }

    /// Evaluate on one configuration: Σ multiplicity × per-shape metrics.
    pub fn eval(&self, cfg: &ArrayConfig) -> Metrics {
        self.shapes
            .iter()
            .map(|&(shape, mult)| gemm_metrics(shape, cfg) * mult)
            .sum()
    }

    /// Like [`Workload::eval`], but per-shape metrics are memoized in
    /// `cache` and reused across calls (and across workloads sharing the
    /// cache).
    pub fn eval_cached(&self, cfg: &ArrayConfig, cache: &EvalCache) -> Metrics {
        self.shapes
            .iter()
            .map(|&(shape, mult)| cache.gemm_metrics(shape, cfg) * mult)
            .sum()
    }
}

/// The configuration fields that determine [`Metrics`] (bitwidths and UB
/// provisioning scale bandwidth reports, not access counts).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct CfgKey {
    height: usize,
    width: usize,
    acc_capacity: usize,
    dataflow: Dataflow,
}

impl CfgKey {
    fn of(cfg: &ArrayConfig) -> CfgKey {
        CfgKey {
            height: cfg.height,
            width: cfg.width,
            acc_capacity: cfg.acc_capacity,
            dataflow: cfg.dataflow,
        }
    }
}

/// Lock shards in [`EvalCache`]. Power of two so the shard index is a
/// mask of the key hash; 32 shards keep write contention negligible even
/// with every core seeding at once, at ~32 × 40 bytes of fixed overhead.
pub const EVAL_CACHE_SHARDS: usize = 32;

/// Total entry cap for [`EvalCache`], split evenly across the shards.
/// This bounds a long-lived server's memory even against a client that
/// iterates arbitrary (shape, configuration) pairs forever.
pub const EVAL_CACHE_CAPACITY: usize = 1 << 18;

/// Per-shard entry cap.
const EVAL_SHARD_CAPACITY: usize = EVAL_CACHE_CAPACITY / EVAL_CACHE_SHARDS;

/// A thread-safe memo table of per-(shape, configuration) metrics. Shared
/// by NSGA-II across generations and objectives, by the coordinator
/// across repeated layers of one inference, and by the long-lived API
/// engine across requests.
///
/// The table is split into [`EVAL_CACHE_SHARDS`] hash-indexed lock shards
/// (DESIGN.md §11): concurrent serve workers hitting distinct keys take
/// distinct `RwLock`s instead of serializing on one process-wide lock,
/// and a full shard evicts *half of itself* rather than flushing the
/// whole table — an overflow costs re-deriving a slice of the memo state,
/// not all of it. Hit/miss counters are relaxed atomics; they order
/// nothing.
#[derive(Debug)]
pub struct EvalCache {
    shards: Vec<EvalShard>,
}

/// One lock shard with its own relaxed traffic counters, so
/// [`EvalCache::stats`] reports per-shard hit rates, occupancy and
/// eviction counts from plain loads, with no cross-shard coordination.
#[derive(Debug, Default)]
struct EvalShard {
    map: RwLock<HashMap<(GemmShape, CfgKey), Metrics>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl EvalShard {
    /// Make room in a full shard before inserting `key`: drop every other
    /// entry. Partial eviction, not a flush — the surviving half keeps
    /// serving hits — and overwriting a key that is already resident
    /// never evicts (the insert won't grow the map). (Which half survives
    /// follows the map's iteration order; the cache is a memo table, so
    /// the choice affects only future hit rates.)
    fn evict_if_full(
        &self,
        map: &mut HashMap<(GemmShape, CfgKey), Metrics>,
        key: &(GemmShape, CfgKey),
    ) {
        if map.len() >= EVAL_SHARD_CAPACITY && !map.contains_key(key) {
            let before = map.len();
            let mut i = 0usize;
            map.retain(|_, _| {
                i += 1;
                i % 2 == 0
            });
            self.evictions.fetch_add((before - map.len()) as u64, Ordering::Relaxed);
        }
    }
}

/// Counters for one [`EvalCache`] shard in a [`stats`](EvalCache::stats)
/// snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EvalCacheShardStats {
    /// Entries currently resident.
    pub entries: usize,
    /// Per-shard entry cap ([`EVAL_CACHE_CAPACITY`] / shard count).
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped by the half-shard eviction policy.
    pub evictions: u64,
}

impl EvalCacheShardStats {
    /// Hits per lookup; 0.0 before any traffic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Aggregate + per-shard snapshot of the evaluation memo table — the
/// eval-cache counterpart of [`crate::sweep::plan::PlanCacheStats`],
/// surfaced through `{"type":"stats"}` (DESIGN.md §14).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct EvalCacheStats {
    pub entries: usize,
    /// Total entry cap across all shards ([`EVAL_CACHE_CAPACITY`]).
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// One entry per shard, in shard-index order.
    pub shards: Vec<EvalCacheShardStats>,
}

impl EvalCacheStats {
    /// Hits per lookup; 0.0 before any traffic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl Default for EvalCache {
    fn default() -> EvalCache {
        EvalCache {
            shards: (0..EVAL_CACHE_SHARDS).map(|_| EvalShard::default()).collect(),
        }
    }
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// The shard holding `key`: a cheap multiplicative field mix, NOT a
    /// full hash — the shard's own `HashMap` re-hashes the key anyway
    /// (SipHash), so this discriminant only needs spread, not collision
    /// resistance, and running SipHash here would hash every memo access
    /// twice. Fibonacci-style odd multipliers equidistribute the
    /// sequential dimension values real workloads produce; the final
    /// multiply-and-shift reads high bits so low-entropy fields still
    /// spread across all shards.
    fn shard(&self, key: &(GemmShape, CfgKey)) -> &EvalShard {
        let (s, c) = key;
        let x = (s.m as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((s.k as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            .wrapping_add((s.n as u64).wrapping_mul(0x1656_67B1_9E37_79F9))
            .wrapping_add((c.height as u64).wrapping_mul(0x27D4_EB2F_1656_67C5))
            .wrapping_add((c.width as u64).wrapping_mul(0x2545_F491_4F6C_DD1D))
            .wrapping_add((c.acc_capacity as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93))
            .wrapping_add(c.dataflow as u64);
        let i = (x.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize;
        &self.shards[i & (EVAL_CACHE_SHARDS - 1)]
    }

    /// Memoized [`gemm_metrics`].
    pub fn gemm_metrics(&self, shape: GemmShape, cfg: &ArrayConfig) -> Metrics {
        let key = (shape, CfgKey::of(cfg));
        let shard = self.shard(&key);
        if let Some(m) = shard.map.read().expect("eval cache poisoned").get(&key) {
            shard.hits.fetch_add(1, Ordering::Relaxed);
            return *m;
        }
        let m = gemm_metrics(shape, cfg);
        shard.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = shard.map.write().expect("eval cache poisoned");
        shard.evict_if_full(&mut map, &key);
        map.insert(key, m);
        m
    }

    /// Insert a precomputed per-(shape, configuration) result. The
    /// segmented sweep core seeds batch results through this
    /// ([`crate::sweep::runner::seed_workload`]) so follow-up
    /// per-request evaluations are pure memo-table hits. Counts as neither
    /// a hit nor a miss, and respects the capacity bound exactly like a
    /// miss-path insert — an arbitrarily large seeded batch can never push
    /// a shard past its cap.
    pub fn seed(&self, shape: GemmShape, cfg: &ArrayConfig, m: Metrics) {
        let key = (shape, CfgKey::of(cfg));
        let shard = self.shard(&key);
        let mut map = shard.map.write().expect("eval cache poisoned");
        shard.evict_if_full(&mut map, &key);
        map.insert(key, m);
    }

    /// Whether a per-(shape, configuration) entry is currently memoized.
    pub fn contains(&self, shape: GemmShape, cfg: &ArrayConfig) -> bool {
        let key = (shape, CfgKey::of(cfg));
        let shard = self.shard(&key);
        shard.map.read().expect("eval cache poisoned").contains_key(&key)
    }

    /// Distinct (shape, configuration) pairs currently memoized.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.map.read().expect("eval cache poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the memo table (all shards).
    pub fn hits(&self) -> u64 {
        self.shards.iter().map(|s| s.hits.load(Ordering::Relaxed)).sum()
    }

    /// Lookups that had to evaluate the closed form (all shards).
    pub fn misses(&self) -> u64 {
        self.shards.iter().map(|s| s.misses.load(Ordering::Relaxed)).sum()
    }

    /// Entries dropped by the half-shard eviction policy (all shards).
    pub fn evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.evictions.load(Ordering::Relaxed)).sum()
    }

    /// A per-shard and aggregate traffic/occupancy snapshot (relaxed
    /// loads; a racing insert may tear between shards, which is fine for
    /// monitoring). Shard order is stable, so successive snapshots are
    /// comparable shard by shard.
    pub fn stats(&self) -> EvalCacheStats {
        let shards: Vec<EvalCacheShardStats> = self
            .shards
            .iter()
            .map(|s| EvalCacheShardStats {
                entries: s.map.read().expect("eval cache poisoned").len(),
                capacity: EVAL_SHARD_CAPACITY,
                hits: s.hits.load(Ordering::Relaxed),
                misses: s.misses.load(Ordering::Relaxed),
                evictions: s.evictions.load(Ordering::Relaxed),
            })
            .collect();
        EvalCacheStats {
            entries: shards.iter().map(|s| s.entries).sum(),
            capacity: EVAL_CACHE_CAPACITY,
            hits: shards.iter().map(|s| s.hits).sum(),
            misses: shards.iter().map(|s| s.misses).sum(),
            evictions: shards.iter().map(|s| s.evictions).sum(),
            shards,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, SpatialDims};

    fn small_net() -> Network {
        Network::new(
            "s",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
                Layer::conv("c3", SpatialDims::square(14), 32, 32, 3, 1, 1, 1), // dup of c2
                Layer::conv("g", SpatialDims::square(14), 32, 32, 3, 1, 1, 4),
            ],
        )
    }

    #[test]
    fn workload_deduplicates() {
        let w = Workload::of(&small_net());
        // c2 and c3 share a shape; the grouped layer is distinct.
        assert_eq!(w.distinct(), 3);
        let dup = w.shapes.iter().find(|(s, _)| s.k == 32 * 9).unwrap();
        assert_eq!(dup.1, 2);
        let grouped = w.shapes.iter().find(|(s, _)| s.k == 8 * 9).unwrap();
        assert_eq!(grouped.1, 4);
        assert_eq!(w.total_gemms(), 1 + 2 + 4);
        assert_eq!(w.macs, small_net().macs());
    }

    #[test]
    fn dedup_preserves_first_seen_order() {
        let w = Workload::of(&small_net());
        // c1's shape first, then the shared c2/c3 shape, then the grouped.
        assert_eq!(w.shapes[0].0.k, 16 * 9);
        assert_eq!(w.shapes[1].0.k, 32 * 9);
        assert_eq!(w.shapes[2].0.k, 8 * 9);
    }

    #[test]
    fn workload_eval_equals_network_metrics() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfg = ArrayConfig::new(16, 8);
        assert_eq!(w.eval(&cfg), net.metrics(&cfg));
    }

    #[test]
    fn from_shapes_merges_duplicates() {
        let a = GemmShape::new(4, 8, 16);
        let b = GemmShape::new(2, 2, 2);
        let w = Workload::from_shapes("syn", vec![(a, 3), (b, 1), (a, 2)]);
        assert_eq!(w.shapes, vec![(a, 5), (b, 1)]);
        assert_eq!(w.macs, a.macs() * 5 + b.macs());
    }

    #[test]
    fn eval_is_linear_in_multiplicity() {
        let a = GemmShape::new(5, 17, 9);
        let once = Workload::from_shapes("x1", vec![(a, 1)]);
        let thrice = Workload::from_shapes("x3", vec![(a, 3)]);
        let cfg = ArrayConfig::new(8, 4).with_acc_capacity(32);
        assert_eq!(thrice.eval(&cfg), once.eval(&cfg) * 3);
    }

    #[test]
    fn cache_returns_identical_metrics_and_counts_hits() {
        let net = small_net();
        let w = Workload::of(&net);
        let cache = EvalCache::new();
        let cfg_a = ArrayConfig::new(16, 8);
        let cfg_b = ArrayConfig::new(8, 16);
        assert_eq!(w.eval_cached(&cfg_a, &cache), w.eval(&cfg_a));
        assert_eq!(cache.misses(), w.distinct() as u64);
        assert_eq!(cache.hits(), 0);
        // Second evaluation of the same config is served entirely from the
        // memo table; a different geometry misses again.
        assert_eq!(w.eval_cached(&cfg_a, &cache), w.eval(&cfg_a));
        assert_eq!(cache.hits(), w.distinct() as u64);
        assert_eq!(w.eval_cached(&cfg_b, &cache), w.eval(&cfg_b));
        assert_eq!(cache.len(), 2 * w.distinct());
    }

    #[test]
    fn seeded_entries_serve_as_hits() {
        let shape = GemmShape::new(7, 13, 5);
        let cfg = ArrayConfig::new(8, 4);
        let cache = EvalCache::new();
        let m = crate::model::gemm::gemm_metrics(shape, &cfg);
        cache.seed(shape, &cfg, m);
        assert_eq!(cache.len(), 1);
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
        assert_eq!(cache.gemm_metrics(shape, &cfg), m);
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
    }

    #[test]
    fn cache_capacity_is_bounded() {
        // Seeding arbitrarily many entries can never exceed the bound —
        // the seed path applies the same per-shard eviction as a miss.
        let cache = EvalCache::new();
        let cfg = ArrayConfig::new(8, 8);
        let m = crate::model::gemm::gemm_metrics(GemmShape::new(1, 1, 1), &cfg);
        for i in 1..=EVAL_CACHE_CAPACITY + 10 {
            cache.seed(GemmShape::new(i, 1, 1), &cfg, m);
        }
        assert!(cache.len() <= EVAL_CACHE_CAPACITY);
        // Eviction is per-shard and partial: overflowing must NOT flush
        // the table wholesale (the pre-§11 behavior left ~10 entries
        // here; the sharded cache keeps at least half of each full
        // shard).
        assert!(
            cache.len() >= EVAL_CACHE_CAPACITY / 4,
            "overflow evicted almost everything: {} entries left",
            cache.len()
        );
        // The evicted cache still answers correctly (recomputes on miss).
        let shape = GemmShape::new(1, 1, 1);
        assert_eq!(
            cache.gemm_metrics(shape, &cfg),
            crate::model::gemm::gemm_metrics(shape, &cfg)
        );
    }

    #[test]
    fn concurrent_shard_access_is_exact() {
        // Many threads hammering overlapping keys: every returned value
        // must equal the direct closed form, and hits+misses must cover
        // every lookup.
        let cache = EvalCache::new();
        let n_threads = 8;
        let lookups = 200;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let cache = &cache;
                s.spawn(move || {
                    for i in 0..lookups {
                        // Overlapping key space across threads.
                        let shape = GemmShape::new(1 + (t + i) % 17, 3 + i % 5, 2 + i % 7);
                        let cfg = ArrayConfig::new(1 + i % 9, 1 + i % 6);
                        assert_eq!(
                            cache.gemm_metrics(shape, &cfg),
                            crate::model::gemm::gemm_metrics(shape, &cfg)
                        );
                    }
                });
            }
        });
        assert_eq!(
            cache.hits() + cache.misses(),
            (n_threads * lookups) as u64
        );
        assert!(cache.len() as u64 <= cache.misses());
    }

    #[test]
    fn cache_distinguishes_metric_relevant_config_fields() {
        let shape = GemmShape::new(10, 20, 30);
        let cache = EvalCache::new();
        let base = ArrayConfig::new(8, 8);
        let small_acc = ArrayConfig::new(8, 8).with_acc_capacity(8);
        let m1 = cache.gemm_metrics(shape, &base);
        let m2 = cache.gemm_metrics(shape, &small_acc);
        assert_ne!(m1, m2);
        assert_eq!(cache.len(), 2);
        // Bitwidths do not affect access counts: same cache entry.
        let rebit = ArrayConfig::new(8, 8).with_bits(16, 16, 32);
        assert_eq!(cache.gemm_metrics(shape, &rebit), m1);
        assert_eq!(cache.len(), 2);
    }
}
