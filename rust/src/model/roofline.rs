//! Roofline analysis for the modeled array: arithmetic intensity per layer
//! (MACs per Unified Buffer byte), the configuration's machine balance
//! (PE throughput over UB bandwidth), and compute- vs memory-bound
//! classification. This quantifies *why* a configuration under-performs —
//! the refinement step the paper defers to slower tools, approximated here
//! from the model's own counters.

use crate::config::ArrayConfig;
use crate::model::layer::Layer;
use crate::model::network::Network;

/// Classification of one layer on one configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// PE array limits throughput (intensity >= machine balance).
    Compute,
    /// UB bandwidth limits throughput.
    Memory,
}

/// Per-layer roofline data.
#[derive(Debug, Clone)]
pub struct LayerRoofline {
    pub layer: String,
    /// MACs per UB byte moved (arithmetic intensity on this config —
    /// depends on tiling-induced re-reads, not just the operand sizes).
    pub intensity: f64,
    /// Fraction of peak MAC throughput actually achieved.
    pub achieved_of_peak: f64,
    pub bound: Bound,
}

/// Machine balance of a configuration: peak MACs/cycle over peak UB
/// bytes/cycle. Port widths scale with the array edges, as in the modeled
/// datapath: the SDS can fetch one full activation column (`height` words)
/// per cycle, the Weight Fetcher one tile row (`width` words), and the
/// accumulator drain writes up to `width` outputs.
pub fn machine_balance(cfg: &ArrayConfig) -> f64 {
    let peak_macs_per_cycle = cfg.pe_count() as f64;
    let act = cfg.height as f64 * cfg.act_bits as f64 / 8.0;
    let wgt = cfg.width as f64 * cfg.weight_bits as f64 / 8.0;
    let out = cfg.width as f64 * cfg.out_bits as f64 / 8.0;
    peak_macs_per_cycle / (act + wgt + out)
}

/// Roofline of one layer.
pub fn layer_roofline(layer: &Layer, cfg: &ArrayConfig) -> LayerRoofline {
    let m = layer.metrics(cfg);
    let ub_bytes = (m.movements.ub_act_reads * cfg.act_bits as u64
        + m.movements.ub_weight_reads * cfg.weight_bits as u64
        + m.movements.ub_out_writes * cfg.out_bits as u64) as f64
        / 8.0;
    let intensity = m.macs as f64 / ub_bytes.max(1.0);
    let achieved = m.macs as f64 / m.cycles.max(1) as f64; // MACs/cycle
    let peak = cfg.pe_count() as f64;
    LayerRoofline {
        layer: layer.name.clone(),
        intensity,
        achieved_of_peak: achieved / peak,
        bound: if intensity >= machine_balance(cfg) {
            Bound::Compute
        } else {
            Bound::Memory
        },
    }
}

/// Whole-network summary: per-layer data plus the memory-bound share.
pub fn network_roofline(net: &Network, cfg: &ArrayConfig) -> (Vec<LayerRoofline>, f64) {
    let layers: Vec<LayerRoofline> = net
        .layers
        .iter()
        .map(|l| layer_roofline(l, cfg))
        .collect();
    let memory_bound = layers.iter().filter(|l| l.bound == Bound::Memory).count();
    let share = memory_bound as f64 / layers.len().max(1) as f64;
    (layers, share)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::SpatialDims;

    #[test]
    fn machine_balance_scales_with_edge_length() {
        // PEs grow with edge^2, port bandwidth with edge: balance ∝ edge —
        // bigger square arrays demand ever more data re-use to stay busy.
        let small = machine_balance(&ArrayConfig::new(16, 16));
        let big = machine_balance(&ArrayConfig::new(256, 256));
        assert!((big / small - 16.0).abs() < 1e-9);
    }

    #[test]
    fn fat_conv_is_compute_bound_on_small_array() {
        // A 3x3 conv with wide channels re-uses every fetched byte many
        // times: high intensity.
        let l = Layer::conv("c", SpatialDims::square(28), 256, 256, 3, 1, 1, 1);
        let r = layer_roofline(&l, &ArrayConfig::new(32, 32));
        assert!(r.intensity > machine_balance(&ArrayConfig::new(32, 32)));
        assert_eq!(r.bound, Bound::Compute);
        assert!(r.achieved_of_peak > 0.0 && r.achieved_of_peak <= 1.0);
    }

    #[test]
    fn fc_layer_is_memory_bound() {
        // Batch-1 FC touches every weight once: intensity < 1 MAC/byte.
        let l = Layer::linear("fc", 4096, 4096);
        let cfg = ArrayConfig::new(128, 128);
        let r = layer_roofline(&l, &cfg);
        assert!(r.intensity < 2.0, "intensity {}", r.intensity);
        assert_eq!(r.bound, Bound::Memory);
    }

    #[test]
    fn vgg_has_memory_bound_tail_resnet_mostly_compute() {
        let cfg = ArrayConfig::new(64, 64);
        let (_, vgg_share) = network_roofline(&crate::nets::build("vgg16").unwrap(), &cfg);
        assert!(vgg_share > 0.0, "VGG's FC tail must be memory-bound");
        let (_, rn_share) = network_roofline(&crate::nets::build("resnet50").unwrap(), &cfg);
        assert!(rn_share < 0.5, "ResNet-50 share {rn_share}");
    }

    #[test]
    fn oversized_array_lowers_achieved_fraction() {
        let l = Layer::conv("c", SpatialDims::square(14), 64, 64, 3, 1, 1, 1);
        let snug = layer_roofline(&l, &ArrayConfig::new(32, 32));
        let huge = layer_roofline(&l, &ArrayConfig::new(256, 256));
        assert!(huge.achieved_of_peak < snug.achieved_of_peak);
    }
}
