//! Analytic per-GEMM metrics.
//!
//! Two implementations of the weight-stationary model:
//!
//! * [`ws_metrics_ref`] — the *reference*: literally walks the pass stream
//!   of [`WsSchedule`] and accumulates per-pass terms. Exact by definition,
//!   O(#passes).
//! * [`ws_metrics`] — closed form, O(1): partial-tile classes are summed
//!   algebraically. This is what the sweep engine runs (the paper's "fast
//!   exploration" claim lives here). Verified against the reference by unit
//!   and property tests, and both against the functional emulator. The
//!   closed form is factored into height-dependent ([`ws_row_factors`]) and
//!   width/accumulator-dependent ([`ws_col_factors`]) parts combined by
//!   [`ws_metrics_from_factors`], so the shape-major sweep core can cache
//!   each part per grid axis (DESIGN.md §4); the col-tile classes further
//!   collapse into the [`WsColScalars`] aggregates consumed by
//!   [`ws_metrics_from_scalars`] and the segmented sweep plan, whose axis
//!   runs come from [`ceil_div_segments`]/[`floor_div_segments`]
//!   (DESIGN.md §10).
//!
//! Plus [`os_metrics`], the output-stationary variant (paper §6 future
//! work) used by the dataflow ablation.
//!
//! Per-pass accounting (see DESIGN.md §3 for derivations). `h`/`w` are the
//! *array* dimensions: the modeled array has no clock gating, so an
//! activation entering an active row propagates through all `w` columns and
//! a partial sum descends through all `h` rows to the accumulators at the
//! bottom edge — partial tiles pay for the idle silicon around them, which
//! is exactly why oversized arrays lose on Equation 1:
//!
//! ```text
//! compute cycles   Mc + h + n_t - 2     (full-height drain)
//! UB act reads     Mc * k_t
//! UB weight reads  k_t * n_t
//! inter-PE act     Mc * k_t * (w - 1)   (full-width propagation)
//! inter-PE psum    Mc * n_t * (h - 1)   (full-height descent)
//! inter-PE weight  n_t * k_t*(k_t-1)/2
//! intra-PE         5 * Mc*k_t*n_t  +  2 * k_t*n_t
//! AA writes        Mc * n_t
//! per (j,c) chunk writeback: AA reads += Mc*n_t, UB out writes += Mc*n_t
//! ```

use crate::config::{ArrayConfig, Dataflow};
use crate::metrics::{Metrics, MovementCounters};
use crate::model::schedule::{GemmShape, WsSchedule};
use crate::util::ceil_div;

/// Dispatch on the configured dataflow.
pub fn gemm_metrics(gemm: GemmShape, cfg: &ArrayConfig) -> Metrics {
    match cfg.dataflow {
        Dataflow::WeightStationary => ws_metrics(gemm, cfg),
        Dataflow::OutputStationary => os_metrics(gemm, cfg),
    }
}

/// Reference implementation: iterate the schedule pass by pass.
pub fn ws_metrics_ref(gemm: GemmShape, cfg: &ArrayConfig) -> Metrics {
    if gemm.is_empty() {
        return Metrics::default();
    }
    let sched = WsSchedule::new(gemm, cfg);
    let mut mv = MovementCounters::default();
    let mut cycles: u64 = 0;
    let mut stall: u64 = 0;
    let mut passes: u64 = 0;
    let mut prev_compute: Option<u64> = None; // D_{p-1}

    for p in sched.passes() {
        let (mc, kt, nt) = (p.mc as u64, p.k_t as u64, p.n_t as u64);
        // Weight-load exposure: first pass exposes its full load; later
        // passes stall for max(0, L_p - D_{p-1}).
        match prev_compute {
            None => cycles += p.load_cycles(),
            Some(d_prev) => {
                let s = p.load_cycles().saturating_sub(d_prev);
                cycles += s;
                stall += s;
            }
        }
        let d = p.compute_cycles();
        cycles += d;
        prev_compute = Some(d);
        passes += 1;

        let h = p.array_height as u64;
        let w = p.array_width as u64;
        mv.ub_act_reads += mc * kt;
        mv.ub_weight_reads += kt * nt;
        mv.inter_pe_act += mc * kt * (w - 1);
        mv.inter_pe_psum += mc * nt * (h - 1);
        mv.inter_pe_weight += nt * kt * (kt - 1) / 2;
        mv.intra_pe += 5 * mc * kt * nt + 2 * kt * nt;
        mv.aa_writes += mc * nt;
        if p.writeback_after {
            mv.aa_reads += mc * nt;
            mv.ub_out_writes += mc * nt;
        }
    }

    Metrics {
        cycles,
        stall_cycles: stall,
        macs: gemm.macs(),
        passes,
        movements: mv,
    }
}

/// The height-dependent factors of the closed-form WS model for one GEMM
/// shape: row-tile count, the weight shift-down hop sum of one tile-column
/// load, and the exposed first-load duration. Computing these once per
/// (shape, height) and reusing them across every width of a sweep grid is
/// what makes the shape-major sweep core fast (DESIGN.md §4) — these are
/// the only places the closed form divides by the array height.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WsRowFactors {
    /// The array height these factors were derived for — carried along so
    /// a cached entry can never be combined under a different height.
    pub height: usize,
    /// Row tiles over K.
    pub tr: u64,
    /// Σ over row-tiles of k_t·(k_t−1)/2.
    pub s_kk: u64,
    /// Exposed initial weight load, k_t(0) = min(K, h).
    pub k0: u64,
}

/// One col-tile class of the closed form: its active width, how many such
/// col-tiles exist, and the accumulator M-chunk count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WsColClass {
    pub nt: u64,
    pub count: u64,
    pub chunks: u64,
}

/// The width/accumulator-dependent factors: (tc−1) full-width col-tiles
/// plus one tail class. The only divisions by width and accumulator
/// capacity in the closed form happen here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WsColFactors {
    /// The array width these factors were derived for (see
    /// [`WsRowFactors::height`]).
    pub width: usize,
    pub classes: [WsColClass; 2],
}

/// Compute [`WsRowFactors`] for one (shape, array height) pair.
pub fn ws_row_factors(gemm: GemmShape, height: usize) -> WsRowFactors {
    if gemm.is_empty() {
        return WsRowFactors {
            height,
            tr: 0,
            s_kk: 0,
            k0: 0,
        };
    }
    let big_k = gemm.k as u64;
    let h = height as u64;
    let tr = ceil_div(gemm.k, height) as u64;
    let k_tail = big_k - (tr - 1) * h; // == h when divisible
    // Sum over row-tiles of k_t*(k_t-1)/2 — the weight shift-down hops of
    // one tile-column load.
    let s_kk = (tr - 1) * (h * (h - 1) / 2) + k_tail * (k_tail - 1) / 2;
    WsRowFactors {
        height,
        tr,
        s_kk,
        k0: big_k.min(h),
    }
}

/// Compute [`WsColFactors`] for one (shape, array width, accumulator
/// capacity) triple.
pub fn ws_col_factors(gemm: GemmShape, width: usize, acc_capacity: usize) -> WsColFactors {
    let empty = WsColClass {
        nt: 0,
        count: 0,
        chunks: 0,
    };
    if gemm.is_empty() {
        return WsColFactors {
            width,
            classes: [empty; 2],
        };
    }
    let big_n = gemm.n as u64;
    let w = width as u64;
    let acc = acc_capacity as u64;
    let tc = ceil_div(gemm.n, width) as u64;
    let n_tail = big_n - (tc - 1) * w;
    let class = |nt: u64, count: u64| -> WsColClass {
        if count == 0 || nt == 0 {
            return empty;
        }
        let r = (acc / nt).max(1); // accumulator row budget
        WsColClass {
            nt,
            count,
            chunks: ceil_div(gemm.m, r as usize) as u64,
        }
    };
    // Col-tile classes: (tc - 1) full tiles of width w, one tail of n_tail.
    WsColFactors {
        width,
        classes: [class(w, tc - 1), class(n_tail, 1)],
    }
}

/// The collapsed ("tile-class-summed") form of [`WsColFactors`]: the four
/// aggregates over col-tile classes that the closed form actually
/// consumes. Every per-class metric term is linear in one of these, so
/// summing the classes once here turns the per-cell combine into a fixed
/// set of scalar multiply-adds — the algebraic step behind the segmented
/// sweep plan (DESIGN.md §10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WsColScalars {
    /// The array width these aggregates were derived for.
    pub width: usize,
    /// Σ count — the col-tile count `tc` for a well-formed factor set.
    pub s_cnt: u64,
    /// Σ count·nt — equals `N` for a well-formed factor set.
    pub s_n: u64,
    /// Σ count·chunks·nt.
    pub s_c: u64,
    /// Σ count·chunks.
    pub s_cc: u64,
}

impl WsColFactors {
    /// Sum the tile classes into [`WsColScalars`]. Classes zeroed by the
    /// [`ws_col_factors`] constructor contribute nothing, exactly as they
    /// are skipped by [`ws_metrics_from_factors`].
    pub fn collapse(&self) -> WsColScalars {
        let mut s = WsColScalars {
            width: self.width,
            s_cnt: 0,
            s_n: 0,
            s_c: 0,
            s_cc: 0,
        };
        for &WsColClass { nt, count, chunks } in &self.classes {
            if count == 0 || nt == 0 {
                continue;
            }
            s.s_cnt += count;
            s.s_n += count * nt;
            s.s_c += count * chunks * nt;
            s.s_cc += count * chunks;
        }
        s
    }
}

/// [`ws_col_factors`] collapsed to its class aggregates.
pub fn ws_col_scalars(gemm: GemmShape, width: usize, acc_capacity: usize) -> WsColScalars {
    ws_col_factors(gemm, width, acc_capacity).collapse()
}

/// Assemble closed-form WS metrics from collapsed class aggregates —
/// byte-identical to [`ws_metrics_from_factors`] by pure reassociation of
/// the exact integer sums (verified by unit and property tests). This is
/// the per-cell kernel of the segmented sweep plan: no divisions, no
/// branches, no per-class loop.
pub fn ws_metrics_from_scalars(gemm: GemmShape, row: &WsRowFactors, col: &WsColScalars) -> Metrics {
    if gemm.is_empty() {
        return Metrics::default();
    }
    let (big_m, big_k) = (gemm.m as u64, gemm.k as u64);
    let h = row.height as u64;
    let w = col.width as u64;
    let WsRowFactors { tr, s_kk, k0, .. } = *row;
    let WsColScalars {
        s_cnt, s_n, s_c, s_cc, ..
    } = *col;

    // Per-class sums of ws_metrics_from_factors, distributed over the
    // aggregates. `M·s_cnt + h·s_cc + s_c >= 2·s_cc` always (chunks <= M
    // and nt >= 1 per counted class), so the compute-sum rearrangement
    // cannot underflow.
    let sum_compute = tr * (big_m * s_cnt + h * s_cc + s_c - 2 * s_cc);
    Metrics {
        cycles: k0 + sum_compute,
        stall_cycles: 0,
        macs: gemm.macs(),
        passes: tr * s_cc,
        movements: MovementCounters {
            ub_act_reads: big_m * big_k * s_cnt,
            ub_weight_reads: big_k * s_c,
            ub_out_writes: big_m * s_n,
            inter_pe_act: big_m * big_k * (w - 1) * s_cnt,
            inter_pe_psum: big_m * (h - 1) * tr * s_n,
            inter_pe_weight: s_kk * s_c,
            intra_pe: 5 * big_m * big_k * s_n + 2 * big_k * s_c,
            aa_writes: big_m * tr * s_n,
            aa_reads: big_m * s_n,
        },
    }
}

/// The height-dependent scalars of the output-stationary closed form for
/// one GEMM shape: the row-tile count `tm = ceil(M/h)` and the drain hop
/// correction `s_mm = Σ over row-tiles of mt·(mt−1)/2`. Like
/// [`WsRowFactors`], these are the only places the OS model divides by
/// the array height, so the segmented OS sweep plan computes them once
/// per (shape, height) — within a constant-`tm` segment `m_tail` is
/// linear in `h` and `s_mm` quadratic (DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OsRowScalars {
    /// The array height these scalars were derived for.
    pub height: usize,
    /// Row tiles over M.
    pub tm: u64,
    /// Σ over row-tiles of mt·(mt−1)/2 — the drain shift-down deficit.
    pub s_mm: u64,
}

/// The width-dependent scalar of the OS closed form: the col-tile count
/// `tc = ceil(N/w)`. The OS model has no accumulator dependence, so this
/// is the *entire* width axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OsColScalars {
    /// The array width this scalar was derived for.
    pub width: usize,
    /// Col tiles over N.
    pub tc: u64,
}

/// The OS drain deficit `Σ over row-tiles of mt·(mt−1)/2` for `tm`
/// row-tiles of `M` rows on an `h`-row array: `tm − 1` full tiles of
/// `mt = h` plus one tail of `M − (tm−1)·h`. The single source of the
/// formula — [`os_row_scalars`] and the segmented OS plan builder (which
/// already knows `tm` from its axis segments) both call it.
pub fn os_drain_deficit(big_m: u64, h: u64, tm: u64) -> u64 {
    let m_tail = big_m - (tm - 1) * h; // == h when divisible
    (tm - 1) * (h * (h - 1) / 2) + m_tail * (m_tail - 1) / 2
}

/// Compute [`OsRowScalars`] for one (shape, array height) pair.
pub fn os_row_scalars(gemm: GemmShape, height: usize) -> OsRowScalars {
    if gemm.is_empty() {
        return OsRowScalars {
            height,
            tm: 0,
            s_mm: 0,
        };
    }
    let big_m = gemm.m as u64;
    let h = height as u64;
    let tm = ceil_div(gemm.m, height) as u64;
    OsRowScalars {
        height,
        tm,
        s_mm: os_drain_deficit(big_m, h, tm),
    }
}

/// Compute [`OsColScalars`] for one (shape, array width) pair.
pub fn os_col_scalars(gemm: GemmShape, width: usize) -> OsColScalars {
    OsColScalars {
        width,
        tc: if gemm.is_empty() {
            0
        } else {
            ceil_div(gemm.n, width) as u64
        },
    }
}

/// Assemble closed-form OS metrics from per-axis scalars — byte-identical
/// to [`os_metrics`] by exact integer reassociation of its tile-class
/// double loop (verified by unit and property tests). Distributing the
/// class sums over `tm = Σ rc`, `M = Σ rc·mt`, `tc = Σ cc`, `N = Σ cc·nt`
/// leaves exactly two terms bilinear in the axes (`tm·tc` in cycles and
/// passes); everything else is a per-axis or constant total, which is
/// what makes the segmented OS sweep plan's per-cell combine two dot
/// products (DESIGN.md §11). Underflow-free: `mt ≤ h` gives
/// `s_mm ≤ M·(h−1)`, and `tm ≤ M` gives the `inter_pe_weight` bound.
pub fn os_metrics_from_scalars(gemm: GemmShape, row: &OsRowScalars, col: &OsColScalars) -> Metrics {
    if gemm.is_empty() {
        return Metrics::default();
    }
    let (big_m, big_k, big_n) = (gemm.m as u64, gemm.k as u64, gemm.n as u64);
    let h = row.height as u64;
    let w = col.width as u64;
    let OsRowScalars { tm, s_mm, .. } = *row;
    let tc = col.tc;
    Metrics {
        // Σ tiles·(K + mt + nt − 2 + h) = tm·tc·(K + h − 2) + M·tc + tm·N.
        cycles: tm * tc * (big_k + h - 2) + big_m * tc + tm * big_n,
        stall_cycles: 0,
        macs: gemm.macs(),
        passes: tm * tc,
        movements: MovementCounters {
            ub_act_reads: big_k * big_m * tc,
            ub_weight_reads: big_k * big_n * tm,
            ub_out_writes: big_m * big_n,
            inter_pe_act: big_k * big_m * tc * (w - 1),
            inter_pe_weight: big_k * big_n * (big_m - tm),
            // Σ tiles·nt·(mt·(h−1) − mt·(mt−1)/2) = N·(M·(h−1) − s_mm).
            inter_pe_psum: big_n * (big_m * (h - 1) - s_mm),
            intra_pe: (5 * big_k + 2) * big_m * big_n,
            aa_writes: big_m * big_n,
            aa_reads: big_m * big_n,
        },
    }
}

/// One maximal run of a tiling step function over a sorted axis:
/// `axis[start..end]` all map to the same `value` (a tile count for
/// [`ceil_div_segments`], a row budget for [`floor_div_segments`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AxisSegment {
    pub start: usize,
    /// Exclusive end index.
    pub end: usize,
    pub value: u64,
}

/// Maximal runs of constant `ceil(dim / a)` over a sorted, deduplicated
/// axis of positive values — the piecewise-constant ("hyperbolic")
/// decomposition of the tile-count step function. `ceil(dim/a) = t` holds
/// exactly for `a ∈ [ceil(dim/t), ceil(dim/(t−1)) − 1]`, so each segment
/// end is found by one division and a binary search instead of dividing
/// per axis value; a dense axis collapses into O(√dim) segments.
pub fn ceil_div_segments(dim: usize, axis: &[usize]) -> Vec<AxisSegment> {
    let mut out = Vec::new();
    if axis.is_empty() {
        return out;
    }
    if dim == 0 {
        out.push(AxisSegment {
            start: 0,
            end: axis.len(),
            value: 0,
        });
        return out;
    }
    let mut i = 0;
    while i < axis.len() {
        let t = ceil_div(dim, axis[i]) as u64;
        let end = if t <= 1 {
            axis.len() // every larger value also covers dim in one tile
        } else {
            let hi = ceil_div(dim, t as usize - 1) - 1;
            i + axis[i..].partition_point(|&a| a <= hi)
        };
        out.push(AxisSegment {
            start: i,
            end,
            value: t,
        });
        i = end;
    }
    out
}

/// Maximal runs of constant `floor(num / a)` over a sorted, deduplicated
/// axis of positive values — the accumulator row-budget step function.
/// `floor(num/a) = q ≥ 1` holds exactly for
/// `a ∈ [floor(num/(q+1)) + 1, floor(num/q)]`; values past `num` share the
/// terminal `q = 0` segment.
pub fn floor_div_segments(num: usize, axis: &[usize]) -> Vec<AxisSegment> {
    let mut out = Vec::new();
    if axis.is_empty() {
        return out;
    }
    let mut i = 0;
    while i < axis.len() {
        let q = (num / axis[i]) as u64;
        let end = if q == 0 {
            axis.len() // axis[i] > num, and the axis only grows
        } else {
            let hi = num / q as usize;
            i + axis[i..].partition_point(|&a| a <= hi)
        };
        out.push(AxisSegment {
            start: i,
            end,
            value: q,
        });
        i = end;
    }
    out
}

/// Assemble closed-form WS metrics from precomputed factors. This is the
/// single implementation of the closed form: [`ws_metrics`] routes through
/// it, and the shape-major sweep core calls it with factors cached per
/// (shape, grid axis) — both paths are byte-identical by construction.
/// The array dimensions come from the factor structs themselves, so
/// mismatched (factors, geometry) pairings are unrepresentable.
/// [`ws_metrics_from_scalars`] is the further-collapsed form the segmented
/// sweep plan assembles cells with.
pub fn ws_metrics_from_factors(gemm: GemmShape, row: &WsRowFactors, col: &WsColFactors) -> Metrics {
    if gemm.is_empty() {
        return Metrics::default();
    }
    let (big_m, big_k) = (gemm.m as u64, gemm.k as u64);
    let h = row.height as u64;
    let w = col.width as u64;
    let WsRowFactors { tr, s_kk, k0, .. } = *row;

    let mut mv = MovementCounters::default();
    let mut passes = 0u64;
    let mut sum_compute = 0u64; // sum of D_p over all passes

    for &WsColClass { nt, count, chunks } in &col.classes {
        if count == 0 || nt == 0 {
            continue;
        }
        let c = chunks;

        // --- movement counters, per single col-tile of this class ---
        let ub_act = big_m * big_k;
        let ub_w = c * big_k * nt;
        // Full-array propagation: acts cross all w columns, psums descend
        // all h rows (per active source element; see module docs).
        let inter_act = big_m * big_k * (w - 1);
        let inter_psum = big_m * nt * (h - 1) * tr;
        let inter_weight = c * nt * s_kk;
        let intra = 5 * big_m * big_k * nt + 2 * c * big_k * nt;
        let aa_w = big_m * nt * tr;
        let out = big_m * nt;

        mv.ub_act_reads += count * ub_act;
        mv.ub_weight_reads += count * ub_w;
        mv.inter_pe_act += count * inter_act;
        mv.inter_pe_psum += count * inter_psum;
        mv.inter_pe_weight += count * inter_weight;
        mv.intra_pe += count * intra;
        mv.aa_writes += count * aa_w;
        mv.aa_reads += count * out;
        mv.ub_out_writes += count * out;

        passes += count * c * tr;
        // Sum of compute durations: sum_{c,i} (mc + h + nt - 2)
        //   = tr * M + C*tr*(h + nt - 2)
        sum_compute += count * (tr * big_m + c * tr * (h + nt - 2));
    }

    // --- cycles: exposed initial load + sum of compute ---
    // With full-height drains every pass lasts at least h cycles, which is
    // always >= the next tile's k_t-cycle load: double buffering hides all
    // loads except the very first (k0). Stalls are structurally impossible
    // in the WS schedule (the bandwidth report still flags the exposure
    // via stall_cycles for the other dataflows/baselines).
    let cycles = k0 + sum_compute;

    Metrics {
        cycles,
        stall_cycles: 0,
        macs: gemm.macs(),
        passes,
        movements: mv,
    }
}

/// Closed-form weight-stationary metrics, O(1) in the operand sizes.
pub fn ws_metrics(gemm: GemmShape, cfg: &ArrayConfig) -> Metrics {
    if gemm.is_empty() {
        return Metrics::default();
    }
    ws_metrics_from_factors(
        gemm,
        &ws_row_factors(gemm, cfg.height),
        &ws_col_factors(gemm, cfg.width, cfg.acc_capacity),
    )
}

/// Output-stationary metrics (closed form). The array pins an (mt x nt)
/// tile of C in the PEs; A streams in from the left, W from the top, for K
/// cycles, then the finished tile drains down its columns.
///
/// Per C-tile (extents mt = min(h, M - ih), nt = min(w, N - jw)):
///
/// ```text
/// cycles          K + mt + nt - 2  (skewed stream)  +  mt (drain)
/// UB act reads    K * mt
/// UB weight reads K * nt
/// inter-PE act    K * mt * (nt - 1)
/// inter-PE weight K * nt * (mt - 1)
/// inter-PE psum   nt * mt*(mt-1)/2          (drain shift-down)
/// intra-PE        5 * K*mt*nt + 2 * mt*nt   (MACs + drain regs)
/// AA writes/reads mt * nt each (outputs cross the array boundary once)
/// UB out writes   mt * nt
/// ```
pub fn os_metrics(gemm: GemmShape, cfg: &ArrayConfig) -> Metrics {
    if gemm.is_empty() {
        return Metrics::default();
    }
    let (big_m, big_k, big_n) = (gemm.m as u64, gemm.k as u64, gemm.n as u64);
    let h = cfg.height as u64;
    let w = cfg.width as u64;
    let tm = ceil_div(gemm.m, cfg.height) as u64;
    let tc = ceil_div(gemm.n, cfg.width) as u64;
    let m_tail = big_m - (tm - 1) * h;
    let n_tail = big_n - (tc - 1) * w;

    let mut mv = MovementCounters::default();
    let mut cycles = 0u64;
    let row_classes = [(h, tm - 1), (m_tail, 1)];
    let col_classes = [(w, tc - 1), (n_tail, 1)];

    for &(mt, rc) in &row_classes {
        for &(nt, cc) in &col_classes {
            let tiles = rc * cc;
            if tiles == 0 {
                continue;
            }
            // Full-array propagation, as in the WS model: activations
            // cross all w columns; the finished tile drains down the full
            // h-row height to the bottom edge.
            cycles += tiles * (big_k + mt + nt - 2 + h);
            mv.ub_act_reads += tiles * big_k * mt;
            mv.ub_weight_reads += tiles * big_k * nt;
            mv.inter_pe_act += tiles * big_k * mt * (w - 1);
            mv.inter_pe_weight += tiles * big_k * nt * (mt - 1);
            // Drain: the output at row r descends (h - 1 - r) hops.
            mv.inter_pe_psum += tiles * nt * (mt * (h - 1) - mt * (mt - 1) / 2);
            mv.intra_pe += tiles * (5 * big_k * mt * nt + 2 * mt * nt);
            mv.aa_writes += tiles * mt * nt;
            mv.aa_reads += tiles * mt * nt;
            mv.ub_out_writes += tiles * mt * nt;
        }
    }

    Metrics {
        cycles,
        stall_cycles: 0,
        macs: gemm.macs(),
        passes: tm * tc,
        movements: mv,
    }
}

/// Accumulator lanes in the fused streaming dot kernels below. Eight
/// 64-bit lanes fill one AVX-512 register (two AVX2, four NEON) per
/// accumulator; the segmented plans pad their SoA tables to a multiple
/// of this so the lane loop never takes the scalar tail on plan tables.
pub const DOT_LANES: usize = 8;

/// The fused weight-stationary cell kernel: one streaming pass over the
/// five SoA operands computes all three per-cell dot products
/// (`inter_weight = skk_m·col_c`, `passes = tr_m·col_cc`,
/// `cyc = tr_m·col_cyc`) with [`DOT_LANES`] independent accumulator
/// lanes per product, written as fixed-width array blocks so LLVM
/// autovectorizes on stable Rust (no nightly `std::simd`).
///
/// Unsigned 64-bit addition is associative and commutative even under
/// wrapping, so the lane reassociation is **byte-identical** to the
/// sequential `iter().zip().map().sum()` it replaces whenever that sum
/// does not overflow — and still equals the sequential *wrapping* fold
/// when it does (unit- and property-tested).
#[inline]
pub fn ws_cell_dots(
    skk_m: &[u64],
    tr_m: &[u64],
    col_c: &[u64],
    col_cc: &[u64],
    col_cyc: &[u64],
) -> (u64, u64, u64) {
    let n = skk_m.len();
    debug_assert!(
        tr_m.len() == n && col_c.len() == n && col_cc.len() == n && col_cyc.len() == n,
        "ws_cell_dots operands must agree in length"
    );
    let mut iw = [0u64; DOT_LANES];
    let mut ps = [0u64; DOT_LANES];
    let mut cy = [0u64; DOT_LANES];
    let mut i = 0;
    while i + DOT_LANES <= n {
        let a: &[u64; DOT_LANES] = skk_m[i..i + DOT_LANES].try_into().unwrap();
        let t: &[u64; DOT_LANES] = tr_m[i..i + DOT_LANES].try_into().unwrap();
        let c: &[u64; DOT_LANES] = col_c[i..i + DOT_LANES].try_into().unwrap();
        let cc: &[u64; DOT_LANES] = col_cc[i..i + DOT_LANES].try_into().unwrap();
        let cyv: &[u64; DOT_LANES] = col_cyc[i..i + DOT_LANES].try_into().unwrap();
        for l in 0..DOT_LANES {
            iw[l] = iw[l].wrapping_add(a[l].wrapping_mul(c[l]));
            ps[l] = ps[l].wrapping_add(t[l].wrapping_mul(cc[l]));
            cy[l] = cy[l].wrapping_add(t[l].wrapping_mul(cyv[l]));
        }
        i += DOT_LANES;
    }
    // Scalar tail — unreachable for lane-padded plan tables, kept so the
    // kernel is total over arbitrary slices.
    let (mut inter_weight, mut passes, mut cyc) = (0u64, 0u64, 0u64);
    while i < n {
        inter_weight = inter_weight.wrapping_add(skk_m[i].wrapping_mul(col_c[i]));
        passes = passes.wrapping_add(tr_m[i].wrapping_mul(col_cc[i]));
        cyc = cyc.wrapping_add(tr_m[i].wrapping_mul(col_cyc[i]));
        i += 1;
    }
    for l in 0..DOT_LANES {
        inter_weight = inter_weight.wrapping_add(iw[l]);
        passes = passes.wrapping_add(ps[l]);
        cyc = cyc.wrapping_add(cy[l]);
    }
    (inter_weight, passes, cyc)
}

/// The fused output-stationary cell kernel: one streaming pass over the
/// three SoA operands computes both per-cell dot products
/// (`cyc = cyc_r·tc`, `passes = tm_m·tc` — the shared `tc` stream is
/// loaded once per lane block). Same lane layout and byte-identity
/// argument as [`ws_cell_dots`].
#[inline]
pub fn os_cell_dots(cyc_r: &[u64], tm_m: &[u64], tc: &[u64]) -> (u64, u64) {
    let n = cyc_r.len();
    debug_assert!(
        tm_m.len() == n && tc.len() == n,
        "os_cell_dots operands must agree in length"
    );
    let mut cy = [0u64; DOT_LANES];
    let mut ps = [0u64; DOT_LANES];
    let mut i = 0;
    while i + DOT_LANES <= n {
        let r: &[u64; DOT_LANES] = cyc_r[i..i + DOT_LANES].try_into().unwrap();
        let m: &[u64; DOT_LANES] = tm_m[i..i + DOT_LANES].try_into().unwrap();
        let c: &[u64; DOT_LANES] = tc[i..i + DOT_LANES].try_into().unwrap();
        for l in 0..DOT_LANES {
            cy[l] = cy[l].wrapping_add(r[l].wrapping_mul(c[l]));
            ps[l] = ps[l].wrapping_add(m[l].wrapping_mul(c[l]));
        }
        i += DOT_LANES;
    }
    let (mut cyc, mut passes) = (0u64, 0u64);
    while i < n {
        cyc = cyc.wrapping_add(cyc_r[i].wrapping_mul(tc[i]));
        passes = passes.wrapping_add(tm_m[i].wrapping_mul(tc[i]));
        i += 1;
    }
    for l in 0..DOT_LANES {
        cyc = cyc.wrapping_add(cy[l]);
        passes = passes.wrapping_add(ps[l]);
    }
    (cyc, passes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(h: usize, w: usize, acc: usize) -> ArrayConfig {
        ArrayConfig::new(h, w).with_acc_capacity(acc)
    }

    #[test]
    fn cell_dot_kernels_match_sequential_sums() {
        let mut rng = crate::util::prng::Rng::new(0xD075);
        for n in 0..=40usize {
            // Small operands: the checked sequential sum cannot overflow,
            // so this covers the exact pre-vectorization semantics on
            // every length class mod DOT_LANES (including 0, 1, 7).
            let v: Vec<Vec<u64>> = (0..5)
                .map(|_| (0..n).map(|_| rng.next_u64() >> 44).collect())
                .collect();
            let dot = |x: &[u64], y: &[u64]| -> u64 {
                x.iter().zip(y).map(|(&a, &b)| a * b).sum()
            };
            assert_eq!(
                ws_cell_dots(&v[0], &v[1], &v[2], &v[3], &v[4]),
                (dot(&v[0], &v[2]), dot(&v[1], &v[3]), dot(&v[1], &v[4])),
                "ws kernel diverged at n={n}"
            );
            assert_eq!(
                os_cell_dots(&v[0], &v[1], &v[2]),
                (dot(&v[0], &v[2]), dot(&v[1], &v[2])),
                "os kernel diverged at n={n}"
            );
        }
    }

    #[test]
    fn cell_dot_kernels_wrap_like_the_sequential_wrapping_fold() {
        // Full-width operands overflow; u64 wrapping addition stays
        // associative and commutative, so the lane reassociation must
        // equal the sequential wrapping fold bit for bit.
        let mut rng = crate::util::prng::Rng::new(0x0F10);
        for n in [1usize, 7, 8, 9, 15, 16, 17, 31] {
            let v: Vec<Vec<u64>> = (0..5)
                .map(|_| (0..n).map(|_| rng.next_u64()).collect())
                .collect();
            let dot = |x: &[u64], y: &[u64]| -> u64 {
                x.iter()
                    .zip(y)
                    .fold(0u64, |s, (&a, &b)| s.wrapping_add(a.wrapping_mul(b)))
            };
            assert_eq!(
                ws_cell_dots(&v[0], &v[1], &v[2], &v[3], &v[4]),
                (dot(&v[0], &v[2]), dot(&v[1], &v[3]), dot(&v[1], &v[4]))
            );
            assert_eq!(
                os_cell_dots(&v[0], &v[1], &v[2]),
                (dot(&v[0], &v[2]), dot(&v[1], &v[2]))
            );
        }
    }

    #[test]
    fn empty_gemm_is_zero() {
        let m = ws_metrics(GemmShape::new(0, 8, 8), &cfg(8, 8, 4096));
        assert_eq!(m, Metrics::default());
    }

    #[test]
    fn single_pass_by_hand() {
        // M=3, K=4, N=2 on a 4x2 array, big accumulator: one pass.
        let g = GemmShape::new(3, 4, 2);
        let m = ws_metrics(g, &cfg(4, 2, 4096));
        assert_eq!(m.passes, 1);
        // cycles = initial load (4) + compute (3+4+2-2 = 7) = 11.
        assert_eq!(m.cycles, 11);
        assert_eq!(m.stall_cycles, 0);
        assert_eq!(m.macs, 24);
        let mv = m.movements;
        assert_eq!(mv.ub_act_reads, 3 * 4);
        assert_eq!(mv.ub_weight_reads, 4 * 2);
        assert_eq!(mv.ub_out_writes, 3 * 2);
        assert_eq!(mv.inter_pe_act, 3 * 4 * 1);
        assert_eq!(mv.inter_pe_psum, 3 * 2 * 3);
        assert_eq!(mv.inter_pe_weight, 2 * (4 * 3) / 2);
        assert_eq!(mv.intra_pe, 5 * 24 + 2 * 8);
        assert_eq!(mv.aa_writes, 6);
        assert_eq!(mv.aa_reads, 6);
    }

    #[test]
    fn closed_form_matches_reference_grid() {
        // Exhaustive small grid, including every partial-tile and
        // accumulator-chunking combination.
        for m in [1, 2, 3, 5, 7, 16] {
            for k in [1, 3, 4, 9, 17] {
                for n in [1, 2, 5, 8, 13] {
                    for (h, w) in [(1, 1), (2, 3), (4, 4), (8, 2), (3, 7)] {
                        for acc in [1, 2, 7, 64, 4096] {
                            let g = GemmShape::new(m, k, n);
                            let c = cfg(h, w, acc);
                            let fast = ws_metrics(g, &c);
                            let slow = ws_metrics_ref(g, &c);
                            assert_eq!(
                                fast, slow,
                                "mismatch at M{m} K{k} N{n} h{h} w{w} acc{acc}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn factor_reuse_across_a_grid_matches_direct_evaluation() {
        // The shape-major sweep caches row factors per height and col
        // factors per width; combining cached factors must be identical to
        // calling ws_metrics per cell.
        let shapes = [
            GemmShape::new(196, 1152, 256),
            GemmShape::new(3136, 64, 64),
            GemmShape::new(1, 9, 1),
            GemmShape::new(7, 33, 129),
        ];
        let heights = [1usize, 3, 8, 16, 96];
        let widths = [1usize, 2, 7, 48, 64];
        for g in shapes {
            for &h in &heights {
                let row = ws_row_factors(g, h);
                for &w in &widths {
                    let col = ws_col_factors(g, w, 4096);
                    let combined = ws_metrics_from_factors(g, &row, &col);
                    let direct = ws_metrics(g, &cfg(h, w, 4096));
                    assert_eq!(combined, direct, "mismatch for {g:?} at ({h}, {w})");
                }
            }
        }
    }

    #[test]
    fn factors_of_empty_shape_are_inert() {
        let g = GemmShape::new(0, 8, 8);
        assert_eq!(ws_row_factors(g, 4).tr, 0);
        assert_eq!(ws_col_factors(g, 4, 64).classes[0].count, 0);
        let m = ws_metrics_from_factors(g, &ws_row_factors(g, 4), &ws_col_factors(g, 4, 64));
        assert_eq!(m, Metrics::default());
    }

    #[test]
    fn weight_reads_grow_with_chunking() {
        // Small accumulator forces weight re-fetch per chunk: the width
        // penalty of DESIGN.md §3.1.
        let g = GemmShape::new(64, 32, 32);
        let roomy = ws_metrics(g, &cfg(8, 32, 4096));
        let tight = ws_metrics(g, &cfg(8, 32, 64)); // budget 2 rows -> 32 chunks
        assert_eq!(roomy.movements.ub_weight_reads, 32 * 32);
        assert_eq!(tight.movements.ub_weight_reads, 32 * 32 * 32);
    }

    #[test]
    fn act_rereads_grow_with_col_tiles() {
        let g = GemmShape::new(10, 16, 64);
        let wide = ws_metrics(g, &cfg(16, 64, 4096)); // Tc = 1
        let narrow = ws_metrics(g, &cfg(16, 8, 4096)); // Tc = 8
        assert_eq!(wide.movements.ub_act_reads, 10 * 16);
        assert_eq!(narrow.movements.ub_act_reads, 10 * 16 * 8);
    }

    #[test]
    fn aa_spills_grow_with_row_tiles() {
        let g = GemmShape::new(10, 64, 8);
        let tall = ws_metrics(g, &cfg(64, 8, 4096)); // Tr = 1
        let short = ws_metrics(g, &cfg(8, 8, 4096)); // Tr = 8
        assert_eq!(tall.movements.aa_writes, 10 * 8);
        assert_eq!(short.movements.aa_writes, 10 * 8 * 8);
    }

    #[test]
    fn utilization_is_one_on_exact_fit_streaming() {
        // Large M amortizes fill/drain: utilization approaches K*N fit.
        let g = GemmShape::new(100_000, 8, 8);
        let m = ws_metrics(g, &cfg(8, 8, 1 << 30));
        let u = m.utilization(64);
        assert!(u > 0.99, "utilization {u}");
    }

    #[test]
    fn oversized_array_wastes_utilization() {
        let g = GemmShape::new(100_000, 8, 8);
        let m = ws_metrics(g, &cfg(64, 64, 1 << 30));
        let u = m.utilization(64 * 64);
        assert!(u < 0.02, "utilization {u}");
    }

    #[test]
    fn weight_loads_hidden_after_first() {
        // Every pass lasts >= h cycles (full-height drain) and loads take
        // k_t <= h: double buffering hides everything but the first load.
        let g = GemmShape::new(1, 65, 8);
        let c = cfg(64, 4, 4096);
        let m = ws_metrics(g, &c);
        assert_eq!(m.stall_cycles, 0);
        assert_eq!(m, ws_metrics_ref(g, &c));
        // First-load exposure is visible: a 1-pass GEMM costs load + D.
        let tiny = ws_metrics(GemmShape::new(2, 8, 4), &cfg(8, 4, 4096));
        assert_eq!(tiny.cycles, 8 + (2 + 8 + 4 - 2));
    }

    #[test]
    fn full_array_propagation_penalizes_oversized_arrays() {
        // A thin operand (depthwise-like: K=9, N=1) on a big array moves
        // far more inter-PE data than on a snug one — the §3.1 mechanism
        // behind "small arrays win".
        let g = GemmShape::new(196, 9, 1);
        let snug = ws_metrics(g, &cfg(9, 1, 4096));
        let huge = ws_metrics(g, &cfg(256, 256, 4096));
        assert!(
            huge.movements.m_inter_pe() > 20 * snug.movements.m_inter_pe(),
            "huge {} vs snug {}",
            huge.movements.m_inter_pe(),
            snug.movements.m_inter_pe()
        );
        // And the energy ordering follows.
        let w = crate::config::EnergyWeights::paper();
        assert!(huge.energy(&w) > snug.energy(&w));
    }

    #[test]
    fn os_single_tile_by_hand() {
        let g = GemmShape::new(4, 6, 2);
        let m = os_metrics(g, &cfg(4, 2, 4096));
        assert_eq!(m.passes, 1);
        // K + mt + nt - 2 + h = 6 + 4 + 2 - 2 + 4 = 14.
        assert_eq!(m.cycles, 14);
        assert_eq!(m.movements.ub_act_reads, 6 * 4);
        assert_eq!(m.movements.ub_weight_reads, 6 * 2);
        assert_eq!(m.movements.ub_out_writes, 8);
        assert_eq!(m.movements.aa_writes, 8);
        // Drain hops: nt * (mt*(h-1) - mt*(mt-1)/2) = 2 * (12 - 6) = 12.
        assert_eq!(m.movements.inter_pe_psum, 12);
    }

    #[test]
    fn os_has_no_accumulator_chunking_penalty() {
        let g = GemmShape::new(512, 64, 64);
        let tiny_acc = os_metrics(g, &cfg(8, 8, 1));
        let huge_acc = os_metrics(g, &cfg(8, 8, 1 << 30));
        assert_eq!(tiny_acc, huge_acc);
    }

    #[test]
    fn dispatch_follows_dataflow() {
        let g = GemmShape::new(16, 16, 16);
        let ws_cfg = cfg(8, 8, 4096);
        let os_cfg = ws_cfg.clone().with_dataflow(Dataflow::OutputStationary);
        assert_eq!(gemm_metrics(g, &ws_cfg), ws_metrics(g, &ws_cfg));
        assert_eq!(gemm_metrics(g, &os_cfg), os_metrics(g, &os_cfg));
    }

    #[test]
    fn scalar_combine_equals_factor_combine() {
        // The collapsed per-cell kernel must be byte-identical to the
        // class-iterating combine on every partial-tile / chunking case.
        for m in [1, 2, 3, 5, 7, 16, 196] {
            for k in [1, 3, 4, 9, 17] {
                for n in [1, 2, 5, 8, 13, 64] {
                    for (h, w) in [(1, 1), (2, 3), (4, 4), (8, 2), (3, 7), (96, 48)] {
                        for acc in [1, 2, 7, 64, 4096] {
                            let g = GemmShape::new(m, k, n);
                            let row = ws_row_factors(g, h);
                            let col = ws_col_factors(g, w, acc);
                            let collapsed =
                                ws_metrics_from_scalars(g, &row, &col.collapse());
                            let classed = ws_metrics_from_factors(g, &row, &col);
                            assert_eq!(
                                collapsed, classed,
                                "mismatch at M{m} K{k} N{n} h{h} w{w} acc{acc}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn os_scalar_combine_equals_os_metrics() {
        // The collapsed OS kernel must be byte-identical to the
        // tile-class double loop on every partial-tile combination.
        for m in [1, 2, 3, 5, 7, 16, 196] {
            for k in [1, 3, 4, 9, 17] {
                for n in [1, 2, 5, 8, 13, 64] {
                    for (h, w) in [(1, 1), (2, 3), (4, 4), (8, 2), (3, 7), (96, 48)] {
                        let g = GemmShape::new(m, k, n);
                        let row = os_row_scalars(g, h);
                        let col = os_col_scalars(g, w);
                        let collapsed = os_metrics_from_scalars(g, &row, &col);
                        // acc is irrelevant to the OS model.
                        let direct = os_metrics(g, &cfg(h, w, 1));
                        assert_eq!(collapsed, direct, "mismatch at M{m} K{k} N{n} h{h} w{w}");
                    }
                }
            }
        }
    }

    #[test]
    fn os_scalars_of_empty_shape_are_inert() {
        let g = GemmShape::new(0, 8, 8);
        assert_eq!(os_row_scalars(g, 4).tm, 0);
        assert_eq!(os_col_scalars(g, 4).tc, 0);
        let m = os_metrics_from_scalars(g, &os_row_scalars(g, 4), &os_col_scalars(g, 4));
        assert_eq!(m, Metrics::default());
    }

    #[test]
    fn col_scalars_aggregate_classes() {
        // M=10, N=13, w=4, acc=8: tc=4, full class (nt=4, count=3,
        // r=2, chunks=5), tail (nt=1, count=1, r=8, chunks=2).
        let s = ws_col_scalars(GemmShape::new(10, 3, 13), 4, 8);
        assert_eq!(s.s_cnt, 4);
        assert_eq!(s.s_n, 13);
        assert_eq!(s.s_c, 3 * 5 * 4 + 2);
        assert_eq!(s.s_cc, 3 * 5 + 2);
        // Empty shape: all-zero aggregates.
        let z = ws_col_scalars(GemmShape::new(0, 3, 13), 4, 8);
        assert_eq!((z.s_cnt, z.s_n, z.s_c, z.s_cc), (0, 0, 0, 0));
    }

    #[test]
    fn ceil_div_segments_match_per_value_division() {
        for dim in [0usize, 1, 7, 9, 64, 100, 961] {
            for axis in [
                (1..=40).collect::<Vec<usize>>(),
                (16..=256).step_by(8).collect(),
                vec![1],
                vec![3, 5, 1000],
                (1..=300).collect(),
            ] {
                let segs = ceil_div_segments(dim, &axis);
                // Segments partition the axis in order.
                let mut cursor = 0;
                for s in &segs {
                    assert_eq!(s.start, cursor, "gap in segments for dim {dim}");
                    assert!(s.end > s.start);
                    cursor = s.end;
                    for &a in &axis[s.start..s.end] {
                        assert_eq!(
                            s.value,
                            ceil_div(dim, a) as u64,
                            "dim {dim} at axis value {a}"
                        );
                    }
                }
                assert_eq!(cursor, axis.len());
                // The collapse is real: far fewer segments than values.
                if dim > 0 && axis.len() > 50 {
                    assert!(segs.len() <= 2 * (dim as f64).sqrt() as usize + 2);
                }
            }
        }
    }

    #[test]
    fn floor_div_segments_match_per_value_division() {
        for num in [0usize, 1, 8, 64, 100, 4096] {
            for axis in [
                (1..=40).collect::<Vec<usize>>(),
                (16..=256).step_by(8).collect(),
                (1..=5000).step_by(7).collect(),
            ] {
                let segs = floor_div_segments(num, &axis);
                let mut cursor = 0;
                for s in &segs {
                    assert_eq!(s.start, cursor);
                    cursor = s.end;
                    for &a in &axis[s.start..s.end] {
                        assert_eq!(s.value, (num / a) as u64);
                    }
                }
                assert_eq!(cursor, axis.len());
            }
        }
    }

    #[test]
    fn macs_are_shape_product() {
        let g = GemmShape::new(7, 11, 13);
        assert_eq!(ws_metrics(g, &cfg(4, 4, 64)).macs, 7 * 11 * 13);
        assert_eq!(os_metrics(g, &cfg(4, 4, 64)).macs, 7 * 11 * 13);
    }
}
