//! Layer descriptions and their lowering to GEMM operands.
//!
//! The emulator only ever sees matrix multiplications; this module captures
//! how convolution variants (strided, padded, dilated, grouped, depthwise)
//! and fully-connected layers map onto GEMM operand dimensions — the
//! "operand's dimension varies substantially" design space the paper's
//! introduction describes.

use crate::config::ArrayConfig;
use crate::metrics::Metrics;
use crate::model::gemm::gemm_metrics;
use crate::model::schedule::GemmShape;
use std::fmt;

/// Spatial input geometry of a layer invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpatialDims {
    pub h: usize,
    pub w: usize,
}

impl SpatialDims {
    pub fn square(s: usize) -> Self {
        Self { h: s, w: s }
    }
}

/// The operator kinds the model zoo uses.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// 2-D convolution, lowered im2col-style. `dilation` expands the
    /// effective receptive field without extra MACs.
    Conv2d {
        c_in: usize,
        c_out: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
        dilation: (usize, usize),
        groups: usize,
    },
    /// Fully-connected layer over a flattened input.
    Linear { in_features: usize, out_features: usize },
}

/// A named layer instance with its input geometry and batch size.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Input spatial dims (ignored for Linear).
    pub input: SpatialDims,
    pub batch: usize,
}

impl Layer {
    pub fn conv(
        name: impl Into<String>,
        input: SpatialDims,
        c_in: usize,
        c_out: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        groups: usize,
    ) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv2d {
                c_in,
                c_out,
                kernel: (kernel, kernel),
                stride: (stride, stride),
                padding: (padding, padding),
                dilation: (1, 1),
                groups,
            },
            input,
            batch: 1,
        }
    }

    pub fn linear(name: impl Into<String>, in_features: usize, out_features: usize) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Linear {
                in_features,
                out_features,
            },
            input: SpatialDims { h: 1, w: 1 },
            batch: 1,
        }
    }

    pub fn with_batch(mut self, batch: usize) -> Layer {
        self.batch = batch;
        self
    }

    /// Output spatial dims of a conv (standard floor formula); Linear
    /// returns 1x1.
    pub fn output_dims(&self) -> SpatialDims {
        match &self.kind {
            LayerKind::Conv2d {
                kernel,
                stride,
                padding,
                dilation,
                ..
            } => {
                let eff_kh = dilation.0 * (kernel.0 - 1) + 1;
                let eff_kw = dilation.1 * (kernel.1 - 1) + 1;
                let oh = (self.input.h + 2 * padding.0).saturating_sub(eff_kh) / stride.0 + 1;
                let ow = (self.input.w + 2 * padding.1).saturating_sub(eff_kw) / stride.1 + 1;
                SpatialDims { h: oh, w: ow }
            }
            LayerKind::Linear { .. } => SpatialDims { h: 1, w: 1 },
        }
    }

    /// Output channel count.
    pub fn c_out(&self) -> usize {
        match &self.kind {
            LayerKind::Conv2d { c_out, .. } => *c_out,
            LayerKind::Linear { out_features, .. } => *out_features,
        }
    }

    /// The per-group GEMM and the group count (the array serializes one
    /// GEMM per group, as the paper notes for group convolutions).
    pub fn gemm(&self) -> (GemmShape, usize) {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                groups,
                ..
            } => {
                assert!(*groups > 0 && c_in % groups == 0 && c_out % groups == 0,
                        "layer {}: channels {}->{} not divisible by groups {}",
                        self.name, c_in, c_out, groups);
                let out = self.output_dims();
                let m = self.batch * out.h * out.w;
                let k = (c_in / groups) * kernel.0 * kernel.1;
                let n = c_out / groups;
                (GemmShape::new(m, k, n), *groups)
            }
            LayerKind::Linear {
                in_features,
                out_features,
            } => (GemmShape::new(self.batch, *in_features, *out_features), 1),
        }
    }

    /// Trainable parameter count (weights only, no biases — the emulator
    /// moves no bias data; matches how the zoo sanity tests count).
    pub fn params(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                groups,
                ..
            } => (c_in / groups) as u64 * kernel.0 as u64 * kernel.1 as u64 * *c_out as u64,
            LayerKind::Linear {
                in_features,
                out_features,
            } => *in_features as u64 * *out_features as u64,
        }
    }

    /// Useful MAC count of the layer.
    pub fn macs(&self) -> u64 {
        let (g, groups) = self.gemm();
        g.macs() * groups as u64
    }

    /// Analytic metrics of this layer on the given array: the per-group
    /// GEMM serialized `groups` times (scalar scaling in the metrics
    /// algebra — identical counters, serialized cycles).
    pub fn metrics(&self, cfg: &ArrayConfig) -> Metrics {
        let (gemm, groups) = self.gemm();
        gemm_metrics(gemm, cfg) * groups as u64
    }

    /// Like [`Layer::metrics`], with the per-group GEMM memoized in
    /// `cache` — repeated layer shapes across a network cost one
    /// closed-form evaluation.
    pub fn metrics_cached(
        &self,
        cfg: &ArrayConfig,
        cache: &crate::model::workload::EvalCache,
    ) -> Metrics {
        let (gemm, groups) = self.gemm();
        cache.gemm_metrics(gemm, cfg) * groups as u64
    }
}

impl fmt::Display for Layer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                stride,
                groups,
                ..
            } => write!(
                f,
                "{}: conv {}x{} {}->{} s{} g{} @{}x{}",
                self.name, kernel.0, kernel.1, c_in, c_out, stride.0, groups,
                self.input.h, self.input.w
            ),
            LayerKind::Linear {
                in_features,
                out_features,
            } => write!(f, "{}: linear {}->{}", self.name, in_features, out_features),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims_standard() {
        // 224x224, 7x7 s2 p3 -> 112x112 (ResNet stem).
        let l = Layer::conv("stem", SpatialDims::square(224), 3, 64, 7, 2, 3, 1);
        assert_eq!(l.output_dims(), SpatialDims::square(112));
        // 56x56, 3x3 s1 p1 -> 56x56.
        let l = Layer::conv("c", SpatialDims::square(56), 64, 64, 3, 1, 1, 1);
        assert_eq!(l.output_dims(), SpatialDims::square(56));
        // 13x13, 3x3 s2 p0 -> 6x6.
        let l = Layer::conv("p", SpatialDims::square(13), 8, 8, 3, 2, 0, 1);
        assert_eq!(l.output_dims(), SpatialDims::square(6));
    }

    #[test]
    fn dilation_expands_receptive_field() {
        // 3x3 d2 has the footprint of 5x5: 32x32 p0 s1 -> 28x28.
        let mut l = Layer::conv("d", SpatialDims::square(32), 4, 4, 3, 1, 0, 1);
        if let LayerKind::Conv2d { dilation, .. } = &mut l.kind {
            *dilation = (2, 2);
        }
        assert_eq!(l.output_dims(), SpatialDims::square(28));
        // MACs are unchanged by dilation (same 9 taps).
        let (g, _) = l.gemm();
        assert_eq!(g.k, 4 * 9);
    }

    #[test]
    fn conv_gemm_lowering() {
        let l = Layer::conv("c", SpatialDims::square(56), 64, 128, 3, 1, 1, 1);
        let (g, groups) = l.gemm();
        assert_eq!(groups, 1);
        assert_eq!(g.m, 56 * 56);
        assert_eq!(g.k, 64 * 9);
        assert_eq!(g.n, 128);
    }

    #[test]
    fn grouped_conv_shrinks_operands() {
        let l = Layer::conv("g", SpatialDims::square(14), 256, 256, 3, 1, 1, 32);
        let (g, groups) = l.gemm();
        assert_eq!(groups, 32);
        assert_eq!(g.k, 8 * 9);
        assert_eq!(g.n, 8);
        // Depthwise: groups == c_in.
        let dw = Layer::conv("dw", SpatialDims::square(14), 256, 256, 3, 1, 1, 256);
        let (g, groups) = dw.gemm();
        assert_eq!(groups, 256);
        assert_eq!((g.k, g.n), (9, 1));
    }

    #[test]
    fn linear_gemm_is_batch_by_features() {
        let l = Layer::linear("fc", 4096, 1000).with_batch(8);
        let (g, groups) = l.gemm();
        assert_eq!((g.m, g.k, g.n, groups), (8, 4096, 1000, 1));
    }

    #[test]
    fn params_and_macs() {
        // AlexNet conv1: 11x11x3x96 = 34848 params.
        let l = Layer::conv("c1", SpatialDims::square(227), 3, 96, 11, 4, 0, 1);
        assert_eq!(l.params(), 11 * 11 * 3 * 96);
        assert_eq!(l.output_dims(), SpatialDims::square(55));
        assert_eq!(l.macs(), 55 * 55 * 11 * 11 * 3 * 96);
        // Grouped params divide by g.
        let g = Layer::conv("g", SpatialDims::square(7), 64, 64, 3, 1, 1, 8);
        assert_eq!(g.params(), (64 / 8) * 9 * 64);
    }

    #[test]
    fn batch_scales_m() {
        let l = Layer::conv("c", SpatialDims::square(8), 4, 4, 3, 1, 1, 1).with_batch(3);
        let (g, _) = l.gemm();
        assert_eq!(g.m, 3 * 64);
    }

    #[test]
    fn group_metrics_serialize() {
        let cfg = ArrayConfig::new(8, 8);
        let l1 = Layer::conv("g1", SpatialDims::square(7), 16, 16, 3, 1, 1, 1);
        let l4 = Layer::conv("g4", SpatialDims::square(7), 16, 16, 3, 1, 1, 4);
        let m1 = l1.metrics(&cfg);
        let m4 = l4.metrics(&cfg);
        // Same useful MACs per layer? No: grouped layer does fewer MACs
        // (that is the efficiency win); but cycles per MAC are worse.
        assert_eq!(m1.macs, l1.macs());
        assert_eq!(m4.macs, l4.macs());
        assert_eq!(m4.macs * 4, m1.macs);
        let upm1 = m1.cycles as f64 / m1.macs as f64;
        let upm4 = m4.cycles as f64 / m4.macs as f64;
        assert!(upm4 > upm1, "grouped should cost more cycles per MAC");
    }

    #[test]
    fn cached_metrics_match_direct() {
        let cfg = ArrayConfig::new(8, 8);
        let cache = crate::model::workload::EvalCache::new();
        let l = Layer::conv("g4", SpatialDims::square(7), 16, 16, 3, 1, 1, 4);
        assert_eq!(l.metrics_cached(&cfg, &cache), l.metrics(&cfg));
        assert_eq!(cache.misses(), 1);
        assert_eq!(l.metrics_cached(&cfg, &cache), l.metrics(&cfg));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn bad_groups_panic() {
        let l = Layer::conv("bad", SpatialDims::square(8), 6, 8, 3, 1, 1, 4);
        let _ = l.gemm();
    }
}
