//! Layer descriptions and their lowering to GEMM operands.
//!
//! The emulator only ever sees matrix multiplications; this module captures
//! how convolution variants (strided, padded, dilated, grouped, depthwise)
//! and fully-connected layers map onto GEMM operand dimensions — the
//! "operand's dimension varies substantially" design space the paper's
//! introduction describes.

use crate::config::ArrayConfig;
use crate::metrics::Metrics;
use crate::model::gemm::gemm_metrics;
use crate::model::schedule::GemmShape;
use crate::util::json::Json;
use std::fmt;

/// Spatial input geometry of a layer invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpatialDims {
    pub h: usize,
    pub w: usize,
}

impl SpatialDims {
    pub fn square(s: usize) -> Self {
        Self { h: s, w: s }
    }
}

/// The operator kinds the model zoo uses.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// 2-D convolution, lowered im2col-style. `dilation` expands the
    /// effective receptive field without extra MACs.
    Conv2d {
        c_in: usize,
        c_out: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
        dilation: (usize, usize),
        groups: usize,
    },
    /// Fully-connected layer over a flattened input.
    Linear { in_features: usize, out_features: usize },
}

/// A named layer instance with its input geometry and batch size.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Input spatial dims (ignored for Linear).
    pub input: SpatialDims,
    pub batch: usize,
}

impl Layer {
    pub fn conv(
        name: impl Into<String>,
        input: SpatialDims,
        c_in: usize,
        c_out: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        groups: usize,
    ) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv2d {
                c_in,
                c_out,
                kernel: (kernel, kernel),
                stride: (stride, stride),
                padding: (padding, padding),
                dilation: (1, 1),
                groups,
            },
            input,
            batch: 1,
        }
    }

    pub fn linear(name: impl Into<String>, in_features: usize, out_features: usize) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Linear {
                in_features,
                out_features,
            },
            input: SpatialDims { h: 1, w: 1 },
            batch: 1,
        }
    }

    pub fn with_batch(mut self, batch: usize) -> Layer {
        self.batch = batch;
        self
    }

    /// Output spatial dims of a conv (standard floor formula); Linear
    /// returns 1x1.
    pub fn output_dims(&self) -> SpatialDims {
        match &self.kind {
            LayerKind::Conv2d {
                kernel,
                stride,
                padding,
                dilation,
                ..
            } => {
                let eff_kh = dilation.0 * (kernel.0 - 1) + 1;
                let eff_kw = dilation.1 * (kernel.1 - 1) + 1;
                let oh = (self.input.h + 2 * padding.0).saturating_sub(eff_kh) / stride.0 + 1;
                let ow = (self.input.w + 2 * padding.1).saturating_sub(eff_kw) / stride.1 + 1;
                SpatialDims { h: oh, w: ow }
            }
            LayerKind::Linear { .. } => SpatialDims { h: 1, w: 1 },
        }
    }

    /// Output channel count.
    pub fn c_out(&self) -> usize {
        match &self.kind {
            LayerKind::Conv2d { c_out, .. } => *c_out,
            LayerKind::Linear { out_features, .. } => *out_features,
        }
    }

    /// The per-group GEMM and the group count (the array serializes one
    /// GEMM per group, as the paper notes for group convolutions).
    pub fn gemm(&self) -> (GemmShape, usize) {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                groups,
                ..
            } => {
                assert!(*groups > 0 && c_in % groups == 0 && c_out % groups == 0,
                        "layer {}: channels {}->{} not divisible by groups {}",
                        self.name, c_in, c_out, groups);
                let out = self.output_dims();
                let m = self.batch * out.h * out.w;
                let k = (c_in / groups) * kernel.0 * kernel.1;
                let n = c_out / groups;
                (GemmShape::new(m, k, n), *groups)
            }
            LayerKind::Linear {
                in_features,
                out_features,
            } => (GemmShape::new(self.batch, *in_features, *out_features), 1),
        }
    }

    /// Trainable parameter count (weights only, no biases — the emulator
    /// moves no bias data; matches how the zoo sanity tests count).
    pub fn params(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                groups,
                ..
            } => (c_in / groups) as u64 * kernel.0 as u64 * kernel.1 as u64 * *c_out as u64,
            LayerKind::Linear {
                in_features,
                out_features,
            } => *in_features as u64 * *out_features as u64,
        }
    }

    /// Useful MAC count of the layer.
    pub fn macs(&self) -> u64 {
        let (g, groups) = self.gemm();
        g.macs() * groups as u64
    }

    /// Analytic metrics of this layer on the given array: the per-group
    /// GEMM serialized `groups` times (scalar scaling in the metrics
    /// algebra — identical counters, serialized cycles).
    pub fn metrics(&self, cfg: &ArrayConfig) -> Metrics {
        let (gemm, groups) = self.gemm();
        gemm_metrics(gemm, cfg) * groups as u64
    }

    /// Like [`Layer::metrics`], with the per-group GEMM memoized in
    /// `cache` — repeated layer shapes across a network cost one
    /// closed-form evaluation.
    pub fn metrics_cached(
        &self,
        cfg: &ArrayConfig,
        cache: &crate::model::workload::EvalCache,
    ) -> Metrics {
        let (gemm, groups) = self.gemm();
        cache.gemm_metrics(gemm, cfg) * groups as u64
    }

    /// Re-check the lowered-GEMM work ceilings (the ones [`Layer::from_json`]
    /// enforces) against the layer's *current* batch. Callers that re-batch
    /// an already-validated layer (`with_batch` overrides from a request or
    /// a network-level spec field) run this so the ingestion bounds compose
    /// instead of multiplying past the exact-arithmetic range.
    pub fn check_work_bounds(&self) -> Result<(), String> {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                groups,
                ..
            } => {
                let out = self.output_dims();
                let m = checked_product(&[self.batch as u128, out.h as u128, out.w as u128]);
                let k = checked_product(&[
                    (c_in / groups) as u128,
                    kernel.0 as u128,
                    kernel.1 as u128,
                ]);
                check_work(&self.name, m, k, (c_out / groups) as u128, *groups as u128)
            }
            LayerKind::Linear {
                in_features,
                out_features,
            } => check_work(
                &self.name,
                self.batch as u128,
                *in_features as u128,
                *out_features as u128,
                1,
            ),
        }
    }

    /// Serialize to the layer-list JSON schema the network-ingestion API
    /// consumes (see DESIGN.md §8).
    pub fn to_json(&self) -> Json {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                stride,
                padding,
                dilation,
                groups,
            } => Json::obj(vec![
                ("op", Json::str("conv2d")),
                ("name", Json::str(self.name.clone())),
                (
                    "input",
                    Json::obj(vec![
                        ("h", Json::num(self.input.h as f64)),
                        ("w", Json::num(self.input.w as f64)),
                    ]),
                ),
                ("batch", Json::num(self.batch as f64)),
                ("c_in", Json::num(*c_in as f64)),
                ("c_out", Json::num(*c_out as f64)),
                ("kernel", pair_json(*kernel)),
                ("stride", pair_json(*stride)),
                ("padding", pair_json(*padding)),
                ("dilation", pair_json(*dilation)),
                ("groups", Json::num(*groups as f64)),
            ]),
            LayerKind::Linear {
                in_features,
                out_features,
            } => Json::obj(vec![
                ("op", Json::str("linear")),
                ("name", Json::str(self.name.clone())),
                ("batch", Json::num(self.batch as f64)),
                ("in_features", Json::num(*in_features as f64)),
                ("out_features", Json::num(*out_features as f64)),
            ]),
        }
    }

    /// Parse one layer of the JSON schema, validating every structural
    /// invariant (`gemm()` may assert; nothing a request sends should ever
    /// reach an assert). Scalar shorthand is accepted wherever a pair is
    /// expected: `"kernel": 3` means `[3, 3]`.
    pub fn from_json(v: &Json) -> Result<Layer, String> {
        let op = spec_str(v, "op")?;
        let name = spec_str(v, "name")?;
        let batch = spec_usize(v, "batch", Some(1))?;
        if batch == 0 {
            return Err(format!("layer '{name}': batch must be positive"));
        }
        match op.as_str() {
            "conv2d" | "conv" => {
                let input = spec_input(v, &name)?;
                let c_in = spec_positive(v, "c_in", None, &name)?;
                let c_out = spec_positive(v, "c_out", None, &name)?;
                let kernel = spec_pair(v, "kernel", None, &name)?;
                let stride = spec_pair(v, "stride", Some((1, 1)), &name)?;
                let padding = spec_pair_allow_zero(v, "padding", Some((0, 0)), &name)?;
                let dilation = spec_pair(v, "dilation", Some((1, 1)), &name)?;
                let groups = spec_positive(v, "groups", Some(1), &name)?;
                if kernel.0 == 0 || kernel.1 == 0 || stride.0 == 0 || stride.1 == 0 {
                    return Err(format!("layer '{name}': kernel and stride must be positive"));
                }
                if dilation.0 == 0 || dilation.1 == 0 {
                    return Err(format!("layer '{name}': dilation must be positive"));
                }
                if c_in % groups != 0 || c_out % groups != 0 {
                    return Err(format!(
                        "layer '{name}': channels {c_in}->{c_out} not divisible by groups {groups}"
                    ));
                }
                // Bound every raw field first: with all of them <= 2^20 no
                // later usize expression (padded input, effective kernel,
                // pass counts) can overflow, in debug or release.
                const FIELD_LIMIT: usize = 1 << 20;
                for (field, val) in [
                    ("input.h", input.h),
                    ("input.w", input.w),
                    ("c_in", c_in),
                    ("c_out", c_out),
                    ("kernel", kernel.0.max(kernel.1)),
                    ("stride", stride.0.max(stride.1)),
                    ("padding", padding.0.max(padding.1)),
                    ("dilation", dilation.0.max(dilation.1)),
                    ("groups", groups),
                    ("batch", batch),
                ] {
                    if val > FIELD_LIMIT {
                        return Err(format!(
                            "layer '{name}': {field} = {val} exceeds the \
                             ingestion limit {FIELD_LIMIT}"
                        ));
                    }
                }
                // Check the lowered GEMM in 128-bit arithmetic before the
                // layer exists: hostile magnitudes and kernels that exceed
                // the padded input are rejected here instead of overflowing
                // (or silently saturating) the usize/u64 math downstream.
                let ph = input.h as u128 + 2 * padding.0 as u128;
                let pw = input.w as u128 + 2 * padding.1 as u128;
                let ekh = dilation.0 as u128 * (kernel.0 as u128 - 1) + 1;
                let ekw = dilation.1 as u128 * (kernel.1 as u128 - 1) + 1;
                if ekh > ph || ekw > pw {
                    return Err(format!(
                        "layer '{name}': effective kernel {ekh}x{ekw} exceeds \
                         padded input {ph}x{pw}"
                    ));
                }
                let oh = (ph - ekh) / stride.0 as u128 + 1;
                let ow = (pw - ekw) / stride.1 as u128 + 1;
                let m = checked_product(&[batch as u128, oh, ow]);
                let k = checked_product(&[
                    (c_in / groups) as u128,
                    kernel.0 as u128,
                    kernel.1 as u128,
                ]);
                check_work(&name, m, k, (c_out / groups) as u128, groups as u128)?;
                Ok(Layer {
                    name,
                    kind: LayerKind::Conv2d {
                        c_in,
                        c_out,
                        kernel,
                        stride,
                        padding,
                        dilation,
                        groups,
                    },
                    input,
                    batch,
                })
            }
            "linear" | "fc" => {
                let in_features = spec_positive(v, "in_features", None, &name)?;
                let out_features = spec_positive(v, "out_features", None, &name)?;
                check_work(
                    &name,
                    batch as u128,
                    in_features as u128,
                    out_features as u128,
                    1,
                )?;
                Ok(Layer {
                    name,
                    kind: LayerKind::Linear {
                        in_features,
                        out_features,
                    },
                    input: SpatialDims { h: 1, w: 1 },
                    batch,
                })
            }
            other => Err(format!("layer '{name}': unknown op '{other}' (conv2d|linear)")),
        }
    }
}

fn pair_json((a, b): (usize, usize)) -> Json {
    Json::arr(vec![Json::num(a as f64), Json::num(b as f64)])
}

/// Per-GEMM-dimension ceiling for ingested layers — generous for any real
/// network, small enough that every downstream usize/u64 computation
/// (tiling, pass counts, movement totals) stays exact.
const DIM_LIMIT: u128 = u32::MAX as u128;
/// Total-work ceiling (MACs) per ingested layer.
const MAC_LIMIT: u128 = 1 << 62;

/// Overflow-free product; saturates to `u128::MAX`, which then fails the
/// limit check in [`check_work`].
fn checked_product(factors: &[u128]) -> u128 {
    factors
        .iter()
        .try_fold(1u128, |acc, &f| acc.checked_mul(f))
        .unwrap_or(u128::MAX)
}

/// Reject a lowered GEMM whose dimensions or total work exceed the limits
/// the analytic model's integer math is exact for.
fn check_work(layer: &str, m: u128, k: u128, n: u128, groups: u128) -> Result<(), String> {
    let macs = checked_product(&[m, k, n, groups]);
    if m > DIM_LIMIT || k > DIM_LIMIT || n > DIM_LIMIT || macs > MAC_LIMIT {
        return Err(format!(
            "layer '{layer}': lowered GEMM is too large (m={m}, k={k}, n={n}, groups={groups})"
        ));
    }
    Ok(())
}

fn spec_str(v: &Json, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("layer missing string field '{key}'"))
}

fn spec_usize(v: &Json, key: &str, default: Option<usize>) -> Result<usize, String> {
    match v.opt_usize_field(key).map_err(|e| format!("layer {e}"))? {
        Some(x) => Ok(x),
        None => default.ok_or_else(|| format!("layer missing field '{key}'")),
    }
}

fn spec_positive(
    v: &Json,
    key: &str,
    default: Option<usize>,
    layer: &str,
) -> Result<usize, String> {
    let x = spec_usize(v, key, default).map_err(|e| format!("layer '{layer}': {e}"))?;
    if x == 0 {
        return Err(format!("layer '{layer}': field '{key}' must be positive"));
    }
    Ok(x)
}

/// A (a, b) pair value: scalar shorthand or a two-element array.
fn pair_value(j: &Json, layer: &str, key: &str) -> Result<(usize, usize), String> {
    let bad = || format!("layer '{layer}': field '{key}' must be an integer or a pair");
    if let Some(s) = j.as_usize() {
        return Ok((s, s));
    }
    let arr = j.as_arr().ok_or_else(bad)?;
    if arr.len() != 2 {
        return Err(bad());
    }
    let a = arr[0].as_usize().ok_or_else(bad)?;
    let b = arr[1].as_usize().ok_or_else(bad)?;
    Ok((a, b))
}

/// A (h, w) pair field given either as a scalar or a two-element array.
fn spec_pair_allow_zero(
    v: &Json,
    key: &str,
    default: Option<(usize, usize)>,
    layer: &str,
) -> Result<(usize, usize), String> {
    match v.get(key) {
        None => default.ok_or_else(|| format!("layer '{layer}': missing field '{key}'")),
        Some(j) => pair_value(j, layer, key),
    }
}

fn spec_pair(
    v: &Json,
    key: &str,
    default: Option<(usize, usize)>,
    layer: &str,
) -> Result<(usize, usize), String> {
    let p = spec_pair_allow_zero(v, key, default, layer)?;
    if p.0 == 0 || p.1 == 0 {
        return Err(format!("layer '{layer}': field '{key}' must be positive"));
    }
    Ok(p)
}

/// Input geometry: `{"h": H, "w": W}`, `[H, W]` or a scalar for square.
fn spec_input(v: &Json, layer: &str) -> Result<SpatialDims, String> {
    let j = v
        .get("input")
        .ok_or_else(|| format!("layer '{layer}': missing field 'input'"))?;
    let (h, w) = match (
        j.get("h").and_then(Json::as_usize),
        j.get("w").and_then(Json::as_usize),
    ) {
        (Some(h), Some(w)) => (h, w),
        _ => pair_value(j, layer, "input")?,
    };
    if h == 0 || w == 0 {
        return Err(format!("layer '{layer}': input dims must be positive"));
    }
    Ok(SpatialDims { h, w })
}

impl fmt::Display for Layer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.kind {
            LayerKind::Conv2d {
                c_in,
                c_out,
                kernel,
                stride,
                groups,
                ..
            } => write!(
                f,
                "{}: conv {}x{} {}->{} s{} g{} @{}x{}",
                self.name, kernel.0, kernel.1, c_in, c_out, stride.0, groups,
                self.input.h, self.input.w
            ),
            LayerKind::Linear {
                in_features,
                out_features,
            } => write!(f, "{}: linear {}->{}", self.name, in_features, out_features),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims_standard() {
        // 224x224, 7x7 s2 p3 -> 112x112 (ResNet stem).
        let l = Layer::conv("stem", SpatialDims::square(224), 3, 64, 7, 2, 3, 1);
        assert_eq!(l.output_dims(), SpatialDims::square(112));
        // 56x56, 3x3 s1 p1 -> 56x56.
        let l = Layer::conv("c", SpatialDims::square(56), 64, 64, 3, 1, 1, 1);
        assert_eq!(l.output_dims(), SpatialDims::square(56));
        // 13x13, 3x3 s2 p0 -> 6x6.
        let l = Layer::conv("p", SpatialDims::square(13), 8, 8, 3, 2, 0, 1);
        assert_eq!(l.output_dims(), SpatialDims::square(6));
    }

    #[test]
    fn dilation_expands_receptive_field() {
        // 3x3 d2 has the footprint of 5x5: 32x32 p0 s1 -> 28x28.
        let mut l = Layer::conv("d", SpatialDims::square(32), 4, 4, 3, 1, 0, 1);
        if let LayerKind::Conv2d { dilation, .. } = &mut l.kind {
            *dilation = (2, 2);
        }
        assert_eq!(l.output_dims(), SpatialDims::square(28));
        // MACs are unchanged by dilation (same 9 taps).
        let (g, _) = l.gemm();
        assert_eq!(g.k, 4 * 9);
    }

    #[test]
    fn conv_gemm_lowering() {
        let l = Layer::conv("c", SpatialDims::square(56), 64, 128, 3, 1, 1, 1);
        let (g, groups) = l.gemm();
        assert_eq!(groups, 1);
        assert_eq!(g.m, 56 * 56);
        assert_eq!(g.k, 64 * 9);
        assert_eq!(g.n, 128);
    }

    #[test]
    fn grouped_conv_shrinks_operands() {
        let l = Layer::conv("g", SpatialDims::square(14), 256, 256, 3, 1, 1, 32);
        let (g, groups) = l.gemm();
        assert_eq!(groups, 32);
        assert_eq!(g.k, 8 * 9);
        assert_eq!(g.n, 8);
        // Depthwise: groups == c_in.
        let dw = Layer::conv("dw", SpatialDims::square(14), 256, 256, 3, 1, 1, 256);
        let (g, groups) = dw.gemm();
        assert_eq!(groups, 256);
        assert_eq!((g.k, g.n), (9, 1));
    }

    #[test]
    fn linear_gemm_is_batch_by_features() {
        let l = Layer::linear("fc", 4096, 1000).with_batch(8);
        let (g, groups) = l.gemm();
        assert_eq!((g.m, g.k, g.n, groups), (8, 4096, 1000, 1));
    }

    #[test]
    fn params_and_macs() {
        // AlexNet conv1: 11x11x3x96 = 34848 params.
        let l = Layer::conv("c1", SpatialDims::square(227), 3, 96, 11, 4, 0, 1);
        assert_eq!(l.params(), 11 * 11 * 3 * 96);
        assert_eq!(l.output_dims(), SpatialDims::square(55));
        assert_eq!(l.macs(), 55 * 55 * 11 * 11 * 3 * 96);
        // Grouped params divide by g.
        let g = Layer::conv("g", SpatialDims::square(7), 64, 64, 3, 1, 1, 8);
        assert_eq!(g.params(), (64 / 8) * 9 * 64);
    }

    #[test]
    fn batch_scales_m() {
        let l = Layer::conv("c", SpatialDims::square(8), 4, 4, 3, 1, 1, 1).with_batch(3);
        let (g, _) = l.gemm();
        assert_eq!(g.m, 3 * 64);
    }

    #[test]
    fn group_metrics_serialize() {
        let cfg = ArrayConfig::new(8, 8);
        let l1 = Layer::conv("g1", SpatialDims::square(7), 16, 16, 3, 1, 1, 1);
        let l4 = Layer::conv("g4", SpatialDims::square(7), 16, 16, 3, 1, 1, 4);
        let m1 = l1.metrics(&cfg);
        let m4 = l4.metrics(&cfg);
        // Same useful MACs per layer? No: grouped layer does fewer MACs
        // (that is the efficiency win); but cycles per MAC are worse.
        assert_eq!(m1.macs, l1.macs());
        assert_eq!(m4.macs, l4.macs());
        assert_eq!(m4.macs * 4, m1.macs);
        let upm1 = m1.cycles as f64 / m1.macs as f64;
        let upm4 = m4.cycles as f64 / m4.macs as f64;
        assert!(upm4 > upm1, "grouped should cost more cycles per MAC");
    }

    #[test]
    fn cached_metrics_match_direct() {
        let cfg = ArrayConfig::new(8, 8);
        let cache = crate::model::workload::EvalCache::new();
        let l = Layer::conv("g4", SpatialDims::square(7), 16, 16, 3, 1, 1, 4);
        assert_eq!(l.metrics_cached(&cfg, &cache), l.metrics(&cfg));
        assert_eq!(cache.misses(), 1);
        assert_eq!(l.metrics_cached(&cfg, &cache), l.metrics(&cfg));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn bad_groups_panic() {
        let l = Layer::conv("bad", SpatialDims::square(8), 6, 8, 3, 1, 1, 4);
        let _ = l.gemm();
    }

    #[test]
    fn json_roundtrip_conv_and_linear() {
        let mut conv = Layer::conv("c", SpatialDims { h: 12, w: 9 }, 8, 16, 3, 2, 1, 2).with_batch(3);
        if let LayerKind::Conv2d { dilation, .. } = &mut conv.kind {
            *dilation = (2, 2);
        }
        let back = Layer::from_json(&conv.to_json()).unwrap();
        assert_eq!(back, conv);
        let fc = Layer::linear("fc", 512, 10).with_batch(4);
        assert_eq!(Layer::from_json(&fc.to_json()).unwrap(), fc);
    }

    #[test]
    fn json_scalar_shorthand_and_defaults() {
        let v = Json::parse(
            r#"{"op":"conv2d","name":"c1","input":{"h":16,"w":16},"c_in":3,"c_out":8,"kernel":3,"padding":1}"#,
        )
        .unwrap();
        let l = Layer::from_json(&v).unwrap();
        assert_eq!(l, Layer::conv("c1", SpatialDims::square(16), 3, 8, 3, 1, 1, 1));
    }

    #[test]
    fn json_rejects_malformed_layers() {
        for bad in [
            r#"{"op":"conv2d","name":"x","input":{"h":8,"w":8},"c_in":6,"c_out":8,"kernel":3,"groups":4}"#,
            r#"{"op":"conv2d","name":"x","input":{"h":8,"w":8},"c_in":0,"c_out":8,"kernel":3}"#,
            r#"{"op":"conv2d","name":"x","input":{"h":8,"w":8},"c_in":4,"c_out":8,"kernel":3,"stride":0}"#,
            r#"{"op":"linear","name":"x","in_features":0,"out_features":10}"#,
            r#"{"op":"pool","name":"x"}"#,
            r#"{"name":"x"}"#,
            // effective kernel exceeds the (padded) input
            r#"{"op":"conv2d","name":"x","input":{"h":2,"w":2},"c_in":4,"c_out":4,"kernel":7}"#,
            // hostile magnitudes must be rejected, not wrap or saturate
            r#"{"op":"conv2d","name":"x","input":{"h":8,"w":8},"c_in":4,"c_out":4,"kernel":3,"padding":100000000000000000}"#,
            r#"{"op":"linear","name":"x","in_features":5000000000,"out_features":5000000000}"#,
            r#"{"op":"conv2d","name":"x","input":{"h":8,"w":8},"c_in":4,"c_out":4,"kernel":3,"batch":10000000000000000000}"#,
            // raw-field magnitudes that would overflow output_dims()'s
            // usize math must never construct a Layer at all
            r#"{"op":"conv2d","name":"x","input":{"h":8,"w":8},"c_in":4,"c_out":4,"kernel":3,"padding":9223372036854775808,"stride":9223372036854775808}"#,
            r#"{"op":"conv2d","name":"x","input":{"h":8,"w":8},"c_in":4,"c_out":4,"kernel":3,"dilation":9007199254740992}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(Layer::from_json(&v).is_err(), "accepted: {bad}");
        }
    }
}
