//! Network-level aggregation: a `Network` is an ordered list of layers (the
//! GEMM-bearing operators only — pooling/activation are metric-neutral in
//! the paper's model) plus metadata. Network metrics are the serialized sum
//! of layer metrics, exactly as the emulator would run inference; the sum
//! is evaluated through the deduplicated workload IR
//! ([`crate::model::workload::Workload`]) — identical by the metrics
//! algebra, and each distinct GEMM shape is costed once.

use crate::config::ArrayConfig;
use crate::metrics::Metrics;
use crate::model::layer::Layer;
use crate::util::json::Json;
use std::collections::HashMap;

/// A named DNN as the emulator sees it.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

/// Per-layer metric breakdown for reports.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: String,
    pub metrics: Metrics,
}

impl Network {
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> Self {
        Self {
            name: name.into(),
            layers,
        }
    }

    /// Re-batch every layer (M scales with batch for convs; FC rows =
    /// batch). Used by `camuy emulate --batch N`.
    pub fn with_batch(mut self, batch: usize) -> Network {
        assert!(batch > 0);
        for l in &mut self.layers {
            l.batch = batch;
        }
        self
    }

    /// Total trainable parameters (conv + fc weights).
    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Total useful MACs for one inference.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Serialized inference metrics on one array configuration, evaluated
    /// shape-deduplicated: Σ over layers of layer metrics equals Σ over
    /// distinct shapes of multiplicity × per-shape metrics exactly (u64
    /// counters are associative/commutative, cycles serialize).
    pub fn metrics(&self, cfg: &ArrayConfig) -> Metrics {
        crate::model::workload::Workload::of(self).eval(cfg)
    }

    /// Per-layer breakdown (for the `camuy emulate --per-layer` report).
    pub fn layer_reports(&self, cfg: &ArrayConfig) -> Vec<LayerReport> {
        self.layers
            .iter()
            .map(|l| LayerReport {
                layer: l.name.clone(),
                metrics: l.metrics(cfg),
            })
            .collect()
    }

    /// Distinct GEMM shapes with multiplicity — the operand-diversity
    /// histogram the paper discusses per architecture family. Linear in the
    /// layer count (HashMap-indexed), first-seen order preserved.
    pub fn gemm_histogram(&self) -> Vec<(crate::model::schedule::GemmShape, usize, usize)> {
        // (shape, groups, occurrence count)
        let mut hist: Vec<(crate::model::schedule::GemmShape, usize, usize)> = Vec::new();
        let mut index: HashMap<(crate::model::schedule::GemmShape, usize), usize> = HashMap::new();
        for l in &self.layers {
            let (g, groups) = l.gemm();
            match index.get(&(g, groups)) {
                Some(&i) => hist[i].2 += 1,
                None => {
                    index.insert((g, groups), hist.len());
                    hist.push((g, groups, 1));
                }
            }
        }
        hist
    }

    pub fn summary_json(&self, cfg: &ArrayConfig) -> Json {
        let m = self.metrics(cfg);
        Json::obj(vec![
            ("network", Json::str(self.name.clone())),
            ("config", cfg.to_json()),
            ("params", Json::num(self.params() as f64)),
            ("macs", Json::num(self.macs() as f64)),
            ("metrics", m.to_json()),
            ("utilization", Json::num(m.utilization(cfg.pe_count()))),
            (
                "energy",
                Json::num(m.energy(&crate::config::EnergyWeights::paper())),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::SpatialDims;

    fn tiny_net() -> Network {
        Network::new(
            "tiny",
            vec![
                Layer::conv("c1", SpatialDims::square(8), 3, 8, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(8), 8, 8, 3, 1, 1, 1),
                Layer::linear("fc", 8 * 8 * 8, 10),
            ],
        )
    }

    #[test]
    fn totals_are_sums() {
        let net = tiny_net();
        let cfg = ArrayConfig::new(8, 8);
        let total = net.metrics(&cfg);
        let by_layer: Metrics = net
            .layers
            .iter()
            .map(|l| l.metrics(&cfg))
            .fold(Metrics::default(), |a, b| a + b);
        assert_eq!(total, by_layer);
        assert_eq!(net.params(), 3 * 8 * 9 + 8 * 8 * 9 + 512 * 10);
        assert!(net.macs() > 0);
    }

    #[test]
    fn layer_reports_align() {
        let net = tiny_net();
        let cfg = ArrayConfig::new(4, 4);
        let reports = net.layer_reports(&cfg);
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0].layer, "c1");
        let sum: Metrics = reports
            .iter()
            .map(|r| r.metrics)
            .fold(Metrics::default(), |a, b| a + b);
        assert_eq!(sum, net.metrics(&cfg));
    }

    #[test]
    fn histogram_collapses_duplicates() {
        let net = Network::new(
            "dup",
            vec![
                Layer::conv("a", SpatialDims::square(8), 8, 8, 3, 1, 1, 1),
                Layer::conv("b", SpatialDims::square(8), 8, 8, 3, 1, 1, 1),
                Layer::conv("c", SpatialDims::square(8), 8, 16, 3, 1, 1, 1),
            ],
        );
        let hist = net.gemm_histogram();
        assert_eq!(hist.len(), 2);
        assert_eq!(hist[0].2, 2);
    }

    #[test]
    fn with_batch_scales_macs_linearly() {
        let net = tiny_net();
        let b4 = tiny_net().with_batch(4);
        assert_eq!(b4.macs(), 4 * net.macs());
        assert_eq!(b4.params(), net.params()); // weights unchanged
        let cfg = ArrayConfig::new(8, 8);
        assert!(b4.metrics(&cfg).cycles > net.metrics(&cfg).cycles);
    }

    #[test]
    fn summary_json_has_fields() {
        let net = tiny_net();
        let j = net.summary_json(&ArrayConfig::new(8, 8));
        assert_eq!(j.get("network").unwrap().as_str().unwrap(), "tiny");
        assert!(j.get("utilization").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("energy").unwrap().as_f64().unwrap() > 0.0);
    }
}
