//! Network-level aggregation: a `Network` is an ordered list of layers (the
//! GEMM-bearing operators only — pooling/activation are metric-neutral in
//! the paper's model) plus metadata. Network metrics are the serialized sum
//! of layer metrics, exactly as the emulator would run inference; the sum
//! is evaluated through the deduplicated workload IR
//! ([`crate::model::workload::Workload`]) — identical by the metrics
//! algebra, and each distinct GEMM shape is costed once.

use crate::config::ArrayConfig;
use crate::metrics::Metrics;
use crate::model::layer::Layer;
use crate::util::json::Json;
use std::collections::HashMap;

/// A named DNN as the emulator sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

/// Per-layer metric breakdown for reports.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: String,
    pub metrics: Metrics,
}

impl Network {
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> Self {
        Self {
            name: name.into(),
            layers,
        }
    }

    /// Re-batch every layer (M scales with batch for convs; FC rows =
    /// batch). Used by `camuy emulate --batch N`.
    pub fn with_batch(mut self, batch: usize) -> Network {
        assert!(batch > 0);
        for l in &mut self.layers {
            l.batch = batch;
        }
        self
    }

    /// Total trainable parameters (conv + fc weights).
    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Total useful MACs for one inference.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Serialized inference metrics on one array configuration, evaluated
    /// shape-deduplicated: Σ over layers of layer metrics equals Σ over
    /// distinct shapes of multiplicity × per-shape metrics exactly (u64
    /// counters are associative/commutative, cycles serialize).
    pub fn metrics(&self, cfg: &ArrayConfig) -> Metrics {
        crate::model::workload::Workload::of(self).eval(cfg)
    }

    /// Per-layer breakdown (for the `camuy emulate --per-layer` report).
    pub fn layer_reports(&self, cfg: &ArrayConfig) -> Vec<LayerReport> {
        self.layers
            .iter()
            .map(|l| LayerReport {
                layer: l.name.clone(),
                metrics: l.metrics(cfg),
            })
            .collect()
    }

    /// Distinct GEMM shapes with multiplicity — the operand-diversity
    /// histogram the paper discusses per architecture family. Linear in the
    /// layer count (HashMap-indexed), first-seen order preserved.
    pub fn gemm_histogram(&self) -> Vec<(crate::model::schedule::GemmShape, usize, usize)> {
        // (shape, groups, occurrence count)
        let mut hist: Vec<(crate::model::schedule::GemmShape, usize, usize)> = Vec::new();
        let mut index: HashMap<(crate::model::schedule::GemmShape, usize), usize> = HashMap::new();
        for l in &self.layers {
            let (g, groups) = l.gemm();
            match index.get(&(g, groups)) {
                Some(&i) => hist[i].2 += 1,
                None => {
                    index.insert((g, groups), hist.len());
                    hist.push((g, groups, 1));
                }
            }
        }
        hist
    }

    /// Serialize the architecture as the layer-list JSON document the
    /// ingestion API accepts (dump a zoo model, tweak it, re-register it).
    pub fn to_json_spec(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("layers", Json::arr(self.layers.iter().map(Layer::to_json))),
        ])
    }

    /// Parse and validate a layer-list JSON document into a `Network`
    /// (the `camuy::api` ingestion path; see DESIGN.md §8). Every layer is
    /// structurally validated, so the resulting network can be lowered to
    /// the workload IR without panicking.
    pub fn from_json_spec(v: &Json) -> Result<Network, String> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .map(str::trim)
            .ok_or_else(|| "network spec missing string field 'name'".to_string())?;
        if name.is_empty() {
            return Err("network name must be non-empty".to_string());
        }
        let layers_json = v
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| "network spec missing array field 'layers'".to_string())?;
        if layers_json.is_empty() {
            return Err("network must have at least one layer".to_string());
        }
        // An ingestion bound, not a model limit: the deepest zoo model has
        // ~200 layers, so this is generous while keeping untrusted
        // documents from materializing unbounded layer lists.
        const MAX_SPEC_LAYERS: usize = 4096;
        if layers_json.len() > MAX_SPEC_LAYERS {
            return Err(format!(
                "network has {} layers; the ingestion limit is {MAX_SPEC_LAYERS}",
                layers_json.len()
            ));
        }
        let mut layers = Vec::with_capacity(layers_json.len());
        for (i, lj) in layers_json.iter().enumerate() {
            layers.push(Layer::from_json(lj).map_err(|e| format!("layer {i}: {e}"))?);
        }
        let mut net = Network::new(name, layers);
        if let Some(b) = v.get("batch") {
            // Same ceiling the per-layer batch field gets, so the network-
            // level override cannot bypass the ingestion bounds.
            const MAX_SPEC_BATCH: usize = 1 << 20;
            let b = b
                .as_usize()
                .filter(|&b| b > 0 && b <= MAX_SPEC_BATCH)
                .ok_or_else(|| {
                    format!("network batch must be in 1..={MAX_SPEC_BATCH}")
                })?;
            net = net.with_batch(b);
            // The override composes with per-layer sizes; re-check the
            // work ceilings at the new batch.
            for l in &net.layers {
                l.check_work_bounds().map_err(|e| format!("batch {b}: {e}"))?;
            }
        }
        Ok(net)
    }

    pub fn summary_json(&self, cfg: &ArrayConfig) -> Json {
        let m = self.metrics(cfg);
        Json::obj(vec![
            ("network", Json::str(self.name.clone())),
            ("config", cfg.to_json()),
            ("params", Json::num(self.params() as f64)),
            ("macs", Json::num(self.macs() as f64)),
            ("metrics", m.to_json()),
            ("utilization", Json::num(m.utilization(cfg.pe_count()))),
            (
                "energy",
                Json::num(m.energy(&crate::config::EnergyWeights::paper())),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::SpatialDims;

    fn tiny_net() -> Network {
        Network::new(
            "tiny",
            vec![
                Layer::conv("c1", SpatialDims::square(8), 3, 8, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(8), 8, 8, 3, 1, 1, 1),
                Layer::linear("fc", 8 * 8 * 8, 10),
            ],
        )
    }

    #[test]
    fn totals_are_sums() {
        let net = tiny_net();
        let cfg = ArrayConfig::new(8, 8);
        let total = net.metrics(&cfg);
        let by_layer: Metrics = net
            .layers
            .iter()
            .map(|l| l.metrics(&cfg))
            .fold(Metrics::default(), |a, b| a + b);
        assert_eq!(total, by_layer);
        assert_eq!(net.params(), 3 * 8 * 9 + 8 * 8 * 9 + 512 * 10);
        assert!(net.macs() > 0);
    }

    #[test]
    fn layer_reports_align() {
        let net = tiny_net();
        let cfg = ArrayConfig::new(4, 4);
        let reports = net.layer_reports(&cfg);
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0].layer, "c1");
        let sum: Metrics = reports
            .iter()
            .map(|r| r.metrics)
            .fold(Metrics::default(), |a, b| a + b);
        assert_eq!(sum, net.metrics(&cfg));
    }

    #[test]
    fn histogram_collapses_duplicates() {
        let net = Network::new(
            "dup",
            vec![
                Layer::conv("a", SpatialDims::square(8), 8, 8, 3, 1, 1, 1),
                Layer::conv("b", SpatialDims::square(8), 8, 8, 3, 1, 1, 1),
                Layer::conv("c", SpatialDims::square(8), 8, 16, 3, 1, 1, 1),
            ],
        );
        let hist = net.gemm_histogram();
        assert_eq!(hist.len(), 2);
        assert_eq!(hist[0].2, 2);
    }

    #[test]
    fn with_batch_scales_macs_linearly() {
        let net = tiny_net();
        let b4 = tiny_net().with_batch(4);
        assert_eq!(b4.macs(), 4 * net.macs());
        assert_eq!(b4.params(), net.params()); // weights unchanged
        let cfg = ArrayConfig::new(8, 8);
        assert!(b4.metrics(&cfg).cycles > net.metrics(&cfg).cycles);
    }

    #[test]
    fn spec_json_roundtrips_exactly() {
        let net = tiny_net().with_batch(2);
        let back = Network::from_json_spec(&net.to_json_spec()).unwrap();
        assert_eq!(back.name, net.name);
        assert_eq!(back.layers, net.layers);
        let cfg = ArrayConfig::new(16, 8);
        assert_eq!(back.metrics(&cfg), net.metrics(&cfg));
    }

    #[test]
    fn spec_json_rejects_malformed_documents() {
        for bad in [
            r#"{"layers":[]}"#,
            r#"{"name":"x","layers":[]}"#,
            r#"{"name":"","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}]}"#,
            r#"{"name":"x"}"#,
            r#"{"name":"x","layers":[{"op":"linear","name":"fc"}],"batch":2}"#,
            r#"{"name":"x","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}],"batch":0}"#,
            r#"{"name":"x","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}],"batch":10000000000}"#,
        ] {
            let v = crate::util::json::Json::parse(bad).unwrap();
            assert!(Network::from_json_spec(&v).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn summary_json_has_fields() {
        let net = tiny_net();
        let j = net.summary_json(&ArrayConfig::new(8, 8));
        assert_eq!(j.get("network").unwrap().as_str().unwrap(), "tiny");
        assert!(j.get("utilization").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("energy").unwrap().as_f64().unwrap() > 0.0);
    }
}
