//! The analytic performance model: the fast path of CAMUY.
//!
//! `schedule` defines the tile schedule shared with the functional emulator
//! (`crate::arch`); `gemm` turns a schedule into closed-form metrics;
//! `layer` lowers convolution variants to GEMM operands; `network`
//! aggregates layers; `graph` lifts networks to a connectivity-aware DAG
//! IR with tensor liveness and branch-parallel scheduling (DESIGN.md §9);
//! `workload` deduplicates a network into the GEMM-shape histogram every
//! evaluating layer consumes (DESIGN.md §2); `bandwidth` derives
//! byte-bandwidth requirements.

pub mod bandwidth;
pub mod gemm;
pub mod graph;
pub mod layer;
pub mod memory;
pub mod multi;
pub mod network;
pub mod roofline;
pub mod schedule;
pub mod workload;

pub use bandwidth::BandwidthReport;
pub use graph::{
    GraphLiveness, GraphNode, GraphSchedule, NetworkGraph, NodeId, NodeOp, ScheduledNode,
    StepResidency, TensorLife, TensorShape,
};
pub use gemm::{
    gemm_metrics, os_metrics, ws_col_factors, ws_metrics, ws_metrics_from_factors, ws_metrics_ref,
    ws_row_factors, WsColClass, WsColFactors, WsRowFactors,
};
pub use layer::{Layer, LayerKind, SpatialDims};
pub use memory::{MemoryAnalysis, DRAM_COST};
pub use multi::{layer_metrics_multi, network_metrics_multi, MultiArrayConfig, MultiMetrics};
pub use network::{LayerReport, Network};
pub use roofline::{layer_roofline, machine_balance, network_roofline, Bound, LayerRoofline};
pub use schedule::{GemmShape, OsSchedule, OsTile, Pass, WsSchedule};
pub use workload::{EvalCache, Workload};
