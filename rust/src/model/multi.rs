//! Multi-array scaling — the paper's §6 future work ("we will extend CAMUY
//! to ... multi-array concepts, in order to improve parallelism for modern
//! CNN models"), built as a first-class analytic feature.
//!
//! Scheduling model: `arrays` identical weight-stationary arrays execute one
//! layer at a time (layers are data-dependent and stay serialized).
//! Within a layer:
//!
//! * a **grouped** layer's per-group GEMMs are independent and distribute
//!   round-robin — makespan = ceil(groups / arrays) serialized rounds;
//! * a **plain** layer (one GEMM) splits its M dimension (output pixels)
//!   evenly — every array must load the *full* weight matrix, so latency
//!   drops while weight traffic multiplies: the bandwidth-for-latency trade
//!   this extension is meant to expose.
//!
//! Energy (Equation 1) uses the summed movements of all arrays; makespan
//! cycles use the slowest array of each layer.

use crate::config::ArrayConfig;
use crate::metrics::Metrics;
use crate::model::gemm::gemm_metrics;
use crate::model::layer::Layer;
use crate::model::network::Network;
use crate::util::ceil_div;

/// A bank of identical arrays.
#[derive(Debug, Clone)]
pub struct MultiArrayConfig {
    pub arrays: usize,
    pub array: ArrayConfig,
}

impl MultiArrayConfig {
    pub fn new(arrays: usize, array: ArrayConfig) -> Self {
        assert!(arrays > 0);
        Self { arrays, array }
    }

    pub fn pe_count(&self) -> usize {
        self.arrays * self.array.pe_count()
    }
}

/// Layer-level result: makespan plus summed movement work.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MultiMetrics {
    /// Critical-path cycles (slowest array, rounds serialized).
    pub makespan_cycles: u64,
    /// Summed metrics across all arrays (movements, MACs, passes; the
    /// `cycles` field holds total busy cycles, not the makespan).
    pub total: Metrics,
}

impl MultiMetrics {
    pub fn energy(&self, w: &crate::config::EnergyWeights) -> f64 {
        self.total.energy(w)
    }

    /// Utilization against the whole bank over the makespan.
    pub fn utilization(&self, cfg: &MultiArrayConfig) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.total.macs as f64 / (cfg.pe_count() as f64 * self.makespan_cycles as f64)
    }
}

impl std::ops::Add for MultiMetrics {
    type Output = MultiMetrics;
    fn add(self, rhs: MultiMetrics) -> MultiMetrics {
        MultiMetrics {
            makespan_cycles: self.makespan_cycles + rhs.makespan_cycles,
            total: self.total + rhs.total,
        }
    }
}

/// One layer on the bank.
pub fn layer_metrics_multi(layer: &Layer, cfg: &MultiArrayConfig) -> MultiMetrics {
    let (gemm, groups) = layer.gemm();
    if groups >= cfg.arrays && groups > 1 {
        // Round-robin the per-group GEMMs; all groups are identical, so
        // total work is a scalar scaling of one GEMM's metrics.
        let one = gemm_metrics(gemm, &cfg.array);
        let rounds = ceil_div(groups, cfg.arrays) as u64;
        MultiMetrics {
            makespan_cycles: rounds * one.cycles,
            total: one * groups as u64,
        }
    } else {
        // Split M across the bank (each split still runs `groups` GEMMs
        // serially on its array — covers 1 < groups < arrays too).
        let splits = cfg.arrays.min(gemm.m);
        let rows = ceil_div(gemm.m, splits);
        let mut makespan = 0u64;
        let mut total = Metrics::default();
        let mut remaining = gemm.m;
        for _ in 0..splits {
            let m_here = rows.min(remaining);
            if m_here == 0 {
                break;
            }
            remaining -= m_here;
            let part = gemm_metrics(
                crate::model::schedule::GemmShape::new(m_here, gemm.k, gemm.n),
                &cfg.array,
            );
            // Each split's array runs its `groups` GEMM slices serially.
            let array_total = part * groups as u64;
            makespan = makespan.max(array_total.cycles);
            total += array_total;
        }
        MultiMetrics {
            makespan_cycles: makespan,
            total,
        }
    }
}

/// A whole network: layers serialize; per-layer makespans add.
pub fn network_metrics_multi(net: &Network, cfg: &MultiArrayConfig) -> MultiMetrics {
    net.layers
        .iter()
        .map(|l| layer_metrics_multi(l, cfg))
        .fold(
            MultiMetrics {
                makespan_cycles: 0,
                total: Metrics::default(),
            },
            |a, b| a + b,
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnergyWeights;
    use crate::model::layer::SpatialDims;

    fn bank(n: usize) -> MultiArrayConfig {
        MultiArrayConfig::new(n, ArrayConfig::new(16, 16))
    }

    #[test]
    fn single_array_matches_plain_model() {
        let layer = Layer::conv("c", SpatialDims::square(14), 32, 64, 3, 1, 1, 1);
        let multi = layer_metrics_multi(&layer, &bank(1));
        let plain = layer.metrics(&bank(1).array);
        assert_eq!(multi.makespan_cycles, plain.cycles);
        assert_eq!(multi.total, plain);
    }

    #[test]
    fn grouped_layer_parallelizes_perfectly() {
        // 32 groups on 4 arrays: 8 serialized rounds instead of 32.
        let layer = Layer::conv("g", SpatialDims::square(14), 256, 256, 3, 1, 1, 32);
        let single = layer_metrics_multi(&layer, &bank(1));
        let multi = layer_metrics_multi(&layer, &bank(4));
        assert_eq!(multi.makespan_cycles * 4, single.makespan_cycles);
        // Movement work is unchanged — group distribution is free.
        assert_eq!(multi.total, single.total);
    }

    #[test]
    fn plain_layer_m_split_trades_weight_traffic_for_latency() {
        let layer = Layer::conv("c", SpatialDims::square(28), 64, 64, 3, 1, 1, 1);
        let single = layer_metrics_multi(&layer, &bank(1));
        let multi = layer_metrics_multi(&layer, &bank(4));
        // Latency improves...
        assert!(multi.makespan_cycles < single.makespan_cycles);
        // ...but every array fetched the full weight matrix at least once.
        assert!(
            multi.total.movements.ub_weight_reads >= single.total.movements.ub_weight_reads,
            "weight traffic should not shrink under M-splitting"
        );
        // MACs are conserved exactly.
        assert_eq!(multi.total.macs, single.total.macs);
        // And Eq.1 energy does not improve (movements only grow).
        let w = EnergyWeights::paper();
        assert!(multi.energy(&w) >= single.energy(&w) * 0.999);
    }

    #[test]
    fn network_scaling_curve_is_monotone_in_latency() {
        let net = crate::nets::build("mobilenetv3l").unwrap();
        let mut last = u64::MAX;
        for arrays in [1usize, 2, 4, 8] {
            let m = network_metrics_multi(&net, &bank(arrays));
            assert!(
                m.makespan_cycles <= last,
                "{arrays} arrays: {} > previous {last}",
                m.makespan_cycles
            );
            last = m.makespan_cycles;
        }
    }

    #[test]
    fn utilization_accounts_for_the_whole_bank() {
        let layer = Layer::conv("c", SpatialDims::square(14), 32, 64, 3, 1, 1, 1);
        let cfg = bank(4);
        let m = layer_metrics_multi(&layer, &cfg);
        let u = m.utilization(&cfg);
        assert!(u > 0.0 && u <= 1.0);
    }

    #[test]
    fn more_arrays_than_rows_degrades_gracefully() {
        // M=4 on 8 arrays: only 4 splits exist.
        let layer = Layer::linear("fc", 64, 32).with_batch(4);
        let m = layer_metrics_multi(&layer, &bank(8));
        assert!(m.makespan_cycles > 0);
        assert_eq!(m.total.macs, layer.macs());
    }
}
