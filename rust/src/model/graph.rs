//! The graph-connectivity IR: DAG networks with explicit inter-layer
//! tensors (DESIGN.md §9).
//!
//! The paper singles out connectivity — "recent networks extent previously
//! plain feedforward models by various connectivity, such as in ResNet or
//! DenseNet" — yet a flat `Vec<Layer>` cannot see it: a skip-add holds its
//! residual tensor live across a whole block, a dense concat keeps every
//! previous feature map alive, and Inception branches are data-independent.
//! A [`NetworkGraph`] makes that structure explicit: nodes are the existing
//! GEMM-bearing [`Layer`]s plus zero-MAC [`NodeOp::Add`] /
//! [`NodeOp::Concat`] junctions, and every edge carries the produced
//! feature-map tensor with its byte size.
//!
//! Three analyses consume the IR:
//!
//! * **Lowering** ([`NetworkGraph::to_network`] / [`NetworkGraph::metrics`])
//!   serializes the layer nodes in topological order through the same
//!   deduplicated workload path as [`Network::metrics`] — byte-identical
//!   for every graph, so connectivity never changes Equation-1 accounting.
//! * **Liveness** ([`NetworkGraph::liveness`]) walks the execution order
//!   tracking which tensors must stay resident in the Unified Buffer,
//!   replacing the linear-chain assumption of
//!   [`crate::model::memory::MemoryAnalysis`] (which lets each input die
//!   immediately) with true peak residency, and charges DRAM round trips
//!   for long-lived skip/concat tensors that cannot fit.
//! * **Branch-parallel scheduling** ([`NetworkGraph::schedule`]) places
//!   data-independent branches concurrently on the arrays of a
//!   [`MultiArrayConfig`] bank with a non-delay critical-path list
//!   scheduler — makespan approaches the critical path instead of the full
//!   serialization that [`crate::model::multi`] charges.

use crate::config::{ArrayConfig, EnergyWeights};
use crate::metrics::Metrics;
use crate::model::bandwidth::ub_working_set_bytes;
use crate::model::layer::{Layer, LayerKind, SpatialDims};
use crate::model::memory::DRAM_COST;
use crate::model::multi::MultiArrayConfig;
use crate::model::network::Network;
use crate::model::workload::{EvalCache, Workload};
use crate::util::json::Json;
use std::collections::{HashMap, HashSet};

/// Index of a node inside its [`NetworkGraph`] (also its execution step:
/// the node list is topologically ordered).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub usize);

/// What a graph node computes.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeOp {
    /// A GEMM-bearing operator — the existing layer model, unchanged.
    Layer(Layer),
    /// Element-wise residual addition (ResNet skips). Moves no matrix
    /// operands and costs zero MACs, but its *inputs* must stay live until
    /// it executes.
    Add,
    /// Channel concatenation (DenseNet, Inception merges). Zero MACs;
    /// output channels are the sum of the input channels.
    Concat,
}

impl NodeOp {
    pub fn is_layer(&self) -> bool {
        matches!(self, NodeOp::Layer(_))
    }

    /// The JSON discriminator of a junction (`None` for layers).
    pub fn junction_str(&self) -> Option<&'static str> {
        match self {
            NodeOp::Layer(_) => None,
            NodeOp::Add => Some("add"),
            NodeOp::Concat => Some("concat"),
        }
    }
}

/// One node: a name, an operator, and the producers of its operands.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphNode {
    pub name: String,
    pub op: NodeOp,
    /// Producers of this node's operands; empty = reads the network input.
    pub inputs: Vec<NodeId>,
}

/// A feature-map tensor travelling along a graph edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub dims: SpatialDims,
    pub channels: usize,
    pub batch: usize,
}

impl TensorShape {
    /// Scalar element count of the tensor.
    pub fn elements(&self) -> u64 {
        self.batch as u64 * self.dims.h as u64 * self.dims.w as u64 * self.channels as u64
    }

    /// Resident bytes at the configured activation width.
    pub fn bytes(&self, act_bits: u32) -> u64 {
        self.elements() * act_bits as u64 / 8
    }
}

/// A validated DAG network. Construction computes every node's output
/// tensor and the consumer lists, so the analyses below never re-derive
/// shapes.
#[derive(Debug, Clone)]
pub struct NetworkGraph {
    pub name: String,
    nodes: Vec<GraphNode>,
    /// Output tensor of every node.
    shapes: Vec<TensorShape>,
    /// Consumer node indices of every node (the edge list, transposed).
    consumers: Vec<Vec<usize>>,
}

impl NetworkGraph {
    /// Validated construction. `nodes` must be topologically ordered
    /// (every input references an earlier node); junction arity and
    /// channel compatibility are checked, and every node's output tensor
    /// is computed. Spatial dims are *not* matched across layer edges —
    /// pooling is metric-neutral and elided, so a consumer may declare a
    /// smaller grid than its producer emits.
    pub fn new(name: impl Into<String>, nodes: Vec<GraphNode>) -> Result<NetworkGraph, String> {
        NetworkGraph::build(name.into(), nodes, true)
    }

    /// The degenerate linear-chain lowering of a flat layer-list network:
    /// layer `i` feeds layer `i + 1` and nothing else. Edge-compatibility
    /// checks are skipped — the flat zoo models elide pooling, flattening
    /// and junction semantics, so their consecutive layers need not chain
    /// shape-wise. Under this lowering every analysis reduces to the
    /// existing per-layer model exactly.
    pub fn chain(net: &Network) -> NetworkGraph {
        let nodes = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| GraphNode {
                name: l.name.clone(),
                op: NodeOp::Layer(l.clone()),
                inputs: if i == 0 { Vec::new() } else { vec![NodeId(i - 1)] },
            })
            .collect();
        NetworkGraph::build(net.name.clone(), nodes, false)
            .expect("chain lowering is structurally valid")
    }

    fn build(name: String, nodes: Vec<GraphNode>, strict: bool) -> Result<NetworkGraph, String> {
        if name.trim().is_empty() {
            return Err("network name must be non-empty".to_string());
        }
        if nodes.is_empty() {
            return Err("graph must have at least one node".to_string());
        }
        let mut seen: HashSet<&str> = HashSet::with_capacity(nodes.len());
        for nd in &nodes {
            if nd.name.trim().is_empty() {
                return Err("node names must be non-empty".to_string());
            }
            if !seen.insert(nd.name.as_str()) {
                return Err(format!("duplicate node name '{}'", nd.name));
            }
        }
        let mut shapes: Vec<TensorShape> = Vec::with_capacity(nodes.len());
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
        let mut layer_count = 0usize;
        for (i, nd) in nodes.iter().enumerate() {
            for &NodeId(p) in &nd.inputs {
                if p >= i {
                    return Err(format!(
                        "node '{}' input #{p} does not precede it \
                         (nodes must be topologically ordered)",
                        nd.name
                    ));
                }
                consumers[p].push(i);
            }
            let shape = match &nd.op {
                NodeOp::Layer(l) => {
                    layer_count += 1;
                    if nd.name != l.name {
                        return Err(format!(
                            "layer node '{}' must be named after its layer '{}'",
                            nd.name, l.name
                        ));
                    }
                    if nd.inputs.len() > 1 {
                        return Err(format!(
                            "layer node '{}' must have at most one input, got {}",
                            nd.name,
                            nd.inputs.len()
                        ));
                    }
                    if strict {
                        if let Some(&NodeId(p)) = nd.inputs.first() {
                            check_layer_edge(l, &nodes[p].name, shapes[p])?;
                        }
                    }
                    TensorShape {
                        dims: l.output_dims(),
                        channels: l.c_out(),
                        batch: l.batch,
                    }
                }
                NodeOp::Add | NodeOp::Concat => {
                    if nd.inputs.len() < 2 {
                        return Err(format!(
                            "junction '{}' needs at least two inputs",
                            nd.name
                        ));
                    }
                    let ins: Vec<TensorShape> =
                        nd.inputs.iter().map(|&NodeId(p)| shapes[p]).collect();
                    let batch = ins[0].batch;
                    if ins.iter().any(|s| s.batch != batch) {
                        return Err(format!("junction '{}' mixes batch sizes", nd.name));
                    }
                    // Merged spatial extent: elementwise minimum of the
                    // inputs — an input arriving larger reaches the
                    // junction through an elided pooling step.
                    let dims = SpatialDims {
                        h: ins.iter().map(|s| s.dims.h).min().unwrap(),
                        w: ins.iter().map(|s| s.dims.w).min().unwrap(),
                    };
                    let channels = match nd.op {
                        NodeOp::Add => {
                            let c = ins[0].channels;
                            if ins.iter().any(|s| s.channels != c) {
                                return Err(format!(
                                    "add junction '{}' inputs disagree on channels",
                                    nd.name
                                ));
                            }
                            c
                        }
                        NodeOp::Concat => ins.iter().map(|s| s.channels).sum(),
                        NodeOp::Layer(_) => unreachable!(),
                    };
                    TensorShape {
                        dims,
                        channels,
                        batch,
                    }
                }
            };
            shapes.push(shape);
        }
        if layer_count == 0 {
            return Err("graph has no layer nodes".to_string());
        }
        Ok(NetworkGraph {
            name,
            nodes,
            shapes,
            consumers,
        })
    }

    // ------------------------------------------------------------- access

    pub fn nodes(&self) -> &[GraphNode] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Output tensor of node `i`.
    pub fn out_shape(&self, i: usize) -> TensorShape {
        self.shapes[i]
    }

    /// Consumer node indices of node `i`.
    pub fn consumers_of(&self, i: usize) -> &[usize] {
        &self.consumers[i]
    }

    pub fn layer_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_layer()).count()
    }

    pub fn junction_count(&self) -> usize {
        self.len() - self.layer_count()
    }

    /// Total edge count (Σ input arity).
    pub fn edge_count(&self) -> usize {
        self.nodes.iter().map(|n| n.inputs.len()).sum()
    }

    /// Is this the degenerate linear chain (every node a layer feeding the
    /// next)?
    pub fn is_chain(&self) -> bool {
        self.nodes.iter().enumerate().all(|(i, n)| {
            n.op.is_layer()
                && match (i, n.inputs.as_slice()) {
                    (0, []) => true,
                    (_, [NodeId(p)]) => p + 1 == i,
                    _ => false,
                }
        })
    }

    /// Lower to the flat layer-list network: the layer nodes in
    /// topological order. For a graph wired over a zoo model this
    /// reproduces the original `Vec<Layer>` exactly (tested across the
    /// registry).
    pub fn to_network(&self) -> Network {
        Network::new(
            self.name.clone(),
            self.nodes
                .iter()
                .filter_map(|n| match &n.op {
                    NodeOp::Layer(l) => Some(l.clone()),
                    _ => None,
                })
                .collect(),
        )
    }

    /// Serialized-inference metrics, evaluated through the same
    /// deduplicated workload path as [`Network::metrics`] — byte-identical
    /// to the flat evaluation for every graph (junctions cost nothing in
    /// the paper's model).
    pub fn metrics(&self, cfg: &ArrayConfig) -> Metrics {
        Workload::of(&self.to_network()).eval(cfg)
    }

    /// Total trainable parameters (layer nodes only).
    pub fn params(&self) -> u64 {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                NodeOp::Layer(l) => Some(l.params()),
                _ => None,
            })
            .sum()
    }

    /// Total useful MACs for one inference.
    pub fn macs(&self) -> u64 {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                NodeOp::Layer(l) => Some(l.macs()),
                _ => None,
            })
            .sum()
    }

    /// Re-batch every layer node, keeping the wiring. Shapes are
    /// recomputed; the caller re-checks the per-layer work ceilings.
    pub fn with_batch(&self, batch: usize) -> Result<NetworkGraph, String> {
        if batch == 0 {
            return Err("batch must be positive".to_string());
        }
        let nodes = self
            .nodes
            .iter()
            .map(|n| GraphNode {
                name: n.name.clone(),
                op: match &n.op {
                    NodeOp::Layer(l) => NodeOp::Layer(l.clone().with_batch(batch)),
                    other => other.clone(),
                },
                inputs: n.inputs.clone(),
            })
            .collect();
        NetworkGraph::build(self.name.clone(), nodes, false)
    }

    // ----------------------------------------------------------- liveness

    /// The tensor-liveness pass: walk the topological execution order and
    /// compute, for every step, the Unified Buffer residency — the node's
    /// own operands plus every long-lived tensor held across the step for
    /// a later consumer. For a pure chain this reduces exactly to the
    /// per-layer maximum of [`MemoryAnalysis`]; for skip/concat graphs the
    /// held tensors inflate the true peak.
    ///
    /// Tensor widths: a tensor consumed by an `Add` junction is a residual
    /// operand — the addition happens in the accumulator domain *before*
    /// requantization (pre-activation residuals), so it is held at
    /// `out_bits`; every other tensor is a requantized activation held at
    /// `act_bits`.
    ///
    /// A greedy spill pass then marks, step by step, the largest held
    /// tensors that must move to DRAM whenever residency exceeds
    /// `cfg.ub_bytes`; each spill costs one store plus one load per
    /// remaining consumer, at the Eyeriss-style [`DRAM_COST`] per word.
    ///
    /// [`MemoryAnalysis`]: crate::model::memory::MemoryAnalysis
    pub fn liveness(&self, cfg: &ArrayConfig) -> GraphLiveness {
        let n = self.nodes.len();
        let bytes: Vec<u64> = (0..n)
            .map(|t| {
                let residual = self.consumers[t]
                    .iter()
                    .any(|&c| matches!(self.nodes[c].op, NodeOp::Add));
                let width = if residual { cfg.out_bits } else { cfg.act_bits };
                self.shapes[t].bytes(width)
            })
            .collect();
        let dies: Vec<usize> = (0..n)
            .map(|i| self.consumers[i].iter().copied().max().unwrap_or(i))
            .collect();
        let own: Vec<u64> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, nd)| match &nd.op {
                NodeOp::Layer(l) => ub_working_set_bytes(l, cfg),
                _ => bytes[i],
            })
            .collect();

        // Is tensor t live while node i executes? A layer's own input is
        // already part of its working set (the im2col view), so only
        // tensors dying strictly later count; a junction reads raw
        // tensors, so tensors dying at the junction still occupy the
        // buffer during the step.
        let live_at = |t: usize, i: usize| -> bool {
            t < i
                && if self.nodes[i].op.is_layer() {
                    dies[t] > i
                } else {
                    dies[t] >= i
                }
        };

        let mut steps = Vec::with_capacity(n);
        let mut peak = 0u64;
        let mut peak_step = 0usize;
        let mut chain_peak = 0u64;
        for i in 0..n {
            let mut held = 0u64;
            let mut held_tensors = 0usize;
            for t in 0..i {
                if live_at(t, i) {
                    held += bytes[t];
                    held_tensors += 1;
                }
            }
            let total = own[i] + held;
            if total > peak {
                peak = total;
                peak_step = i;
            }
            if self.nodes[i].op.is_layer() {
                chain_peak = chain_peak.max(own[i]);
            }
            steps.push(StepResidency {
                node: i,
                name: self.nodes[i].name.clone(),
                own_bytes: own[i],
                held_bytes: held,
                held_tensors,
                total_bytes: total,
            });
        }

        // Greedy spill pass: whenever residency exceeds the UB, evict the
        // largest held tensors not being read at this step. A spilled
        // tensor stops counting toward residency except at the steps that
        // re-fetch it.
        let ub = cfg.ub_bytes as u64;
        let mut spilled = vec![false; n];
        let mut dram_words = vec![0u64; n];
        for i in 0..n {
            let consumed_here = |t: usize| self.nodes[i].inputs.contains(&NodeId(t));
            let mut resident = own[i];
            let mut evictable: Vec<usize> = Vec::new();
            for t in 0..i {
                if !live_at(t, i) {
                    continue;
                }
                if spilled[t] {
                    if consumed_here(t) {
                        resident += bytes[t]; // re-fetched for this read
                    }
                } else {
                    resident += bytes[t];
                    if !consumed_here(t) {
                        evictable.push(t);
                    }
                }
            }
            if resident <= ub {
                continue;
            }
            evictable.sort_by(|&a, &b| bytes[b].cmp(&bytes[a]).then(a.cmp(&b)));
            for t in evictable {
                spilled[t] = true;
                let later_reads = self.consumers[t].iter().filter(|&&c| c > i).count() as u64;
                dram_words[t] = self.shapes[t].elements() * (1 + later_reads);
                resident -= bytes[t];
                if resident <= ub {
                    break;
                }
            }
        }

        let tensors: Vec<TensorLife> = (0..n)
            .map(|t| TensorLife {
                producer: t,
                name: self.nodes[t].name.clone(),
                bytes: bytes[t],
                dies: dies[t],
                spilled: spilled[t],
                dram_words: dram_words[t],
            })
            .collect();
        let spilled_tensors = spilled.iter().filter(|&&s| s).count();
        let edge_dram_words: u64 = dram_words.iter().sum();
        GraphLiveness {
            steps,
            tensors,
            peak_bytes: peak,
            peak_step,
            chain_peak_bytes: chain_peak,
            spilled_tensors,
            edge_dram_words,
        }
    }

    /// Eq.1 energy plus the DRAM overhead from *both* spill sources:
    /// layers whose own working set exceeds the UB
    /// ([`crate::model::memory::MemoryAnalysis`]) and long-lived
    /// skip/concat tensors the liveness pass must push off chip.
    pub fn corrected_energy(&self, cfg: &ArrayConfig, w: &EnergyWeights) -> f64 {
        let net = self.to_network();
        let layer = crate::model::memory::MemoryAnalysis::of(&net, cfg);
        net.metrics(cfg).energy(w) + layer.dram_energy() + self.liveness(cfg).dram_energy()
    }

    // --------------------------------------------------------- scheduling

    /// Branch-parallel list scheduling on a multi-array bank: every layer
    /// node runs whole on ONE array (so weight traffic is *not*
    /// multiplied, unlike the M-split model of [`crate::model::multi`]),
    /// junctions are free, and data-independent branches overlap. The
    /// scheduler is non-delay (no array idles while a ready layer exists),
    /// breaking ties by longest remaining path — so the makespan never
    /// exceeds the serialized sum and never beats the critical path, with
    /// equality to the serial sum on pure chains.
    pub fn schedule(&self, cfg: &MultiArrayConfig, cache: &EvalCache) -> GraphSchedule {
        self.schedule_threaded(cfg, cache, crate::runtime::pool::default_threads())
    }

    /// [`NetworkGraph::schedule`] with an explicit executor budget for
    /// the node-duration evaluation — the serve path passes its
    /// `--threads` bound through here so a graph request respects the
    /// same concurrency contract as every other fan-out (`threads = 1`
    /// is exactly serial).
    pub fn schedule_threaded(
        &self,
        cfg: &MultiArrayConfig,
        cache: &EvalCache,
        threads: usize,
    ) -> GraphSchedule {
        let n = self.nodes.len();
        // Node durations fan out over the shared pool (DESIGN.md §11);
        // the memo cache is sharded, so concurrent layer evaluations do
        // not serialize on one lock. Totals are summed in node order
        // afterwards — integer metrics, so the result is byte-identical
        // to the serial loop.
        let per_node: Vec<Option<Metrics>> =
            crate::runtime::pool::parallel_map(n, threads, |i| {
                // Cancellation granularity is one node's metrics; the
                // faultpoint lets tests panic mid-schedule (DESIGN.md §15).
                crate::robust::checkpoint();
                crate::faultpoint::hit("graph.schedule");
                match &self.nodes[i].op {
                    NodeOp::Layer(l) => Some(l.metrics_cached(&cfg.array, cache)),
                    _ => None,
                }
            });
        let mut dur = vec![0u64; n];
        let mut total = Metrics::default();
        for (i, m) in per_node.into_iter().enumerate() {
            if let Some(m) = m {
                dur[i] = m.cycles;
                total += m;
            }
        }
        // Bottom levels: longest path to a sink, own duration included.
        let mut bl = vec![0u64; n];
        for i in (0..n).rev() {
            let down = self.consumers[i].iter().map(|&c| bl[c]).max().unwrap_or(0);
            bl[i] = dur[i] + down;
        }
        let critical_path_cycles = bl.iter().copied().max().unwrap_or(0);

        let mut indeg: Vec<usize> = self.nodes.iter().map(|nd| nd.inputs.len()).collect();
        let mut finish = vec![0u64; n];
        let mut free = vec![0u64; cfg.arrays];
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut assignments: Vec<ScheduledNode> = Vec::with_capacity(self.layer_count());
        let mut pending = n;
        while pending > 0 {
            // Junctions cost nothing: resolve every ready junction first.
            if let Some(pos) = ready.iter().position(|&i| !self.nodes[i].op.is_layer()) {
                let i = ready.swap_remove(pos);
                finish[i] = self.nodes[i]
                    .inputs
                    .iter()
                    .map(|&NodeId(p)| finish[p])
                    .max()
                    .unwrap_or(0);
                for &c in &self.consumers[i] {
                    indeg[c] -= 1;
                    if indeg[c] == 0 {
                        ready.push(c);
                    }
                }
                pending -= 1;
                continue;
            }
            // Among ready layers, pick the one that can start earliest
            // (non-delay), breaking ties by bottom level then index; place
            // it on the earliest-free array.
            let (a, &f) = free
                .iter()
                .enumerate()
                .min_by_key(|&(ai, &fa)| (fa, ai))
                .expect("bank has at least one array");
            let mut best: Option<(u64, std::cmp::Reverse<u64>, usize)> = None;
            for &i in &ready {
                let rt = self.nodes[i]
                    .inputs
                    .iter()
                    .map(|&NodeId(p)| finish[p])
                    .max()
                    .unwrap_or(0);
                let key = (rt.max(f), std::cmp::Reverse(bl[i]), i);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
            let (start, _, i) = best.expect("a ready layer exists in a non-empty DAG");
            let end = start + dur[i];
            free[a] = end;
            finish[i] = end;
            assignments.push(ScheduledNode {
                node: i,
                name: self.nodes[i].name.clone(),
                array: a,
                start_cycle: start,
                end_cycle: end,
            });
            let pos = ready.iter().position(|&r| r == i).expect("chosen node is ready");
            ready.swap_remove(pos);
            for &c in &self.consumers[i] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    ready.push(c);
                }
            }
            pending -= 1;
        }
        let makespan_cycles = finish.iter().copied().max().unwrap_or(0);
        GraphSchedule {
            arrays: cfg.arrays,
            makespan_cycles,
            serialized_cycles: total.cycles,
            critical_path_cycles,
            assignments,
            total,
        }
    }

    // --------------------------------------------------------------- JSON

    /// Serialize as the graph-spec JSON document: the layer-list schema
    /// plus `junctions` and `edges` sections (DESIGN.md §9).
    pub fn to_json_spec(&self) -> Json {
        let layers: Vec<Json> = self
            .nodes
            .iter()
            .filter_map(|n| match &n.op {
                NodeOp::Layer(l) => Some(l.to_json()),
                _ => None,
            })
            .collect();
        let junctions: Vec<Json> = self
            .nodes
            .iter()
            .filter_map(|n| {
                n.op.junction_str().map(|op| {
                    Json::obj(vec![
                        ("name", Json::str(n.name.clone())),
                        ("op", Json::str(op)),
                    ])
                })
            })
            .collect();
        let mut edges: Vec<Json> = Vec::with_capacity(self.edge_count());
        for nd in &self.nodes {
            for &NodeId(p) in &nd.inputs {
                edges.push(Json::arr(vec![
                    Json::str(self.nodes[p].name.clone()),
                    Json::str(nd.name.clone()),
                ]));
            }
        }
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("layers", Json::arr(layers)),
            ("junctions", Json::arr(junctions)),
            ("edges", Json::arr(edges)),
        ])
    }

    /// Parse and validate a graph-spec JSON document. A document without
    /// an `edges` section is the existing pure-chain schema and lowers via
    /// [`NetworkGraph::chain`]; with `edges`, the named wiring is
    /// topologically sorted (junctions placed as early as their inputs
    /// allow, layers kept in declared order) and strictly validated.
    pub fn from_json_spec(v: &Json) -> Result<NetworkGraph, String> {
        if v.get("edges").is_none() {
            if v.get("junctions").is_some() {
                return Err(
                    "graph spec has a 'junctions' section but no 'edges' wiring".to_string()
                );
            }
            return Ok(NetworkGraph::chain(&Network::from_json_spec(v)?));
        }
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .map(str::trim)
            .ok_or_else(|| "graph spec missing string field 'name'".to_string())?;
        if name.is_empty() {
            return Err("network name must be non-empty".to_string());
        }
        let layers_json = v
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| "graph spec missing array field 'layers'".to_string())?;
        // Ingestion bounds, matching the chain schema's spirit: generous
        // for any real network, hostile documents stay cheap.
        const MAX_SPEC_LAYERS: usize = 4096;
        const MAX_SPEC_JUNCTIONS: usize = 4096;
        const MAX_SPEC_EDGES: usize = 32768;
        if layers_json.is_empty() {
            return Err("graph must have at least one layer".to_string());
        }
        if layers_json.len() > MAX_SPEC_LAYERS {
            return Err(format!(
                "graph has {} layers; the ingestion limit is {MAX_SPEC_LAYERS}",
                layers_json.len()
            ));
        }
        // Unordered node table: layers first, then junctions.
        let mut ops: Vec<(String, NodeOp)> = Vec::new();
        for (i, lj) in layers_json.iter().enumerate() {
            let l = Layer::from_json(lj).map_err(|e| format!("layer {i}: {e}"))?;
            ops.push((l.name.clone(), NodeOp::Layer(l)));
        }
        let layer_count = ops.len();
        if let Some(js) = v.get("junctions") {
            let arr = js
                .as_arr()
                .ok_or_else(|| "field 'junctions' must be an array".to_string())?;
            if arr.len() > MAX_SPEC_JUNCTIONS {
                return Err(format!(
                    "graph has {} junctions; the ingestion limit is {MAX_SPEC_JUNCTIONS}",
                    arr.len()
                ));
            }
            for (i, jj) in arr.iter().enumerate() {
                let jname = jj
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("junction {i}: missing string field 'name'"))?;
                let op = match jj.get("op").and_then(Json::as_str) {
                    Some("add") => NodeOp::Add,
                    Some("concat") => NodeOp::Concat,
                    other => {
                        return Err(format!(
                            "junction '{jname}': op must be 'add' or 'concat', got {other:?}"
                        ))
                    }
                };
                ops.push((jname.to_string(), op));
            }
        }
        let mut index: HashMap<&str, usize> = HashMap::with_capacity(ops.len());
        for (i, (nname, _)) in ops.iter().enumerate() {
            if index.insert(nname.as_str(), i).is_some() {
                return Err(format!("duplicate node name '{nname}'"));
            }
        }
        // Edges by name.
        let edges_json = v
            .get("edges")
            .and_then(Json::as_arr)
            .ok_or_else(|| "field 'edges' must be an array".to_string())?;
        if edges_json.len() > MAX_SPEC_EDGES {
            return Err(format!(
                "graph has {} edges; the ingestion limit is {MAX_SPEC_EDGES}",
                edges_json.len()
            ));
        }
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); ops.len()];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); ops.len()];
        let mut seen_edges: HashSet<(usize, usize)> = HashSet::with_capacity(edges_json.len());
        for (i, ej) in edges_json.iter().enumerate() {
            let pair = ej
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| format!("edge {i}: must be a [from, to] pair"))?;
            let from = pair[0]
                .as_str()
                .and_then(|s| index.get(s).copied())
                .ok_or_else(|| format!("edge {i}: unknown 'from' node"))?;
            let to = pair[1]
                .as_str()
                .and_then(|s| index.get(s).copied())
                .ok_or_else(|| format!("edge {i}: unknown 'to' node"))?;
            if from == to {
                return Err(format!("edge {i}: node feeds itself"));
            }
            if !seen_edges.insert((from, to)) {
                return Err(format!("edge {i}: duplicate edge"));
            }
            preds[to].push(from);
            succs[from].push(to);
        }
        // Topological schedule: junctions as early as their inputs allow,
        // layers in declared order (so a spec dumped from a graph
        // round-trips node for node).
        let mut indeg: Vec<usize> = preds.iter().map(Vec::len).collect();
        let mut ready: Vec<usize> = (0..ops.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order: Vec<usize> = Vec::with_capacity(ops.len());
        let mut new_id = vec![usize::MAX; ops.len()];
        while !ready.is_empty() {
            // Prefer the lowest-index ready junction, else the
            // earliest-declared ready layer.
            let pick = ready
                .iter()
                .copied()
                .filter(|&i| i >= layer_count)
                .min()
                .or_else(|| ready.iter().copied().filter(|&i| i < layer_count).min())
                .unwrap();
            let pos = ready.iter().position(|&i| i == pick).unwrap();
            ready.swap_remove(pos);
            new_id[pick] = order.len();
            order.push(pick);
            for &s in &succs[pick] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(s);
                }
            }
        }
        if order.len() != ops.len() {
            return Err("graph has a cycle".to_string());
        }
        let nodes: Vec<GraphNode> = order
            .iter()
            .map(|&old| GraphNode {
                name: ops[old].0.clone(),
                op: ops[old].1.clone(),
                inputs: preds[old].iter().map(|&p| NodeId(new_id[p])).collect(),
            })
            .collect();
        NetworkGraph::build(name.to_string(), nodes, true)
    }
}

/// Strict producer→layer compatibility: channels must line up (concat
/// sums and residual adds are exactly where connectivity matters); spatial
/// dims are not matched because pooling is metric-neutral and elided.
fn check_layer_edge(l: &Layer, producer: &str, from: TensorShape) -> Result<(), String> {
    if l.batch != from.batch {
        return Err(format!(
            "layer '{}' batch {} != producer '{}' batch {}",
            l.name, l.batch, producer, from.batch
        ));
    }
    match &l.kind {
        LayerKind::Conv2d { c_in, .. } => {
            if *c_in != from.channels {
                return Err(format!(
                    "layer '{}' expects {} input channels but producer '{}' emits {}",
                    l.name, c_in, producer, from.channels
                ));
            }
        }
        LayerKind::Linear { in_features, .. } => {
            if in_features % from.channels != 0 {
                return Err(format!(
                    "layer '{}' in_features {} is not a multiple of producer '{}' \
                     channels {}",
                    l.name, in_features, producer, from.channels
                ));
            }
        }
    }
    Ok(())
}

// ------------------------------------------------------------ result types

/// The lifetime of one node-output tensor.
#[derive(Debug, Clone)]
pub struct TensorLife {
    /// Producing node index (== its execution step).
    pub producer: usize,
    pub name: String,
    /// Resident bytes at the held width (`out_bits` for residual-add
    /// operands, `act_bits` otherwise).
    pub bytes: u64,
    /// Execution step of the last consumer (== `producer` when unconsumed).
    pub dies: usize,
    /// The greedy spill pass had to push this tensor to DRAM.
    pub spilled: bool,
    /// DRAM words the spill streams (one store plus one load per remaining
    /// consumer); zero when not spilled.
    pub dram_words: u64,
}

/// Unified Buffer residency while one node executes.
#[derive(Debug, Clone)]
pub struct StepResidency {
    pub node: usize,
    pub name: String,
    /// The node's own operands: a layer's UB working set, a junction's
    /// output tensor.
    pub own_bytes: u64,
    /// Long-lived tensors held across this step for later consumers.
    pub held_bytes: u64,
    /// How many distinct tensors are held (DenseNet keeps a whole block's
    /// growth outputs alive; ResNet one residual).
    pub held_tensors: usize,
    pub total_bytes: u64,
}

/// Result of the tensor-liveness pass ([`NetworkGraph::liveness`]).
#[derive(Debug, Clone)]
pub struct GraphLiveness {
    /// Per-node residency in execution order.
    pub steps: Vec<StepResidency>,
    /// Per-node output-tensor lifetimes.
    pub tensors: Vec<TensorLife>,
    /// True peak UB residency with every live tensor held on chip.
    pub peak_bytes: u64,
    /// Node index where the peak occurs.
    pub peak_step: usize,
    /// What the linear-chain assumption reports: the maximum per-layer
    /// working set ([`crate::model::memory::MemoryAnalysis`]'s peak).
    pub chain_peak_bytes: u64,
    /// Tensors the greedy spill pass pushed to DRAM.
    pub spilled_tensors: usize,
    /// Total DRAM words those spills stream.
    pub edge_dram_words: u64,
}

impl GraphLiveness {
    /// Energy overhead of the edge spills in Equation-1 units.
    pub fn dram_energy(&self) -> f64 {
        self.edge_dram_words as f64 * DRAM_COST
    }

    /// The `n` heaviest residency steps (total bytes descending, ties by
    /// execution order) — the one ranking the JSON and CLI surfaces share.
    pub fn top_steps(&self, n: usize) -> Vec<&StepResidency> {
        let mut top: Vec<&StepResidency> = self.steps.iter().collect();
        top.sort_by(|a, b| b.total_bytes.cmp(&a.total_bytes).then(a.node.cmp(&b.node)));
        top.truncate(n);
        top
    }

    /// How much the linear-chain assumption under-reports the peak.
    pub fn inflation(&self) -> f64 {
        if self.chain_peak_bytes == 0 {
            return 1.0;
        }
        self.peak_bytes as f64 / self.chain_peak_bytes as f64
    }
}

/// One layer placed on one array of the bank.
#[derive(Debug, Clone)]
pub struct ScheduledNode {
    pub node: usize,
    pub name: String,
    pub array: usize,
    pub start_cycle: u64,
    pub end_cycle: u64,
}

/// A branch-parallel schedule of a graph on a multi-array bank
/// ([`NetworkGraph::schedule`]).
#[derive(Debug, Clone)]
pub struct GraphSchedule {
    pub arrays: usize,
    /// Critical-path-aware list-scheduled makespan.
    pub makespan_cycles: u64,
    /// The fully serialized baseline (Σ layer cycles) — what a
    /// layer-at-a-time bank pays.
    pub serialized_cycles: u64,
    /// Longest dependency chain; no schedule can beat this.
    pub critical_path_cycles: u64,
    /// Layer placements in scheduling order.
    pub assignments: Vec<ScheduledNode>,
    /// Summed metrics over all layers. Each layer runs whole on one
    /// array, so movements equal the single-array totals — weight traffic
    /// is not multiplied (`cycles` holds total busy cycles, not the
    /// makespan).
    pub total: Metrics,
}

impl GraphSchedule {
    /// Serialized-over-parallel latency ratio (≥ 1).
    pub fn speedup(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 1.0;
        }
        self.serialized_cycles as f64 / self.makespan_cycles as f64
    }

    /// Utilization of the whole bank over the makespan.
    pub fn utilization(&self, cfg: &MultiArrayConfig) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.total.macs as f64 / (cfg.pe_count() as f64 * self.makespan_cycles as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::memory::MemoryAnalysis;

    fn conv(name: &str, c_in: usize, c_out: usize) -> Layer {
        Layer::conv(name, SpatialDims::square(8), c_in, c_out, 3, 1, 1, 1)
    }

    fn chain_net() -> Network {
        Network::new(
            "chain",
            vec![conv("c1", 4, 8), conv("c2", 8, 8), conv("c3", 8, 16)],
        )
    }

    /// c1 → c2 → c3 → add(c1, c3): the skip tensor is held across c2/c3.
    fn skip_graph() -> NetworkGraph {
        let nodes = vec![
            GraphNode {
                name: "c1".into(),
                op: NodeOp::Layer(conv("c1", 4, 8)),
                inputs: vec![],
            },
            GraphNode {
                name: "c2".into(),
                op: NodeOp::Layer(conv("c2", 8, 8)),
                inputs: vec![NodeId(0)],
            },
            GraphNode {
                name: "c3".into(),
                op: NodeOp::Layer(conv("c3", 8, 8)),
                inputs: vec![NodeId(1)],
            },
            GraphNode {
                name: "add".into(),
                op: NodeOp::Add,
                inputs: vec![NodeId(0), NodeId(2)],
            },
        ];
        NetworkGraph::new("skip", nodes).unwrap()
    }

    #[test]
    fn chain_lowering_round_trips_and_matches_metrics() {
        let net = chain_net();
        let g = NetworkGraph::chain(&net);
        assert!(g.is_chain());
        assert_eq!(g.layer_count(), 3);
        assert_eq!(g.junction_count(), 0);
        assert_eq!(g.edge_count(), 2);
        assert_eq!(g.to_network().layers, net.layers);
        let cfg = ArrayConfig::new(8, 8);
        assert_eq!(g.metrics(&cfg), net.metrics(&cfg));
        assert_eq!(g.params(), net.params());
        assert_eq!(g.macs(), net.macs());
    }

    #[test]
    fn junction_shapes_propagate() {
        let g = skip_graph();
        assert!(!g.is_chain());
        assert_eq!(g.junction_count(), 1);
        // The add output matches its inputs: 8x8 spatial, 8 channels.
        let s = g.out_shape(3);
        assert_eq!(s.channels, 8);
        assert_eq!(s.dims, SpatialDims::square(8));
        assert_eq!(s.elements(), 8 * 8 * 8);
        assert_eq!(s.bytes(8), 8 * 8 * 8);
        assert_eq!(s.bytes(16), 2 * 8 * 8 * 8);
        // Consumers: c1 feeds c2 and the add.
        assert_eq!(g.consumers_of(0), &[1, 3]);
    }

    #[test]
    fn validation_rejects_malformed_graphs() {
        let l = conv("c1", 4, 8);
        // Forward reference.
        assert!(NetworkGraph::new(
            "bad",
            vec![GraphNode {
                name: "c1".into(),
                op: NodeOp::Layer(l.clone()),
                inputs: vec![NodeId(0)],
            }]
        )
        .is_err());
        // Junction with one input.
        assert!(NetworkGraph::new(
            "bad",
            vec![
                GraphNode {
                    name: "c1".into(),
                    op: NodeOp::Layer(l.clone()),
                    inputs: vec![],
                },
                GraphNode {
                    name: "j".into(),
                    op: NodeOp::Add,
                    inputs: vec![NodeId(0)],
                },
            ]
        )
        .is_err());
        // Add with mismatched channels.
        assert!(NetworkGraph::new(
            "bad",
            vec![
                GraphNode {
                    name: "c1".into(),
                    op: NodeOp::Layer(conv("c1", 4, 8)),
                    inputs: vec![],
                },
                GraphNode {
                    name: "c2".into(),
                    op: NodeOp::Layer(conv("c2", 8, 16)),
                    inputs: vec![NodeId(0)],
                },
                GraphNode {
                    name: "j".into(),
                    op: NodeOp::Add,
                    inputs: vec![NodeId(0), NodeId(1)],
                },
            ]
        )
        .is_err());
        // Layer consuming the wrong channel count.
        assert!(NetworkGraph::new(
            "bad",
            vec![
                GraphNode {
                    name: "c1".into(),
                    op: NodeOp::Layer(conv("c1", 4, 8)),
                    inputs: vec![],
                },
                GraphNode {
                    name: "c2".into(),
                    op: NodeOp::Layer(conv("c2", 16, 8)),
                    inputs: vec![NodeId(0)],
                },
            ]
        )
        .is_err());
        // Duplicate names.
        assert!(NetworkGraph::new(
            "bad",
            vec![
                GraphNode {
                    name: "c1".into(),
                    op: NodeOp::Layer(conv("c1", 4, 8)),
                    inputs: vec![],
                },
                GraphNode {
                    name: "c1".into(),
                    op: NodeOp::Layer(conv("c1", 8, 8)),
                    inputs: vec![NodeId(0)],
                },
            ]
        )
        .is_err());
        // No layers at all.
        assert!(NetworkGraph::new("bad", vec![]).is_err());
    }

    #[test]
    fn chain_liveness_matches_the_linear_assumption() {
        let net = chain_net();
        let g = NetworkGraph::chain(&net);
        let cfg = ArrayConfig::new(8, 8);
        let live = g.liveness(&cfg);
        let mem = MemoryAnalysis::of(&net, &cfg);
        assert_eq!(live.peak_bytes, mem.peak_working_set_bytes);
        assert_eq!(live.chain_peak_bytes, mem.peak_working_set_bytes);
        assert_eq!(live.spilled_tensors, 0);
        assert_eq!(live.edge_dram_words, 0);
        for s in &live.steps {
            assert_eq!(s.held_bytes, 0, "{}: chains hold nothing", s.name);
        }
        assert!((live.inflation() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skip_graph_holds_the_residual_live() {
        // Hand-checked: c1's output is 8x8x8 = 512 elements; it feeds the
        // residual add, so it is held at out_bits (32) = 2048 bytes while
        // c2 and c3 execute (its last consumer is the add).
        let g = skip_graph();
        let cfg = ArrayConfig::new(8, 8);
        let live = g.liveness(&cfg);
        let skip_bytes = g.out_shape(0).bytes(cfg.out_bits);
        assert_eq!(g.out_shape(0).elements(), 512);
        assert_eq!(skip_bytes, 2048);
        assert_eq!(live.steps[1].held_bytes, skip_bytes); // during c2
        assert_eq!(live.steps[2].held_bytes, skip_bytes); // during c3
        assert_eq!(live.steps[1].held_tensors, 1);
        assert_eq!(live.steps[0].held_bytes, 0);
        // The peak strictly exceeds the linear-chain estimate: the max-ws
        // layer (c2 or c3, identical shapes) runs with the skip held.
        let ws2 = ub_working_set_bytes(&conv("c2", 8, 8), &cfg);
        assert_eq!(live.chain_peak_bytes, ws2);
        assert_eq!(live.peak_bytes, ws2 + skip_bytes);
        assert!(live.peak_bytes > live.chain_peak_bytes);
        assert!(live.inflation() > 1.0);
        assert_eq!(live.spilled_tensors, 0); // 24 MiB UB fits everything
        // Tensor lifetimes: c1's output dies at the add (step 3).
        assert_eq!(live.tensors[0].dies, 3);
        assert_eq!(live.tensors[1].dies, 2);
    }

    #[test]
    fn tiny_ub_forces_edge_spills() {
        let g = skip_graph();
        // A UB just large enough for the layers' own working sets but not
        // the held skip tensor.
        let cfg = ArrayConfig::new(8, 8);
        let ws = ub_working_set_bytes(&conv("c2", 8, 8), &cfg);
        let tight = ArrayConfig::new(8, 8).with_ub_bytes(ws as usize + 100);
        let live = g.liveness(&tight);
        assert_eq!(live.spilled_tensors, 1);
        assert!(live.tensors[0].spilled);
        // One store plus one load (a single remaining consumer, the add).
        assert_eq!(live.edge_dram_words, 2 * g.out_shape(0).elements());
        assert!(live.dram_energy() > 0.0);
        // And the corrected energy strictly exceeds the on-chip figure.
        let w = EnergyWeights::paper();
        let base = g.metrics(&tight).energy(&w);
        assert!(g.corrected_energy(&tight, &w) > base);
    }

    #[test]
    fn chain_schedule_serializes_for_any_array_count() {
        let g = NetworkGraph::chain(&chain_net());
        let cache = EvalCache::new();
        for arrays in [1usize, 2, 4] {
            let cfg = MultiArrayConfig::new(arrays, ArrayConfig::new(8, 8));
            let s = g.schedule(&cfg, &cache);
            assert_eq!(s.makespan_cycles, s.serialized_cycles, "{arrays} arrays");
            assert_eq!(s.makespan_cycles, s.critical_path_cycles);
            assert!((s.speedup() - 1.0).abs() < 1e-12);
            assert_eq!(s.assignments.len(), 3);
        }
    }

    #[test]
    fn diamond_schedules_branches_in_parallel() {
        // src → (b1, b2) → concat: with two arrays the equal branches
        // overlap completely.
        let nodes = vec![
            GraphNode {
                name: "src".into(),
                op: NodeOp::Layer(conv("src", 4, 8)),
                inputs: vec![],
            },
            GraphNode {
                name: "b1".into(),
                op: NodeOp::Layer(conv("b1", 8, 8)),
                inputs: vec![NodeId(0)],
            },
            GraphNode {
                name: "b2".into(),
                op: NodeOp::Layer(conv("b2", 8, 8)),
                inputs: vec![NodeId(0)],
            },
            GraphNode {
                name: "cat".into(),
                op: NodeOp::Concat,
                inputs: vec![NodeId(1), NodeId(2)],
            },
        ];
        let g = NetworkGraph::new("diamond", nodes).unwrap();
        let cache = EvalCache::new();
        let cfg1 = MultiArrayConfig::new(1, ArrayConfig::new(8, 8));
        let cfg2 = MultiArrayConfig::new(2, ArrayConfig::new(8, 8));
        let s1 = g.schedule(&cfg1, &cache);
        let s2 = g.schedule(&cfg2, &cache);
        assert_eq!(s1.makespan_cycles, s1.serialized_cycles);
        // Two arrays: src, then both branches concurrently.
        let src = conv("src", 4, 8).metrics(&cfg2.array).cycles;
        let branch = conv("b1", 8, 8).metrics(&cfg2.array).cycles;
        assert_eq!(s2.makespan_cycles, src + branch);
        assert_eq!(s2.critical_path_cycles, src + branch);
        assert!(s2.makespan_cycles < s1.makespan_cycles);
        // Movements are conserved: same totals whichever bank size.
        assert_eq!(s1.total, s2.total);
        assert!(s2.speedup() > 1.0);
        assert!(s2.utilization(&cfg2) > 0.0 && s2.utilization(&cfg2) <= 1.0);
        // The two branches landed on different arrays.
        let arrays: std::collections::HashSet<usize> = s2
            .assignments
            .iter()
            .filter(|a| a.name.starts_with('b'))
            .map(|a| a.array)
            .collect();
        assert_eq!(arrays.len(), 2);
    }

    #[test]
    fn schedule_never_beats_critical_path_or_exceeds_serial() {
        let g = skip_graph();
        let cache = EvalCache::new();
        for arrays in [1usize, 2, 3, 8] {
            let cfg = MultiArrayConfig::new(arrays, ArrayConfig::new(16, 8));
            let s = g.schedule(&cfg, &cache);
            assert!(s.makespan_cycles <= s.serialized_cycles);
            assert!(s.makespan_cycles >= s.critical_path_cycles);
        }
    }

    #[test]
    fn graph_spec_json_round_trips() {
        let g = skip_graph();
        let spec = g.to_json_spec();
        let back = NetworkGraph::from_json_spec(&spec).unwrap();
        assert_eq!(
            back.to_json_spec().to_string_compact(),
            spec.to_string_compact()
        );
        assert_eq!(back.to_network().layers, g.to_network().layers);
        let cfg = ArrayConfig::new(8, 8);
        assert_eq!(back.metrics(&cfg), g.metrics(&cfg));
        assert_eq!(
            back.liveness(&cfg).peak_bytes,
            g.liveness(&cfg).peak_bytes
        );
    }

    #[test]
    fn spec_without_edges_is_a_chain() {
        let net = chain_net();
        let g = NetworkGraph::from_json_spec(&net.to_json_spec()).unwrap();
        assert!(g.is_chain());
        assert_eq!(g.to_network().layers, net.layers);
    }

    #[test]
    fn spec_json_rejects_malformed_graphs() {
        for bad in [
            // unknown edge endpoint
            r#"{"name":"x","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}],"edges":[["fc","ghost"]]}"#,
            // self edge
            r#"{"name":"x","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}],"edges":[["fc","fc"]]}"#,
            // cycle
            r#"{"name":"x","layers":[{"op":"linear","name":"a","in_features":4,"out_features":4},{"op":"linear","name":"b","in_features":4,"out_features":4}],"edges":[["a","b"],["b","a"]]}"#,
            // junction with a bogus op
            r#"{"name":"x","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}],"junctions":[{"name":"j","op":"mul"}],"edges":[]}"#,
            // junctions without the edges wiring must be rejected, not
            // silently dropped by the chain fallback
            r#"{"name":"x","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}],"junctions":[{"name":"j","op":"add"}]}"#,
            // junction with a single input
            r#"{"name":"x","layers":[{"op":"linear","name":"fc","in_features":4,"out_features":2}],"junctions":[{"name":"j","op":"add"}],"edges":[["fc","j"]]}"#,
            // duplicate edge
            r#"{"name":"x","layers":[{"op":"linear","name":"a","in_features":4,"out_features":4},{"op":"linear","name":"b","in_features":4,"out_features":4}],"edges":[["a","b"],["a","b"]]}"#,
            // no layers
            r#"{"name":"x","layers":[],"edges":[]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(NetworkGraph::from_json_spec(&v).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn with_batch_scales_every_layer_and_tensor() {
        let g = skip_graph();
        let b4 = g.with_batch(4).unwrap();
        assert_eq!(b4.macs(), 4 * g.macs());
        assert_eq!(b4.out_shape(0).elements(), 4 * g.out_shape(0).elements());
        assert!(b4.with_batch(0).is_err());
    }
}
