//! Design-space exploration: configuration grids, the shape-major parallel
//! sweep engine (DESIGN.md §4), cross-model normalization (Section 5) and
//! the equal-PE-count aspect-ratio space (Figure 6).

pub mod grid;
pub mod normalize;
pub mod runner;

pub use grid::{equal_pe_factorizations, DimGrid};
pub use normalize::RobustObjectives;
pub use runner::{
    default_threads, parallel_map, seed_workload, sweep_network, sweep_workload,
    sweep_workload_config_major, SweepPoint, SweepResult, Workload,
};
