//! Design-space exploration: configuration grids, the segmented
//! piecewise-constant sweep engine (DESIGN.md §10, with the shape-major
//! and config-major cores of §4 kept as byte-identical baselines),
//! cross-model normalization (Section 5) and the equal-PE-count
//! aspect-ratio space (Figure 6).

pub mod grid;
pub mod normalize;
pub mod plan;
pub mod runner;

pub use grid::{equal_pe_factorizations, normalize_axis, DimGrid, GridError};
pub use normalize::RobustObjectives;
pub use plan::{
    PlanCache, PlanCacheStats, SegmentedOsPlan, SegmentedWsPlan, PLAN_CACHE_CAPACITY,
    PLAN_CACHE_WORD_BUDGET,
};
pub use runner::{
    default_threads, parallel_map, seed_workload, seed_workload_planned, sweep_network,
    sweep_network_planned, sweep_workload, sweep_workload_config_major, sweep_workload_planned,
    sweep_workload_segmented, sweep_workload_segmented_scalar, sweep_workload_shape_major,
    SweepPoint, SweepResult, Workload,
};
