//! Configuration grids for design-space sweeps.
//!
//! Every constructor normalizes its axes — sorted ascending, deduplicated,
//! zero values dropped — so duplicate or unsorted user-supplied axes can
//! neither inflate a sweep with repeated cells nor break the segmented
//! plan's binary searches ([`crate::sweep::plan`]), and a zero can never
//! reach the tiling divisions.

use crate::config::ArrayConfig;
use std::fmt;

/// Typed grid-construction errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridError {
    /// A range grid was asked to step by zero, which would never terminate.
    ZeroStep,
}

impl fmt::Display for GridError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GridError::ZeroStep => write!(f, "grid step must be positive"),
        }
    }
}

impl std::error::Error for GridError {}

/// Sort ascending, deduplicate, and drop zeros (a zero-length array edge
/// is not a configuration; [`ArrayConfig::validate`] rejects it anyway).
pub fn normalize_axis(mut axis: Vec<usize>) -> Vec<usize> {
    axis.retain(|&v| v > 0);
    axis.sort_unstable();
    axis.dedup();
    axis
}

/// A rectangular (height, width) grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DimGrid {
    pub heights: Vec<usize>,
    pub widths: Vec<usize>,
}

impl DimGrid {
    /// Normalizing constructor: both axes are sorted, deduplicated and
    /// stripped of zeros (see the module docs).
    pub fn new(heights: Vec<usize>, widths: Vec<usize>) -> DimGrid {
        DimGrid {
            heights: normalize_axis(heights),
            widths: normalize_axis(widths),
        }
    }

    /// The paper's evaluation grid: "all possible width and height
    /// combinations from 16 to 256 in increments of 8, for a total of 961
    /// possible dimensions" (Section 4.1).
    pub fn paper() -> DimGrid {
        DimGrid::coarse(16, 256, 8)
    }

    /// The dense step-1 exploration grid over the paper's range: 241 × 241
    /// = 58 081 cells, the segmented sweep plan's headline setting
    /// (DESIGN.md §10).
    pub fn dense() -> DimGrid {
        DimGrid::coarse(16, 256, 1)
    }

    /// A smaller grid for quick runs and tests. Panics on a zero step;
    /// use [`DimGrid::try_coarse`] for a typed error.
    pub fn coarse(lo: usize, hi: usize, step: usize) -> DimGrid {
        DimGrid::try_coarse(lo, hi, step).expect("grid step must be positive")
    }

    /// `lo..=hi` stepping by `step` on both axes; rejects a zero step with
    /// a typed error instead of panicking inside the range iterator.
    pub fn try_coarse(lo: usize, hi: usize, step: usize) -> Result<DimGrid, GridError> {
        if step == 0 {
            return Err(GridError::ZeroStep);
        }
        let axis: Vec<usize> = (lo..=hi).step_by(step).collect();
        Ok(DimGrid::new(axis.clone(), axis))
    }

    pub fn len(&self) -> usize {
        self.heights.len() * self.widths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All (height, width) pairs, row-major (height-major).
    pub fn pairs(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.len());
        for &h in &self.heights {
            for &w in &self.widths {
                out.push((h, w));
            }
        }
        out
    }

    /// Configurations built from a template (geometry substituted).
    pub fn configs(&self, template: &ArrayConfig) -> Vec<ArrayConfig> {
        self.pairs()
            .into_iter()
            .map(|(h, w)| {
                let mut c = template.clone();
                c.height = h;
                c.width = w;
                c
            })
            .collect()
    }
}

/// The equal-PE-count spaces of Figure 6 (the SCALE-SIM aspect-ratio
/// study): all power-of-two (h, w) factorizations of each PE budget.
pub fn equal_pe_factorizations(pe_count: usize, min_dim: usize) -> Vec<(usize, usize)> {
    assert!(pe_count.is_power_of_two(), "PE budget must be a power of two");
    let mut out = Vec::new();
    let mut h = min_dim;
    while h <= pe_count / min_dim {
        let w = pe_count / h;
        out.push((h, w));
        h *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_is_961() {
        let g = DimGrid::paper();
        assert_eq!(g.len(), 961);
        assert_eq!(g.heights.len(), 31);
        assert_eq!(g.heights[0], 16);
        assert_eq!(*g.heights.last().unwrap(), 256);
        assert_eq!(g.pairs().len(), 961);
    }

    #[test]
    fn dense_grid_is_step_one() {
        let g = DimGrid::dense();
        assert_eq!(g.heights.len(), 241);
        assert_eq!(g.len(), 241 * 241);
        assert_eq!(g.heights[0], 16);
        assert_eq!(*g.widths.last().unwrap(), 256);
    }

    #[test]
    fn pairs_are_height_major() {
        let g = DimGrid::coarse(2, 4, 2);
        assert_eq!(g.pairs(), vec![(2, 2), (2, 4), (4, 2), (4, 4)]);
    }

    #[test]
    fn constructors_normalize_axes() {
        let g = DimGrid::new(vec![8, 2, 8, 0, 4], vec![0, 16, 16]);
        assert_eq!(g.heights, vec![2, 4, 8]);
        assert_eq!(g.widths, vec![16]);
        assert_eq!(g.len(), 3);
        // Zero-only axes leave an empty (rejectable) grid, not a panic.
        assert!(DimGrid::new(vec![0], vec![4]).is_empty());
    }

    #[test]
    fn zero_step_is_a_typed_error() {
        assert_eq!(DimGrid::try_coarse(8, 16, 0), Err(GridError::ZeroStep));
        assert!(DimGrid::try_coarse(8, 16, 4).is_ok());
        assert_eq!(GridError::ZeroStep.to_string(), "grid step must be positive");
    }

    #[test]
    fn configs_substitute_geometry_only() {
        let template = ArrayConfig::new(1, 1).with_acc_capacity(2048).with_bits(16, 8, 32);
        let cfgs = DimGrid::coarse(8, 16, 8).configs(&template);
        assert_eq!(cfgs.len(), 4);
        for c in &cfgs {
            assert_eq!(c.acc_capacity, 2048);
            assert_eq!(c.weight_bits, 16);
        }
        assert_eq!((cfgs[1].height, cfgs[1].width), (8, 16));
    }

    #[test]
    fn equal_pe_space() {
        let f = equal_pe_factorizations(16384, 8);
        // 8x2048 .. 2048x8: 9 entries.
        assert_eq!(f.len(), 9);
        assert!(f.contains(&(128, 128)));
        assert!(f.contains(&(8, 2048)));
        assert!(f.contains(&(2048, 8)));
        for (h, w) in f {
            assert_eq!(h * w, 16384);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn equal_pe_rejects_non_pow2() {
        equal_pe_factorizations(1000, 8);
    }
}
