//! Cross-model aggregation for the robustness analysis (paper Section 5):
//! each model's metric is min-max normalized over the grid, then averaged
//! across models, so no single large network dominates the objective.

use crate::sweep::runner::SweepResult;
use crate::util::stats::min_max_normalize;

/// Averaged normalized objectives per grid point, aligned with the
/// configuration order shared by all input sweeps.
#[derive(Debug, Clone)]
pub struct RobustObjectives {
    pub heights: Vec<usize>,
    pub widths: Vec<usize>,
    /// Mean over models of min-max-normalized energy.
    pub avg_norm_energy: Vec<f64>,
    /// Mean over models of min-max-normalized cycle count.
    pub avg_norm_cycles: Vec<f64>,
}

impl RobustObjectives {
    /// Combine per-model sweeps (all over the identical config sequence).
    pub fn from_sweeps(sweeps: &[SweepResult]) -> RobustObjectives {
        assert!(!sweeps.is_empty(), "no sweeps to aggregate");
        let n = sweeps[0].points.len();
        for s in sweeps {
            assert_eq!(s.points.len(), n, "sweeps must share the grid");
            for (a, b) in s.points.iter().zip(&sweeps[0].points) {
                assert_eq!(
                    (a.height, a.width),
                    (b.height, b.width),
                    "sweeps must share the config order"
                );
            }
        }

        let mut avg_e = vec![0.0; n];
        let mut avg_c = vec![0.0; n];
        for s in sweeps {
            let ne = min_max_normalize(&s.energies());
            let nc = min_max_normalize(&s.cycles());
            for i in 0..n {
                avg_e[i] += ne[i];
                avg_c[i] += nc[i];
            }
        }
        let k = sweeps.len() as f64;
        for i in 0..n {
            avg_e[i] /= k;
            avg_c[i] /= k;
        }

        RobustObjectives {
            heights: sweeps[0].points.iter().map(|p| p.height).collect(),
            widths: sweeps[0].points.iter().map(|p| p.width).collect(),
            avg_norm_energy: avg_e,
            avg_norm_cycles: avg_c,
        }
    }

    pub fn len(&self) -> usize {
        self.heights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heights.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrayConfig, EnergyWeights};
    use crate::model::layer::{Layer, SpatialDims};
    use crate::model::network::Network;
    use crate::sweep::grid::DimGrid;
    use crate::sweep::runner::sweep_network;

    fn sweeps() -> Vec<SweepResult> {
        let cfgs = DimGrid::coarse(8, 32, 8).configs(&ArrayConfig::new(1, 1));
        let nets = [
            Network::new(
                "a",
                vec![Layer::conv("c", SpatialDims::square(14), 16, 32, 3, 1, 1, 1)],
            ),
            Network::new(
                "b",
                vec![Layer::conv("c", SpatialDims::square(28), 64, 64, 1, 1, 0, 1)],
            ),
        ];
        nets.iter()
            .map(|n| sweep_network(n, &cfgs, &EnergyWeights::paper(), 2))
            .collect()
    }

    #[test]
    fn averaged_values_in_unit_interval() {
        let r = RobustObjectives::from_sweeps(&sweeps());
        assert_eq!(r.len(), 16);
        for i in 0..r.len() {
            assert!((0.0..=1.0).contains(&r.avg_norm_energy[i]));
            assert!((0.0..=1.0).contains(&r.avg_norm_cycles[i]));
        }
    }

    #[test]
    fn single_model_reduces_to_normalization() {
        let all = sweeps();
        let one = RobustObjectives::from_sweeps(&all[..1]);
        let ne = min_max_normalize(&all[0].energies());
        assert_eq!(one.avg_norm_energy, ne);
    }

    #[test]
    #[should_panic(expected = "no sweeps")]
    fn empty_input_panics() {
        let _ = RobustObjectives::from_sweeps(&[]);
    }
}
