//! The segmented piecewise-constant sweep plan (DESIGN.md §10).
//!
//! For a fixed GEMM shape, the WS closed form depends on the array height
//! only through the row-tile step function `tr = ceil(K/h)` (plus terms
//! polynomial in `h` within a constant-`tr` run) and on the width through
//! the col-tile step function `tc = ceil(N/w)` and the accumulator
//! row-budget step `floor(acc/w)`. A dense grid axis therefore collapses
//! into O(√dim) **equivalence segments** per shape
//! ([`crate::model::gemm::ceil_div_segments`]): every tiling division of a
//! sweep happens once per (shape, segment) — or once per (shape, axis
//! value) for the tail-chunk residual — at plan-build time, and the
//! per-cell hot loop is division- and branch-free.
//!
//! [`SegmentedWsPlan`] stores the per-(shape, axis value) tile scalars in
//! flat structure-of-arrays tables of primitives, pre-scaled by workload
//! multiplicity and pre-reduced into per-axis totals wherever a metric
//! term depends on only one axis. What remains genuinely per-cell is three
//! dot products over the shape dimension ([`SegmentedWsPlan::cell`]); the
//! result is byte-identical to the config-major oracle by exact integer
//! reassociation (property-tested).
//!
//! [`PlanCache`] memoizes plans across requests keyed by the workload
//! fingerprint (the exact deduplicated shape histogram), the grid axes and
//! the accumulator capacity, so a long-lived [`crate::api::Engine`] builds
//! each segment table once per distinct (workload, grid) no matter how
//! many sweep / Pareto / equal-PE / serve requests replay it.

use crate::config::Dataflow;
use crate::metrics::{Metrics, MovementCounters};
use crate::model::gemm::{
    ceil_div_segments, floor_div_segments, os_cell_dots, os_metrics_from_scalars, ws_cell_dots,
    ws_metrics_from_scalars, DOT_LANES, OsColScalars, OsRowScalars, WsColScalars, WsRowFactors,
};
use crate::model::schedule::GemmShape;
use crate::model::workload::Workload;
use crate::sweep::grid::normalize_axis;
use crate::util::ceil_div;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// A segmented weight-stationary sweep plan for one (workload, height
/// axis, width axis, accumulator capacity). See the module docs.
#[derive(Debug)]
pub struct SegmentedWsPlan {
    heights: Vec<usize>,
    widths: Vec<usize>,
    acc: usize,
    shapes: Vec<(GemmShape, u64)>,
    /// Table stride per axis value: `shapes.len()` rounded up to a
    /// [`DOT_LANES`] multiple, so the fused cell kernels stream whole
    /// lane blocks with no scalar tail (the zero padding is inert in
    /// every dot product).
    stride: usize,
    // --- row tables, indexed hi * stride + si ---
    /// Row-tile count `tr` (unscaled — the seeding path reads these).
    tr: Vec<u64>,
    /// Weight shift-down hop sum `Σ k_t(k_t−1)/2` (unscaled).
    s_kk: Vec<u64>,
    /// Exposed first load `min(K, h)` (unscaled).
    k0: Vec<u64>,
    /// Multiplicity-scaled `tr` and `s_kk` — the dot-product operands.
    tr_m: Vec<u64>,
    skk_m: Vec<u64>,
    // --- col tables, indexed wi * stride + si ---
    /// Col-class aggregates (DESIGN.md §10): Σ count, Σ count·chunks·nt,
    /// Σ count·chunks, and the per-shape cycle coefficient
    /// `M·s_cnt + s_c − 2·s_cc`.
    col_cnt: Vec<u64>,
    col_c: Vec<u64>,
    col_cc: Vec<u64>,
    col_cyc: Vec<u64>,
    // --- per-axis totals (terms that depend on one axis only) ---
    /// Σ mult·k0 per height.
    tot_k0: Vec<u64>,
    /// Σ mult·M·N·tr per height (aa_writes; ×(h−1) gives inter_pe_psum).
    tot_mn_tr: Vec<u64>,
    /// Σ mult·M·K·s_cnt per width (ub_act_reads; ×(w−1) gives
    /// inter_pe_act).
    tot_mk_cnt: Vec<u64>,
    /// Σ mult·K·s_c per width (ub_weight_reads; ×2 plus `tot_5mkn` gives
    /// intra_pe).
    tot_k_c: Vec<u64>,
    // --- axis-independent totals ---
    tot_mn: u64,
    tot_5mkn: u64,
    tot_macs: u64,
    row_segments: usize,
    col_segments: usize,
}

impl SegmentedWsPlan {
    /// Build the plan. Axes are normalized (sorted, deduplicated, zeros
    /// dropped); all tiling divisions of the whole sweep happen here.
    pub fn new(
        workload: &Workload,
        heights: &[usize],
        widths: &[usize],
        acc: usize,
    ) -> SegmentedWsPlan {
        let heights = normalize_axis(heights.to_vec());
        let widths = normalize_axis(widths.to_vec());
        let s = workload.shapes.len();
        let stride = ceil_div(s, DOT_LANES) * DOT_LANES;
        let (nh, nw) = (heights.len(), widths.len());
        let mut p = SegmentedWsPlan {
            heights,
            widths,
            acc,
            shapes: workload.shapes.clone(),
            stride,
            tr: vec![0; nh * stride],
            s_kk: vec![0; nh * stride],
            k0: vec![0; nh * stride],
            tr_m: vec![0; nh * stride],
            skk_m: vec![0; nh * stride],
            col_cnt: vec![0; nw * stride],
            col_c: vec![0; nw * stride],
            col_cc: vec![0; nw * stride],
            col_cyc: vec![0; nw * stride],
            tot_k0: vec![0; nh],
            tot_mn_tr: vec![0; nh],
            tot_mk_cnt: vec![0; nw],
            tot_k_c: vec![0; nw],
            tot_mn: 0,
            tot_5mkn: 0,
            tot_macs: 0,
            row_segments: 0,
            col_segments: 0,
        };
        // The accumulator row-budget runs are shape-independent.
        let acc_runs = floor_div_segments(acc, &p.widths);
        let mut cf = vec![0u64; nw]; // scratch: full-class chunks per width
        for (si, &(shape, mult)) in workload.shapes.iter().enumerate() {
            if shape.is_empty() {
                continue; // contributes Metrics::default() everywhere
            }
            let (m, k, n) = (shape.m as u64, shape.k as u64, shape.n as u64);
            p.tot_mn += mult * m * n;
            p.tot_5mkn += mult * 5 * m * k * n;
            p.tot_macs += mult * shape.macs();
            // Row axis: segments of constant tr = ceil(K/h); within a
            // segment the remaining row factors are division-free
            // polynomials in h (k_tail is linear, s_kk quadratic).
            for seg in ceil_div_segments(shape.k, &p.heights) {
                p.row_segments += 1;
                let tr = seg.value;
                for hi in seg.start..seg.end {
                    let h = p.heights[hi] as u64;
                    let k_tail = k - (tr - 1) * h;
                    let s_kk = (tr - 1) * (h * (h - 1) / 2) + k_tail * (k_tail - 1) / 2;
                    let k0 = k.min(h);
                    let at = hi * stride + si;
                    p.tr[at] = tr;
                    p.s_kk[at] = s_kk;
                    p.k0[at] = k0;
                    p.tr_m[at] = mult * tr;
                    p.skk_m[at] = mult * s_kk;
                    p.tot_k0[hi] += mult * k0;
                    p.tot_mn_tr[hi] += mult * m * n * tr;
                }
            }
            // Full-class chunk count: one division per (shape, budget run)
            // broadcast over the run.
            for run in &acc_runs {
                let cfv = ceil_div(shape.m, (run.value as usize).max(1)) as u64;
                cf[run.start..run.end].fill(cfv);
            }
            // Col axis: segments of constant tc = ceil(N/w). The tail
            // class's chunk count still depends on n_tail = N − (tc−1)·w,
            // which genuinely varies inside a segment — that one residual
            // division stays per (shape, axis value), never per cell.
            for seg in ceil_div_segments(shape.n, &p.widths) {
                p.col_segments += 1;
                let tc = seg.value;
                for wi in seg.start..seg.end {
                    let w = p.widths[wi] as u64;
                    let n_tail = n - (tc - 1) * w;
                    let r_tail = (acc as u64 / n_tail).max(1);
                    let ct = ceil_div(shape.m, r_tail as usize) as u64;
                    let (full_cnt, full_c) = if tc > 1 { (tc - 1, cf[wi]) } else { (0, 0) };
                    let s_cnt = full_cnt + 1;
                    let s_c = full_cnt * full_c * w + ct * n_tail;
                    let s_cc = full_cnt * full_c + ct;
                    let at = wi * stride + si;
                    p.col_cnt[at] = s_cnt;
                    p.col_c[at] = s_c;
                    p.col_cc[at] = s_cc;
                    p.col_cyc[at] = m * s_cnt + s_c - 2 * s_cc;
                    p.tot_mk_cnt[wi] += mult * m * k * s_cnt;
                    p.tot_k_c[wi] += mult * k * s_c;
                }
            }
        }
        p
    }

    /// The normalized height axis.
    pub fn heights(&self) -> &[usize] {
        &self.heights
    }

    /// The normalized width axis.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// The accumulator capacity the plan was built for.
    pub fn acc_capacity(&self) -> usize {
        self.acc
    }

    /// Row-tile equivalence segments summed over shapes (plan statistics).
    pub fn row_segments(&self) -> usize {
        self.row_segments
    }

    /// Col-tile equivalence segments summed over shapes.
    pub fn col_segments(&self) -> usize {
        self.col_segments
    }

    /// Index of a height on the plan axis.
    pub fn height_index(&self, h: usize) -> Option<usize> {
        self.heights.binary_search(&h).ok()
    }

    /// Index of a width on the plan axis.
    pub fn width_index(&self, w: usize) -> Option<usize> {
        self.widths.binary_search(&w).ok()
    }

    /// Workload metrics of one grid cell: Σ over shapes of multiplicity ×
    /// the WS closed form, assembled from the SoA tables — three dot
    /// products over the shape dimension, fused into one streaming pass
    /// through the multi-lane [`ws_cell_dots`] kernel (the tables are
    /// lane-padded at construction, so the kernel never takes its scalar
    /// tail). Byte-identical to the config-major oracle and to
    /// [`SegmentedWsPlan::cell_scalar`].
    pub fn cell(&self, hi: usize, wi: usize) -> Metrics {
        let n = self.stride;
        let (ro, co) = (hi * n, wi * n);
        let (inter_weight, passes, cyc) = ws_cell_dots(
            &self.skk_m[ro..ro + n],
            &self.tr_m[ro..ro + n],
            &self.col_c[co..co + n],
            &self.col_cc[co..co + n],
            &self.col_cyc[co..co + n],
        );
        let h = self.heights[hi] as u64;
        let w = self.widths[wi] as u64;
        Metrics {
            cycles: self.tot_k0[hi] + cyc + h * passes,
            stall_cycles: 0,
            macs: self.tot_macs,
            passes,
            movements: MovementCounters {
                ub_act_reads: self.tot_mk_cnt[wi],
                ub_weight_reads: self.tot_k_c[wi],
                ub_out_writes: self.tot_mn,
                inter_pe_act: (w - 1) * self.tot_mk_cnt[wi],
                inter_pe_psum: (h - 1) * self.tot_mn_tr[hi],
                inter_pe_weight: inter_weight,
                intra_pe: self.tot_5mkn + 2 * self.tot_k_c[wi],
                aa_writes: self.tot_mn_tr[hi],
                aa_reads: self.tot_mn,
            },
        }
    }

    /// The pre-vectorization per-cell combine: sequential `iter().zip()`
    /// dot products over the live (unpadded) prefix of the SoA tables.
    /// Kept as the scalar baseline rung of the oracle chain — the
    /// property tests assert it byte-identical to [`SegmentedWsPlan::cell`],
    /// and the bench smoke gate requires the fused kernel to beat it.
    pub fn cell_scalar(&self, hi: usize, wi: usize) -> Metrics {
        let s = self.shapes.len();
        let (ro, co) = (hi * self.stride, wi * self.stride);
        let tr_m = &self.tr_m[ro..ro + s];
        let skk_m = &self.skk_m[ro..ro + s];
        let col_c = &self.col_c[co..co + s];
        let col_cc = &self.col_cc[co..co + s];
        let col_cyc = &self.col_cyc[co..co + s];
        let inter_weight: u64 = skk_m.iter().zip(col_c).map(|(&a, &b)| a * b).sum();
        let passes: u64 = tr_m.iter().zip(col_cc).map(|(&a, &b)| a * b).sum();
        let cyc: u64 = tr_m.iter().zip(col_cyc).map(|(&a, &b)| a * b).sum();
        let h = self.heights[hi] as u64;
        let w = self.widths[wi] as u64;
        Metrics {
            cycles: self.tot_k0[hi] + cyc + h * passes,
            stall_cycles: 0,
            macs: self.tot_macs,
            passes,
            movements: MovementCounters {
                ub_act_reads: self.tot_mk_cnt[wi],
                ub_weight_reads: self.tot_k_c[wi],
                ub_out_writes: self.tot_mn,
                inter_pe_act: (w - 1) * self.tot_mk_cnt[wi],
                inter_pe_psum: (h - 1) * self.tot_mn_tr[hi],
                inter_pe_weight: inter_weight,
                intra_pe: self.tot_5mkn + 2 * self.tot_k_c[wi],
                aa_writes: self.tot_mn_tr[hi],
                aa_reads: self.tot_mn,
            },
        }
    }

    /// Words each axis value owns in every row/col table:
    /// `shapes.len()` rounded up to a [`DOT_LANES`] multiple. The blocked
    /// dispatch sizes its cache blocks from this.
    pub fn lane_stride(&self) -> usize {
        self.stride
    }

    /// [`SegmentedWsPlan::cell`] looked up by axis values: two binary
    /// searches plus the combine — no divisions. `None` if (h, w) is off
    /// the plan's axes.
    pub fn probe(&self, h: usize, w: usize) -> Option<Metrics> {
        let hi = self.height_index(h)?;
        let wi = self.width_index(w)?;
        Some(self.cell(hi, wi))
    }

    /// Per-shape metrics of one cell, unscaled by multiplicity —
    /// byte-identical to `ws_metrics` for that (shape, geometry). The
    /// serve path seeds the per-(shape, configuration) memo table with
    /// these.
    pub fn shape_cell(&self, si: usize, hi: usize, wi: usize) -> Metrics {
        let (shape, _) = self.shapes[si];
        let (ra, ca) = (hi * self.stride + si, wi * self.stride + si);
        let row = WsRowFactors {
            height: self.heights[hi],
            tr: self.tr[ra],
            s_kk: self.s_kk[ra],
            k0: self.k0[ra],
        };
        let col = WsColScalars {
            width: self.widths[wi],
            s_cnt: self.col_cnt[ca],
            s_n: if shape.is_empty() { 0 } else { shape.n as u64 },
            s_c: self.col_c[ca],
            s_cc: self.col_cc[ca],
        };
        ws_metrics_from_scalars(shape, &row, &col)
    }

    /// The shapes (with multiplicities) the plan was built over.
    pub fn shapes(&self) -> &[(GemmShape, u64)] {
        &self.shapes
    }

    /// Resident size of the SoA tables in 64-bit words — what the plan
    /// cache's memory budget accounts. Lane padding included: the cache
    /// bounds what is actually allocated, not the live prefix.
    pub fn table_words(&self) -> usize {
        let s = self.stride;
        let (nh, nw) = (self.heights.len(), self.widths.len());
        5 * nh * s + 4 * nw * s + 2 * nh + 2 * nw
    }
}

/// A segmented output-stationary sweep plan for one (workload, height
/// axis, width axis). The OS closed form ([`crate::model::gemm::os_metrics`])
/// touches the height axis only through `tm = ceil(M/h)` (plus the drain
/// deficit `s_mm`, polynomial in `h` within a constant-`tm` run) and the
/// width axis only through `tc = ceil(N/w)` — no accumulator dependence
/// at all, so one plan serves every accumulator capacity. Distributing
/// the tile-class sums ([`os_metrics_from_scalars`]) leaves exactly two
/// bilinear terms (cycles and passes); the per-cell combine is therefore
/// **two** dot products over the shape dimension plus per-axis totals,
/// byte-identical to the config-major oracle (property-tested).
#[derive(Debug)]
pub struct SegmentedOsPlan {
    heights: Vec<usize>,
    widths: Vec<usize>,
    shapes: Vec<(GemmShape, u64)>,
    /// Table stride per axis value (`shapes.len()` lane-padded), as in
    /// [`SegmentedWsPlan`].
    stride: usize,
    // --- row tables, indexed hi * stride + si ---
    /// Row-tile count `tm` (unscaled — the seeding path reads these).
    tm: Vec<u64>,
    /// Drain deficit `Σ mt(mt−1)/2` (unscaled).
    s_mm: Vec<u64>,
    /// Multiplicity-scaled `tm` and the cycle row coefficient
    /// `mult·tm·(K + h − 2)` — the dot-product operands.
    tm_m: Vec<u64>,
    cyc_r: Vec<u64>,
    // --- col table, indexed wi * stride + si ---
    /// Col-tile count `tc` (unscaled; both dot products consume it).
    tc: Vec<u64>,
    // --- per-axis totals ---
    /// Σ mult·K·N·tm per height (ub_weight_reads; `tot_kmn −` this gives
    /// inter_pe_weight).
    tot_kn_tm: Vec<u64>,
    /// Σ mult·tm·N per height (cycles term).
    tot_tm_n: Vec<u64>,
    /// Σ mult·N·s_mm per height (inter_pe_psum correction).
    tot_n_smm: Vec<u64>,
    /// Σ mult·K·M·tc per width (ub_act_reads; ×(w−1) gives inter_pe_act).
    tot_km_tc: Vec<u64>,
    /// Σ mult·M·tc per width (cycles term).
    tot_m_tc: Vec<u64>,
    // --- axis-independent totals ---
    tot_mn: u64,
    tot_kmn: u64,
    tot_5k2mn: u64,
    tot_macs: u64,
    row_segments: usize,
    col_segments: usize,
}

impl SegmentedOsPlan {
    /// Build the plan. Axes are normalized (sorted, deduplicated, zeros
    /// dropped); all tiling divisions of the whole sweep happen here.
    pub fn new(workload: &Workload, heights: &[usize], widths: &[usize]) -> SegmentedOsPlan {
        let heights = normalize_axis(heights.to_vec());
        let widths = normalize_axis(widths.to_vec());
        let s = workload.shapes.len();
        let stride = ceil_div(s, DOT_LANES) * DOT_LANES;
        let (nh, nw) = (heights.len(), widths.len());
        let mut p = SegmentedOsPlan {
            heights,
            widths,
            shapes: workload.shapes.clone(),
            stride,
            tm: vec![0; nh * stride],
            s_mm: vec![0; nh * stride],
            tm_m: vec![0; nh * stride],
            cyc_r: vec![0; nh * stride],
            tc: vec![0; nw * stride],
            tot_kn_tm: vec![0; nh],
            tot_tm_n: vec![0; nh],
            tot_n_smm: vec![0; nh],
            tot_km_tc: vec![0; nw],
            tot_m_tc: vec![0; nw],
            tot_mn: 0,
            tot_kmn: 0,
            tot_5k2mn: 0,
            tot_macs: 0,
            row_segments: 0,
            col_segments: 0,
        };
        for (si, &(shape, mult)) in workload.shapes.iter().enumerate() {
            if shape.is_empty() {
                continue; // contributes Metrics::default() everywhere
            }
            let (m, k, n) = (shape.m as u64, shape.k as u64, shape.n as u64);
            p.tot_mn += mult * m * n;
            p.tot_kmn += mult * k * m * n;
            p.tot_5k2mn += mult * (5 * k + 2) * m * n;
            p.tot_macs += mult * shape.macs();
            // Row axis: segments of constant tm = ceil(M/h); within a
            // segment m_tail is linear in h and s_mm quadratic.
            for seg in ceil_div_segments(shape.m, &p.heights) {
                p.row_segments += 1;
                let tm = seg.value;
                for hi in seg.start..seg.end {
                    let h = p.heights[hi] as u64;
                    let s_mm = crate::model::gemm::os_drain_deficit(m, h, tm);
                    let at = hi * stride + si;
                    p.tm[at] = tm;
                    p.s_mm[at] = s_mm;
                    p.tm_m[at] = mult * tm;
                    p.cyc_r[at] = mult * tm * (k + h - 2);
                    p.tot_kn_tm[hi] += mult * k * n * tm;
                    p.tot_tm_n[hi] += mult * tm * n;
                    p.tot_n_smm[hi] += mult * n * s_mm;
                }
            }
            // Col axis: segments of constant tc = ceil(N/w) — the entire
            // width dependence of the OS model.
            for seg in ceil_div_segments(shape.n, &p.widths) {
                p.col_segments += 1;
                let tc = seg.value;
                for wi in seg.start..seg.end {
                    let at = wi * stride + si;
                    p.tc[at] = tc;
                    p.tot_km_tc[wi] += mult * k * m * tc;
                    p.tot_m_tc[wi] += mult * m * tc;
                }
            }
        }
        p
    }

    /// The normalized height axis.
    pub fn heights(&self) -> &[usize] {
        &self.heights
    }

    /// The normalized width axis.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Row-tile equivalence segments summed over shapes (plan statistics).
    pub fn row_segments(&self) -> usize {
        self.row_segments
    }

    /// Col-tile equivalence segments summed over shapes.
    pub fn col_segments(&self) -> usize {
        self.col_segments
    }

    /// Index of a height on the plan axis.
    pub fn height_index(&self, h: usize) -> Option<usize> {
        self.heights.binary_search(&h).ok()
    }

    /// Index of a width on the plan axis.
    pub fn width_index(&self, w: usize) -> Option<usize> {
        self.widths.binary_search(&w).ok()
    }

    /// Workload metrics of one grid cell: Σ over shapes of multiplicity ×
    /// the OS closed form, assembled from the SoA tables — two dot
    /// products over the shape dimension (fused into one streaming pass
    /// through the multi-lane [`os_cell_dots`] kernel) plus per-axis
    /// totals. Byte-identical to the config-major oracle and to
    /// [`SegmentedOsPlan::cell_scalar`].
    pub fn cell(&self, hi: usize, wi: usize) -> Metrics {
        let n = self.stride;
        let (ro, co) = (hi * n, wi * n);
        let (cyc, passes) = os_cell_dots(
            &self.cyc_r[ro..ro + n],
            &self.tm_m[ro..ro + n],
            &self.tc[co..co + n],
        );
        let h = self.heights[hi] as u64;
        let w = self.widths[wi] as u64;
        Metrics {
            cycles: cyc + self.tot_m_tc[wi] + self.tot_tm_n[hi],
            stall_cycles: 0,
            macs: self.tot_macs,
            passes,
            movements: MovementCounters {
                ub_act_reads: self.tot_km_tc[wi],
                ub_weight_reads: self.tot_kn_tm[hi],
                ub_out_writes: self.tot_mn,
                inter_pe_act: (w - 1) * self.tot_km_tc[wi],
                inter_pe_psum: (h - 1) * self.tot_mn - self.tot_n_smm[hi],
                inter_pe_weight: self.tot_kmn - self.tot_kn_tm[hi],
                intra_pe: self.tot_5k2mn,
                aa_writes: self.tot_mn,
                aa_reads: self.tot_mn,
            },
        }
    }

    /// The pre-vectorization per-cell combine: sequential `iter().zip()`
    /// dot products over the live (unpadded) prefix of the SoA tables.
    /// Kept as the scalar baseline rung of the oracle chain, exactly as
    /// [`SegmentedWsPlan::cell_scalar`].
    pub fn cell_scalar(&self, hi: usize, wi: usize) -> Metrics {
        let s = self.shapes.len();
        let (ro, co) = (hi * self.stride, wi * self.stride);
        let cyc_r = &self.cyc_r[ro..ro + s];
        let tm_m = &self.tm_m[ro..ro + s];
        let tc = &self.tc[co..co + s];
        let cyc: u64 = cyc_r.iter().zip(tc).map(|(&a, &b)| a * b).sum();
        let passes: u64 = tm_m.iter().zip(tc).map(|(&a, &b)| a * b).sum();
        let h = self.heights[hi] as u64;
        let w = self.widths[wi] as u64;
        Metrics {
            cycles: cyc + self.tot_m_tc[wi] + self.tot_tm_n[hi],
            stall_cycles: 0,
            macs: self.tot_macs,
            passes,
            movements: MovementCounters {
                ub_act_reads: self.tot_km_tc[wi],
                ub_weight_reads: self.tot_kn_tm[hi],
                ub_out_writes: self.tot_mn,
                inter_pe_act: (w - 1) * self.tot_km_tc[wi],
                inter_pe_psum: (h - 1) * self.tot_mn - self.tot_n_smm[hi],
                inter_pe_weight: self.tot_kmn - self.tot_kn_tm[hi],
                intra_pe: self.tot_5k2mn,
                aa_writes: self.tot_mn,
                aa_reads: self.tot_mn,
            },
        }
    }

    /// Words each axis value owns in every row/col table (lane-padded),
    /// as in [`SegmentedWsPlan::lane_stride`].
    pub fn lane_stride(&self) -> usize {
        self.stride
    }

    /// [`SegmentedOsPlan::cell`] looked up by axis values — two binary
    /// searches plus the combine. `None` if (h, w) is off the plan axes.
    pub fn probe(&self, h: usize, w: usize) -> Option<Metrics> {
        let hi = self.height_index(h)?;
        let wi = self.width_index(w)?;
        Some(self.cell(hi, wi))
    }

    /// Per-shape metrics of one cell, unscaled by multiplicity —
    /// byte-identical to `os_metrics` for that (shape, geometry). The
    /// serve path seeds the per-(shape, configuration) memo table with
    /// these.
    pub fn shape_cell(&self, si: usize, hi: usize, wi: usize) -> Metrics {
        let (shape, _) = self.shapes[si];
        let (ra, ca) = (hi * self.stride + si, wi * self.stride + si);
        let row = OsRowScalars {
            height: self.heights[hi],
            tm: self.tm[ra],
            s_mm: self.s_mm[ra],
        };
        let col = OsColScalars {
            width: self.widths[wi],
            tc: self.tc[ca],
        };
        os_metrics_from_scalars(shape, &row, &col)
    }

    /// The shapes (with multiplicities) the plan was built over.
    pub fn shapes(&self) -> &[(GemmShape, u64)] {
        &self.shapes
    }

    /// Resident size of the SoA tables in 64-bit words — what the plan
    /// cache's memory budget accounts. Lane padding included, as in
    /// [`SegmentedWsPlan::table_words`].
    pub fn table_words(&self) -> usize {
        let s = self.stride;
        let (nh, nw) = (self.heights.len(), self.widths.len());
        4 * nh * s + nw * s + 3 * nh + 2 * nw
    }
}

/// The cache key: the dataflow whose closed form the plan models, the
/// exact deduplicated shape histogram (a structural workload fingerprint
/// — collision-free by construction), the normalized grid axes and the
/// accumulator capacity (normalized to 0 for OS plans, which have no
/// accumulator dependence, so every capacity shares one plan). Bitwidths
/// are deliberately absent: they scale bandwidth/energy reports, not
/// access counts, so one plan serves every bitwidth knob — the same
/// argument as the eval cache's `CfgKey`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    dataflow: Dataflow,
    shapes: Vec<(GemmShape, u64)>,
    heights: Vec<usize>,
    widths: Vec<usize>,
    acc: usize,
}

/// A cached plan of either dataflow. The key's `dataflow` field decides
/// the variant, so a lookup can never see the wrong one.
#[derive(Debug, Clone)]
enum CachedPlan {
    Ws(Arc<SegmentedWsPlan>),
    Os(Arc<SegmentedOsPlan>),
}

impl CachedPlan {
    fn table_words(&self) -> usize {
        match self {
            CachedPlan::Ws(p) => p.table_words(),
            CachedPlan::Os(p) => p.table_words(),
        }
    }
}

/// Most plans a long-lived engine holds before flushing wholesale. Plans
/// are memo state, not semantics — a flush only costs rebuilding tables.
pub const PLAN_CACHE_CAPACITY: usize = 64;

/// Total SoA words the cache may keep resident (128 MiB of `u64`s). A
/// wire-reachable worst case — thousands of distinct shapes on the dense
/// axes — costs tens of MB *per plan*, so an entry count alone would not
/// bound a hostile client's memory (the PR-2 capped-cache invariant);
/// exceeding the budget flushes wholesale, exactly like the entry cap.
pub const PLAN_CACHE_WORD_BUDGET: usize = 1 << 24;

/// A thread-safe memo table of segmented sweep plans (both dataflows).
/// Shared by the API engine across sweep / Pareto / equal-PE / figure
/// requests. Because the key embeds the exact shape histogram,
/// re-registering a user network under the same name simply stops
/// matching the old entries — stale reuse is unrepresentable and no
/// explicit invalidation hook is needed (the capacity bounds
/// garbage-collect orphaned entries).
#[derive(Debug, Default)]
pub struct PlanCache {
    map: RwLock<HashMap<PlanKey, CachedPlan>>,
    /// Σ `table_words` over the map; mutated only while holding the map's
    /// write lock.
    words: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Look up `key`, or admit `build(&key)`'s plan under the capacity
    /// and word-budget bounds (evicting wholesale on overflow — plans are
    /// memo state, a flush only costs rebuilding tables). The build
    /// closure reads the normalized axes from the key itself, so the hit
    /// path never copies them.
    fn fetch(&self, key: PlanKey, build: impl FnOnce(&PlanKey) -> CachedPlan) -> CachedPlan {
        if let Some(p) = self.map.read().expect("plan cache poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return p.clone();
        }
        let plan = build(&key);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let new_words = plan.table_words() as u64;
        let mut map = self.map.write().expect("plan cache poisoned");
        if !map.contains_key(&key)
            && (map.len() >= PLAN_CACHE_CAPACITY
                || self.words.load(Ordering::Relaxed) + new_words
                    > PLAN_CACHE_WORD_BUDGET as u64)
        {
            map.clear();
            self.words.store(0, Ordering::Relaxed);
        }
        if !map.contains_key(&key) {
            self.words.fetch_add(new_words, Ordering::Relaxed);
        }
        map.entry(key).or_insert(plan).clone()
    }

    /// Fetch or build the WS plan for (workload, axes, accumulator
    /// capacity).
    pub fn plan(
        &self,
        workload: &Workload,
        heights: &[usize],
        widths: &[usize],
        acc: usize,
    ) -> Arc<SegmentedWsPlan> {
        let key = PlanKey {
            dataflow: Dataflow::WeightStationary,
            shapes: workload.shapes.clone(),
            heights: normalize_axis(heights.to_vec()),
            widths: normalize_axis(widths.to_vec()),
            acc,
        };
        let cached = self.fetch(key, |k| {
            CachedPlan::Ws(Arc::new(SegmentedWsPlan::new(workload, &k.heights, &k.widths, acc)))
        });
        match cached {
            CachedPlan::Ws(p) => p,
            // Unreachable: the key's dataflow selects the variant.
            CachedPlan::Os(_) => unreachable!("WS key resolved to an OS plan"),
        }
    }

    /// Fetch or build the OS plan for (workload, axes). The OS closed
    /// form has no accumulator dependence, so the key normalizes the
    /// capacity away and one plan serves every provisioning.
    pub fn plan_os(
        &self,
        workload: &Workload,
        heights: &[usize],
        widths: &[usize],
    ) -> Arc<SegmentedOsPlan> {
        let key = PlanKey {
            dataflow: Dataflow::OutputStationary,
            shapes: workload.shapes.clone(),
            heights: normalize_axis(heights.to_vec()),
            widths: normalize_axis(widths.to_vec()),
            acc: 0,
        };
        let cached = self.fetch(key, |k| {
            CachedPlan::Os(Arc::new(SegmentedOsPlan::new(workload, &k.heights, &k.widths)))
        });
        match cached {
            CachedPlan::Os(p) => p,
            CachedPlan::Ws(_) => unreachable!("OS key resolved to a WS plan"),
        }
    }

    /// Drop every cached plan (benchmarks isolate rebuild cost with this).
    pub fn clear(&self) {
        let mut map = self.map.write().expect("plan cache poisoned");
        map.clear();
        self.words.store(0, Ordering::Relaxed);
    }

    /// Cached plans.
    pub fn len(&self) -> usize {
        self.map.read().expect("plan cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to build a plan.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// One-call snapshot of occupancy and traffic — what `camuy serve`
    /// logs per connection (groundwork for a `/metrics` endpoint).
    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            entries: self.len(),
            table_words: self.words.load(Ordering::Relaxed),
            hits: self.hits(),
            misses: self.misses(),
        }
    }
}

/// A point-in-time snapshot of [`PlanCache`] occupancy and traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PlanCacheStats {
    /// Plans currently resident.
    pub entries: usize,
    /// Σ `table_words` over the resident plans (lane padding included).
    pub table_words: u64,
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to build a plan.
    pub misses: u64,
}

impl PlanCacheStats {
    /// Hits over total lookups; 0.0 before any traffic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArrayConfig;
    use crate::model::gemm::ws_metrics;
    use crate::model::layer::{Layer, SpatialDims};
    use crate::model::network::Network;

    fn small_net() -> Network {
        Network::new(
            "s",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
                Layer::conv("c3", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
                Layer::conv("g", SpatialDims::square(14), 32, 32, 3, 1, 1, 4),
            ],
        )
    }

    #[test]
    fn cell_matches_direct_workload_eval() {
        let w = Workload::of(&small_net());
        let heights: Vec<usize> = (1..=40).collect();
        let widths: Vec<usize> = (1..=40).collect();
        for acc in [1usize, 7, 64, 4096] {
            let plan = SegmentedWsPlan::new(&w, &heights, &widths, acc);
            for (hi, &h) in heights.iter().enumerate() {
                for (wi, &wd) in widths.iter().enumerate() {
                    let cfg = ArrayConfig::new(h, wd).with_acc_capacity(acc);
                    assert_eq!(
                        plan.cell(hi, wi),
                        w.eval(&cfg),
                        "cell mismatch at ({h}, {wd}) acc {acc}"
                    );
                }
            }
        }
    }

    #[test]
    fn probe_is_cell_by_value() {
        let w = Workload::of(&small_net());
        let plan = SegmentedWsPlan::new(&w, &[8, 16, 32], &[4, 24], 4096);
        assert_eq!(plan.probe(16, 24), Some(plan.cell(1, 1)));
        assert_eq!(plan.probe(17, 24), None);
        assert_eq!(plan.probe(16, 25), None);
    }

    #[test]
    fn shape_cell_matches_ws_metrics() {
        let w = Workload::of(&small_net());
        let heights = [1usize, 3, 8, 19, 300];
        let widths = [1usize, 2, 7, 48, 1000];
        let plan = SegmentedWsPlan::new(&w, &heights, &widths, 64);
        for (si, &(shape, _)) in w.shapes.iter().enumerate() {
            for (hi, &h) in heights.iter().enumerate() {
                for (wi, &wd) in widths.iter().enumerate() {
                    let cfg = ArrayConfig::new(h, wd).with_acc_capacity(64);
                    assert_eq!(
                        plan.shape_cell(si, hi, wi),
                        ws_metrics(shape, &cfg),
                        "shape {shape:?} at ({h}, {wd})"
                    );
                }
            }
        }
    }

    #[test]
    fn plan_normalizes_axes() {
        let w = Workload::of(&small_net());
        let plan = SegmentedWsPlan::new(&w, &[16, 8, 16, 0], &[4, 4, 2], 4096);
        assert_eq!(plan.heights(), &[8, 16]);
        assert_eq!(plan.widths(), &[2, 4]);
        assert_eq!(plan.height_index(16), Some(1));
        assert_eq!(plan.height_index(0), None);
    }

    #[test]
    fn empty_shapes_contribute_nothing() {
        let live = GemmShape::new(5, 7, 9);
        let with_empty = Workload::from_shapes(
            "z",
            vec![(GemmShape::new(0, 8, 8), 3), (live, 2), (GemmShape::new(4, 0, 2), 1)],
        );
        let only_live = Workload::from_shapes("l", vec![(live, 2)]);
        let axes: Vec<usize> = (1..=12).collect();
        let a = SegmentedWsPlan::new(&with_empty, &axes, &axes, 32);
        let b = SegmentedWsPlan::new(&only_live, &axes, &axes, 32);
        for hi in 0..axes.len() {
            for wi in 0..axes.len() {
                assert_eq!(a.cell(hi, wi), b.cell(hi, wi));
            }
        }
        // The empty shape's seeded per-shape metrics are the identity.
        assert_eq!(a.shape_cell(0, 3, 3), Metrics::default());
    }

    #[test]
    fn plan_cache_hits_on_identical_requests() {
        let w = Workload::of(&small_net());
        let cache = PlanCache::new();
        let a = cache.plan(&w, &[8, 16], &[4, 8], 4096);
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 1, 1));
        // Same key (even with unsorted, duplicated axes): a hit, same Arc.
        let b = cache.plan(&w, &[16, 8, 8], &[8, 4], 4096);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // A different accumulator capacity is a different plan.
        let c = cache.plan(&w, &[8, 16], &[4, 8], 64);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
        // A different workload fingerprint is a different plan — the
        // re-register invalidation story.
        let other = Workload::from_shapes("s", vec![(GemmShape::new(3, 3, 3), 1)]);
        let d = cache.plan(&other, &[8, 16], &[4, 8], 4096);
        assert!(!Arc::ptr_eq(&a, &d));
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn plan_cache_word_budget_is_bounded() {
        // Many distinct shapes on dense axes make each plan tables-heavy;
        // the cache must flush on the word budget, long before the entry
        // cap would ever trigger.
        let shapes: Vec<(GemmShape, u64)> = (1..=512)
            .map(|i| (GemmShape::new(i, i + 1, i + 2), 1))
            .collect();
        let w = Workload::from_shapes("big", shapes);
        let axes: Vec<usize> = (16..=256).collect();
        let per_plan = SegmentedWsPlan::new(&w, &axes, &axes, 4096).table_words();
        let fits = PLAN_CACHE_WORD_BUDGET / per_plan;
        assert!(fits + 1 < PLAN_CACHE_CAPACITY, "budget must bind first");
        let cache = PlanCache::new();
        for i in 0..fits + 2 {
            cache.plan(&w, &axes, &axes, 4096 + i);
        }
        // At most the budget's worth of plans (+1 for the entry admitted
        // right after a flush) stays resident.
        assert!(cache.len() <= fits + 1, "{} plans resident", cache.len());
        // A flushed cache still answers.
        let p = cache.plan(&w, &axes, &axes, 4096);
        assert_eq!(p.acc_capacity(), 4096);
    }

    #[test]
    fn os_cell_matches_direct_workload_eval() {
        let w = Workload::of(&small_net());
        let heights: Vec<usize> = (1..=40).collect();
        let widths: Vec<usize> = (1..=40).collect();
        let plan = SegmentedOsPlan::new(&w, &heights, &widths);
        for (hi, &h) in heights.iter().enumerate() {
            for (wi, &wd) in widths.iter().enumerate() {
                // The OS model ignores the accumulator capacity: any
                // provisioning must match the same plan cell.
                for acc in [1usize, 64, 4096] {
                    let cfg = ArrayConfig::new(h, wd)
                        .with_acc_capacity(acc)
                        .with_dataflow(Dataflow::OutputStationary);
                    assert_eq!(
                        plan.cell(hi, wi),
                        w.eval(&cfg),
                        "OS cell mismatch at ({h}, {wd}) acc {acc}"
                    );
                }
            }
        }
    }

    #[test]
    fn os_shape_cell_matches_os_metrics() {
        let w = Workload::of(&small_net());
        let heights = [1usize, 3, 8, 19, 300];
        let widths = [1usize, 2, 7, 48, 1000];
        let plan = SegmentedOsPlan::new(&w, &heights, &widths);
        for (si, &(shape, _)) in w.shapes.iter().enumerate() {
            for (hi, &h) in heights.iter().enumerate() {
                for (wi, &wd) in widths.iter().enumerate() {
                    let cfg = ArrayConfig::new(h, wd).with_dataflow(Dataflow::OutputStationary);
                    assert_eq!(
                        plan.shape_cell(si, hi, wi),
                        crate::model::gemm::os_metrics(shape, &cfg),
                        "shape {shape:?} at ({h}, {wd})"
                    );
                }
            }
        }
    }

    #[test]
    fn os_plan_probe_and_normalization() {
        let w = Workload::of(&small_net());
        let plan = SegmentedOsPlan::new(&w, &[16, 8, 16, 0], &[4, 4, 2]);
        assert_eq!(plan.heights(), &[8, 16]);
        assert_eq!(plan.widths(), &[2, 4]);
        assert_eq!(plan.probe(16, 4), Some(plan.cell(1, 1)));
        assert_eq!(plan.probe(17, 4), None);
    }

    #[test]
    fn plan_cache_keeps_dataflows_apart_and_shares_os_across_acc() {
        let w = Workload::of(&small_net());
        let cache = PlanCache::new();
        let ws = cache.plan(&w, &[8, 16], &[4, 8], 4096);
        let os = cache.plan_os(&w, &[8, 16], &[4, 8]);
        assert_eq!(cache.len(), 2);
        assert_eq!(ws.heights(), os.heights());
        // OS plans are accumulator-independent: any capacity hits the
        // same entry.
        let os2 = cache.plan_os(&w, &[16, 8, 8], &[8, 4]);
        assert!(Arc::ptr_eq(&os, &os2));
        assert_eq!(cache.len(), 2);
        assert!(cache.hits() >= 1);
    }

    #[test]
    fn plan_cache_capacity_is_bounded() {
        let w = Workload::of(&small_net());
        let cache = PlanCache::new();
        for i in 0..PLAN_CACHE_CAPACITY + 5 {
            cache.plan(&w, &[8 + i], &[4], 4096);
        }
        assert!(cache.len() <= PLAN_CACHE_CAPACITY);
        // A flushed cache still answers (rebuilds on miss).
        let p = cache.plan(&w, &[8], &[4], 4096);
        assert_eq!(p.heights(), &[8]);
    }

    #[test]
    fn tables_are_lane_padded_and_the_scalar_cell_agrees() {
        // Shape counts on every interesting residue class mod DOT_LANES.
        for extra in [0usize, 1, 6, 7, 8, 9] {
            let mut shapes = vec![(GemmShape::new(5, 7, 9), 2)];
            for i in 0..extra {
                shapes.push((GemmShape::new(3 + i, 11, 4 + 2 * i), 1 + i as u64));
            }
            let w = Workload::from_shapes("pad", shapes);
            let axes: Vec<usize> = (1..=17).collect();
            let ws = SegmentedWsPlan::new(&w, &axes, &axes, 19);
            let os = SegmentedOsPlan::new(&w, &axes, &axes);
            assert_eq!(ws.lane_stride() % DOT_LANES, 0);
            assert!(ws.lane_stride() >= w.distinct());
            assert!(ws.lane_stride() < w.distinct() + DOT_LANES);
            assert_eq!(os.lane_stride(), ws.lane_stride());
            for hi in 0..axes.len() {
                for wi in 0..axes.len() {
                    assert_eq!(ws.cell(hi, wi), ws.cell_scalar(hi, wi));
                    assert_eq!(os.cell(hi, wi), os.cell_scalar(hi, wi));
                }
            }
        }
    }

    #[test]
    fn stats_snapshot_tracks_occupancy_and_traffic() {
        let w = Workload::of(&small_net());
        let cache = PlanCache::new();
        assert_eq!(cache.stats(), PlanCacheStats::default());
        assert_eq!(cache.stats().hit_rate(), 0.0);
        let a = cache.plan(&w, &[8, 16], &[4, 8], 4096);
        cache.plan(&w, &[8, 16], &[4, 8], 4096);
        let s = cache.stats();
        assert_eq!((s.entries, s.hits, s.misses), (1, 1, 1));
        assert_eq!(s.table_words, a.table_words() as u64);
        assert_eq!(s.hit_rate(), 0.5);
        cache.clear();
        let s = cache.stats();
        assert_eq!((s.entries, s.table_words), (0, 0));
        // Traffic counters survive a flush (they are lifetime totals).
        assert_eq!((s.hits, s.misses), (1, 1));
    }
}
