//! The sweep engine: evaluates a workload (the deduplicated GEMM-shape IR
//! of [`crate::model::workload`]) over a configuration grid, in parallel
//! across OS threads (the offline environment has no rayon; a scoped
//! work-stealing pool over an atomic index does the job).
//!
//! The hot loop is **shape-major** (DESIGN.md §4): the closed-form WS model
//! factors into height-dependent row factors and width/accumulator-
//! dependent col factors ([`crate::model::gemm`]), and the sweep computes
//! each factor once per (shape, grid axis) instead of once per (shape,
//! configuration). All tiling divisions thus leave the per-cell loop; a
//! grid of H heights × W widths pays O(S·(H+W)) divisions instead of
//! O(S·H·W). [`sweep_workload_config_major`] keeps the naive config-major
//! path alive as the property-test oracle and the bench baseline — the two
//! are byte-identical by construction because both assemble metrics through
//! [`ws_metrics_from_factors`].

use crate::config::{ArrayConfig, Dataflow, EnergyWeights};
use crate::metrics::Metrics;
use crate::model::gemm::{
    gemm_metrics, ws_col_factors, ws_metrics_from_factors, ws_row_factors, WsColFactors,
    WsRowFactors,
};
pub use crate::model::workload::Workload;
use crate::model::network::Network;
use crate::model::workload::EvalCache;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub height: usize,
    pub width: usize,
    pub metrics: Metrics,
    pub energy: f64,
    pub utilization: f64,
}

/// A complete sweep of one network over a grid.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub network: String,
    pub points: Vec<SweepPoint>,
}

impl SweepResult {
    pub fn energies(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.energy).collect()
    }

    pub fn cycles(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.metrics.cycles as f64).collect()
    }

    pub fn utilizations(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.utilization).collect()
    }

    /// Point with minimal value of `f`.
    pub fn argmin(&self, f: impl Fn(&SweepPoint) -> f64) -> &SweepPoint {
        self.points
            .iter()
            .min_by(|a, b| f(a).partial_cmp(&f(b)).unwrap())
            .expect("non-empty sweep")
    }
}

/// The shape-major evaluation plan for one (workload, config list) pair:
/// WS tiling factors cached per (shape, height) and per (shape, width,
/// accumulator capacity), plus per-config indices into those tables.
/// Configs running a non-WS dataflow fall back to direct per-shape
/// evaluation.
struct ShapeMajorPlan<'a> {
    workload: &'a Workload,
    /// Flat factor tables; each distinct axis value owns a contiguous
    /// `workload.distinct()`-sized block.
    rows: Vec<WsRowFactors>,
    cols: Vec<WsColFactors>,
    /// Per config: block starts into `rows`/`cols`, or `None` for the
    /// fallback path.
    blocks: Vec<Option<(usize, usize)>>,
}

impl<'a> ShapeMajorPlan<'a> {
    fn new(workload: &'a Workload, configs: &[ArrayConfig]) -> ShapeMajorPlan<'a> {
        let mut rows: Vec<WsRowFactors> = Vec::new();
        let mut cols: Vec<WsColFactors> = Vec::new();
        let mut row_start: HashMap<usize, usize> = HashMap::new();
        let mut col_start: HashMap<(usize, usize), usize> = HashMap::new();
        let mut blocks = Vec::with_capacity(configs.len());
        for cfg in configs {
            if cfg.dataflow != Dataflow::WeightStationary {
                blocks.push(None);
                continue;
            }
            let rs = match row_start.get(&cfg.height) {
                Some(&s) => s,
                None => {
                    let s = rows.len();
                    for &(shape, _) in &workload.shapes {
                        rows.push(ws_row_factors(shape, cfg.height));
                    }
                    row_start.insert(cfg.height, s);
                    s
                }
            };
            let ck = (cfg.width, cfg.acc_capacity);
            let cs = match col_start.get(&ck) {
                Some(&s) => s,
                None => {
                    let s = cols.len();
                    for &(shape, _) in &workload.shapes {
                        cols.push(ws_col_factors(shape, cfg.width, cfg.acc_capacity));
                    }
                    col_start.insert(ck, s);
                    s
                }
            };
            blocks.push(Some((rs, cs)));
        }
        ShapeMajorPlan {
            workload,
            rows,
            cols,
            blocks,
        }
    }

    /// Evaluate config `i`: Σ multiplicity × per-shape metrics, assembled
    /// from the cached factors (or the direct path for non-WS dataflows).
    /// With `seed`, every per-shape result is also written into the memo
    /// table, so later per-(shape, config) lookups hit.
    fn eval(&self, i: usize, cfg: &ArrayConfig, seed: Option<&EvalCache>) -> Metrics {
        match self.blocks[i] {
            None => match seed {
                None => self.workload.eval(cfg),
                Some(cache) => self.workload.eval_cached(cfg, cache),
            },
            Some((rs, cs)) => {
                let mut total = Metrics::default();
                for (si, &(shape, mult)) in self.workload.shapes.iter().enumerate() {
                    let m =
                        ws_metrics_from_factors(shape, &self.rows[rs + si], &self.cols[cs + si]);
                    if let Some(cache) = seed {
                        cache.seed(shape, cfg, m);
                    }
                    total += m * mult;
                }
                total
            }
        }
    }
}

/// Run `f(i)` for every index in `0..n` across `threads` workers that
/// steal indices from a shared atomic counter — no static chunking, so a
/// straggler task (large shape count, slow cell, heavy request) cannot
/// idle the pool. Shared by the sweep cores and the serve loop's request
/// fan-out.
pub fn parallel_map<T: Send + Sync>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let workers = threads.max(1).min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let _ = slots[i].set(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("all slots filled"))
        .collect()
}

fn point_of(cfg: &ArrayConfig, m: Metrics, weights: &EnergyWeights) -> SweepPoint {
    SweepPoint {
        height: cfg.height,
        width: cfg.width,
        metrics: m,
        energy: m.energy(weights),
        utilization: m.utilization(cfg.pe_count()),
    }
}

/// Sweep one network over explicit configurations, parallel across threads.
pub fn sweep_network(
    net: &Network,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> SweepResult {
    let workload = Workload::of(net);
    let points = sweep_workload(&workload, configs, weights, threads);
    SweepResult {
        network: net.name.clone(),
        points,
    }
}

/// Sweep a prepared workload shape-major: tiling factors are computed once
/// per (shape, grid axis) and reused across the whole config list.
pub fn sweep_workload(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> Vec<SweepPoint> {
    let plan = ShapeMajorPlan::new(workload, configs);
    parallel_map(configs.len(), threads, |i| {
        point_of(&configs[i], plan.eval(i, &configs[i], None), weights)
    })
}

/// Seed `cache` with the per-(shape, configuration) metrics of every
/// cell, shape-major, without assembling sweep points (no energy or
/// utilization is computed — the caller reads the memo table). This is
/// the batched serving path: `camuy serve` groups concurrent eval
/// requests by workload, runs their distinct configurations through the
/// shape-major core once, and answers each request from the now-hot memo
/// table.
pub fn seed_workload(
    workload: &Workload,
    configs: &[ArrayConfig],
    threads: usize,
    cache: &EvalCache,
) {
    let plan = ShapeMajorPlan::new(workload, configs);
    parallel_map(configs.len(), threads, |i| {
        plan.eval(i, &configs[i], Some(cache));
    });
}

/// The naive config-major path: every (shape, config) cell recomputes its
/// tiling from scratch. Kept as the property-test oracle and the bench
/// baseline the shape-major core is measured against.
pub fn sweep_workload_config_major(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> Vec<SweepPoint> {
    parallel_map(configs.len(), threads, |i| {
        let cfg = &configs[i];
        let m: Metrics = workload
            .shapes
            .iter()
            .map(|&(shape, mult)| gemm_metrics(shape, cfg) * mult)
            .sum();
        point_of(cfg, m, weights)
    })
}

/// Default parallelism: available cores.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, SpatialDims};
    use crate::sweep::grid::DimGrid;

    fn small_net() -> Network {
        Network::new(
            "s",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
                Layer::conv("c3", SpatialDims::square(14), 32, 32, 3, 1, 1, 1), // dup of c2
                Layer::conv("g", SpatialDims::square(14), 32, 32, 3, 1, 1, 4),
            ],
        )
    }

    #[test]
    fn workload_eval_equals_network_metrics() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfg = ArrayConfig::new(16, 8);
        assert_eq!(w.eval(&cfg), net.metrics(&cfg));
    }

    #[test]
    fn shape_major_equals_config_major() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfgs = DimGrid::coarse(4, 32, 4).configs(&ArrayConfig::new(1, 1).with_acc_capacity(64));
        let ew = EnergyWeights::paper();
        let fast = sweep_workload(&w, &cfgs, &ew, 1);
        let naive = sweep_workload_config_major(&w, &cfgs, &ew, 1);
        assert_eq!(fast.len(), naive.len());
        for (a, b) in fast.iter().zip(&naive) {
            assert_eq!((a.height, a.width), (b.height, b.width));
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.energy, b.energy);
            assert_eq!(a.utilization, b.utilization);
        }
    }

    #[test]
    fn seeding_fills_the_cache_with_exact_metrics() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfgs = DimGrid::coarse(8, 24, 8).configs(&ArrayConfig::new(1, 1));
        let cache = EvalCache::new();
        seed_workload(&w, &cfgs, 2, &cache);
        // Every (shape, config) cell was seeded; evaluating through the
        // cache is now hit-only and byte-identical to the direct path.
        assert_eq!(cache.len(), w.distinct() * cfgs.len());
        let misses = cache.misses();
        for cfg in &cfgs {
            assert_eq!(w.eval_cached(cfg, &cache), w.eval(cfg));
        }
        assert_eq!(cache.misses(), misses);
    }

    #[test]
    fn non_ws_dataflow_falls_back_and_matches() {
        let net = small_net();
        let w = Workload::of(&net);
        // A mixed config list: WS and OS entries interleaved.
        let mut cfgs = DimGrid::coarse(8, 24, 8).configs(&ArrayConfig::new(1, 1));
        let os: Vec<ArrayConfig> = cfgs
            .iter()
            .map(|c| c.clone().with_dataflow(crate::config::Dataflow::OutputStationary))
            .collect();
        cfgs.extend(os);
        let ew = EnergyWeights::paper();
        let fast = sweep_workload(&w, &cfgs, &ew, 2);
        for (p, cfg) in fast.iter().zip(&cfgs) {
            assert_eq!(p.metrics, w.eval(cfg));
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let net = small_net();
        let cfgs = DimGrid::coarse(4, 32, 4).configs(&ArrayConfig::new(1, 1));
        let ew = EnergyWeights::paper();
        let serial = sweep_network(&net, &cfgs, &ew, 1);
        let parallel = sweep_network(&net, &cfgs, &ew, 4);
        assert_eq!(serial.points.len(), parallel.points.len());
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!((a.height, a.width), (b.height, b.width));
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.energy, b.energy);
        }
    }

    #[test]
    fn more_workers_than_configs_degrades_gracefully() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 16, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 64);
        assert_eq!(res.points.len(), cfgs.len());
        let serial = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 1);
        for (a, b) in res.points.iter().zip(&serial.points) {
            assert_eq!(a.metrics, b.metrics);
        }
    }

    #[test]
    fn argmin_finds_minimum() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 64, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 2);
        let best = res.argmin(|p| p.energy);
        for p in &res.points {
            assert!(best.energy <= p.energy);
        }
    }

    #[test]
    fn utilization_in_unit_interval() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 32, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 2);
        for p in &res.points {
            assert!((0.0..=1.0).contains(&p.utilization), "{}", p.utilization);
        }
    }
}
