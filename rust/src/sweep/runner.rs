//! The sweep engine: evaluates a network (or several) over a configuration
//! grid, in parallel across OS threads (the offline environment has no
//! rayon; `std::thread::scope` over chunks does the job).
//!
//! The hot path deduplicates GEMM shapes first: a network is reduced to its
//! shape histogram once, then each configuration evaluates each *distinct*
//! shape exactly once and scales by multiplicity — DenseNet-201's 201
//! layers collapse to ~120 distinct GEMMs, ResNet-152's 156 to ~40.

use crate::config::{ArrayConfig, EnergyWeights};
use crate::metrics::Metrics;
use crate::model::gemm::gemm_metrics;
use crate::model::network::Network;
use crate::model::schedule::GemmShape;

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub height: usize,
    pub width: usize,
    pub metrics: Metrics,
    pub energy: f64,
    pub utilization: f64,
}

/// A complete sweep of one network over a grid.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub network: String,
    pub points: Vec<SweepPoint>,
}

impl SweepResult {
    pub fn energies(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.energy).collect()
    }

    pub fn cycles(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.metrics.cycles as f64).collect()
    }

    pub fn utilizations(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.utilization).collect()
    }

    /// Point with minimal value of `f`.
    pub fn argmin(&self, f: impl Fn(&SweepPoint) -> f64) -> &SweepPoint {
        self.points
            .iter()
            .min_by(|a, b| f(a).partial_cmp(&f(b)).unwrap())
            .expect("non-empty sweep")
    }
}

/// The deduplicated workload of a network: distinct (shape, groups) with
/// multiplicity.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub shapes: Vec<(GemmShape, u64)>, // (shape, groups * occurrences)
    pub macs: u64,
}

impl Workload {
    pub fn of(net: &Network) -> Workload {
        let mut shapes: Vec<(GemmShape, u64)> = Vec::new();
        for (shape, groups, count) in net.gemm_histogram() {
            let mult = (groups * count) as u64;
            if let Some(e) = shapes.iter_mut().find(|(s, _)| *s == shape) {
                e.1 += mult;
            } else {
                shapes.push((shape, mult));
            }
        }
        Workload {
            name: net.name.clone(),
            shapes,
            macs: net.macs(),
        }
    }

    /// Evaluate on one configuration: Σ multiplicity × per-shape metrics.
    pub fn eval(&self, cfg: &ArrayConfig) -> Metrics {
        let mut total = Metrics::default();
        for &(shape, mult) in &self.shapes {
            let one = gemm_metrics(shape, cfg);
            total.cycles += one.cycles * mult;
            total.stall_cycles += one.stall_cycles * mult;
            total.macs += one.macs * mult;
            total.passes += one.passes * mult;
            total.movements.ub_act_reads += one.movements.ub_act_reads * mult;
            total.movements.ub_weight_reads += one.movements.ub_weight_reads * mult;
            total.movements.ub_out_writes += one.movements.ub_out_writes * mult;
            total.movements.inter_pe_act += one.movements.inter_pe_act * mult;
            total.movements.inter_pe_psum += one.movements.inter_pe_psum * mult;
            total.movements.inter_pe_weight += one.movements.inter_pe_weight * mult;
            total.movements.intra_pe += one.movements.intra_pe * mult;
            total.movements.aa_writes += one.movements.aa_writes * mult;
            total.movements.aa_reads += one.movements.aa_reads * mult;
        }
        total
    }
}

/// Sweep one network over explicit configurations, parallel across threads.
pub fn sweep_network(
    net: &Network,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> SweepResult {
    let workload = Workload::of(net);
    let points = sweep_workload(&workload, configs, weights, threads);
    SweepResult {
        network: net.name.clone(),
        points,
    }
}

/// Sweep a prepared workload (used by benches to skip re-deduplication).
pub fn sweep_workload(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> Vec<SweepPoint> {
    let threads = threads.max(1);
    let eval_one = |cfg: &ArrayConfig| -> SweepPoint {
        let m = workload.eval(cfg);
        SweepPoint {
            height: cfg.height,
            width: cfg.width,
            metrics: m,
            energy: m.energy(weights),
            utilization: m.utilization(cfg.pe_count()),
        }
    };

    if threads == 1 || configs.len() < 2 * threads {
        return configs.iter().map(eval_one).collect();
    }

    let chunk = configs.len().div_ceil(threads);
    let mut points: Vec<Option<SweepPoint>> = vec![None; configs.len()];
    std::thread::scope(|scope| {
        for (slot_chunk, cfg_chunk) in points.chunks_mut(chunk).zip(configs.chunks(chunk)) {
            scope.spawn(move || {
                for (slot, cfg) in slot_chunk.iter_mut().zip(cfg_chunk) {
                    *slot = Some(eval_one(cfg));
                }
            });
        }
    });
    points.into_iter().map(|p| p.expect("all slots filled")).collect()
}

/// Default parallelism: available cores.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, SpatialDims};
    use crate::sweep::grid::DimGrid;

    fn small_net() -> Network {
        Network::new(
            "s",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
                Layer::conv("c3", SpatialDims::square(14), 32, 32, 3, 1, 1, 1), // dup of c2
                Layer::conv("g", SpatialDims::square(14), 32, 32, 3, 1, 1, 4),
            ],
        )
    }

    #[test]
    fn workload_deduplicates() {
        let w = Workload::of(&small_net());
        // c2 and c3 share a shape; the grouped layer is distinct.
        assert_eq!(w.shapes.len(), 3);
        let dup = w.shapes.iter().find(|(s, _)| s.k == 32 * 9).unwrap();
        assert_eq!(dup.1, 2);
        let grouped = w.shapes.iter().find(|(s, _)| s.k == 8 * 9).unwrap();
        assert_eq!(grouped.1, 4);
    }

    #[test]
    fn workload_eval_equals_network_metrics() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfg = ArrayConfig::new(16, 8);
        assert_eq!(w.eval(&cfg), net.metrics(&cfg));
    }

    #[test]
    fn parallel_matches_serial() {
        let net = small_net();
        let cfgs = DimGrid::coarse(4, 32, 4).configs(&ArrayConfig::new(1, 1));
        let ew = EnergyWeights::paper();
        let serial = sweep_network(&net, &cfgs, &ew, 1);
        let parallel = sweep_network(&net, &cfgs, &ew, 4);
        assert_eq!(serial.points.len(), parallel.points.len());
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!((a.height, a.width), (b.height, b.width));
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.energy, b.energy);
        }
    }

    #[test]
    fn argmin_finds_minimum() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 64, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 2);
        let best = res.argmin(|p| p.energy);
        for p in &res.points {
            assert!(best.energy <= p.energy);
        }
    }

    #[test]
    fn utilization_in_unit_interval() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 32, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 2);
        for p in &res.points {
            assert!((0.0..=1.0).contains(&p.utilization), "{}", p.utilization);
        }
    }
}
