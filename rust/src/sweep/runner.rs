//! The sweep engine: evaluates a workload (the deduplicated GEMM-shape IR
//! of [`crate::model::workload`]) over a configuration grid, fanned out
//! through the process-wide persistent work-stealing pool
//! ([`crate::runtime::pool`], DESIGN.md §11 — the offline environment has
//! no rayon).
//!
//! The default hot loop is **segmented** (DESIGN.md §10/§11): for each
//! shape, every grid axis collapses into the piecewise-constant
//! equivalence segments of its tile-count step functions, per-axis tile
//! scalars land in flat SoA tables
//! ([`crate::sweep::plan::SegmentedWsPlan`] for weight-stationary,
//! [`crate::sweep::plan::SegmentedOsPlan`] for output-stationary), and
//! each cell is assembled with a handful of dot products over the shape
//! dimension — no divisions, no branches, no pointer chasing, on either
//! dataflow. Two older cores stay alive as byte-identical correctness
//! baselines and bench rungs:
//!
//! * [`sweep_workload_shape_major`] — factors computed once per (shape,
//!   grid axis), combined per cell through `ws_metrics_from_factors`
//!   (DESIGN.md §4, the PR-1 core).
//! * [`sweep_workload_config_major`] — the naive oracle: every (shape,
//!   config) cell recomputes its tiling from scratch.
//!
//! All three produce byte-identical `Metrics` (property-tested); the
//! segmented core is additionally reachable with an engine-owned
//! [`PlanCache`] so repeated requests reuse segment tables.

use crate::config::{ArrayConfig, Dataflow, EnergyWeights};
use crate::metrics::Metrics;
use crate::model::gemm::{
    gemm_metrics, ws_col_factors, ws_metrics_from_factors, ws_row_factors, WsColFactors,
    WsRowFactors,
};
pub use crate::model::workload::Workload;
use crate::model::network::Network;
use crate::model::workload::EvalCache;
use crate::runtime::pool;
use crate::sweep::plan::{PlanCache, SegmentedOsPlan, SegmentedWsPlan};
use std::collections::HashMap;
use std::sync::Arc;

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub height: usize,
    pub width: usize,
    pub metrics: Metrics,
    pub energy: f64,
    pub utilization: f64,
}

/// A complete sweep of one network over a grid.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub network: String,
    pub points: Vec<SweepPoint>,
}

impl SweepResult {
    pub fn energies(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.energy).collect()
    }

    pub fn cycles(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.metrics.cycles as f64).collect()
    }

    pub fn utilizations(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.utilization).collect()
    }

    /// Point with minimal value of `f`, or `None` for an empty sweep.
    /// Uses the IEEE total order, so a NaN objective can never panic —
    /// (positive) NaNs sort after every number and lose the argmin.
    pub fn argmin(&self, f: impl Fn(&SweepPoint) -> f64) -> Option<&SweepPoint> {
        self.points.iter().min_by(|a, b| f(a).total_cmp(&f(b)))
    }
}

/// The shape-major evaluation plan for one (workload, config list) pair:
/// WS tiling factors cached per (shape, height) and per (shape, width,
/// accumulator capacity), plus per-config indices into those tables.
/// Configs running a non-WS dataflow fall back to direct per-shape
/// evaluation.
struct ShapeMajorPlan<'a> {
    workload: &'a Workload,
    /// Flat factor tables; each distinct axis value owns a contiguous
    /// `workload.distinct()`-sized block.
    rows: Vec<WsRowFactors>,
    cols: Vec<WsColFactors>,
    /// Per config: block starts into `rows`/`cols`, or `None` for the
    /// fallback path.
    blocks: Vec<Option<(usize, usize)>>,
}

impl<'a> ShapeMajorPlan<'a> {
    fn new(workload: &'a Workload, configs: &[ArrayConfig]) -> ShapeMajorPlan<'a> {
        let mut rows: Vec<WsRowFactors> = Vec::new();
        let mut cols: Vec<WsColFactors> = Vec::new();
        let mut row_start: HashMap<usize, usize> = HashMap::new();
        let mut col_start: HashMap<(usize, usize), usize> = HashMap::new();
        let mut blocks = Vec::with_capacity(configs.len());
        for cfg in configs {
            if cfg.dataflow != Dataflow::WeightStationary {
                blocks.push(None);
                continue;
            }
            let rs = match row_start.get(&cfg.height) {
                Some(&s) => s,
                None => {
                    let s = rows.len();
                    for &(shape, _) in &workload.shapes {
                        rows.push(ws_row_factors(shape, cfg.height));
                    }
                    row_start.insert(cfg.height, s);
                    s
                }
            };
            let ck = (cfg.width, cfg.acc_capacity);
            let cs = match col_start.get(&ck) {
                Some(&s) => s,
                None => {
                    let s = cols.len();
                    for &(shape, _) in &workload.shapes {
                        cols.push(ws_col_factors(shape, cfg.width, cfg.acc_capacity));
                    }
                    col_start.insert(ck, s);
                    s
                }
            };
            blocks.push(Some((rs, cs)));
        }
        ShapeMajorPlan {
            workload,
            rows,
            cols,
            blocks,
        }
    }

    /// Evaluate config `i`: Σ multiplicity × per-shape metrics, assembled
    /// from the cached factors (or the direct path for non-WS dataflows).
    /// With `seed`, every per-shape result is also written into the memo
    /// table, so later per-(shape, config) lookups hit.
    fn eval(&self, i: usize, cfg: &ArrayConfig, seed: Option<&EvalCache>) -> Metrics {
        match self.blocks[i] {
            None => match seed {
                None => self.workload.eval(cfg),
                Some(cache) => self.workload.eval_cached(cfg, cache),
            },
            Some((rs, cs)) => {
                let mut total = Metrics::default();
                for (si, &(shape, mult)) in self.workload.shapes.iter().enumerate() {
                    let m =
                        ws_metrics_from_factors(shape, &self.rows[rs + si], &self.cols[cs + si]);
                    if let Some(cache) = seed {
                        cache.seed(shape, cfg, m);
                    }
                    total += m * mult;
                }
                total
            }
        }
    }
}

// Historically the sweep engine owned the process's fan-out primitives;
// since DESIGN.md §11 they live in the persistent-pool runtime and are
// re-exported here so `camuy::sweep::{parallel_map, default_threads}`
// remain valid paths (and true synonyms, not wrappers that could drift).
pub use crate::runtime::pool::parallel_map;

fn point_of(cfg: &ArrayConfig, m: Metrics, weights: &EnergyWeights) -> SweepPoint {
    SweepPoint {
        height: cfg.height,
        width: cfg.width,
        metrics: m,
        energy: m.energy(weights),
        utilization: m.utilization(cfg.pe_count()),
    }
}

/// Sweep one network over explicit configurations, parallel across threads
/// (the segmented core, no plan cache).
pub fn sweep_network(
    net: &Network,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> SweepResult {
    sweep_network_planned(net, configs, weights, threads, None)
}

/// [`sweep_network`] with an optional engine-owned [`PlanCache`] so
/// repeated sweeps of one workload reuse the segment tables.
pub fn sweep_network_planned(
    net: &Network,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
    plans: Option<&PlanCache>,
) -> SweepResult {
    let workload = Workload::of(net);
    let points = sweep_workload_planned(&workload, configs, weights, threads, plans);
    SweepResult {
        network: net.name.clone(),
        points,
    }
}

/// Sweep a prepared workload. This is the segmented core
/// ([`sweep_workload_segmented`]); the shape-major and config-major cores
/// remain available as byte-identical baselines.
pub fn sweep_workload(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> Vec<SweepPoint> {
    sweep_workload_planned(workload, configs, weights, threads, None)
}

/// How each configuration of a request is evaluated: through a segmented
/// plan cell (either dataflow), or directly (the defensive fallback for
/// degenerate geometries a plan cannot index).
#[derive(Clone, Copy)]
enum CellRoute {
    Plan { plan: usize, hi: usize, wi: usize },
    Direct,
}

/// A built segmented plan of either dataflow, dispatched per cell.
enum PlanRef {
    Ws(Arc<SegmentedWsPlan>),
    Os(Arc<SegmentedOsPlan>),
}

impl PlanRef {
    fn height_index(&self, h: usize) -> Option<usize> {
        match self {
            PlanRef::Ws(p) => p.height_index(h),
            PlanRef::Os(p) => p.height_index(h),
        }
    }

    fn width_index(&self, w: usize) -> Option<usize> {
        match self {
            PlanRef::Ws(p) => p.width_index(w),
            PlanRef::Os(p) => p.width_index(w),
        }
    }

    /// The scalar (pre-vectorization) per-cell combine — the baseline
    /// rung [`sweep_workload_segmented_scalar`] dispatches through.
    fn cell_scalar(&self, hi: usize, wi: usize) -> Metrics {
        match self {
            PlanRef::Ws(p) => p.cell_scalar(hi, wi),
            PlanRef::Os(p) => p.cell_scalar(hi, wi),
        }
    }

    fn shape_cell(&self, si: usize, hi: usize, wi: usize) -> Metrics {
        match self {
            PlanRef::Ws(p) => p.shape_cell(si, hi, wi),
            PlanRef::Os(p) => p.shape_cell(si, hi, wi),
        }
    }
}

/// Group WS configurations by accumulator capacity (one
/// [`SegmentedWsPlan`] per group over the group's axis values) and OS
/// configurations into a single accumulator-independent
/// [`SegmentedOsPlan`], then map every configuration to its route. Both
/// dataflows sweep segmented (DESIGN.md §10/§11).
fn build_routes(
    workload: &Workload,
    configs: &[ArrayConfig],
    plans: Option<&PlanCache>,
) -> (Vec<PlanRef>, Vec<CellRoute>) {
    let mut ws_groups: HashMap<usize, (Vec<usize>, Vec<usize>)> = HashMap::new();
    let mut os_axes: (Vec<usize>, Vec<usize>) = (Vec::new(), Vec::new());
    for cfg in configs {
        match cfg.dataflow {
            Dataflow::WeightStationary => {
                let axes = ws_groups.entry(cfg.acc_capacity).or_default();
                axes.0.push(cfg.height);
                axes.1.push(cfg.width);
            }
            Dataflow::OutputStationary => {
                os_axes.0.push(cfg.height);
                os_axes.1.push(cfg.width);
            }
        }
    }
    let mut built: Vec<PlanRef> = Vec::with_capacity(ws_groups.len() + 1);
    let mut ws_plan_of: HashMap<usize, usize> = HashMap::with_capacity(ws_groups.len());
    for (acc, (hs, ws)) in ws_groups {
        // Plan builds dominate a cold sweep's serial prefix; let a
        // deadline fire between them rather than only once cells run.
        crate::robust::checkpoint();
        let plan = match plans {
            Some(cache) => cache.plan(workload, &hs, &ws, acc),
            None => Arc::new(SegmentedWsPlan::new(workload, &hs, &ws, acc)),
        };
        ws_plan_of.insert(acc, built.len());
        built.push(PlanRef::Ws(plan));
    }
    let os_plan = if os_axes.0.is_empty() {
        None
    } else {
        let plan = match plans {
            Some(cache) => cache.plan_os(workload, &os_axes.0, &os_axes.1),
            None => Arc::new(SegmentedOsPlan::new(workload, &os_axes.0, &os_axes.1)),
        };
        built.push(PlanRef::Os(plan));
        Some(built.len() - 1)
    };
    let routes = configs
        .iter()
        .map(|cfg| {
            let pi = match cfg.dataflow {
                Dataflow::WeightStationary => ws_plan_of[&cfg.acc_capacity],
                Dataflow::OutputStationary => os_plan.expect("OS configs imply an OS plan"),
            };
            match (
                built[pi].height_index(cfg.height),
                built[pi].width_index(cfg.width),
            ) {
                (Some(hi), Some(wi)) => CellRoute::Plan { plan: pi, hi, wi },
                // Unreachable for valid configs (the plan axes cover the
                // group); a zero edge falls through to the direct path,
                // which fails exactly like a direct evaluation would.
                _ => CellRoute::Direct,
            }
        })
        .collect();
    (built, routes)
}

/// The segmented sweep core (DESIGN.md §10): axis collapse into
/// equivalence segments, SoA tile-scalar tables, per-cell assembly by dot
/// products. Byte-identical to [`sweep_workload_config_major`].
pub fn sweep_workload_segmented(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> Vec<SweepPoint> {
    sweep_workload_planned(workload, configs, weights, threads, None)
}

/// Consecutive cells one worker claims at a time. A segmented cell is a
/// few hundred nanoseconds, so per-index work-stealing overhead (atomic
/// claim + `OnceLock` publish) would be a visible fraction of the cell
/// itself; claiming short runs amortizes it while keeping stealing
/// granular enough that a straggler cannot idle the pool.
const SWEEP_CHUNK: usize = 64;

/// Combined bytes of the hot row- and col-table slices one (height,
/// width) cache block streams while its cells are assembled — the
/// blocked dispatch picks the block edge so this fits comfortably in a
/// typical 256 KiB–1 MiB L2, leaving headroom for the per-axis totals
/// and the output points.
const BLOCK_TABLE_BYTES: usize = 192 * 1024;

/// Cache-block edge (axis values per side) for a plan whose cells
/// stream `hot_tables` SoA tables of `stride` words per axis value: a
/// `B × B` block touches `B · stride · hot_tables` words of row plus
/// col tables, so both block slices together stay under
/// [`BLOCK_TABLE_BYTES`]. Clamped so degenerate strides can neither
/// collapse the blocks to single cells nor unblock the traversal.
fn block_edge(stride: usize, hot_tables: usize) -> usize {
    let per_value_bytes = 8 * stride.max(1) * hot_tables;
    (BLOCK_TABLE_BYTES / per_value_bytes.max(1)).clamp(8, 512)
}

/// A routed cell in block-major order: the original config index plus
/// its plan coordinates (zero for direct-path cells).
#[derive(Clone, Copy)]
struct BlockCell {
    cfg: usize,
    hi: usize,
    wi: usize,
}

/// One block-granular dispatch unit: a run of consecutive entries in
/// the block-major cell order, all routed through the same plan (or all
/// direct). The unit — not the cell — is the work-stealing quantum, so
/// the plan variant is dispatched **once per unit** and the inner loop
/// is monomorphic over the concrete plan type, letting the fused cell
/// kernels inline.
struct SweepUnit {
    /// Index into the built plans, or [`DIRECT`].
    plan: usize,
    /// Half-open range into the block-major cell order.
    start: usize,
    end: usize,
}

/// Sentinel plan index for cells on the direct-evaluation fallback.
const DIRECT: usize = usize::MAX;

/// Append `run` (already ordered) to the block-major cell list and cut
/// it into stealable units of at most [`SWEEP_CHUNK`] cells. Units
/// never straddle plans; a cache block larger than one unit is shared
/// by several executors, which then all stream the same resident table
/// slices.
fn append_units(
    cells: &mut Vec<BlockCell>,
    units: &mut Vec<SweepUnit>,
    plan: usize,
    run: Vec<BlockCell>,
) {
    let base = cells.len();
    let len = run.len();
    cells.extend(run);
    let mut s = 0;
    while s < len {
        let e = (s + SWEEP_CHUNK).min(len);
        units.push(SweepUnit {
            plan,
            start: base + s,
            end: base + e,
        });
        s = e;
    }
}

/// [`sweep_workload_segmented`] with an optional [`PlanCache`]. This is
/// the vectorized blocked core: cells are bucketed per plan, ordered
/// block-major — by (height block, width block, height, width) with the
/// block edge sized from the plan's table stride — and dispatched as
/// block-granular units through the pool, so segment-table slices load
/// once per block instead of once per cell and each unit runs one
/// monomorphic fused-kernel loop. Byte-identical to
/// [`sweep_workload_segmented_scalar`] and the config-major oracle.
pub fn sweep_workload_planned(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
    plans: Option<&PlanCache>,
) -> Vec<SweepPoint> {
    let (built, routes) = build_routes(workload, configs, plans);
    crate::telemetry::global().sweep_cells.add(configs.len() as u64);
    let mut buckets: Vec<Vec<BlockCell>> = (0..built.len()).map(|_| Vec::new()).collect();
    let mut direct: Vec<BlockCell> = Vec::new();
    for (i, route) in routes.iter().enumerate() {
        match *route {
            CellRoute::Plan { plan, hi, wi } => buckets[plan].push(BlockCell { cfg: i, hi, wi }),
            CellRoute::Direct => direct.push(BlockCell { cfg: i, hi: 0, wi: 0 }),
        }
    }
    let mut cells: Vec<BlockCell> = Vec::with_capacity(configs.len());
    let mut units: Vec<SweepUnit> = Vec::new();
    for (pi, mut bucket) in buckets.into_iter().enumerate() {
        let edge = match &built[pi] {
            // WS cells stream two row tables and three col tables.
            PlanRef::Ws(p) => block_edge(p.lane_stride(), 5),
            // OS cells stream two row tables and one col table.
            PlanRef::Os(p) => block_edge(p.lane_stride(), 3),
        };
        bucket.sort_unstable_by_key(|c| (c.hi / edge, c.wi / edge, c.hi, c.wi));
        append_units(&mut cells, &mut units, pi, bucket);
    }
    append_units(&mut cells, &mut units, DIRECT, direct);
    pool::parallel_scatter(configs.len(), threads, units.len(), |u, out| {
        // Cancellation granularity is one dispatch unit (a cache-blocked
        // run of cells); the faultpoint lets tests make units slow or
        // panicking deterministically (DESIGN.md §15).
        crate::robust::checkpoint();
        crate::faultpoint::hit("sweep.unit");
        let unit = &units[u];
        let run = &cells[unit.start..unit.end];
        // One plan dispatch per unit; `built.get(DIRECT)` is `None`, so
        // the fallback cells share the same match.
        match built.get(unit.plan) {
            Some(PlanRef::Ws(p)) => {
                for c in run {
                    out.set(c.cfg, point_of(&configs[c.cfg], p.cell(c.hi, c.wi), weights));
                }
            }
            Some(PlanRef::Os(p)) => {
                for c in run {
                    out.set(c.cfg, point_of(&configs[c.cfg], p.cell(c.hi, c.wi), weights));
                }
            }
            None => {
                for c in run {
                    let cfg = &configs[c.cfg];
                    out.set(c.cfg, point_of(cfg, workload.eval(cfg), weights));
                }
            }
        }
    })
}

/// The scalar segmented baseline: identical routing and plan tables to
/// [`sweep_workload_planned`], but every cell runs the sequential
/// pre-vectorization combine ([`SegmentedWsPlan::cell_scalar`] /
/// [`SegmentedOsPlan::cell_scalar`]) with per-cell dispatch and no
/// cache blocking. Kept as the rung the vectorized core is
/// property-tested equal to and bench-gated against.
pub fn sweep_workload_segmented_scalar(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
    plans: Option<&PlanCache>,
) -> Vec<SweepPoint> {
    let (built, routes) = build_routes(workload, configs, plans);
    pool::parallel_map_chunked(configs.len(), threads, SWEEP_CHUNK, |i| {
        let m = match routes[i] {
            CellRoute::Plan { plan, hi, wi } => built[plan].cell_scalar(hi, wi),
            CellRoute::Direct => workload.eval(&configs[i]),
        };
        point_of(&configs[i], m, weights)
    })
}

/// The shape-major core (DESIGN.md §4): tiling factors are computed once
/// per (shape, grid axis) and combined per cell. Kept as the intermediate
/// bench rung between the config-major oracle and the segmented core.
pub fn sweep_workload_shape_major(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> Vec<SweepPoint> {
    let plan = ShapeMajorPlan::new(workload, configs);
    pool::parallel_map(configs.len(), threads, |i| {
        point_of(&configs[i], plan.eval(i, &configs[i], None), weights)
    })
}

/// Seed `cache` with the per-(shape, configuration) metrics of every
/// cell without assembling sweep points (no energy or utilization is
/// computed — the caller reads the memo table). This is the batched
/// serving path: `camuy serve` groups concurrent eval requests by
/// workload, runs their distinct configurations through the segmented
/// core once, and answers each request from the now-hot memo table.
pub fn seed_workload(
    workload: &Workload,
    configs: &[ArrayConfig],
    threads: usize,
    cache: &EvalCache,
) {
    seed_workload_planned(workload, configs, threads, cache, None)
}

/// [`seed_workload`] through an optional engine-owned [`PlanCache`], so a
/// serve batch that replays a previously seen (workload, axes) reuses the
/// segment tables instead of re-deriving them.
pub fn seed_workload_planned(
    workload: &Workload,
    configs: &[ArrayConfig],
    threads: usize,
    cache: &EvalCache,
    plans: Option<&PlanCache>,
) {
    let (built, routes) = build_routes(workload, configs, plans);
    crate::telemetry::global().sweep_cells.add(configs.len() as u64);
    pool::parallel_map(configs.len(), threads, |i| {
        let cfg = &configs[i];
        match routes[i] {
            CellRoute::Plan { plan, hi, wi } => {
                let p = &built[plan];
                for (si, &(shape, _)) in workload.shapes.iter().enumerate() {
                    cache.seed(shape, cfg, p.shape_cell(si, hi, wi));
                }
            }
            CellRoute::Direct => {
                workload.eval_cached(cfg, cache);
            }
        }
    });
}

/// The naive config-major path: every (shape, config) cell recomputes its
/// tiling from scratch. Kept as the property-test oracle and the bench
/// baseline the shape-major core is measured against.
pub fn sweep_workload_config_major(
    workload: &Workload,
    configs: &[ArrayConfig],
    weights: &EnergyWeights,
    threads: usize,
) -> Vec<SweepPoint> {
    pool::parallel_map(configs.len(), threads, |i| {
        let cfg = &configs[i];
        let m: Metrics = workload
            .shapes
            .iter()
            .map(|&(shape, mult)| gemm_metrics(shape, cfg) * mult)
            .sum();
        point_of(cfg, m, weights)
    })
}

pub use crate::runtime::pool::default_threads;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, SpatialDims};
    use crate::sweep::grid::DimGrid;

    fn small_net() -> Network {
        Network::new(
            "s",
            vec![
                Layer::conv("c1", SpatialDims::square(14), 16, 32, 3, 1, 1, 1),
                Layer::conv("c2", SpatialDims::square(14), 32, 32, 3, 1, 1, 1),
                Layer::conv("c3", SpatialDims::square(14), 32, 32, 3, 1, 1, 1), // dup of c2
                Layer::conv("g", SpatialDims::square(14), 32, 32, 3, 1, 1, 4),
            ],
        )
    }

    #[test]
    fn workload_eval_equals_network_metrics() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfg = ArrayConfig::new(16, 8);
        assert_eq!(w.eval(&cfg), net.metrics(&cfg));
    }

    #[test]
    fn shape_major_equals_config_major() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfgs = DimGrid::coarse(4, 32, 4).configs(&ArrayConfig::new(1, 1).with_acc_capacity(64));
        let ew = EnergyWeights::paper();
        let fast = sweep_workload(&w, &cfgs, &ew, 1);
        let naive = sweep_workload_config_major(&w, &cfgs, &ew, 1);
        assert_eq!(fast.len(), naive.len());
        for (a, b) in fast.iter().zip(&naive) {
            assert_eq!((a.height, a.width), (b.height, b.width));
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.energy, b.energy);
            assert_eq!(a.utilization, b.utilization);
        }
    }

    #[test]
    fn seeding_fills_the_cache_with_exact_metrics() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfgs = DimGrid::coarse(8, 24, 8).configs(&ArrayConfig::new(1, 1));
        let cache = EvalCache::new();
        seed_workload(&w, &cfgs, 2, &cache);
        // Every (shape, config) cell was seeded; evaluating through the
        // cache is now hit-only and byte-identical to the direct path.
        assert_eq!(cache.len(), w.distinct() * cfgs.len());
        let misses = cache.misses();
        for cfg in &cfgs {
            assert_eq!(w.eval_cached(cfg, &cache), w.eval(cfg));
        }
        assert_eq!(cache.misses(), misses);
    }

    #[test]
    fn mixed_dataflows_match_direct_eval() {
        let net = small_net();
        let w = Workload::of(&net);
        // A mixed config list: WS and OS entries interleaved — each
        // routes through its own segmented plan.
        let mut cfgs = DimGrid::coarse(8, 24, 8).configs(&ArrayConfig::new(1, 1));
        let os: Vec<ArrayConfig> = cfgs
            .iter()
            .map(|c| c.clone().with_dataflow(crate::config::Dataflow::OutputStationary))
            .collect();
        cfgs.extend(os);
        let ew = EnergyWeights::paper();
        let fast = sweep_workload(&w, &cfgs, &ew, 2);
        for (p, cfg) in fast.iter().zip(&cfgs) {
            assert_eq!(p.metrics, w.eval(cfg));
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let net = small_net();
        let cfgs = DimGrid::coarse(4, 32, 4).configs(&ArrayConfig::new(1, 1));
        let ew = EnergyWeights::paper();
        let serial = sweep_network(&net, &cfgs, &ew, 1);
        let parallel = sweep_network(&net, &cfgs, &ew, 4);
        assert_eq!(serial.points.len(), parallel.points.len());
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!((a.height, a.width), (b.height, b.width));
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.energy, b.energy);
        }
    }

    #[test]
    fn more_workers_than_configs_degrades_gracefully() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 16, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 64);
        assert_eq!(res.points.len(), cfgs.len());
        let serial = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 1);
        for (a, b) in res.points.iter().zip(&serial.points) {
            assert_eq!(a.metrics, b.metrics);
        }
    }

    #[test]
    fn argmin_finds_minimum() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 64, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 2);
        let best = res.argmin(|p| p.energy).expect("non-empty sweep");
        for p in &res.points {
            assert!(best.energy <= p.energy);
        }
    }

    #[test]
    fn argmin_is_none_on_empty_and_total_on_nan() {
        let empty = SweepResult {
            network: "e".into(),
            points: Vec::new(),
        };
        assert!(empty.argmin(|p| p.energy).is_none());
        // A NaN objective must neither panic nor win the argmin.
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 24, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 1);
        let best = res
            .argmin(|p| if p.height == 8 { f64::NAN } else { p.energy })
            .expect("non-empty sweep");
        assert_ne!(best.height, 8);
    }

    #[test]
    fn segmented_equals_shape_major_and_config_major() {
        let net = small_net();
        let w = Workload::of(&net);
        // Mixed accumulator capacities and duplicate configs in one list:
        // the router must group, dedup axes, and keep input order.
        let mut cfgs =
            DimGrid::coarse(1, 24, 1).configs(&ArrayConfig::new(1, 1).with_acc_capacity(64));
        cfgs.extend(
            DimGrid::coarse(3, 17, 2).configs(&ArrayConfig::new(1, 1).with_acc_capacity(7)),
        );
        cfgs.push(cfgs[0].clone());
        let ew = EnergyWeights::paper();
        let seg = sweep_workload_segmented(&w, &cfgs, &ew, 2);
        let sm = sweep_workload_shape_major(&w, &cfgs, &ew, 2);
        let cm = sweep_workload_config_major(&w, &cfgs, &ew, 2);
        assert_eq!(seg.len(), cfgs.len());
        for i in 0..cfgs.len() {
            assert_eq!((seg[i].height, seg[i].width), (cfgs[i].height, cfgs[i].width));
            assert_eq!(seg[i].metrics, sm[i].metrics, "segmented != shape-major at {i}");
            assert_eq!(seg[i].metrics, cm[i].metrics, "segmented != config-major at {i}");
            assert_eq!(seg[i].energy, cm[i].energy);
            assert_eq!(seg[i].utilization, cm[i].utilization);
        }
    }

    #[test]
    fn scalar_segmented_rung_matches_the_vectorized_blocked_core() {
        let net = small_net();
        let w = Workload::of(&net);
        // Mixed dataflows, mixed accumulator capacities, duplicates: the
        // blocked dispatch must scatter every cell back to request order
        // and stay byte-identical to the per-cell scalar rung.
        let mut cfgs =
            DimGrid::coarse(1, 24, 1).configs(&ArrayConfig::new(1, 1).with_acc_capacity(64));
        cfgs.extend(
            DimGrid::coarse(3, 17, 2).configs(&ArrayConfig::new(1, 1).with_acc_capacity(7)),
        );
        let os: Vec<ArrayConfig> = cfgs
            .iter()
            .step_by(3)
            .map(|c| c.clone().with_dataflow(crate::config::Dataflow::OutputStationary))
            .collect();
        cfgs.extend(os);
        cfgs.push(cfgs[0].clone());
        let ew = EnergyWeights::paper();
        for threads in [1usize, 4] {
            let vec = sweep_workload_planned(&w, &cfgs, &ew, threads, None);
            let scalar = sweep_workload_segmented_scalar(&w, &cfgs, &ew, threads, None);
            assert_eq!(vec.len(), cfgs.len());
            for i in 0..cfgs.len() {
                assert_eq!((vec[i].height, vec[i].width), (cfgs[i].height, cfgs[i].width));
                assert_eq!(vec[i].metrics, scalar[i].metrics, "cell {i} diverged");
                assert_eq!(vec[i].energy, scalar[i].energy);
                assert_eq!(vec[i].utilization, scalar[i].utilization);
            }
        }
    }

    #[test]
    fn block_edge_is_budgeted_and_clamped() {
        // A dense-plan stride: the edge follows the table-byte budget.
        assert_eq!(block_edge(64, 5), BLOCK_TABLE_BYTES / (8 * 64 * 5));
        // Tiny strides hit the upper clamp, huge strides the lower one.
        assert_eq!(block_edge(0, 5), 512);
        assert_eq!(block_edge(1 << 20, 5), 8);
        // Fewer hot tables (the OS plan) allow a wider edge.
        assert!(block_edge(64, 3) >= block_edge(64, 5));
    }

    #[test]
    fn planned_sweep_reuses_the_plan_cache() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfgs = DimGrid::coarse(8, 32, 8).configs(&ArrayConfig::new(1, 1));
        let ew = EnergyWeights::paper();
        let plans = crate::sweep::plan::PlanCache::new();
        let a = sweep_workload_planned(&w, &cfgs, &ew, 2, Some(&plans));
        assert_eq!((plans.len(), plans.misses()), (1, 1));
        let b = sweep_workload_planned(&w, &cfgs, &ew, 2, Some(&plans));
        assert!(plans.hits() >= 1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.metrics, y.metrics);
        }
        // Seeding through the same cache hits the same plan.
        let cache = EvalCache::new();
        seed_workload_planned(&w, &cfgs, 2, &cache, Some(&plans));
        assert_eq!(plans.len(), 1);
        assert_eq!(cache.len(), w.distinct() * cfgs.len());
        for cfg in &cfgs {
            assert_eq!(w.eval_cached(cfg, &cache), w.eval(cfg));
        }
    }

    #[test]
    fn os_sweeps_route_through_the_plan_cache() {
        let net = small_net();
        let w = Workload::of(&net);
        let cfgs: Vec<ArrayConfig> = DimGrid::coarse(4, 32, 4)
            .configs(&ArrayConfig::new(1, 1))
            .into_iter()
            .map(|c| c.with_dataflow(crate::config::Dataflow::OutputStationary))
            .collect();
        let ew = EnergyWeights::paper();
        let plans = crate::sweep::plan::PlanCache::new();
        let a = sweep_workload_planned(&w, &cfgs, &ew, 2, Some(&plans));
        assert_eq!((plans.len(), plans.misses()), (1, 1));
        let b = sweep_workload_planned(&w, &cfgs, &ew, 2, Some(&plans));
        assert!(plans.hits() >= 1);
        for (i, cfg) in cfgs.iter().enumerate() {
            assert_eq!(a[i].metrics, w.eval(cfg), "OS plan cell diverged at {cfg}");
            assert_eq!(a[i].metrics, b[i].metrics);
        }
        // Seeding OS configs plants exact per-shape os_metrics.
        let cache = EvalCache::new();
        seed_workload_planned(&w, &cfgs, 2, &cache, Some(&plans));
        assert_eq!(cache.len(), w.distinct() * cfgs.len());
        for cfg in &cfgs {
            assert_eq!(w.eval_cached(cfg, &cache), w.eval(cfg));
        }
    }

    #[test]
    fn utilization_in_unit_interval() {
        let net = small_net();
        let cfgs = DimGrid::coarse(8, 32, 8).configs(&ArrayConfig::new(1, 1));
        let res = sweep_network(&net, &cfgs, &EnergyWeights::paper(), 2);
        for p in &res.points {
            assert!((0.0..=1.0).contains(&p.utilization), "{}", p.utilization);
        }
    }
}
