//! Heatmap rendering for the Figure 2/4 style grids: ASCII shading for the
//! terminal, CSV for plotting, and PGM (portable graymap) as an
//! image-without-dependencies format.

use crate::util::csv::{fmt_f64, CsvTable};
use crate::util::stats::min_max_normalize;

/// A dense (height x width) grid of values, heights as rows.
#[derive(Debug, Clone)]
pub struct Heatmap {
    pub title: String,
    pub row_labels: Vec<usize>, // heights
    pub col_labels: Vec<usize>, // widths
    values: Vec<f64>,           // row-major
}

impl Heatmap {
    /// Build from sweep output in height-major pair order (the order
    /// `DimGrid::pairs` produces).
    pub fn from_grid(
        title: impl Into<String>,
        heights: Vec<usize>,
        widths: Vec<usize>,
        values: Vec<f64>,
    ) -> Heatmap {
        assert_eq!(values.len(), heights.len() * widths.len());
        Heatmap {
            title: title.into(),
            row_labels: heights,
            col_labels: widths,
            values,
        }
    }

    pub fn get(&self, row: usize, col: usize) -> f64 {
        self.values[row * self.col_labels.len() + col]
    }

    /// Minimum cell with its (height, width) labels.
    pub fn min_cell(&self) -> (usize, usize, f64) {
        let (mut best, mut bi) = (f64::INFINITY, 0);
        for (i, &v) in self.values.iter().enumerate() {
            if v < best {
                best = v;
                bi = i;
            }
        }
        let r = bi / self.col_labels.len();
        let c = bi % self.col_labels.len();
        (self.row_labels[r], self.col_labels[c], best)
    }

    /// ASCII shading: low values light, high values dark (the paper's
    /// green-to-red spectrum collapsed to grayscale glyphs).
    pub fn ascii(&self) -> String {
        const SHADES: &[u8] = b" .:-=+*#%@";
        let norm = min_max_normalize(&self.values);
        let mut out = String::new();
        out.push_str(&format!("{} (rows: height, cols: width)\n", self.title));
        // Column header (sparse to stay readable).
        out.push_str("      ");
        for (c, &w) in self.col_labels.iter().enumerate() {
            if c % 5 == 0 {
                out.push_str(&format!("{w:<5}"));
            }
        }
        out.push('\n');
        for (r, &h) in self.row_labels.iter().enumerate() {
            out.push_str(&format!("{h:>5} "));
            for c in 0..self.col_labels.len() {
                let v = norm[r * self.col_labels.len() + c];
                let idx = ((v * (SHADES.len() - 1) as f64).round() as usize).min(SHADES.len() - 1);
                out.push(SHADES[idx] as char);
            }
            out.push('\n');
        }
        let (bh, bw, bv) = self.min_cell();
        out.push_str(&format!("min = {} at ({bh}, {bw})\n", fmt_f64(bv)));
        out
    }

    /// Long-format CSV: height,width,value.
    pub fn to_csv(&self) -> CsvTable {
        let mut t = CsvTable::new(vec!["height", "width", "value"]);
        for (r, &h) in self.row_labels.iter().enumerate() {
            for (c, &w) in self.col_labels.iter().enumerate() {
                t.push(vec![h.to_string(), w.to_string(), fmt_f64(self.get(r, c))]);
            }
        }
        t
    }

    /// PGM (P2) grayscale image, low = white, high = black, one pixel per
    /// cell.
    pub fn to_pgm(&self) -> String {
        let norm = min_max_normalize(&self.values);
        let mut out = format!(
            "P2\n# {}\n{} {}\n255\n",
            self.title,
            self.col_labels.len(),
            self.row_labels.len()
        );
        for r in 0..self.row_labels.len() {
            let row: Vec<String> = (0..self.col_labels.len())
                .map(|c| {
                    let v = norm[r * self.col_labels.len() + c];
                    format!("{}", 255 - (v * 255.0).round() as u32)
                })
                .collect();
            out.push_str(&row.join(" "));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Heatmap {
        Heatmap::from_grid(
            "t",
            vec![16, 24],
            vec![16, 24, 32],
            vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0],
        )
    }

    #[test]
    fn indexing_row_major() {
        let h = sample();
        assert_eq!(h.get(0, 0), 6.0);
        assert_eq!(h.get(1, 2), 1.0);
    }

    #[test]
    fn min_cell_labels() {
        let (height, width, v) = sample().min_cell();
        assert_eq!((height, width, v), (24, 32, 1.0));
    }

    #[test]
    fn ascii_contains_labels_and_min() {
        let s = sample().ascii();
        assert!(s.contains("   16 "));
        assert!(s.contains("min = 1 at (24, 32)"));
        // Lightest glyph for the min, darkest for the max.
        assert!(s.contains('@'));
        assert!(s.contains(' '));
    }

    #[test]
    fn csv_long_format() {
        let t = sample().to_csv();
        assert_eq!(t.rows.len(), 6);
        assert_eq!(t.rows[0], vec!["16", "16", "6"]);
        assert_eq!(t.rows[5], vec!["24", "32", "1"]);
    }

    #[test]
    fn pgm_shape_and_range() {
        let p = sample().to_pgm();
        assert!(p.starts_with("P2\n"));
        assert!(p.contains("3 2\n255"));
        // Min value maps to white (255), max to black (0).
        assert!(p.contains("255"));
        let last_row = p.lines().last().unwrap();
        assert!(last_row.ends_with("255"));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Heatmap::from_grid("t", vec![1], vec![1, 2], vec![1.0]);
    }
}
