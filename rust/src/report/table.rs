//! Plain-text and CSV tables for Pareto sets and per-layer breakdowns.

use crate::pareto::nsga2::Solution;
use crate::util::csv::{fmt_f64, CsvTable};

/// Render a Pareto set as a text table, annotated (height, width) like the
/// paper's figures.
pub fn pareto_table(title: &str, objective_names: &[&str], sols: &[Solution]) -> String {
    let mut out = format!("{title}\n");
    out.push_str(&format!("{:>8} {:>8}", "height", "width"));
    for n in objective_names {
        out.push_str(&format!(" {n:>16}"));
    }
    out.push('\n');
    for s in sols {
        out.push_str(&format!("{:>8} {:>8}", s.height, s.width));
        for v in &s.objectives {
            out.push_str(&format!(" {:>16}", fmt_f64(*v)));
        }
        out.push('\n');
    }
    out
}

/// CSV version of a Pareto set.
pub fn pareto_csv(objective_names: &[&str], sols: &[Solution]) -> CsvTable {
    let mut header = vec!["height".to_string(), "width".to_string()];
    header.extend(objective_names.iter().map(|s| s.to_string()));
    let mut t = CsvTable::new(header);
    for s in sols {
        let mut row = vec![s.height.to_string(), s.width.to_string()];
        row.extend(s.objectives.iter().map(|v| fmt_f64(*v)));
        t.push(row);
    }
    t
}

/// A generic aligned key/value listing for summary blocks.
pub fn kv_block(title: &str, pairs: &[(&str, String)]) -> String {
    let width = pairs.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (k, v) in pairs {
        out.push_str(&format!("  {k:<width$} : {v}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sols() -> Vec<Solution> {
        vec![
            Solution {
                height: 128,
                width: 16,
                objectives: vec![1.5, 2.0],
            },
            Solution {
                height: 64,
                width: 32,
                objectives: vec![2.5, 1.0],
            },
        ]
    }

    #[test]
    fn table_renders_annotations() {
        let t = pareto_table("Pareto", &["energy", "cycles"], &sols());
        assert!(t.contains("128"));
        assert!(t.contains("energy"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn csv_has_all_rows() {
        let c = pareto_csv(&["e", "c"], &sols());
        assert_eq!(c.header, vec!["height", "width", "e", "c"]);
        assert_eq!(c.rows.len(), 2);
    }

    #[test]
    fn kv_alignment() {
        let s = kv_block("Summary", &[("a", "1".into()), ("longer", "2".into())]);
        assert!(s.contains("a      : 1"));
        assert!(s.contains("longer : 2"));
    }
}
