//! Reporting: heatmaps, Pareto tables, and the Figure 2–6 regeneration
//! drivers shared by the CLI, the examples and the benches.

pub mod figures;
pub mod heatmap;
pub mod table;

pub use figures::{
    fig2_heatmaps, fig2_heatmaps_for, fig2_heatmaps_planned, fig3_pareto, fig3_pareto_for,
    fig3_pareto_planned, fig4_heatmaps, fig4_heatmaps_planned, fig5_robust, fig5_robust_planned,
    fig6_equal_pe, fig6_equal_pe_planned, fig7_liveness_energy, write_fig2, write_fig3,
    write_fig4, write_fig5, write_fig6, write_fig7, write_graph_liveness, Fig2Data, Fig3Data,
    Fig5Data, Fig6Data, Fig7Row, FigureContext,
};
pub use heatmap::Heatmap;
pub use table::{kv_block, pareto_csv, pareto_table};
