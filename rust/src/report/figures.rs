//! Figure regeneration: one driver per paper artifact (Figures 2–6). Each
//! `figN_*` function computes the figure's data from scratch through the
//! sweep/pareto machinery; `write_*` companions serialize CSV + ASCII/PGM
//! into an output directory. The CLI, the examples and the benches all call
//! through here, so the paper pipeline has exactly one implementation.

use crate::config::{ArrayConfig, EnergyWeights};
use crate::model::network::Network;
use crate::model::workload::Workload;
use crate::nets;
use crate::pareto::dominance::pareto_front_indices;
use crate::pareto::nsga2::{
    nsga2, nsga2_workload_planned, nsga2_workload_planned_os, Nsga2Params, Solution,
    WorkloadObjective,
};
use crate::report::heatmap::Heatmap;
use crate::report::table::{pareto_csv, pareto_table};
use crate::sweep::grid::{equal_pe_factorizations, DimGrid};
use crate::sweep::normalize::RobustObjectives;
use crate::sweep::plan::PlanCache;
use crate::sweep::runner::{sweep_network_planned, sweep_workload_planned, SweepResult};
use crate::util::csv::{fmt_f64, CsvTable};
use crate::util::stats::min_max_normalize;
use std::collections::HashMap;
use std::io;
use std::path::Path;

/// Shared sweep context.
#[derive(Debug, Clone)]
pub struct FigureContext {
    pub grid: DimGrid,
    pub template: ArrayConfig,
    pub weights: EnergyWeights,
    pub threads: usize,
}

impl FigureContext {
    /// The paper's setup: 16..256 step 8, TPUv1-style provisioning.
    pub fn paper() -> FigureContext {
        FigureContext {
            grid: DimGrid::paper(),
            template: ArrayConfig::new(1, 1),
            weights: EnergyWeights::paper(),
            threads: crate::sweep::runner::default_threads(),
        }
    }

    /// A reduced grid for tests and smoke runs.
    pub fn smoke() -> FigureContext {
        FigureContext {
            grid: DimGrid::coarse(16, 64, 16),
            ..FigureContext::paper()
        }
    }

    /// The dense step-1 grid over the paper's range (58 081 cells) — the
    /// segmented sweep plan's headline setting (DESIGN.md §10).
    pub fn dense() -> FigureContext {
        FigureContext {
            grid: DimGrid::dense(),
            ..FigureContext::paper()
        }
    }

    fn configs(&self) -> Vec<ArrayConfig> {
        self.grid.configs(&self.template)
    }
}

impl Default for FigureContext {
    /// The paper's setup ([`FigureContext::paper`]).
    fn default() -> FigureContext {
        FigureContext::paper()
    }
}

// ---------------------------------------------------------------- Figure 2

/// Figure 2: data-movement-cost and utilization heatmaps for one network.
#[derive(Debug, Clone)]
pub struct Fig2Data {
    pub network: String,
    pub energy: Heatmap,
    pub utilization: Heatmap,
    pub sweep: SweepResult,
}

pub fn fig2_heatmaps(net_name: &str, ctx: &FigureContext) -> Fig2Data {
    let net = nets::build(net_name).unwrap_or_else(|| panic!("unknown network {net_name}"));
    fig2_heatmaps_for(&net, ctx)
}

/// [`fig2_heatmaps`] for an already-resolved network — the `camuy::api`
/// engine path, where user-registered networks sweep exactly like zoo ones.
pub fn fig2_heatmaps_for(net: &Network, ctx: &FigureContext) -> Fig2Data {
    fig2_heatmaps_planned(net, ctx, None)
}

/// [`fig2_heatmaps_for`] with an optional engine-owned [`PlanCache`], so
/// repeated sweep requests reuse segment tables (DESIGN.md §10).
pub fn fig2_heatmaps_planned(
    net: &Network,
    ctx: &FigureContext,
    plans: Option<&PlanCache>,
) -> Fig2Data {
    let sweep = sweep_network_planned(net, &ctx.configs(), &ctx.weights, ctx.threads, plans);
    let energy = Heatmap::from_grid(
        format!("{}: data movement cost E", net.name),
        ctx.grid.heights.clone(),
        ctx.grid.widths.clone(),
        sweep.energies(),
    );
    let utilization = Heatmap::from_grid(
        format!("{}: PE utilization", net.name),
        ctx.grid.heights.clone(),
        ctx.grid.widths.clone(),
        sweep.utilizations(),
    );
    Fig2Data {
        network: net.name.clone(),
        energy,
        utilization,
        sweep,
    }
}

pub fn write_fig2(data: &Fig2Data, outdir: &Path) -> io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let base = outdir.join(format!("fig2_{}", data.network));
    data.energy
        .to_csv()
        .write_to(base.with_extension("energy.csv"))?;
    data.utilization
        .to_csv()
        .write_to(base.with_extension("utilization.csv"))?;
    std::fs::write(base.with_extension("energy.pgm"), data.energy.to_pgm())?;
    std::fs::write(
        base.with_extension("txt"),
        format!("{}\n{}", data.energy.ascii(), data.utilization.ascii()),
    )
}

// ---------------------------------------------------------------- Figure 3

/// Figure 3: Pareto sets for (E, cycles) and (1 - utilization, cycles),
/// via NSGA-II, plus the exhaustive fronts for validation.
#[derive(Debug, Clone)]
pub struct Fig3Data {
    pub network: String,
    pub energy_front: Vec<Solution>,
    pub utilization_front: Vec<Solution>,
    pub exhaustive_energy_front: Vec<Solution>,
    pub exhaustive_utilization_front: Vec<Solution>,
}

pub fn fig3_pareto(net_name: &str, ctx: &FigureContext, params: &Nsga2Params) -> Fig3Data {
    let net = nets::build(net_name).unwrap_or_else(|| panic!("unknown network {net_name}"));
    fig3_pareto_for(&net, ctx, params)
}

/// [`fig3_pareto`] for an already-resolved network (the `camuy::api`
/// engine path).
pub fn fig3_pareto_for(net: &Network, ctx: &FigureContext, params: &Nsga2Params) -> Fig3Data {
    fig3_pareto_planned(net, ctx, params, None)
}

/// [`fig3_pareto_for`] with an optional engine-owned [`PlanCache`]: the
/// exhaustive sweep and both NSGA-II objective runs all evaluate through
/// one segmented plan, so a genome probe is two binary searches plus the
/// SoA combine (DESIGN.md §10) — and across requests the plan itself is a
/// cache hit.
pub fn fig3_pareto_planned(
    net: &Network,
    ctx: &FigureContext,
    params: &Nsga2Params,
    plans: Option<&PlanCache>,
) -> Fig3Data {
    let workload = Workload::of(net);

    // Without an engine cache, a request-local one still shares the single
    // segment-table build between the exhaustive sweep and both NSGA-II
    // objective runs.
    let local_plans = PlanCache::new();
    let plans = plans.unwrap_or(&local_plans);

    // Exhaustive validation fronts from the full segmented sweep; the
    // grid's config order is pairs() order, so points align with pairs.
    let sweep_points =
        sweep_workload_planned(&workload, &ctx.configs(), &ctx.weights, ctx.threads, Some(plans));
    let exhaustive = |objs: &dyn Fn(&crate::sweep::runner::SweepPoint) -> Vec<f64>| -> Vec<Solution> {
        let points: Vec<Vec<f64>> = sweep_points.iter().map(objs).collect();
        let mut sols: Vec<Solution> = pareto_front_indices(&points)
            .into_iter()
            .map(|i| Solution {
                height: sweep_points[i].height,
                width: sweep_points[i].width,
                objectives: points[i].clone(),
            })
            .collect();
        sols.sort_by(|a, b| a.objectives[0].partial_cmp(&b.objectives[0]).unwrap());
        sols
    };

    // NSGA-II consumes the workload IR directly. Every genome probe
    // routes through one segmented plan of the template's dataflow,
    // shared by both objective runs (and, with an engine cache, across
    // requests — the fetch below hits the plan the exhaustive sweep just
    // built): WS plans since §10, OS plans since §11 — no dataflow is
    // left on the cell-by-cell fallback.
    enum GenomePlan {
        Ws(std::sync::Arc<crate::sweep::plan::SegmentedWsPlan>),
        Os(std::sync::Arc<crate::sweep::plan::SegmentedOsPlan>),
    }
    let plan = match ctx.template.dataflow {
        crate::config::Dataflow::WeightStationary => GenomePlan::Ws(plans.plan(
            &workload,
            &ctx.grid.heights,
            &ctx.grid.widths,
            ctx.template.acc_capacity,
        )),
        crate::config::Dataflow::OutputStationary => {
            GenomePlan::Os(plans.plan_os(&workload, &ctx.grid.heights, &ctx.grid.widths))
        }
    };
    let front_of = |objective: WorkloadObjective| -> Vec<Solution> {
        match &plan {
            GenomePlan::Ws(p) => nsga2_workload_planned(
                &ctx.grid,
                params,
                &workload,
                &ctx.template,
                &ctx.weights,
                p,
                objective,
                ctx.threads,
            ),
            GenomePlan::Os(p) => nsga2_workload_planned_os(
                &ctx.grid,
                params,
                &workload,
                &ctx.template,
                &ctx.weights,
                p,
                objective,
                ctx.threads,
            ),
        }
    };

    Fig3Data {
        network: net.name.clone(),
        energy_front: front_of(WorkloadObjective::EnergyCycles),
        utilization_front: front_of(WorkloadObjective::InverseUtilizationCycles),
        exhaustive_energy_front: exhaustive(&|p| vec![p.energy, p.metrics.cycles as f64]),
        exhaustive_utilization_front: exhaustive(&|p| {
            vec![1.0 - p.utilization, p.metrics.cycles as f64]
        }),
    }
}

pub fn write_fig3(data: &Fig3Data, outdir: &Path) -> io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let base = outdir.join(format!("fig3_{}", data.network));
    pareto_csv(&["energy", "cycles"], &data.energy_front)
        .write_to(base.with_extension("energy_pareto.csv"))?;
    pareto_csv(&["one_minus_util", "cycles"], &data.utilization_front)
        .write_to(base.with_extension("util_pareto.csv"))?;
    let txt = format!(
        "{}\n{}",
        pareto_table(
            &format!("{}: Pareto (E vs cycles), NSGA-II", data.network),
            &["energy", "cycles"],
            &data.energy_front
        ),
        pareto_table(
            &format!("{}: Pareto (1-utilization vs cycles), NSGA-II", data.network),
            &["1-util", "cycles"],
            &data.utilization_front
        ),
    );
    std::fs::write(base.with_extension("txt"), txt)
}

// ---------------------------------------------------------------- Figure 4

/// Figure 4: data-movement heatmaps for the nine paper models.
pub fn fig4_heatmaps(ctx: &FigureContext) -> Vec<Fig2Data> {
    fig4_heatmaps_planned(ctx, None)
}

/// [`fig4_heatmaps`] with an optional engine-owned [`PlanCache`].
pub fn fig4_heatmaps_planned(ctx: &FigureContext, plans: Option<&PlanCache>) -> Vec<Fig2Data> {
    nets::PAPER_MODELS
        .iter()
        .map(|name| {
            let net = nets::build(name).unwrap_or_else(|| panic!("unknown network {name}"));
            fig2_heatmaps_planned(&net, ctx, plans)
        })
        .collect()
}

pub fn write_fig4(data: &[Fig2Data], outdir: &Path) -> io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut combined = String::new();
    for d in data {
        let base = outdir.join(format!("fig4_{}", d.network));
        d.energy.to_csv().write_to(base.with_extension("energy.csv"))?;
        std::fs::write(base.with_extension("energy.pgm"), d.energy.to_pgm())?;
        combined.push_str(&d.energy.ascii());
        combined.push('\n');
    }
    std::fs::write(outdir.join("fig4_all.txt"), combined)
}

// ---------------------------------------------------------------- Figure 5

/// Figure 5: robust Pareto over averaged normalized (E, cycles) across all
/// paper models.
#[derive(Debug, Clone)]
pub struct Fig5Data {
    pub front: Vec<Solution>,
    pub exhaustive_front: Vec<Solution>,
    pub objectives: RobustObjectives,
}

pub fn fig5_robust(ctx: &FigureContext, params: &Nsga2Params) -> Fig5Data {
    fig5_robust_planned(ctx, params, None)
}

/// [`fig5_robust`] with an optional engine-owned [`PlanCache`].
pub fn fig5_robust_planned(
    ctx: &FigureContext,
    params: &Nsga2Params,
    plans: Option<&PlanCache>,
) -> Fig5Data {
    let configs = ctx.configs();
    let sweeps: Vec<SweepResult> = nets::paper_models()
        .iter()
        .map(|net| sweep_network_planned(net, &configs, &ctx.weights, ctx.threads, plans))
        .collect();
    let objectives = RobustObjectives::from_sweeps(&sweeps);

    let lut: HashMap<(usize, usize), (f64, f64)> = (0..objectives.len())
        .map(|i| {
            (
                (objectives.heights[i], objectives.widths[i]),
                (objectives.avg_norm_energy[i], objectives.avg_norm_cycles[i]),
            )
        })
        .collect();
    let eval = |h: usize, w: usize| -> Vec<f64> {
        let (e, c) = lut[&(h, w)];
        vec![e, c]
    };

    let pairs = ctx.grid.pairs();
    let points: Vec<Vec<f64>> = pairs.iter().map(|&(h, w)| eval(h, w)).collect();
    let mut exhaustive: Vec<Solution> = pareto_front_indices(&points)
        .into_iter()
        .map(|i| Solution {
            height: pairs[i].0,
            width: pairs[i].1,
            objectives: points[i].clone(),
        })
        .collect();
    exhaustive.sort_by(|a, b| a.objectives[0].partial_cmp(&b.objectives[0]).unwrap());

    Fig5Data {
        front: nsga2(&ctx.grid, params, eval),
        exhaustive_front: exhaustive,
        objectives,
    }
}

pub fn write_fig5(data: &Fig5Data, outdir: &Path) -> io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    pareto_csv(&["avg_norm_energy", "avg_norm_cycles"], &data.front)
        .write_to(outdir.join("fig5_robust_pareto.csv"))?;
    let mut all = CsvTable::new(vec!["height", "width", "avg_norm_energy", "avg_norm_cycles"]);
    for i in 0..data.objectives.len() {
        all.push(vec![
            data.objectives.heights[i].to_string(),
            data.objectives.widths[i].to_string(),
            fmt_f64(data.objectives.avg_norm_energy[i]),
            fmt_f64(data.objectives.avg_norm_cycles[i]),
        ]);
    }
    all.write_to(outdir.join("fig5_all_points.csv"))?;
    std::fs::write(
        outdir.join("fig5_robust_pareto.txt"),
        pareto_table(
            "Robust Pareto: averaged normalized E vs cycles (all models)",
            &["avg_norm_E", "avg_norm_cycles"],
            &data.front,
        ),
    )
}

// ---------------------------------------------------------------- Figure 6

/// Figure 6: normalized data-movement cost at equal PE counts across
/// extreme aspect ratios, per model.
#[derive(Debug, Clone)]
pub struct Fig6Data {
    pub pe_budget: usize,
    /// (height, width) factorizations in ascending height order.
    pub shapes: Vec<(usize, usize)>,
    /// Per model: (name, normalized E per shape aligned with `shapes`).
    pub series: Vec<(String, Vec<f64>)>,
    /// Average across models per shape.
    pub average: Vec<f64>,
}

pub fn fig6_equal_pe(pe_budget: usize, min_dim: usize, ctx: &FigureContext) -> Fig6Data {
    fig6_equal_pe_planned(pe_budget, min_dim, ctx, None)
}

/// [`fig6_equal_pe`] with an optional engine-owned [`PlanCache`].
pub fn fig6_equal_pe_planned(
    pe_budget: usize,
    min_dim: usize,
    ctx: &FigureContext,
    plans: Option<&PlanCache>,
) -> Fig6Data {
    let shapes = equal_pe_factorizations(pe_budget, min_dim);
    let configs: Vec<ArrayConfig> = shapes
        .iter()
        .map(|&(h, w)| {
            let mut c = ctx.template.clone();
            c.height = h;
            c.width = w;
            c
        })
        .collect();

    let mut series = Vec::new();
    let mut avg = vec![0.0; shapes.len()];
    let models = nets::paper_models();
    for net in &models {
        let sweep = sweep_network_planned(net, &configs, &ctx.weights, ctx.threads, plans);
        let norm = min_max_normalize(&sweep.energies());
        for (a, n) in avg.iter_mut().zip(&norm) {
            *a += n;
        }
        series.push((net.name.clone(), norm));
    }
    for a in &mut avg {
        *a /= models.len() as f64;
    }

    Fig6Data {
        pe_budget,
        shapes,
        series,
        average: avg,
    }
}

pub fn write_fig6(data: &[Fig6Data], outdir: &Path) -> io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut t = CsvTable::new(vec!["pe_budget", "height", "width", "model", "norm_energy"]);
    let mut txt = String::new();
    for d in data {
        txt.push_str(&format!("PE budget {}\n", d.pe_budget));
        txt.push_str(&format!("{:>8} {:>8} {:>12}\n", "height", "width", "avg_norm_E"));
        for (si, &(h, w)) in d.shapes.iter().enumerate() {
            for (name, norm) in &d.series {
                t.push(vec![
                    d.pe_budget.to_string(),
                    h.to_string(),
                    w.to_string(),
                    name.clone(),
                    fmt_f64(norm[si]),
                ]);
            }
            txt.push_str(&format!(
                "{:>8} {:>8} {:>12}\n",
                h,
                w,
                fmt_f64(d.average[si])
            ));
        }
        txt.push('\n');
    }
    t.write_to(outdir.join("fig6_equal_pe.csv"))?;
    std::fs::write(outdir.join("fig6_equal_pe.txt"), txt)
}

// ---------------------------------------------------------------- Figure 7

/// Figure 7 (extension, DESIGN.md §9): liveness-corrected energy and true
/// peak residency across the paper zoo on a TPUv1-sized 128x128 instance —
/// how much the linear-chain assumption under-reports for connected
/// architectures.
#[derive(Debug, Clone)]
pub struct Fig7Row {
    pub network: String,
    pub is_chain: bool,
    /// Graph-aware peak UB residency (skip/concat tensors held live).
    pub peak_bytes: u64,
    /// The linear-chain estimate (max per-layer working set).
    pub chain_peak_bytes: u64,
    pub base_energy: f64,
    /// DRAM overhead of layers whose own working set exceeds the UB.
    pub layer_spill_energy: f64,
    /// DRAM overhead of long-lived edge tensors the liveness pass spills.
    pub edge_spill_energy: f64,
}

impl Fig7Row {
    pub fn corrected_energy(&self) -> f64 {
        self.base_energy + self.layer_spill_energy + self.edge_spill_energy
    }
}

pub fn fig7_liveness_energy(ctx: &FigureContext) -> Vec<Fig7Row> {
    let mut cfg = ctx.template.clone();
    cfg.height = 128;
    cfg.width = 128;
    nets::PAPER_MODELS
        .iter()
        .map(|name| {
            let g = nets::build_graph(name).expect("registered");
            let net = g.to_network();
            let live = g.liveness(&cfg);
            let mem = crate::model::memory::MemoryAnalysis::of(&net, &cfg);
            Fig7Row {
                network: name.to_string(),
                is_chain: g.is_chain(),
                peak_bytes: live.peak_bytes,
                chain_peak_bytes: live.chain_peak_bytes,
                base_energy: net.metrics(&cfg).energy(&ctx.weights),
                layer_spill_energy: mem.dram_energy(),
                edge_spill_energy: live.dram_energy(),
            }
        })
        .collect()
}

pub fn write_fig7(rows: &[Fig7Row], outdir: &Path) -> io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut t = CsvTable::new(vec![
        "network",
        "topology",
        "peak_bytes",
        "chain_peak_bytes",
        "inflation",
        "base_energy",
        "layer_spill_energy",
        "edge_spill_energy",
        "corrected_energy",
    ]);
    let mut txt = String::from(
        "Liveness-corrected energy (128x128, paper weights)\n",
    );
    for r in rows {
        let inflation = if r.chain_peak_bytes == 0 {
            1.0
        } else {
            r.peak_bytes as f64 / r.chain_peak_bytes as f64
        };
        t.push(vec![
            r.network.clone(),
            if r.is_chain { "chain" } else { "dag" }.to_string(),
            r.peak_bytes.to_string(),
            r.chain_peak_bytes.to_string(),
            fmt_f64(inflation),
            fmt_f64(r.base_energy),
            fmt_f64(r.layer_spill_energy),
            fmt_f64(r.edge_spill_energy),
            fmt_f64(r.corrected_energy()),
        ]);
        txt.push_str(&format!(
            "{:<16} {:>5} peak {:>12} (chain est {:>12}, {:.2}x)  E {:.3e} -> {:.3e}\n",
            r.network,
            if r.is_chain { "chain" } else { "dag" },
            r.peak_bytes,
            r.chain_peak_bytes,
            inflation,
            r.base_energy,
            r.corrected_energy(),
        ));
    }
    t.write_to(outdir.join("fig7_liveness_energy.csv"))?;
    std::fs::write(outdir.join("fig7_liveness_energy.txt"), txt)
}

/// Write one network's per-step liveness table (`camuy graph --out`).
pub fn write_graph_liveness(
    network: &str,
    live: &crate::model::graph::GraphLiveness,
    outdir: &Path,
) -> io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut t = CsvTable::new(vec![
        "step",
        "node",
        "own_bytes",
        "held_bytes",
        "total_bytes",
    ]);
    for s in &live.steps {
        t.push(vec![
            s.node.to_string(),
            s.name.clone(),
            s.own_bytes.to_string(),
            s.held_bytes.to_string(),
            s.total_bytes.to_string(),
        ]);
    }
    t.write_to(outdir.join(format!("graph_{network}.liveness.csv")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_smoke_produces_dense_heatmaps() {
        let ctx = FigureContext::smoke();
        let d = fig2_heatmaps("alexnet", &ctx);
        assert_eq!(d.energy.row_labels.len(), 4);
        assert_eq!(d.sweep.points.len(), 16);
        // Energy positive everywhere; utilization within (0, 1].
        for p in &d.sweep.points {
            assert!(p.energy > 0.0);
            assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        }
    }

    #[test]
    fn fig3_nsga2_front_is_subset_of_exhaustive() {
        let ctx = FigureContext::smoke();
        let params = Nsga2Params {
            population: 24,
            generations: 30,
            ..Default::default()
        };
        let d = fig3_pareto("alexnet", &ctx, &params);
        let exact: std::collections::HashSet<(usize, usize)> = d
            .exhaustive_energy_front
            .iter()
            .map(|s| (s.height, s.width))
            .collect();
        for s in &d.energy_front {
            assert!(
                exact.contains(&(s.height, s.width)),
                "NSGA-II returned dominated point ({}, {})",
                s.height,
                s.width
            );
        }
        assert!(!d.energy_front.is_empty());
        assert!(!d.utilization_front.is_empty());
    }

    #[test]
    fn fig6_shapes_and_series_align() {
        let mut ctx = FigureContext::smoke();
        ctx.threads = 2;
        let d = fig6_equal_pe(4096, 16, &ctx);
        assert_eq!(d.series.len(), 9);
        for (_, s) in &d.series {
            assert_eq!(s.len(), d.shapes.len());
        }
        assert_eq!(d.average.len(), d.shapes.len());
        for &a in &d.average {
            assert!((0.0..=1.0).contains(&a));
        }
    }

    #[test]
    fn writers_create_files() {
        let ctx = FigureContext::smoke();
        let tmp = std::env::temp_dir().join("camuy_fig_test");
        let _ = std::fs::remove_dir_all(&tmp);
        let d2 = fig2_heatmaps("alexnet", &ctx);
        write_fig2(&d2, &tmp).unwrap();
        assert!(tmp.join("fig2_alexnet.energy.csv").exists());
        assert!(tmp.join("fig2_alexnet.txt").exists());
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn fig7_rows_cover_the_paper_set_and_dags_inflate() {
        let ctx = FigureContext::smoke();
        let rows = fig7_liveness_energy(&ctx);
        assert_eq!(rows.len(), 9);
        for r in &rows {
            assert!(r.peak_bytes >= r.chain_peak_bytes, "{}", r.network);
            assert!(r.corrected_energy() >= r.base_energy, "{}", r.network);
        }
        // The connectivity families hold tensors live; the plain chains
        // match their linear estimate exactly.
        let by_name = |n: &str| rows.iter().find(|r| r.network == n).unwrap();
        assert!(by_name("resnet152").peak_bytes > by_name("resnet152").chain_peak_bytes);
        assert!(by_name("densenet201").peak_bytes > by_name("densenet201").chain_peak_bytes);
        assert_eq!(by_name("vgg16").peak_bytes, by_name("vgg16").chain_peak_bytes);
        let tmp = std::env::temp_dir().join("camuy_fig7_test");
        let _ = std::fs::remove_dir_all(&tmp);
        write_fig7(&rows, &tmp).unwrap();
        assert!(tmp.join("fig7_liveness_energy.csv").exists());
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
