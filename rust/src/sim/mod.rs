//! Event-driven cycle-level simulator with Perfetto trace export
//! (DESIGN.md §13).
//!
//! The pipeline units of the modeled TPU-like array — Weight Fetcher,
//! Systolic Data Setup FIFOs, PE array wavefront, Accumulator Array and
//! Unified Buffer — run as *contexts* joined by bounded [`channel`]s and
//! advanced by a monotone [`event`] queue, in the style of dataflow
//! abstract machines: timing emerges from channel capacities and each
//! unit's initiation interval, not from a closed-form formula. A full
//! network's tiling schedule is simulated tile-by-tile for both dataflows
//! (reusing `model::schedule`'s `WsSchedule`/`OsSchedule`), with
//! independent per-layer simulations fanned out over `runtime::pool`.
//!
//! This makes the simulator a *second, independent oracle* for the whole
//! analytic chain: `tests/property_sim.rs` proves simulated total cycles
//! and every `MovementCounters` field byte-identical to
//! `ws_metrics`/`os_metrics` on random shapes and configs — which the
//! segmented and vectorized sweep plans are in turn property-tested
//! against. Where the closed forms are algebra, the simulator is an
//! executable machine whose stalls are *measured* (time blocked on the
//! weight channel), so a bug in either side breaks the equality.
//!
//! Every context emits Perfetto-compatible trace slices and counter
//! tracks behind the zero-cost-when-disabled [`trace::TraceSink`]; see
//! `camuy emulate --trace out.json` and load the file at
//! <https://ui.perfetto.dev>.

pub mod channel;
pub mod event;
mod network;
mod os;
pub mod trace;
mod ws;

use crate::config::{ArrayConfig, Dataflow};
use crate::metrics::Metrics;
use crate::model::schedule::GemmShape;

pub use network::{
    gemm_fifo_depth, network_fifo_depth, simulate_network, LayerSim, NetworkSim, SimOptions,
};
pub use trace::{perfetto_trace, TraceBuffer, TraceSink, Track};

/// Result of simulating one GEMM's full tiling schedule.
#[derive(Debug, Clone, Default)]
pub struct GemmSim {
    pub metrics: Metrics,
    /// Peak rows staged in the Systolic Data Setup FIFOs.
    pub max_fifo_depth: usize,
    /// Events processed by the queue (the events/sec bench denominator).
    pub events: u64,
}

/// Simulate one GEMM under `cfg`'s dataflow. An empty GEMM is zero work.
pub fn simulate_gemm(gemm: GemmShape, cfg: &ArrayConfig, trace: &mut TraceSink) -> GemmSim {
    if gemm.is_empty() {
        return GemmSim::default();
    }
    match cfg.dataflow {
        Dataflow::WeightStationary => ws::simulate_ws(gemm, cfg, trace),
        Dataflow::OutputStationary => os::simulate_os(gemm, cfg, trace),
    }
}
