//! The output-stationary pipeline as channel-connected contexts
//! (DESIGN.md §13).
//!
//! ```text
//!  Streamer (UB reads) ──tiles (cap 1)──► PE Array ──notices──► Accumulator
//!                                                                    │
//!                                         Unified Buffer ◄──chunks───┘
//! ```
//!
//! OS keeps each `(mt x nt)` tile of C pinned in the PEs while A and W
//! stream through for the full reduction depth, then drains the finished
//! tile down the array's columns. Unlike WS there is no double-buffered
//! load to hide: operand streaming is concurrent with compute (the
//! streamer's slice *is* the compute window), and the drain is *not*
//! overlapped — the next tile cannot start until the PEs are free, so
//! tiles serialize end-to-start and the measured stall is structurally
//! zero. The tile channel still carries one tile of lookahead; the
//! backpressure mechanism is identical to the WS pipeline even though
//! this dataflow never exercises it. Totals are compared field-by-field
//! against `os_metrics`.
//!
//! Counter ownership mirrors the WS pipeline: the streamer counts UB
//! operand reads, the array the in-fabric traffic (including the drain's
//! shift-down hops — they happen between PEs), the accumulator its port
//! crossings, the UB the final writes.

use crate::config::ArrayConfig;
use crate::metrics::{Metrics, MovementCounters};
use crate::model::schedule::{GemmShape, OsSchedule, OsTile};
use crate::sim::channel::{Channel, Recvd, Sent};
use crate::sim::event::{CtxId, EventQueue};
use crate::sim::trace::{Counter, Track, TraceSink};
use crate::sim::GemmSim;

const STREAMER: CtxId = 0;
const ARRAY: CtxId = 1;
const ACC: CtxId = 2;
const UB: CtxId = 3;

struct TileMsg {
    tile: OsTile,
    idx: u64,
}

struct AccMsg {
    tile: OsTile,
    /// When the drain reached the bottom edge (= tile end).
    end: u64,
}

struct ChunkMsg {
    mt: usize,
    nt: usize,
    at: u64,
}

pub(crate) fn simulate_os(gemm: GemmShape, cfg: &ArrayConfig, trace: &mut TraceSink) -> GemmSim {
    let sched = OsSchedule::new(gemm, cfg);
    let (h, w) = (cfg.height as u64, cfg.width as u64);
    let big_k = gemm.k as u64;

    let mut tiles_ch: Channel<TileMsg> = Channel::new("tiles", 1);
    let mut notices: Channel<AccMsg> = Channel::new("notices", 1);
    let mut chunks: Channel<ChunkMsg> = Channel::new("chunks", 1);

    let mut tile_iter = sched.tiles();
    let mut staged: Option<OsTile> = tile_iter.next();
    let mut next_idx: u64 = 0;

    // Array state.
    let mut computing: Option<(OsTile, u64)> = None; // (tile, end)
    let mut pending_notice: Option<AccMsg> = None;
    let mut started: u64 = 0;
    let mut last_end: u64 = 0;
    let mut max_staged: usize = 0;

    let resident_base = (gemm.m as u64 * gemm.k as u64 * cfg.act_bits as u64
        + gemm.k as u64 * gemm.n as u64 * cfg.weight_bits as u64)
        / 8;
    let out_word_bytes = cfg.out_bits as u64 / 8;
    let mut out_bytes_written: u64 = 0;
    if trace.is_on() {
        trace.counter(Counter::UbResidency, 0, resident_base as f64);
    }

    let mut mv = MovementCounters::default();
    let mut q = EventQueue::new();
    q.push(0, STREAMER);
    q.push(0, ARRAY);
    q.push(0, ACC);
    q.push(0, UB);

    while let Some((now, ctx)) = q.pop() {
        match ctx {
            STREAMER => {
                while let Some(tile) = staged {
                    match tiles_ch.try_send(
                        TileMsg {
                            tile,
                            idx: next_idx,
                        },
                        STREAMER,
                    ) {
                        Sent::Ok { woke } => {
                            let (mt, nt) = (tile.mt as u64, tile.nt as u64);
                            mv.ub_act_reads += big_k * mt;
                            mv.ub_weight_reads += big_k * nt;
                            max_staged = max_staged.max(tile.mt);
                            next_idx += 1;
                            staged = tile_iter.next();
                            if let Some(c) = woke {
                                q.push(now, c);
                            }
                        }
                        Sent::Full => break, // one tile of lookahead is the limit
                    }
                }
            }
            ARRAY => loop {
                if let Some(msg) = pending_notice.take() {
                    match notices.try_send(msg, ARRAY) {
                        Sent::Ok { woke } => {
                            if let Some(c) = woke {
                                q.push(now, c);
                            }
                        }
                        Sent::Full => unreachable!("notice channel full with an eager consumer"),
                    }
                }
                if let Some((tile, end)) = computing {
                    if now < end {
                        break;
                    }
                    computing = None;
                    last_end = end;
                    pending_notice = Some(AccMsg { tile, end });
                    continue;
                }
                match tiles_ch.try_recv(ARRAY) {
                    Recvd::Ok { msg, woke } => {
                        if let Some(c) = woke {
                            q.push(now, c);
                        }
                        let t = msg.tile;
                        let (mt, nt) = (t.mt as u64, t.nt as u64);
                        mv.inter_pe_act += big_k * mt * (w - 1);
                        mv.inter_pe_weight += big_k * nt * (mt - 1);
                        // Drain: the output at row r descends (h - 1 - r)
                        // hops between PEs.
                        mv.inter_pe_psum += nt * (mt * (h - 1) - mt * (mt - 1) / 2);
                        mv.intra_pe += 5 * big_k * mt * nt + 2 * mt * nt;
                        let stream = big_k + mt + nt - 2;
                        let d = t.compute_cycles(); // stream + full-height drain
                        trace.slice(Track::Array, now, d, || {
                            format!(
                                "tile {} i{} j{} ({}x{} K={})",
                                msg.idx, t.i, t.j, t.mt, t.nt, t.k
                            )
                        });
                        if trace.is_on() {
                            // Operand streams are concurrent with compute:
                            // the streamer/SDS slices span the stream window.
                            trace.slice(Track::Fetcher, now, big_k + nt - 1, || {
                                format!("stream W K x {} (tile {})", t.nt, msg.idx)
                            });
                            trace.slice(Track::Setup, now, big_k + mt - 1, || {
                                format!("stream A {} x K (tile {})", t.mt, msg.idx)
                            });
                            trace.counter(Counter::FifoOccupancy, now, t.mt as f64);
                            trace.counter(Counter::FifoOccupancy, now + big_k + mt - 1, 0.0);
                            let util = (mt * nt) as f64 / (h * w) as f64;
                            trace.counter(Counter::PeUtilization, now, util);
                            trace.counter(Counter::PeUtilization, now + d, 0.0);
                            trace.slice(Track::Accumulator, now + stream, h, || {
                                format!("drain {}x{} (tile {})", t.mt, t.nt, msg.idx)
                            });
                        }
                        computing = Some((t, now + d));
                        started += 1;
                        q.push(now + d, ARRAY);
                    }
                    Recvd::Empty => break,
                }
            },
            ACC => loop {
                match notices.try_recv(ACC) {
                    Recvd::Ok { msg, woke } => {
                        if let Some(c) = woke {
                            q.push(now, c);
                        }
                        let t = msg.tile;
                        let words = t.mt as u64 * t.nt as u64;
                        // Outputs cross the array boundary exactly once.
                        mv.aa_writes += words;
                        mv.aa_reads += words;
                        match chunks.try_send(
                            ChunkMsg {
                                mt: t.mt,
                                nt: t.nt,
                                at: msg.end,
                            },
                            ACC,
                        ) {
                            Sent::Ok { woke } => {
                                if let Some(c) = woke {
                                    q.push(now, c);
                                }
                            }
                            Sent::Full => {
                                unreachable!("chunk channel full with an eager consumer")
                            }
                        }
                    }
                    Recvd::Empty => break,
                }
            },
            UB => loop {
                match chunks.try_recv(UB) {
                    Recvd::Ok { msg, woke } => {
                        if let Some(c) = woke {
                            q.push(now, c);
                        }
                        let words = msg.mt as u64 * msg.nt as u64;
                        mv.ub_out_writes += words;
                        out_bytes_written += words * out_word_bytes;
                        trace.slice(Track::UnifiedBuffer, msg.at, msg.mt as u64, || {
                            format!("writeback {}x{}", msg.mt, msg.nt)
                        });
                        trace.counter(
                            Counter::UbResidency,
                            msg.at,
                            (resident_base + out_bytes_written) as f64,
                        );
                    }
                    Recvd::Empty => break,
                }
            },
            _ => unreachable!(),
        }
    }

    debug_assert!(staged.is_none() && computing.is_none());
    debug_assert_eq!(started, sched.tile_count());

    GemmSim {
        metrics: Metrics {
            cycles: last_end,
            stall_cycles: 0,
            macs: gemm.macs(),
            passes: started,
            movements: mv,
        },
        max_fifo_depth: max_staged,
        events: q.processed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataflow;
    use crate::model::gemm::os_metrics;

    fn cfg(h: usize, w: usize) -> ArrayConfig {
        ArrayConfig::new(h, w).with_dataflow(Dataflow::OutputStationary)
    }

    #[test]
    fn single_tile_matches_closed_form() {
        let g = GemmShape::new(3, 7, 4);
        let c = cfg(4, 4);
        let sim = simulate_os(g, &c, &mut TraceSink::Off);
        assert_eq!(sim.metrics, os_metrics(g, &c));
        assert_eq!(sim.max_fifo_depth, 3);
    }

    #[test]
    fn tiled_matches_closed_form() {
        let g = GemmShape::new(37, 29, 23);
        let c = cfg(8, 4);
        let sim = simulate_os(g, &c, &mut TraceSink::Off);
        assert_eq!(sim.metrics, os_metrics(g, &c));
        assert_eq!(sim.max_fifo_depth, 8);
    }

    #[test]
    fn degenerate_arrays_match_closed_form() {
        for (h, w) in [(1, 16), (16, 1), (1, 1)] {
            let g = GemmShape::new(9, 11, 7);
            let c = cfg(h, w);
            let sim = simulate_os(g, &c, &mut TraceSink::Off);
            assert_eq!(sim.metrics, os_metrics(g, &c), "array {h}x{w}");
        }
    }

    #[test]
    fn one_array_slice_per_tile() {
        let g = GemmShape::new(10, 5, 12);
        let c = cfg(4, 4);
        let mut sink = TraceSink::on(1 << 16);
        let sim = simulate_os(g, &c, &mut sink);
        let buf = sink.take().unwrap();
        let array_slices = buf
            .slices
            .iter()
            .filter(|s| s.track == Track::Array)
            .count() as u64;
        assert_eq!(array_slices, sim.metrics.passes);
    }
}
