//! Perfetto trace export (DESIGN.md §13).
//!
//! The simulator records slices and counter samples into a [`TraceBuffer`]
//! behind the [`TraceSink`] enum. `TraceSink::Off` is the zero-cost path:
//! every recording method is `#[inline]` and reduces to one tag check —
//! slice names are built by closures that are never called when tracing is
//! off, so the disabled simulator allocates nothing per pass. The
//! trace-overhead bench (`benches/sim_trace.rs`) holds this to account.
//!
//! [`perfetto_trace`] assembles per-layer buffers into the Chrome/Perfetto
//! JSON trace-event format (the legacy `{"traceEvents": [...]}` schema,
//! which Perfetto loads natively): one *process* per network layer
//! (`"M"`/`process_name`), one *thread* per pipeline unit
//! (`"M"`/`thread_name` — Weight Fetcher, Systolic Data Setup, PE Array,
//! Accumulator Array, Unified Buffer), `"X"` complete slices with
//! microsecond timestamps (1 simulated cycle ≡ 1 µs), and `"C"` counter
//! events for SDS occupancy, UB residency and PE utilization. Load the
//! file at <https://ui.perfetto.dev> (or `chrome://tracing`) unmodified.

use crate::util::json::Json;

/// One pipeline unit = one named Perfetto thread track.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Track {
    Fetcher,
    Setup,
    Array,
    Accumulator,
    UnifiedBuffer,
}

impl Track {
    pub const ALL: [Track; 5] = [
        Track::Fetcher,
        Track::Setup,
        Track::Array,
        Track::Accumulator,
        Track::UnifiedBuffer,
    ];

    /// Human-readable track name shown in the Perfetto UI (and grepped by
    /// the CI trace-smoke step — keep in sync with `.github/workflows`).
    pub fn name(self) -> &'static str {
        match self {
            Track::Fetcher => "Weight Fetcher",
            Track::Setup => "Systolic Data Setup",
            Track::Array => "PE Array",
            Track::Accumulator => "Accumulator Array",
            Track::UnifiedBuffer => "Unified Buffer",
        }
    }

    /// Stable thread id; tid 0 is reserved for counter tracks.
    pub fn tid(self) -> u64 {
        match self {
            Track::Fetcher => 1,
            Track::Setup => 2,
            Track::Array => 3,
            Track::Accumulator => 4,
            Track::UnifiedBuffer => 5,
        }
    }
}

/// One counter track per process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Rows staged in the Systolic Data Setup FIFOs.
    FifoOccupancy,
    /// Bytes resident in the Unified Buffer (inputs + weights + outputs
    /// written back so far).
    UbResidency,
    /// Active PEs / total PEs of the pass that just started.
    PeUtilization,
}

impl Counter {
    pub fn name(self) -> &'static str {
        match self {
            Counter::FifoOccupancy => "SDS occupancy (rows)",
            Counter::UbResidency => "UB residency (bytes)",
            Counter::PeUtilization => "PE utilization",
        }
    }
}

/// A completed `"X"` slice in layer-local cycles.
#[derive(Debug, Clone)]
pub struct Slice {
    pub track: Track,
    pub name: String,
    pub start: u64,
    pub dur: u64,
}

/// A `"C"` counter sample in layer-local cycles.
#[derive(Debug, Clone, Copy)]
pub struct CounterSample {
    pub counter: Counter,
    pub at: u64,
    pub value: f64,
}

/// Recorded events for one simulated GEMM, capped at `cap` slices so a
/// hostile request cannot make the service materialize millions of events
/// (the wire caps `max_slices`; metrics are unaffected by truncation).
#[derive(Debug)]
pub struct TraceBuffer {
    pub slices: Vec<Slice>,
    pub counters: Vec<CounterSample>,
    cap: usize,
    dropped: u64,
}

impl TraceBuffer {
    pub fn new(cap: usize) -> Self {
        Self {
            slices: Vec::new(),
            counters: Vec::new(),
            cap,
            dropped: 0,
        }
    }

    /// True when the slice cap was hit and events were dropped.
    pub fn truncated(&self) -> bool {
        self.dropped > 0
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// The recording façade handed to every context. `Off` must cost nothing:
/// all methods are `#[inline]` one-branch no-ops, and name closures are
/// only invoked (and their `String`s only allocated) when recording.
#[derive(Debug)]
pub enum TraceSink {
    Off,
    On(Box<TraceBuffer>),
}

impl TraceSink {
    pub fn on(cap: usize) -> Self {
        TraceSink::On(Box::new(TraceBuffer::new(cap)))
    }

    #[inline]
    pub fn is_on(&self) -> bool {
        matches!(self, TraceSink::On(_))
    }

    /// Record a complete slice; `name` is evaluated lazily.
    #[inline]
    pub fn slice(&mut self, track: Track, start: u64, dur: u64, name: impl FnOnce() -> String) {
        if let TraceSink::On(buf) = self {
            if buf.slices.len() >= buf.cap {
                buf.dropped += 1;
                return;
            }
            buf.slices.push(Slice {
                track,
                name: name(),
                start,
                dur,
            });
        }
    }

    /// Record a counter sample (counters ride along with slices and are
    /// capped at twice the slice budget — two samples per slice).
    #[inline]
    pub fn counter(&mut self, counter: Counter, at: u64, value: f64) {
        if let TraceSink::On(buf) = self {
            if buf.counters.len() >= buf.cap.saturating_mul(2) {
                return;
            }
            buf.counters.push(CounterSample { counter, at, value });
        }
    }

    /// Take the recorded buffer, leaving the sink off.
    pub fn take(&mut self) -> Option<TraceBuffer> {
        match std::mem::replace(self, TraceSink::Off) {
            TraceSink::Off => None,
            TraceSink::On(buf) => Some(*buf),
        }
    }
}

/// One layer's worth of trace data plus its placement in the network run.
pub struct TraceProcess<'a> {
    /// Process name shown in the UI, e.g. `"3: conv2 (x2 groups)"`.
    pub name: String,
    /// Cycle offset of this layer's start in the network timeline; all
    /// layer-local event times are shifted by this.
    pub offset: u64,
    pub buffer: &'a TraceBuffer,
}

/// The `"M"` process-name metadata event naming process `pid`.
fn process_meta_event(pid: f64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("process_name")),
        ("pid", Json::num(pid)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// One `"C"` counter sample: Perfetto draws these as a per-name counter
/// track, with the value riding in `args.value`. Shared by the
/// simulator document assembler and the engine-telemetry export
/// ([`crate::telemetry::TelemetrySnapshot::perfetto_counters`]).
fn counter_event(pid: f64, name: &str, ts: f64, value: f64) -> Json {
    Json::obj(vec![
        ("ph", Json::str("C")),
        ("name", Json::str(name)),
        ("pid", Json::num(pid)),
        ("ts", Json::num(ts)),
        ("args", Json::obj(vec![("value", Json::num(value))])),
    ])
}

/// Wrap a finished event list in the trace-event document envelope.
fn trace_document(events: Vec<Json>) -> Json {
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ns")),
        ("traceEvents", Json::arr(events)),
    ])
}

/// Assemble the Perfetto JSON trace-event document. `pid` is 1-based per
/// process, `ts` is in microseconds with 1 cycle ≡ 1 µs.
pub fn perfetto_trace(processes: &[TraceProcess<'_>]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (idx, p) in processes.iter().enumerate() {
        let pid = (idx + 1) as f64;
        events.push(process_meta_event(pid, &p.name));
        for t in Track::ALL {
            events.push(Json::obj(vec![
                ("ph", Json::str("M")),
                ("name", Json::str("thread_name")),
                ("pid", Json::num(pid)),
                ("tid", Json::num(t.tid() as f64)),
                ("args", Json::obj(vec![("name", Json::str(t.name()))])),
            ]));
        }
        for s in &p.buffer.slices {
            events.push(Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str(s.name.clone())),
                ("pid", Json::num(pid)),
                ("tid", Json::num(s.track.tid() as f64)),
                ("ts", Json::num((p.offset + s.start) as f64)),
                ("dur", Json::num(s.dur as f64)),
            ]));
        }
        for c in &p.buffer.counters {
            let ts = (p.offset + c.at) as f64;
            events.push(counter_event(pid, c.counter.name(), ts, c.value));
        }
    }
    trace_document(events)
}

/// Assemble a counter-only Perfetto document: one process named
/// `process` holding one counter track per `(name, value)` sample. Each
/// track is sampled at t=0 and `t=ts_us` so it renders as a level over
/// the process lifetime rather than an invisible point. This is the
/// writer behind the engine-telemetry export (DESIGN.md §14); it shares
/// the event shapes with [`perfetto_trace`], so both documents load
/// side by side in ui.perfetto.dev.
pub fn perfetto_counter_doc(process: &str, ts_us: u64, samples: &[(String, f64)]) -> Json {
    let pid = 1.0;
    let mut events = vec![process_meta_event(pid, process)];
    for (name, value) in samples {
        events.push(counter_event(pid, name, 0.0, *value));
        events.push(counter_event(pid, name, ts_us.max(1) as f64, *value));
    }
    trace_document(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_sink_records_nothing_and_never_calls_name() {
        let mut sink = TraceSink::Off;
        sink.slice(Track::Array, 0, 5, || unreachable!("name built while off"));
        sink.counter(Counter::PeUtilization, 0, 1.0);
        assert!(sink.take().is_none());
    }

    #[test]
    fn cap_truncates_slices_but_counts_drops() {
        let mut sink = TraceSink::on(2);
        for i in 0..5 {
            sink.slice(Track::Array, i, 1, || format!("pass {i}"));
        }
        let buf = sink.take().unwrap();
        assert_eq!(buf.slices.len(), 2);
        assert!(buf.truncated());
        assert_eq!(buf.dropped(), 3);
    }

    #[test]
    fn perfetto_document_shape() {
        let mut sink = TraceSink::on(16);
        sink.slice(Track::Fetcher, 0, 3, || "load tile".into());
        sink.slice(Track::Array, 3, 7, || "pass 0".into());
        sink.counter(Counter::PeUtilization, 3, 0.5);
        let buf = sink.take().unwrap();
        let doc = perfetto_trace(&[TraceProcess {
            name: "1: conv".into(),
            offset: 100,
            buffer: &buf,
        }]);
        let text = doc.to_string_compact();
        // Round-trips through our own parser.
        let back = Json::parse(&text).unwrap();
        let events = back.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 1 process_name + 5 thread_name + 2 slices + 1 counter.
        assert_eq!(events.len(), 9);
        for t in Track::ALL {
            assert!(text.contains(t.name()));
        }
        // Slice times shifted by the layer offset.
        assert!(text.contains("\"ts\":103"));
    }
}
