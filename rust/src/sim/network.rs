//! Whole-network simulation: the tiling schedule of every layer is run
//! through the event-driven pipeline, layer sims dispatched independently
//! across `runtime::pool`, then stitched into one serialized timeline
//! (layers execute back-to-back on a single array, exactly like
//! `Coordinator::run_inference_cached`). The stitched totals must equal
//! the analytic `Workload` evaluation byte-for-byte — property-tested in
//! `tests/property_sim.rs`.
//!
//! Grouped layers (depthwise/grouped convs) run `groups` identical
//! block-diagonal GEMMs back to back. The simulator runs the pipeline
//! once and scales by the group count (the metrics algebra guarantees
//! `m * g == m + ... + m` exactly); the trace shows group 0 in full
//! detail plus one aggregate slice covering the remaining groups.

use crate::config::{ArrayConfig, Dataflow};
use crate::metrics::Metrics;
use crate::model::schedule::{GemmShape, WsSchedule};
use crate::model::Network;
use crate::runtime::pool;
use crate::sim::trace::{perfetto_trace, Slice, TraceBuffer, TraceProcess, TraceSink, Track};
use crate::sim::{simulate_gemm, GemmSim};
use crate::util::json::Json;

/// Simulation options. `trace_cap` enables tracing with a per-layer slice
/// budget; `None` runs with `TraceSink::Off` (the zero-cost path).
#[derive(Debug, Clone, Copy, Default)]
pub struct SimOptions {
    pub trace_cap: Option<usize>,
}

impl SimOptions {
    pub fn traced(cap: usize) -> Self {
        Self {
            trace_cap: Some(cap),
        }
    }
}

/// One layer's simulated execution.
#[derive(Debug)]
pub struct LayerSim {
    pub name: String,
    pub gemm: GemmShape,
    pub groups: u64,
    /// Placement in the serialized network timeline.
    pub start_cycle: u64,
    pub end_cycle: u64,
    /// Whole-layer metrics (single-group sim scaled by `groups`).
    pub metrics: Metrics,
    /// Peak rows staged in the Systolic Data Setup FIFOs.
    pub max_fifo_depth: usize,
    /// Events the layer's queue processed.
    pub events: u64,
    pub trace: Option<TraceBuffer>,
}

/// A full network run through the event-driven simulator.
#[derive(Debug)]
pub struct NetworkSim {
    pub network: String,
    pub layers: Vec<LayerSim>,
    pub total: Metrics,
    pub max_fifo_depth: usize,
    pub events: u64,
}

impl NetworkSim {
    /// Assemble the Perfetto trace-event document: one process per layer,
    /// offset into the serialized timeline. Empty (but valid) when the
    /// run was untraced.
    pub fn perfetto(&self) -> Json {
        let procs: Vec<TraceProcess<'_>> = self
            .layers
            .iter()
            .enumerate()
            .filter_map(|(i, l)| {
                l.trace.as_ref().map(|buffer| TraceProcess {
                    name: if l.groups > 1 {
                        format!("{}: {} (x{} groups)", i + 1, l.name, l.groups)
                    } else {
                        format!("{}: {}", i + 1, l.name)
                    },
                    offset: l.start_cycle,
                    buffer,
                })
            })
            .collect();
        perfetto_trace(&procs)
    }

    /// True when any layer hit its slice budget.
    pub fn truncated(&self) -> bool {
        self.layers
            .iter()
            .any(|l| l.trace.as_ref().is_some_and(|t| t.truncated()))
    }

    /// Total recorded slices across all layers.
    pub fn slice_count(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.trace.as_ref().map_or(0, |t| t.slices.len() as u64))
            .sum()
    }
}

/// Simulate every layer of `net` (fanned out over the worker pool — the
/// layers are independent; only the timeline stitching is serial).
pub fn simulate_network(
    net: &Network,
    cfg: &ArrayConfig,
    threads: usize,
    opts: &SimOptions,
) -> NetworkSim {
    struct LayerOut {
        sim: GemmSim,
        trace: Option<TraceBuffer>,
        gemm: GemmShape,
        groups: u64,
    }

    let outs: Vec<LayerOut> = pool::parallel_map(net.layers.len(), threads, |i| {
        // Cancellation granularity is one simulated layer; the faultpoint
        // lets tests panic mid-simulation (DESIGN.md §15).
        crate::robust::checkpoint();
        crate::faultpoint::hit("sim.layer");
        let (gemm, groups) = net.layers[i].gemm();
        let groups = groups as u64;
        let mut sink = match opts.trace_cap {
            Some(cap) => TraceSink::on(cap),
            None => TraceSink::Off,
        };
        let sim = simulate_gemm(gemm, cfg, &mut sink);
        let mut trace = sink.take();
        if let Some(buf) = &mut trace {
            if groups > 1 && sim.metrics.cycles > 0 {
                // Groups 2..G repeat group 1's schedule exactly; collapse
                // them into one aggregate slice so the trace stays bounded.
                buf.slices.push(Slice {
                    track: Track::Array,
                    name: format!("groups 2..{groups} (x{} repeats)", groups - 1),
                    start: sim.metrics.cycles,
                    dur: sim.metrics.cycles * (groups - 1),
                });
            }
        }
        LayerOut {
            sim,
            trace,
            gemm,
            groups,
        }
    });

    let mut layers = Vec::with_capacity(outs.len());
    let mut clock: u64 = 0;
    let mut total = Metrics::default();
    let mut max_fifo_depth = 0usize;
    let mut events: u64 = 0;
    for (i, out) in outs.into_iter().enumerate() {
        let metrics = out.sim.metrics * out.groups;
        let start = clock;
        clock += metrics.cycles;
        total += metrics;
        max_fifo_depth = max_fifo_depth.max(out.sim.max_fifo_depth);
        events += out.sim.events;
        layers.push(LayerSim {
            name: net.layers[i].name.clone(),
            gemm: out.gemm,
            groups: out.groups,
            start_cycle: start,
            end_cycle: clock,
            metrics,
            max_fifo_depth: out.sim.max_fifo_depth,
            events: out.sim.events,
            trace: out.trace,
        });
    }

    NetworkSim {
        network: net.name.clone(),
        layers,
        total,
        max_fifo_depth,
        events,
    }
}

/// Closed-form peak SDS staging depth for one GEMM — what the simulator
/// measures as `max_fifo_depth`, derivable without running it: the
/// largest M-chunk any pass stages (WS: the accumulator row budget caps
/// chunks, and only the col-tile width changes the budget; OS: a tile
/// stages at most `min(M, h)` rows).
pub fn gemm_fifo_depth(gemm: GemmShape, cfg: &ArrayConfig) -> usize {
    if gemm.is_empty() {
        return 0;
    }
    match cfg.dataflow {
        Dataflow::WeightStationary => {
            let s = WsSchedule::new(gemm, cfg);
            // Only two col-tile classes exist (full width and the tail),
            // so the max over j needs only the first and last.
            let d0 = gemm.m.min(s.row_budget(0));
            let dt = gemm.m.min(s.row_budget(s.tc - 1));
            d0.max(dt)
        }
        Dataflow::OutputStationary => gemm.m.min(cfg.height),
    }
}

/// Peak SDS staging depth across a whole network (groups share the depth
/// of a single block-diagonal GEMM).
pub fn network_fifo_depth(net: &Network, cfg: &ArrayConfig) -> usize {
    net.layers
        .iter()
        .map(|l| gemm_fifo_depth(l.gemm().0, cfg))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Workload;

    #[test]
    fn network_sim_matches_workload_eval() {
        let net = crate::nets::build("alexnet").unwrap();
        let cfg = ArrayConfig::new(32, 32);
        let sim = simulate_network(&net, &cfg, 1, &SimOptions::default());
        let analytic = Workload::of(&net).eval(&cfg);
        assert_eq!(sim.total, analytic);
        // Timeline is gap-free and serialized.
        let mut clock = 0;
        for l in &sim.layers {
            assert_eq!(l.start_cycle, clock);
            clock = l.end_cycle;
        }
        assert_eq!(clock, sim.total.cycles);
    }

    #[test]
    fn fifo_depth_closed_form_matches_sim() {
        let net = crate::nets::build("alexnet").unwrap();
        for df in [Dataflow::WeightStationary, Dataflow::OutputStationary] {
            let cfg = ArrayConfig::new(16, 16)
                .with_acc_capacity(256)
                .with_dataflow(df);
            let sim = simulate_network(&net, &cfg, 1, &SimOptions::default());
            assert_eq!(sim.max_fifo_depth, network_fifo_depth(&net, &cfg));
        }
    }

    #[test]
    fn traced_run_is_metric_identical_and_offsets_shift() {
        let net = crate::nets::build("alexnet").unwrap();
        let cfg = ArrayConfig::new(64, 64);
        let plain = simulate_network(&net, &cfg, 1, &SimOptions::default());
        let traced = simulate_network(&net, &cfg, 2, &SimOptions::traced(1 << 14));
        assert_eq!(plain.total, traced.total);
        assert!(traced.slice_count() > 0);
        let doc = traced.perfetto().to_string_compact();
        assert!(doc.contains("PE Array"));
        assert!(doc.contains("traceEvents"));
        // A grouped layer (alexnet conv2 has groups=2) gets the aggregate
        // repeat slice.
        assert!(doc.contains("repeats"));
    }
}
