//! The weight-stationary pipeline as five channel-connected contexts
//! (DESIGN.md §13).
//!
//! ```text
//!              credits (cap 1, seeded with 1 token)
//!        ┌─────────────────────────────────────────┐
//!        ▼                                         │
//!  Weight Fetcher ──weights (cap 1)──► PE Array ───┤
//!                                        ▲         └─notices (cap 1)─► Accumulator
//!  Systolic Data Setup ──acts (cap 1)────┘                                  │
//!                                                   Unified Buffer ◄─chunks─┘
//!                                                        (cap 1)
//! ```
//!
//! Timing emerges from the channel interlock, not from a formula:
//!
//! * The weight channel's capacity of 1 *is* the array's single set of
//!   shadow registers; the credit channel's capacity of 1 *is* the rule
//!   that at most one tile load runs ahead of the wavefront. The fetcher
//!   starts loading pass `p+1`'s tile the moment the array begins pass `p`
//!   (the credit is granted at compute start), and its initiation interval
//!   is one weight row per cycle — `k_t` cycles per tile.
//! * The array begins a pass when *both* its weight tile and its staged
//!   activation chunk have arrived: `start(p) = max(end(p-1), fetch_done(p))`
//!   with `fetch_done(p) = start(p-1) + k_t(p)` — exactly the recurrence
//!   `ws_metrics_ref` walks, which is why the property tests can demand
//!   byte-identical cycle counts. Waiting on the weight channel after the
//!   first pass is the *measured* stall time.
//! * Writeback (Accumulator → Unified Buffer) is architecturally
//!   overlapped with the next pass, so its trace slices run concurrently
//!   with compute and contribute no cycles — matching the closed form,
//!   where drains are free.
//!
//! Each context owns the movement counters of the traffic it causes:
//! the fetcher counts weight-fetch UB reads and shift-down hops, the SDS
//! counts activation UB reads, the array counts the MAC-side traffic, the
//! accumulator its port crossings, and the UB the final output writes.
//! Their sum is compared field-by-field against `ws_metrics`.

use crate::config::ArrayConfig;
use crate::metrics::{Metrics, MovementCounters};
use crate::model::schedule::{GemmShape, Pass, WsSchedule};
use crate::sim::channel::{Channel, Recvd, Sent};
use crate::sim::event::{CtxId, EventQueue};
use crate::sim::trace::{Counter, Track, TraceSink};
use crate::sim::GemmSim;

const FETCHER: CtxId = 0;
const SETUP: CtxId = 1;
const ARRAY: CtxId = 2;
const ACC: CtxId = 3;
const UB: CtxId = 4;

/// Sequential cursor over a [`WsSchedule`]'s pass stream. Each context
/// walks the schedule at its own rate, so each holds its own cursor —
/// passes are generated on the fly and never materialized (a deep sweep
/// shape can have hundreds of thousands of passes).
struct PassCursor<'a> {
    s: &'a WsSchedule,
    j: usize,
    c: usize,
    i: usize,
    idx: u64,
}

impl<'a> PassCursor<'a> {
    fn new(s: &'a WsSchedule) -> Self {
        Self {
            s,
            j: 0,
            c: 0,
            i: 0,
            idx: 0,
        }
    }

    fn peek(&self) -> Option<Pass> {
        if self.j >= self.s.tc {
            return None;
        }
        let r = self.s.row_budget(self.j);
        Some(Pass {
            j: self.j,
            n_t: self.s.n_t(self.j),
            c: self.c,
            row_start: self.c * r,
            mc: self.s.chunk_rows(self.j, self.c),
            i: self.i,
            k_t: self.s.k_t(self.i),
            array_height: self.s.height,
            array_width: self.s.width,
            writeback_after: self.i == self.s.tr - 1,
        })
    }

    fn advance(&mut self) {
        self.idx += 1;
        self.i += 1;
        if self.i == self.s.tr {
            self.i = 0;
            self.c += 1;
            if self.c == self.s.chunks(self.j) {
                self.c = 0;
                self.j += 1;
            }
        }
    }
}

/// Weight tile delivered to the array's shadow registers.
struct WeightMsg {
    pass: Pass,
    idx: u64,
}

/// Activation chunk staged in the SDS FIFOs.
struct ActMsg {
    idx: u64,
    staged_at: u64,
}

/// A finished pass crossing into the accumulator array.
struct AccMsg {
    pass: Pass,
    end: u64,
}

/// A drained output chunk headed back to the UB.
struct ChunkMsg {
    mc: usize,
    n_t: usize,
    at: u64,
}

struct Fetcher<'a> {
    cursor: PassCursor<'a>,
    loading: Option<(Pass, u64, u64)>, // (pass, idx, done_at) — idx packed below
}

struct Setup<'a> {
    cursor: PassCursor<'a>,
    max_staged: usize,
}

struct ArrayCtx {
    computing: Option<(Pass, u64)>, // (pass, end)
    pending: Option<AccMsg>,
    prev_end: u64,
    started: u64,
    stall: u64,
    last_end: u64,
}

struct AccCtx {
    pending: Option<ChunkMsg>,
}

struct UbCtx {
    resident_base: u64,
    out_bytes_written: u64,
    out_word_bytes: u64,
}

pub(crate) fn simulate_ws(gemm: GemmShape, cfg: &ArrayConfig, trace: &mut TraceSink) -> GemmSim {
    let sched = WsSchedule::new(gemm, cfg);
    let (h, w) = (cfg.height as u64, cfg.width as u64);

    let mut credits: Channel<()> = Channel::new("credits", 1);
    let mut weights: Channel<WeightMsg> = Channel::new("weights", 1);
    let mut acts: Channel<ActMsg> = Channel::new("acts", 1);
    let mut notices: Channel<AccMsg> = Channel::new("notices", 1);
    let mut chunks: Channel<ChunkMsg> = Channel::new("chunks", 1);
    // Seed the credit channel: the first load needs no preceding pass.
    let Sent::Ok { .. } = credits.try_send((), ARRAY) else {
        unreachable!()
    };

    let mut fetcher = Fetcher {
        cursor: PassCursor::new(&sched),
        loading: None,
    };
    let mut setup = Setup {
        cursor: PassCursor::new(&sched),
        max_staged: 0,
    };
    let mut array = ArrayCtx {
        computing: None,
        pending: None,
        prev_end: 0,
        started: 0,
        stall: 0,
        last_end: 0,
    };
    let mut acc = AccCtx { pending: None };
    let mut ub = UbCtx {
        resident_base: (gemm.m as u64 * gemm.k as u64 * cfg.act_bits as u64
            + gemm.k as u64 * gemm.n as u64 * cfg.weight_bits as u64)
            / 8,
        out_bytes_written: 0,
        out_word_bytes: cfg.out_bits as u64 / 8,
    };
    if trace.is_on() {
        trace.counter(Counter::UbResidency, 0, ub.resident_base as f64);
    }

    let mut mv = MovementCounters::default();
    let mut q = EventQueue::new();
    // Every context gets one initial wake-up: producers start their first
    // work items, and pure consumers (accumulator, UB) park themselves on
    // their empty input channels so later sends know whom to wake.
    q.push(0, SETUP);
    q.push(0, FETCHER);
    q.push(0, ARRAY);
    q.push(0, ACC);
    q.push(0, UB);

    while let Some((now, ctx)) = q.pop() {
        match ctx {
            FETCHER => loop {
                if let Some((pass, idx, done)) = fetcher.loading {
                    if now < done {
                        break; // wake at `done` already queued
                    }
                    match weights.try_send(WeightMsg { pass, idx }, FETCHER) {
                        Sent::Ok { woke } => {
                            let (kt, nt) = (pass.k_t as u64, pass.n_t as u64);
                            mv.ub_weight_reads += kt * nt;
                            // Shift-down hops while the tile descends into
                            // place, plus main+shadow register writes.
                            mv.inter_pe_weight += nt * kt * (kt - 1) / 2;
                            mv.intra_pe += 2 * kt * nt;
                            let load = pass.load_cycles();
                            trace.slice(Track::Fetcher, done - load, load, || {
                                format!("load W {}x{} (pass {})", pass.k_t, pass.n_t, idx)
                            });
                            fetcher.loading = None;
                            if let Some(c) = woke {
                                q.push(now, c);
                            }
                        }
                        Sent::Full => break, // parked on the weight channel
                    }
                } else {
                    let Some(pass) = fetcher.cursor.peek() else {
                        break; // all tiles fetched
                    };
                    match credits.try_recv(FETCHER) {
                        Recvd::Ok { woke, .. } => {
                            debug_assert!(woke.is_none(), "credit channel never fills");
                            let done = now + pass.load_cycles();
                            fetcher.loading = Some((pass, fetcher.cursor.idx, done));
                            fetcher.cursor.advance();
                            q.push(done, FETCHER);
                            break;
                        }
                        Recvd::Empty => break, // parked on credits
                    }
                }
            },
            SETUP => loop {
                let Some(pass) = setup.cursor.peek() else {
                    break;
                };
                match acts.try_send(
                    ActMsg {
                        idx: setup.cursor.idx,
                        staged_at: now,
                    },
                    SETUP,
                ) {
                    Sent::Ok { woke } => {
                        mv.ub_act_reads += pass.mc as u64 * pass.k_t as u64;
                        setup.max_staged = setup.max_staged.max(pass.mc);
                        trace.counter(Counter::FifoOccupancy, now, pass.mc as f64);
                        setup.cursor.advance();
                        if let Some(c) = woke {
                            q.push(now, c);
                        }
                    }
                    Sent::Full => break, // one chunk staged ahead is the limit
                }
            },
            ARRAY => loop {
                if let Some(msg) = array.pending.take() {
                    match notices.try_send(msg, ARRAY) {
                        Sent::Ok { woke } => {
                            if let Some(c) = woke {
                                q.push(now, c);
                            }
                        }
                        Sent::Full => {
                            // Re-park: `try_send` moved the message, so it
                            // must be rebuilt — impossible here because the
                            // accumulator always drains same-cycle, but
                            // handled for robustness.
                            unreachable!("notice channel full with an eager consumer");
                        }
                    }
                }
                if let Some((pass, end)) = array.computing {
                    if now < end {
                        break;
                    }
                    array.computing = None;
                    array.prev_end = end;
                    array.last_end = end;
                    array.pending = Some(AccMsg { pass, end });
                    continue; // deliver the notice, then look for more work
                }
                // Idle: a pass starts only when both inputs are present.
                if weights.peek().is_none() {
                    let Recvd::Empty = weights.try_recv(ARRAY) else {
                        unreachable!()
                    };
                    break;
                }
                if acts.peek().is_none() {
                    let Recvd::Empty = acts.try_recv(ARRAY) else {
                        unreachable!()
                    };
                    break;
                }
                let Recvd::Ok { msg: wm, woke: w1 } = weights.try_recv(ARRAY) else {
                    unreachable!()
                };
                let Recvd::Ok { msg: am, woke: w2 } = acts.try_recv(ARRAY) else {
                    unreachable!()
                };
                debug_assert_eq!(wm.idx, am.idx, "fetcher and SDS walk the same schedule");
                for c in [w1, w2].into_iter().flatten() {
                    q.push(now, c);
                }
                let pass = wm.pass;
                if array.started > 0 {
                    // Waiting on the weight channel past the previous
                    // pass's end is the double-buffering stall; the first
                    // pass's exposed load is startup, not stall.
                    array.stall += now - array.prev_end;
                }
                // Compute begins: the shadow registers are free again, so
                // grant the fetcher its next-load credit.
                match credits.try_send((), ARRAY) {
                    Sent::Ok { woke } => {
                        if let Some(c) = woke {
                            q.push(now, c);
                        }
                    }
                    Sent::Full => unreachable!("at most one credit in flight"),
                }
                let (mc, kt, nt) = (pass.mc as u64, pass.k_t as u64, pass.n_t as u64);
                mv.inter_pe_act += mc * kt * (w - 1);
                mv.inter_pe_psum += mc * nt * (h - 1);
                mv.intra_pe += 5 * mc * kt * nt;
                let d = pass.compute_cycles();
                trace.slice(Track::Array, now, d, || {
                    format!(
                        "pass {} j{} c{} i{} ({}r x {}x{})",
                        wm.idx, pass.j, pass.c, pass.i, pass.mc, pass.k_t, pass.n_t
                    )
                });
                if trace.is_on() {
                    let util = (kt * nt) as f64 / (h * w) as f64;
                    trace.counter(Counter::PeUtilization, now, util);
                    trace.counter(Counter::PeUtilization, now + d, 0.0);
                    // The staged chunk issues one row per cycle once the
                    // wavefront starts; the FIFOs are empty `mc` in.
                    trace.counter(Counter::FifoOccupancy, now + pass.mc as u64, 0.0);
                    // SDS slice: staged while the previous pass ran, fully
                    // issued `mc` cycles into this one.
                    trace.slice(
                        Track::Setup,
                        am.staged_at,
                        now + pass.mc as u64 - am.staged_at,
                        || format!("stage {} rows (pass {})", pass.mc, wm.idx),
                    );
                }
                array.computing = Some((pass, now + d));
                array.started += 1;
                q.push(now + d, ARRAY);
            },
            ACC => loop {
                if let Some(msg) = acc.pending.take() {
                    match chunks.try_send(msg, ACC) {
                        Sent::Ok { woke } => {
                            if let Some(c) = woke {
                                q.push(now, c);
                            }
                        }
                        Sent::Full => unreachable!("chunk channel full with an eager consumer"),
                    }
                }
                match notices.try_recv(ACC) {
                    Recvd::Ok { msg, woke } => {
                        if let Some(c) = woke {
                            q.push(now, c);
                        }
                        let p = msg.pass;
                        let (mc, nt) = (p.mc as u64, p.n_t as u64);
                        mv.aa_writes += mc * nt;
                        if p.writeback_after {
                            mv.aa_reads += mc * nt;
                            // Drain one output row per cycle — overlapped
                            // with the next pass, so the slice runs past
                            // `end` without adding cycles.
                            trace.slice(Track::Accumulator, msg.end, mc as u64, || {
                                format!("drain {}x{} (j{} c{})", p.mc, p.n_t, p.j, p.c)
                            });
                            acc.pending = Some(ChunkMsg {
                                mc: p.mc,
                                n_t: p.n_t,
                                at: msg.end,
                            });
                        }
                    }
                    Recvd::Empty => break,
                }
            },
            UB => loop {
                match chunks.try_recv(UB) {
                    Recvd::Ok { msg, woke } => {
                        if let Some(c) = woke {
                            q.push(now, c);
                        }
                        let words = msg.mc as u64 * msg.n_t as u64;
                        mv.ub_out_writes += words;
                        ub.out_bytes_written += words * ub.out_word_bytes;
                        trace.slice(Track::UnifiedBuffer, msg.at, msg.mc as u64, || {
                            format!("writeback {}x{}", msg.mc, msg.n_t)
                        });
                        trace.counter(
                            Counter::UbResidency,
                            msg.at,
                            (ub.resident_base + ub.out_bytes_written) as f64,
                        );
                    }
                    Recvd::Empty => break,
                }
            },
            _ => unreachable!(),
        }
    }

    debug_assert!(fetcher.cursor.peek().is_none(), "fetcher drained");
    debug_assert!(setup.cursor.peek().is_none(), "SDS drained");
    debug_assert!(array.computing.is_none() && weights.is_empty() && acts.is_empty());
    debug_assert_eq!(array.started, sched.pass_count());

    GemmSim {
        metrics: Metrics {
            cycles: array.last_end,
            stall_cycles: array.stall,
            macs: gemm.macs(),
            passes: array.started,
            movements: mv,
        },
        max_fifo_depth: setup.max_staged,
        events: q.processed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gemm::ws_metrics_ref;

    fn cfg(h: usize, w: usize, acc: usize) -> ArrayConfig {
        ArrayConfig::new(h, w).with_acc_capacity(acc)
    }

    #[test]
    fn single_pass_matches_reference() {
        let g = GemmShape::new(5, 8, 4);
        let c = cfg(8, 4, 4096);
        let sim = simulate_ws(g, &c, &mut TraceSink::Off);
        assert_eq!(sim.metrics, ws_metrics_ref(g, &c));
        assert_eq!(sim.max_fifo_depth, 5);
    }

    #[test]
    fn multi_tile_matches_reference() {
        let g = GemmShape::new(37, 29, 23);
        let c = cfg(8, 4, 32);
        let sim = simulate_ws(g, &c, &mut TraceSink::Off);
        assert_eq!(sim.metrics, ws_metrics_ref(g, &c));
    }

    #[test]
    fn degenerate_arrays_match_reference() {
        for (h, w) in [(1, 16), (16, 1), (1, 1)] {
            let g = GemmShape::new(9, 11, 7);
            let c = cfg(h, w, 16);
            let sim = simulate_ws(g, &c, &mut TraceSink::Off);
            assert_eq!(sim.metrics, ws_metrics_ref(g, &c), "array {h}x{w}");
        }
    }

    #[test]
    fn trace_records_one_array_slice_per_pass() {
        let g = GemmShape::new(10, 20, 12);
        let c = cfg(8, 4, 64);
        let mut sink = TraceSink::on(1 << 16);
        let sim = simulate_ws(g, &c, &mut sink);
        let buf = sink.take().unwrap();
        let array_slices = buf
            .slices
            .iter()
            .filter(|s| s.track == Track::Array)
            .count() as u64;
        assert_eq!(array_slices, sim.metrics.passes);
        let fetch_slices = buf
            .slices
            .iter()
            .filter(|s| s.track == Track::Fetcher)
            .count() as u64;
        assert_eq!(fetch_slices, sim.metrics.passes);
        assert!(!buf.truncated());
    }

    #[test]
    fn tracing_does_not_change_metrics() {
        let g = GemmShape::new(19, 33, 21);
        let c = cfg(8, 8, 48);
        let off = simulate_ws(g, &c, &mut TraceSink::Off);
        let mut sink = TraceSink::on(1 << 16);
        let on = simulate_ws(g, &c, &mut sink);
        assert_eq!(off.metrics, on.metrics);
        assert_eq!(off.max_fifo_depth, on.max_fifo_depth);
    }
}
