//! Bounded single-producer/single-consumer channels joining the simulated
//! contexts (DESIGN.md §13).
//!
//! A channel's capacity *is* the hardware buffering it models: the weight
//! channel has capacity 1 because the array owns exactly one set of shadow
//! registers, and the credit channel (array → fetcher) has capacity 1
//! because at most one tile load may run ahead of the compute wavefront.
//! Backpressure therefore falls out of `try_send` failing on a full
//! channel, not out of any timing formula.
//!
//! Blocking is cooperative: a context whose `try_send`/`try_recv` fails
//! parks itself (the channel remembers *who* is blocked), and the opposite
//! operation returns the parked context's id so the caller can schedule
//! its wake-up at the current cycle. Channels never touch the event queue
//! directly — that keeps them pure data structures, unit-testable without
//! a scheduler.

use crate::sim::event::CtxId;
use std::collections::VecDeque;

/// Result of a [`Channel::try_send`].
#[derive(Debug, PartialEq, Eq)]
pub enum Sent {
    /// Enqueued; `woke` is a consumer that was parked on the empty channel.
    Ok { woke: Option<CtxId> },
    /// Channel full — the sender is now parked and must retry when woken.
    Full,
}

/// Result of a [`Channel::try_recv`].
#[derive(Debug, PartialEq, Eq)]
pub enum Recvd<T> {
    /// Dequeued; `woke` is a producer that was parked on the full channel.
    Ok { msg: T, woke: Option<CtxId> },
    /// Channel empty — the receiver is now parked and must retry when woken.
    Empty,
}

/// A bounded FIFO with parked-context bookkeeping and occupancy stats.
#[derive(Debug)]
pub struct Channel<T> {
    name: &'static str,
    cap: usize,
    q: VecDeque<T>,
    peak: usize,
    pushes: u64,
    blocked_send: Option<CtxId>,
    blocked_recv: Option<CtxId>,
}

impl<T> Channel<T> {
    pub fn new(name: &'static str, cap: usize) -> Self {
        assert!(cap >= 1, "channel {name} needs capacity >= 1");
        Self {
            name,
            cap,
            q: VecDeque::with_capacity(cap),
            peak: 0,
            pushes: 0,
            blocked_send: None,
            blocked_recv: None,
        }
    }

    /// Try to enqueue `msg`; on failure the calling context `me` is parked.
    pub fn try_send(&mut self, msg: T, me: CtxId) -> Sent {
        if self.q.len() >= self.cap {
            self.blocked_send = Some(me);
            return Sent::Full;
        }
        self.q.push_back(msg);
        self.pushes += 1;
        self.peak = self.peak.max(self.q.len());
        Sent::Ok {
            woke: self.blocked_recv.take(),
        }
    }

    /// Try to dequeue; on failure the calling context `me` is parked.
    pub fn try_recv(&mut self, me: CtxId) -> Recvd<T> {
        match self.q.pop_front() {
            Some(msg) => Recvd::Ok {
                msg,
                woke: self.blocked_send.take(),
            },
            None => {
                self.blocked_recv = Some(me);
                Recvd::Empty
            }
        }
    }

    /// Peek the head without consuming (used when a context needs two
    /// channels simultaneously and must not hold a popped message while
    /// the other is empty).
    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// High-water mark of occupancy over the whole run.
    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn pushes(&self) -> u64 {
        self.pushes
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_send_recv_with_wakeups() {
        let mut ch: Channel<u32> = Channel::new("t", 1);
        assert_eq!(ch.try_send(10, 7), Sent::Ok { woke: None });
        // Full: sender 7 parks.
        assert_eq!(ch.try_send(11, 7), Sent::Full);
        // Recv drains and wakes the parked sender.
        assert_eq!(
            ch.try_recv(9),
            Recvd::Ok {
                msg: 10,
                woke: Some(7)
            }
        );
        // Empty: receiver 9 parks; next send wakes it.
        assert_eq!(ch.try_recv(9), Recvd::Empty);
        assert_eq!(ch.try_send(12, 7), Sent::Ok { woke: Some(9) });
        assert_eq!(ch.peak(), 1);
        assert_eq!(ch.pushes(), 2);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut ch: Channel<u8> = Channel::new("t", 3);
        ch.try_send(1, 0);
        ch.try_send(2, 0);
        assert_eq!(ch.peak(), 2);
        ch.try_recv(1);
        ch.try_recv(1);
        assert_eq!(ch.peak(), 2);
        assert!(ch.is_empty());
    }

    #[test]
    fn peek_does_not_consume() {
        let mut ch: Channel<u8> = Channel::new("t", 2);
        ch.try_send(5, 0);
        assert_eq!(ch.peek(), Some(&5));
        assert_eq!(ch.len(), 1);
    }
}
