//! The monotone event queue driving the simulator (DESIGN.md §13).
//!
//! Every context wake-up is an `(time, context)` event. The queue is a
//! min-heap ordered by `(time, sequence)`: ties at the same cycle pop in
//! insertion order, which makes the simulation fully deterministic — the
//! property tests compare its outputs byte-for-byte against the closed
//! forms, so nondeterminism anywhere would show up as flaky exactness.
//!
//! Monotonicity is a hard invariant, not a convention: `pop` asserts that
//! time never moves backwards. A context that tried to schedule a wake-up
//! in its own past would silently corrupt the cycle count; here it panics
//! in debug and release builds alike.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Identifies one simulated context (Weight Fetcher, SDS, array, ...).
/// Plain index — each pipeline defines its own constants.
pub type CtxId = usize;

/// Min-heap of `(time, ctx)` wake-ups with deterministic tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u64, CtxId)>>,
    seq: u64,
    now: u64,
    processed: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `ctx` to run at `time`. Scheduling at the current time is
    /// fine (same-cycle wake-ups pop after everything already queued for
    /// that cycle); scheduling in the past is a bug and is asserted away
    /// at `pop` time.
    pub fn push(&mut self, time: u64, ctx: CtxId) {
        self.heap.push(Reverse((time, self.seq, ctx)));
        self.seq += 1;
    }

    /// Pop the next wake-up, advancing (never rewinding) simulated time.
    pub fn pop(&mut self) -> Option<(u64, CtxId)> {
        let Reverse((time, _, ctx)) = self.heap.pop()?;
        assert!(
            time >= self.now,
            "event queue lost monotonicity: popped t={time} after t={}",
            self.now
        );
        self.now = time;
        self.processed += 1;
        Some((time, ctx))
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Events processed so far — the denominator of the events/sec bench.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5, 0);
        q.push(1, 1);
        q.push(3, 2);
        assert_eq!(q.pop(), Some((1, 1)));
        assert_eq!(q.pop(), Some((3, 2)));
        assert_eq!(q.pop(), Some((5, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        q.push(7, 3);
        q.push(7, 1);
        q.push(7, 2);
        let order: Vec<CtxId> = std::iter::from_fn(|| q.pop()).map(|(_, c)| c).collect();
        assert_eq!(order, vec![3, 1, 2]);
    }

    #[test]
    fn tracks_now_and_processed() {
        let mut q = EventQueue::new();
        q.push(2, 0);
        q.push(9, 0);
        q.pop();
        assert_eq!(q.now(), 2);
        q.push(2, 1); // same-cycle wake-up while at t=2 is legal
        q.pop();
        q.pop();
        assert_eq!(q.now(), 9);
        assert_eq!(q.processed(), 3);
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "monotonicity")]
    fn scheduling_in_the_past_panics() {
        let mut q = EventQueue::new();
        q.push(10, 0);
        q.pop();
        q.push(3, 0); // in the past of t=10
        q.pop();
    }
}
