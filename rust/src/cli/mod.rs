//! The `camuy` command-line interface — a thin adapter over the typed
//! query API: every subcommand builds a request struct, calls the
//! long-lived [`crate::api::Engine`], and formats the typed response.
//!
//! ```text
//! camuy zoo [--net NAME]            list networks / dump one as JSON spec
//! camuy emulate --net resnet152 --height 128 --width 64 [--per-layer] [--json]
//! camuy emulate --net resnet152 --trace out.json   event-driven sim + Perfetto trace
//! camuy sweep   --net resnet152 [--grid paper|smoke] [--out DIR]   (Fig 2)
//! camuy pareto  --net resnet152 [--out DIR]                        (Fig 3)
//! camuy heatmaps [--out DIR]                                       (Fig 4)
//! camuy robust  [--out DIR]                                        (Fig 5)
//! camuy equal-pe [--budget N]... [--out DIR]                       (Fig 6)
//! camuy figures --out DIR          regenerate every paper figure
//! camuy memory  --net vgg16 [--graph]  per-layer UB working sets and spills
//! camuy graph   --net resnet50 [--arrays N]  DAG stats, liveness, schedule
//! camuy serve   [--listen ADDR]    batched JSON-lines request server
//! camuy stats   [--connect ADDR]   engine telemetry (counters, latency, caches)
//! camuy verify  [--artifacts DIR]  three-way artifact verification
//! camuy --version                  print the crate version
//! ```

pub mod args;

use crate::api::{
    Engine, EqualPeRequest, EvalRequest, EvalResponse, GraphRequest, MemoryRequest,
    ParetoRequest, ServeOptions, StatsRequest, SweepRequest, SweepSpec, TraceRequest,
};
use crate::config::{ArrayConfig, Dataflow, EnergyWeights};
use crate::pareto::nsga2::Nsga2Params;
use crate::report::figures;
use crate::report::{kv_block, pareto_table};
use crate::runtime::{Manifest, PjrtRuntime};
use crate::util::human_count;
use crate::util::json::Json;
use args::{Args, Schema};
use std::path::{Path, PathBuf};

const SCHEMA: Schema = Schema {
    options: &[
        "net", "height", "width", "acc", "batch", "arrays", "grid", "out", "budget", "min-dim",
        "threads", "artifacts", "dataflow", "seed", "energy-model", "listen", "batch-max",
        "trace", "max-slices", "connect", "perfetto", "snapshot", "restore", "snapshot-secs",
        "admission-max", "idle-secs", "max-conns", "write-cap-bytes",
    ],
    flags: &[
        "json", "per-layer", "smoke", "dense", "help", "quiet", "verbose", "version", "graph",
        "buckets", "threaded",
    ],
};

pub fn usage() -> &'static str {
    "camuy — Configurable Accelerator Modeling for Understanding and Analysis

USAGE: camuy <command> [options]

COMMANDS:
  zoo                 list registered networks (--net NAME dumps its JSON spec)
  emulate             run one network on one array configuration
  sweep               Fig 2: heatmaps for one network over the grid
  pareto              Fig 3: NSGA-II Pareto sets for one network
  heatmaps            Fig 4: data-movement heatmaps for all paper models
  robust              Fig 5: robust Pareto across all paper models
  equal-pe            Fig 6: equal-PE-count aspect-ratio study
  figures             regenerate every paper figure into --out
  memory              per-layer UB working sets, spills, DRAM overhead
  graph               DAG connectivity: liveness-true residency + branch-
                      parallel multi-array schedule (see DESIGN.md §9)
  serve               batched JSON-lines request server (stdin, or --listen)
  stats               engine telemetry: request counts/latency, caches, pool
  verify              three-way check: reference = emulator = PJRT artifact

OPTIONS:
  --net NAME          network (see `camuy zoo`)
  --batch N           inference batch size (emulate/graph; default 1)
  --arrays N          multi-array bank size (emulate/graph; default 1)
  --graph             memory: attach the graph-aware liveness pass
  --height H --width W --acc N   array geometry / accumulator entries
  --dataflow ws|os    dataflow concept (default ws)
  --energy-model paper|dally14nm  Equation-1 weights
  --grid paper|smoke|dense  sweep grid (961-point paper, 4x4 smoke, or the
                      58081-cell step-1 dense grid; --dense is shorthand)
  --budget N          equal-PE budget (repeatable; default 4096 16384 65536)
  --min-dim N         equal-PE minimum edge length (default 8)
  --out DIR           output directory for CSV/PGM/TXT (default results/)
  --threads N         sweep / serve parallelism (default: cores)
  --listen ADDR       serve on a TCP address instead of stdin/stdout
  --batch-max N       serve: most requests coalesced per batch (default 64)
  --admission-max N   serve: compute requests admitted concurrently before
                      load shedding answers `overloaded` (default 256)
  --idle-secs N       serve: close a connection idle this long with a
                      structured `idle_timeout` error (default 60; 0 = off)
  --max-conns N       serve: stop accepting after N connections (default:
                      serve forever; mostly for tests and benchmarks)
  --write-cap-bytes N serve: shed a connection once this many response
                      bytes sit unread in its write queue (default 8 MiB)
  --threaded          serve: legacy thread-per-connection TCP front end
                      instead of the event loop (the non-Linux default)
  --snapshot FILE     serve: write the registered-network store here
                      periodically and on graceful SIGTERM drain
  --snapshot-secs N   serve: seconds between snapshot writes (default 30)
  --restore FILE      serve: load a snapshot before serving (a missing
                      file logs a warning and starts cold)
  --connect ADDR      stats: query a running `camuy serve --listen` server
  --perfetto FILE     stats: also write a Perfetto counter-trace JSON file
  --buckets           stats: include raw histogram buckets (with --json)
  --artifacts DIR     AOT artifact directory (default artifacts/)
  --trace FILE        emulate: run the event-driven simulator (DESIGN.md §13)
                      and write a Perfetto trace-event JSON file — open it at
                      https://ui.perfetto.dev (Open trace file) to see per-unit
                      tracks, FIFO occupancy, UB residency and PE utilization
  --max-slices N      trace: per-layer slice budget (default 65536)
  --per-layer --json --smoke --quiet --verbose --version --help
"
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    let args = match Args::parse(argv, &SCHEMA) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return 2;
        }
    };
    crate::util::logging::init(crate::util::logging::level_from_verbosity(
        args.flag("quiet"),
        if args.flag("verbose") { 1 } else { 0 },
    ));
    if args.flag("version") {
        println!("camuy {}", env!("CARGO_PKG_VERSION"));
        return 0;
    }
    if args.flag("help") || args.command.is_none() {
        println!("{}", usage());
        return if args.command.is_none() && !args.flag("help") { 2 } else { 0 };
    }
    let engine = Engine::new();
    let cmd = args.command.clone().unwrap();
    let result = match cmd.as_str() {
        "zoo" => cmd_zoo(&engine, &args),
        "emulate" => cmd_emulate(&engine, &args),
        "sweep" => cmd_sweep(&engine, &args),
        "pareto" => cmd_pareto(&engine, &args),
        "heatmaps" => cmd_heatmaps(&engine, &args),
        "robust" => cmd_robust(&engine, &args),
        "equal-pe" => cmd_equal_pe(&engine, &args),
        "figures" => cmd_figures(&engine, &args),
        "memory" => cmd_memory(&engine, &args),
        "graph" => cmd_graph(&engine, &args),
        "serve" => cmd_serve(&engine, &args),
        "stats" => cmd_stats(&engine, &args),
        "verify" => cmd_verify(&args),
        other => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

// ------------------------------------------------------- request builders

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt("out").unwrap_or("results"))
}

fn energy_weights(args: &Args) -> anyhow::Result<EnergyWeights> {
    Ok(match args.opt("energy-model").unwrap_or("paper") {
        "paper" => EnergyWeights::paper(),
        "dally14nm" => EnergyWeights::dally_14nm(),
        other => anyhow::bail!("unknown energy model '{other}' (paper|dally14nm)"),
    })
}

fn template_config(args: &Args, def_h: usize, def_w: usize) -> anyhow::Result<ArrayConfig> {
    let mut cfg = ArrayConfig::new(
        args.opt_usize("height", def_h)?,
        args.opt_usize("width", def_w)?,
    );
    cfg.acc_capacity = args.opt_usize("acc", cfg.acc_capacity)?;
    if let Some(df) = args.opt("dataflow") {
        cfg.dataflow =
            Dataflow::parse(df).ok_or_else(|| anyhow::anyhow!("unknown dataflow '{df}'"))?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn require_net(args: &Args) -> anyhow::Result<String> {
    args.opt("net")
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("--net is required (see `camuy zoo`)"))
}

fn sweep_spec(args: &Args) -> anyhow::Result<SweepSpec> {
    let mut spec = match args.opt("grid").unwrap_or("paper") {
        "paper" => SweepSpec::default(),
        "smoke" => SweepSpec::smoke(),
        "dense" => SweepSpec::dense(),
        g => anyhow::bail!("unknown grid '{g}' (paper|smoke|dense)"),
    };
    if args.flag("smoke") {
        spec.grid = SweepSpec::smoke().grid;
    }
    if args.flag("dense") {
        spec.grid = crate::sweep::grid::DimGrid::dense();
    }
    spec.template = template_config(args, 1, 1)?;
    spec.threads = args.opt_usize("threads", spec.threads)?;
    spec.weights = energy_weights(args)?;
    Ok(spec)
}

/// `--batch N` if given (`None` keeps the network's registered batch).
fn opt_batch(args: &Args) -> anyhow::Result<Option<usize>> {
    match args.opt("batch") {
        None => Ok(None),
        Some(_) => Ok(Some(args.opt_usize("batch", 1)?)),
    }
}

fn eval_request(args: &Args) -> anyhow::Result<EvalRequest> {
    Ok(EvalRequest {
        net: require_net(args)?,
        batch: opt_batch(args)?,
        arrays: args.opt_usize("arrays", 1)?,
        config: template_config(args, 128, 128)?,
        weights: energy_weights(args)?,
        per_layer: args.flag("per-layer"),
    })
}

// ------------------------------------------------------------ subcommands

fn cmd_zoo(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    if let Some(name) = args.opt("net") {
        println!("{}", engine.network_spec(name)?.to_string_pretty());
        return Ok(());
    }
    println!(
        "{:<18} {:>6} {:>10} {:>10} {:>8} {:>15}",
        "network", "source", "params", "MACs", "layers", "distinct GEMMs"
    );
    for e in engine.list_networks() {
        println!(
            "{:<18} {:>6} {:>10} {:>10} {:>8} {:>15}",
            e.name,
            e.source.as_str(),
            human_count(e.params),
            human_count(e.macs),
            e.layers,
            e.distinct_gemms,
        );
    }
    Ok(())
}

fn cmd_emulate(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.opt("trace") {
        return cmd_emulate_trace(engine, args, Path::new(path));
    }
    let req = eval_request(args)?;
    let resp = engine.eval(&req)?;
    if args.flag("json") {
        println!("{}", resp.to_json().to_string_pretty());
        return Ok(());
    }
    match resp {
        EvalResponse::Multi {
            network,
            config,
            metrics,
            utilization,
            energy,
        } => {
            println!(
                "{}",
                kv_block(
                    &format!("{network} on {}x [{}]", config.arrays, config.array),
                    &[
                        ("makespan cycles", human_count(metrics.makespan_cycles)),
                        ("busy cycles (sum)", human_count(metrics.total.cycles)),
                        ("MACs", human_count(metrics.total.macs)),
                        ("bank utilization", format!("{utilization:.4}")),
                        ("energy (Eq.1)", format!("{energy:.4e}")),
                        ("M_UB", human_count(metrics.total.movements.m_ub())),
                    ]
                )
            );
        }
        EvalResponse::Single {
            run,
            energy,
            max_fifo_depth,
            per_layer,
        } => {
            println!(
                "{}",
                kv_block(
                    &format!("{} on {}", run.network, run.config),
                    &[
                        ("cycles", human_count(run.total.cycles)),
                        ("stall cycles", human_count(run.total.stall_cycles)),
                        ("MACs", human_count(run.total.macs)),
                        ("passes", human_count(run.total.passes)),
                        ("utilization", format!("{:.4}", run.utilization())),
                        ("max FIFO depth", human_count(max_fifo_depth as u64)),
                        ("energy (Eq.1)", format!("{energy:.4e}")),
                        ("M_UB", human_count(run.total.movements.m_ub())),
                        ("M_INTER_PE", human_count(run.total.movements.m_inter_pe())),
                        ("M_AA", human_count(run.total.movements.m_aa())),
                        ("M_INTRA_PE", human_count(run.total.movements.m_intra_pe())),
                        (
                            "UB bandwidth (B/cy)",
                            format!("{:.2}", run.bandwidth.ub_total())
                        ),
                        (
                            "UB spills",
                            if run.ub_violations.is_empty() {
                                "none".to_string()
                            } else {
                                format!("{} layers exceed the UB", run.ub_violations.len())
                            }
                        ),
                    ]
                )
            );
            if let Some(pl) = per_layer {
                println!(
                    "top layers by cycles (machine balance {:.1} MACs/B; {:.0}% of layers memory-bound):",
                    pl.machine_balance,
                    100.0 * pl.memory_bound_share
                );
                let roofline_of = |name: &str| pl.rooflines.iter().find(|r| r.layer == name);
                for t in run.top_layers_by_cycles(15) {
                    let rl = roofline_of(&t.layer);
                    println!(
                        "  {:<40} {:>12} cycles  util {:.3}  E {:.3e}  {} ({:.1} MACs/B)",
                        t.layer,
                        human_count(t.metrics.cycles),
                        t.utilization,
                        t.energy,
                        rl.map(|r| match r.bound {
                            crate::model::roofline::Bound::Compute => "compute-bound",
                            crate::model::roofline::Bound::Memory => "memory-bound",
                        })
                        .unwrap_or("?"),
                        rl.map(|r| r.intensity).unwrap_or(0.0),
                    );
                }
            }
        }
    }
    Ok(())
}

/// `camuy emulate --trace FILE`: run the event-driven simulator over the
/// network's full tiling schedule and write the Perfetto trace-event
/// document (DESIGN.md §13). Load the file at <https://ui.perfetto.dev>.
fn cmd_emulate_trace(engine: &Engine, args: &Args, path: &Path) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.opt_usize("arrays", 1)? == 1,
        "--trace simulates a single array; drop --arrays"
    );
    let max_slices = args.opt_usize("max-slices", TraceRequest::DEFAULT_SLICES)?;
    anyhow::ensure!(
        max_slices > 0 && max_slices <= TraceRequest::MAX_SLICES,
        "--max-slices must be in 1..={}",
        TraceRequest::MAX_SLICES
    );
    let req = TraceRequest {
        net: require_net(args)?,
        batch: opt_batch(args)?,
        config: template_config(args, 128, 128)?,
        per_layer: args.flag("per-layer"),
        max_slices,
    };
    let threads = args.opt_usize("threads", crate::sweep::runner::default_threads())?;
    let resp = engine.trace_threaded(&req, threads)?;
    std::fs::write(path, resp.sim.perfetto().to_string_compact())?;
    if args.flag("json") {
        // The trace itself went to the file; print the summary document
        // without duplicating it inline.
        let mut j = resp.to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("trace");
        }
        println!("{}", j.to_string_pretty());
        return Ok(());
    }
    println!(
        "{}",
        kv_block(
            &format!("{} simulated on {}", resp.sim.network, resp.config),
            &[
                ("cycles", human_count(resp.sim.total.cycles)),
                ("stall cycles", human_count(resp.sim.total.stall_cycles)),
                ("MACs", human_count(resp.sim.total.macs)),
                ("passes", human_count(resp.sim.total.passes)),
                ("max FIFO depth", human_count(resp.sim.max_fifo_depth as u64)),
                ("events", human_count(resp.sim.events)),
                ("trace slices", human_count(resp.sim.slice_count())),
                (
                    "truncated",
                    if resp.sim.truncated() {
                        "yes (raise --max-slices)".to_string()
                    } else {
                        "no".to_string()
                    }
                ),
            ]
        )
    );
    if req.per_layer {
        println!("per-layer timeline:");
        for l in &resp.sim.layers {
            println!(
                "  {:<40} [{:>12}, {:>12})  fifo {:>5}  {:>9} events",
                l.name,
                l.start_cycle,
                l.end_cycle,
                l.max_fifo_depth,
                human_count(l.events)
            );
        }
    }
    println!(
        "wrote Perfetto trace to {} — open it at https://ui.perfetto.dev",
        path.display()
    );
    Ok(())
}

fn cmd_sweep(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let req = SweepRequest {
        net: require_net(args)?,
        spec: sweep_spec(args)?,
    };
    log::info!("sweeping {} over {} configs", req.net, req.spec.grid.len());
    let data = engine.sweep(&req)?;
    let dir = out_dir(args);
    figures::write_fig2(&data, &dir)?;
    println!("{}", data.energy.ascii());
    println!("{}", data.utilization.ascii());
    println!("wrote fig2 outputs to {}", dir.display());
    Ok(())
}

fn cmd_pareto(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let req = ParetoRequest {
        net: require_net(args)?,
        spec: sweep_spec(args)?,
        params: Nsga2Params {
            seed: args.opt_usize("seed", 0xCA_0001)? as u64,
            ..Default::default()
        },
    };
    let data = engine.pareto(&req)?;
    let dir = out_dir(args);
    figures::write_fig3(&data, &dir)?;
    println!(
        "{}",
        pareto_table(
            &format!("{}: Pareto set (E, cycles) — NSGA-II", req.net),
            &["energy", "cycles"],
            &data.energy_front
        )
    );
    println!(
        "exhaustive front: {} points; NSGA-II found {}",
        data.exhaustive_energy_front.len(),
        data.energy_front.len()
    );
    println!("wrote fig3 outputs to {}", dir.display());
    Ok(())
}

fn cmd_heatmaps(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let data = engine.heatmaps(&sweep_spec(args)?)?;
    let dir = out_dir(args);
    figures::write_fig4(&data, &dir)?;
    for d in &data {
        let (h, w, v) = d.energy.min_cell();
        println!("{:<16} min E {v:.3e} at ({h:>3}, {w:>3})", d.network);
    }
    println!("wrote fig4 outputs to {}", dir.display());
    Ok(())
}

fn cmd_robust(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let data = engine.robust(&sweep_spec(args)?, &Nsga2Params::default())?;
    let dir = out_dir(args);
    figures::write_fig5(&data, &dir)?;
    println!(
        "{}",
        pareto_table(
            "Robust Pareto (avg normalized E, cycles) — all paper models",
            &["avg_norm_E", "avg_norm_cyc"],
            &data.front
        )
    );
    println!("wrote fig5 outputs to {}", dir.display());
    Ok(())
}

fn equal_pe_request(args: &Args) -> anyhow::Result<EqualPeRequest> {
    let budgets: Vec<usize> = {
        let given = args.opt_list("budget");
        if given.is_empty() {
            EqualPeRequest::DEFAULT_BUDGETS.to_vec()
        } else {
            given
                .iter()
                .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad --budget '{s}'")))
                .collect::<anyhow::Result<_>>()?
        }
    };
    let req = EqualPeRequest {
        budgets,
        min_dim: args.opt_usize("min-dim", 8)?,
        spec: sweep_spec(args)?,
    };
    req.validate()?;
    Ok(req)
}

fn cmd_equal_pe(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let data = engine.equal_pe(&equal_pe_request(args)?)?;
    let dir = out_dir(args);
    figures::write_fig6(&data, &dir)?;
    for d in &data {
        println!("PE budget {}:", d.pe_budget);
        for (i, &(h, w)) in d.shapes.iter().enumerate() {
            println!("  {h:>5} x {w:<5} avg norm E = {:.4}", d.average[i]);
        }
    }
    println!("wrote fig6 outputs to {}", dir.display());
    Ok(())
}

fn cmd_figures(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let spec = sweep_spec(args)?;
    let dir = out_dir(args);
    let params = Nsga2Params::default();

    log::info!("Fig 2 (ResNet-152 heatmaps)…");
    let f2 = engine.sweep(&SweepRequest {
        net: "resnet152".to_string(),
        spec: spec.clone(),
    })?;
    figures::write_fig2(&f2, &dir)?;
    log::info!("Fig 3 (ResNet-152 Pareto)…");
    let f3 = engine.pareto(&ParetoRequest {
        net: "resnet152".to_string(),
        spec: spec.clone(),
        params: params.clone(),
    })?;
    figures::write_fig3(&f3, &dir)?;
    log::info!("Fig 4 (all-model heatmaps)…");
    figures::write_fig4(&engine.heatmaps(&spec)?, &dir)?;
    log::info!("Fig 5 (robust Pareto)…");
    figures::write_fig5(&engine.robust(&spec, &params)?, &dir)?;
    log::info!("Fig 6 (equal-PE aspect ratios)…");
    let f6 = engine.equal_pe(&EqualPeRequest {
        budgets: EqualPeRequest::DEFAULT_BUDGETS.to_vec(),
        min_dim: 8,
        spec: spec.clone(),
    })?;
    figures::write_fig6(&f6, &dir)?;
    log::info!("Fig 7 (liveness-corrected energy)…");
    figures::write_fig7(&figures::fig7_liveness_energy(&spec), &dir)?;
    println!("all figures written to {}", dir.display());
    Ok(())
}

fn cmd_memory(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let req = MemoryRequest {
        net: require_net(args)?,
        batch: opt_batch(args)?,
        config: template_config(args, 128, 128)?,
        weights: energy_weights(args)?,
        graph: args.flag("graph"),
    };
    let resp = engine.memory(&req)?;
    println!(
        "{} on {} (UB {} MiB):",
        resp.network,
        resp.config,
        resp.config.ub_bytes >> 20
    );
    println!(
        "  peak working set {:.2} MiB; {} of {} layers spill; DRAM words {}",
        resp.analysis.peak_working_set_bytes as f64 / (1 << 20) as f64,
        resp.analysis.spilling_layers,
        resp.analysis.layers.len(),
        human_count(resp.analysis.total_dram_words)
    );
    println!(
        "  Eq.1 energy {:.4e}; with DRAM spills {:.4e} ({:+.1}%)",
        resp.base_energy,
        resp.corrected_energy,
        100.0 * (resp.corrected_energy / resp.base_energy - 1.0)
    );
    if let Some(live) = &resp.liveness {
        println!(
            "  graph-aware peak residency {:.2} MiB ({:.2}x the linear-chain \
             estimate); {} long-lived tensors spill, {} edge DRAM words",
            live.peak_bytes as f64 / (1 << 20) as f64,
            live.inflation(),
            live.spilled_tensors,
            human_count(live.edge_dram_words)
        );
    }
    for l in resp.spillers().into_iter().take(10) {
        println!(
            "    {:<40} {:.2} MiB working set, {} DRAM words",
            l.layer,
            l.working_set_bytes as f64 / (1 << 20) as f64,
            human_count(l.dram_words)
        );
    }
    Ok(())
}

fn cmd_graph(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let req = GraphRequest {
        net: require_net(args)?,
        batch: opt_batch(args)?,
        arrays: args.opt_usize("arrays", 1)?,
        config: template_config(args, 128, 128)?,
        weights: energy_weights(args)?,
    };
    let threads = args.opt_usize("threads", crate::sweep::runner::default_threads())?;
    let resp = engine.graph_threaded(&req, threads)?;
    if args.flag("json") {
        println!("{}", resp.to_json().to_string_pretty());
        return Ok(());
    }
    let mib = |b: u64| format!("{:.2} MiB", b as f64 / (1 << 20) as f64);
    println!(
        "{}",
        kv_block(
            &format!("{} graph on {}", resp.network, resp.config),
            &[
                (
                    "topology",
                    if resp.is_chain { "chain".to_string() } else { "DAG".to_string() }
                ),
                (
                    "nodes",
                    format!(
                        "{} ({} layers, {} junctions, {} edges)",
                        resp.nodes, resp.layers, resp.junctions, resp.edges
                    )
                ),
                ("cycles (serialized)", human_count(resp.metrics.cycles)),
                ("MACs", human_count(resp.metrics.macs)),
                ("peak residency", mib(resp.liveness.peak_bytes)),
                ("linear-chain estimate", mib(resp.liveness.chain_peak_bytes)),
                (
                    "liveness inflation",
                    format!("{:.3}x", resp.liveness.inflation())
                ),
                (
                    "spilled tensors",
                    format!(
                        "{} ({} edge DRAM words)",
                        resp.liveness.spilled_tensors,
                        human_count(resp.liveness.edge_dram_words)
                    )
                ),
                ("energy (Eq.1)", format!("{:.4e}", resp.base_energy)),
                ("energy + DRAM", format!("{:.4e}", resp.corrected_energy)),
            ]
        )
    );
    println!(
        "schedule on {} array(s): makespan {} cycles (serialized {}, critical path {}, \
         speedup {:.2}x)",
        resp.schedule.arrays,
        human_count(resp.schedule.makespan_cycles),
        human_count(resp.schedule.serialized_cycles),
        human_count(resp.schedule.critical_path_cycles),
        resp.schedule.speedup()
    );
    println!("top residency steps:");
    for s in resp.liveness.top_steps(10) {
        println!(
            "  {:<44} own {:>12} held {:>12} total {:>12}",
            s.name,
            mib(s.own_bytes),
            mib(s.held_bytes),
            mib(s.total_bytes)
        );
    }
    if let Some(out) = args.opt("out") {
        let dir = PathBuf::from(out);
        figures::write_graph_liveness(&resp.network, &resp.liveness, &dir)?;
        println!("wrote liveness table to {}", dir.display());
    }
    Ok(())
}

fn cmd_serve(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let defaults = ServeOptions::default();
    let opts = ServeOptions {
        threads: args.opt_usize("threads", defaults.threads)?,
        batch_max: args.opt_usize("batch-max", defaults.batch_max)?,
        admission_max: args.opt_usize("admission-max", defaults.admission_max)?,
        snapshot: args.opt("snapshot").map(PathBuf::from),
        snapshot_secs: args.opt_usize("snapshot-secs", defaults.snapshot_secs as usize)? as u64,
        threaded: args.flag("threaded"),
        idle_secs: args.opt_usize("idle-secs", defaults.idle_secs as usize)? as u64,
        max_connections: match args.opt("max-conns") {
            Some(_) => Some(args.opt_usize("max-conns", 0)?),
            None => defaults.max_connections,
        },
        write_cap_bytes: args.opt_usize("write-cap-bytes", defaults.write_cap_bytes)?,
        ..defaults
    };
    anyhow::ensure!(opts.batch_max > 0, "--batch-max must be positive");
    anyhow::ensure!(opts.admission_max > 0, "--admission-max must be positive");
    anyhow::ensure!(opts.snapshot_secs > 0, "--snapshot-secs must be positive");
    anyhow::ensure!(
        opts.max_connections != Some(0),
        "--max-conns must be positive"
    );
    anyhow::ensure!(opts.write_cap_bytes > 0, "--write-cap-bytes must be positive");
    // Warm restart (DESIGN.md §15): reload the registered-network store a
    // previous `--snapshot` run wrote. A missing file is the normal first
    // boot, not an error.
    if let Some(path) = args.opt("restore") {
        let path = Path::new(path);
        if path.exists() {
            let n = engine
                .restore_from(path)
                .map_err(|e| anyhow::anyhow!("--restore {}: {e}", path.display()))?;
            log::info!("restored {n} network(s) from {}", path.display());
        } else {
            log::warn!("--restore {}: no such file, starting cold", path.display());
        }
    }
    if let Some(addr) = args.opt("listen") {
        let listener = std::net::TcpListener::bind(addr)?;
        log::info!("serving on {}", listener.local_addr()?);
        crate::api::serve_tcp(engine, listener, &opts)?;
    } else {
        let stdin = std::io::BufReader::new(std::io::stdin());
        let stdout = std::io::stdout();
        let stats = crate::api::serve(engine, stdin, &mut stdout.lock(), &opts)?;
        let summary = crate::api::connection_summary(engine, &stats);
        log::info!("served {summary}");
        // The stdin path has no accept loop to snapshot periodically;
        // write once after the session drains.
        if let Some(path) = &opts.snapshot {
            engine.snapshot_to(path)?;
            log::info!("wrote snapshot to {}", path.display());
        }
    }
    Ok(())
}

/// `camuy stats`: render the engine-wide telemetry snapshot — this
/// process's engine by default, or a running `camuy serve --listen`
/// server via `--connect ADDR` (one `{"type": "stats"}` round trip).
fn cmd_stats(engine: &Engine, args: &Args) -> anyhow::Result<()> {
    let req = StatsRequest {
        buckets: args.flag("buckets"),
    };
    let doc = match args.opt("connect") {
        Some(addr) => fetch_remote_stats(addr, &req)?,
        None => engine.stats(&req).to_json(),
    };
    if let Some(path) = args.opt("perfetto") {
        let secs = doc.get("uptime_seconds").and_then(Json::as_f64).unwrap_or(0.0);
        let secs = if secs.is_finite() { secs.max(0.0) } else { 0.0 };
        let uptime = std::time::Duration::from_secs_f64(secs);
        let trace = crate::telemetry::perfetto_counters_from_json(&doc, uptime);
        std::fs::write(path, trace.to_string_compact())?;
        println!("wrote Perfetto counter trace to {path}");
    }
    if args.flag("json") {
        println!("{}", doc.to_string_pretty());
        return Ok(());
    }
    let num = |path: &[&str]| -> f64 {
        let mut v = Some(&doc);
        for k in path {
            v = v.and_then(|x| x.get(k));
        }
        v.and_then(Json::as_f64).unwrap_or(0.0)
    };
    let enabled = doc.get("enabled").and_then(Json::as_bool).unwrap_or(false);
    println!(
        "engine telemetry ({}; up {:.1} s):",
        if enabled { "enabled" } else { "disabled" },
        num(&["uptime_seconds"])
    );
    println!(
        "{:<10} {:>9} {:>7} {:>10} {:>10} {:>10}",
        "request", "count", "errors", "p50 ms", "p95 ms", "p99 ms"
    );
    for kind in crate::telemetry::ReqKind::ALL {
        let count = num(&["requests", kind.name(), "count"]);
        if count == 0.0 {
            continue;
        }
        println!(
            "{:<10} {:>9} {:>7} {:>10.2} {:>10.2} {:>10.2}",
            kind.name(),
            count,
            num(&["requests", kind.name(), "errors"]),
            num(&["requests", kind.name(), "latency", "p50"]) / 1e6,
            num(&["requests", kind.name(), "latency", "p95"]) / 1e6,
            num(&["requests", kind.name(), "latency", "p99"]) / 1e6,
        );
    }
    println!(
        "serve: {} connection(s), {} batch(es), {} B in / {} B out",
        num(&["serve", "connections"]),
        num(&["serve", "batches"]),
        num(&["serve", "bytes_in"]),
        num(&["serve", "bytes_out"])
    );
    println!(
        "conns: {} active, {} idle-closed, {} aborted, {} B queued",
        num(&["serve", "connections_active"]),
        num(&["serve", "connections_idle_closed"]),
        num(&["serve", "connections_aborted"]),
        num(&["serve", "write_queue_bytes"])
    );
    println!(
        "pool: {} worker(s), {} job(s), {} steal(s), queue depth {}, job p99 {:.2} ms",
        num(&["pool", "workers"]),
        num(&["pool", "jobs"]),
        num(&["pool", "steals"]),
        num(&["pool", "queue_depth"]),
        num(&["pool", "job_latency", "p99"]) / 1e6
    );
    println!(
        "sweep: {} cell(s) evaluated",
        num(&["sweep", "cells_evaluated"])
    );
    println!(
        "robust: {} shed, {} deadline-exceeded, {} panic(s) caught, \
         {} snapshot write(s), admission depth {}",
        num(&["robust", "requests_shed"]),
        num(&["robust", "deadline_exceeded"]),
        num(&["robust", "panics_caught"]),
        num(&["robust", "snapshot_writes"]),
        num(&["robust", "admission_depth"])
    );
    if doc.get("eval_cache").is_some() {
        println!(
            "eval cache: {} entr(ies), {:.0}% hit rate ({} hits / {} misses, {} evictions)",
            num(&["eval_cache", "entries"]),
            100.0 * num(&["eval_cache", "hit_rate"]),
            num(&["eval_cache", "hits"]),
            num(&["eval_cache", "misses"]),
            num(&["eval_cache", "evictions"])
        );
    }
    if doc.get("plan_cache").is_some() {
        println!(
            "plan cache: {} plan(s), {:.0}% hit rate, {} table word(s)",
            num(&["plan_cache", "entries"]),
            100.0 * num(&["plan_cache", "hit_rate"]),
            num(&["plan_cache", "table_words"])
        );
    }
    if doc.get("networks").is_some() {
        println!(
            "networks: {} zoo, {} user-registered",
            num(&["networks", "zoo"]),
            num(&["networks", "user"])
        );
    }
    Ok(())
}

/// One `{"type": "stats"}` round trip against a running
/// `camuy serve --listen` server, returning the unwrapped `result`.
fn fetch_remote_stats(addr: &str, req: &StatsRequest) -> anyhow::Result<Json> {
    use std::io::{BufRead, BufReader, Write};
    let mut pairs = vec![("type", Json::str("stats"))];
    if req.buckets {
        pairs.push(("buckets", Json::Bool(true)));
    }
    let mut stream = std::net::TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    writeln!(stream, "{}", Json::obj(pairs).to_string_compact())?;
    stream.flush()?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let trimmed = line.trim();
    anyhow::ensure!(
        !trimmed.is_empty(),
        "server closed the connection without answering"
    );
    let v = Json::parse(trimmed).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    if v.get("ok").and_then(Json::as_bool) != Some(true) {
        let err = v.get("error").cloned().unwrap_or(Json::Null);
        anyhow::bail!("server error: {}", err.to_string_compact());
    }
    v.get("result")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("response has no result"))
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::default_artifact_dir);
    let manifest = Manifest::load(Path::new(&dir))?;
    let rt = PjrtRuntime::cpu()?;
    let cfg = template_config(args, 32, 32)?;
    println!(
        "PJRT platform: {} | artifacts: {}",
        rt.platform(),
        manifest.artifacts.len()
    );
    let mut failures = 0;
    for entry in manifest.artifacts.iter().filter(|a| a.kind == "gemm") {
        let report = crate::coordinator::verify_gemm_artifact(&rt, entry, &cfg, 42)?;
        println!("{report}");
        if !report.pass {
            failures += 1;
        }
    }
    anyhow::ensure!(failures == 0, "{failures} artifact verification(s) failed");
    println!("verification PASSED");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn version_flag_parses_and_exits_zero() {
        let a = Args::parse(&argv(&["--version"]), &SCHEMA).unwrap();
        assert!(a.flag("version"));
        assert_eq!(run(&argv(&["--version"])), 0);
        // The flag wins even alongside a command.
        assert_eq!(run(&argv(&["zoo", "--version"])), 0);
    }

    #[test]
    fn usage_lists_every_dispatched_command() {
        for cmd in [
            "zoo", "emulate", "sweep", "pareto", "heatmaps", "robust", "equal-pe", "figures",
            "memory", "graph", "serve", "stats", "verify",
        ] {
            assert!(usage().contains(cmd), "usage() missing {cmd}");
        }
        assert!(usage().contains("--version"));
    }

    #[test]
    fn serve_options_parse() {
        let a = Args::parse(
            &argv(&["serve", "--batch-max", "16", "--threads", "2"]),
            &SCHEMA,
        )
        .unwrap();
        assert_eq!(a.opt_usize("batch-max", 64).unwrap(), 16);
        assert_eq!(a.opt_usize("threads", 0).unwrap(), 2);
    }
}
