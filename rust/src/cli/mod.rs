//! The `camuy` command-line interface.
//!
//! ```text
//! camuy zoo                         list networks (params, MACs, shapes)
//! camuy emulate --net resnet152 --height 128 --width 64 [--per-layer] [--json]
//! camuy sweep   --net resnet152 [--grid paper|smoke] [--out DIR]   (Fig 2)
//! camuy pareto  --net resnet152 [--out DIR]                        (Fig 3)
//! camuy heatmaps [--out DIR]                                       (Fig 4)
//! camuy robust  [--out DIR]                                        (Fig 5)
//! camuy equal-pe [--budget N]... [--out DIR]                       (Fig 6)
//! camuy figures --out DIR          regenerate every paper figure
//! camuy verify  [--artifacts DIR]  three-way artifact verification
//! ```

pub mod args;

use crate::config::{ArrayConfig, Dataflow, EnergyWeights};
use crate::coordinator::Coordinator;
use crate::nets;
use crate::pareto::nsga2::Nsga2Params;
use crate::report::figures::{self, FigureContext};
use crate::report::{kv_block, pareto_table};
use crate::runtime::{Manifest, PjrtRuntime};
use crate::util::human_count;
use args::{Args, Schema};
use std::path::{Path, PathBuf};

const SCHEMA: Schema = Schema {
    options: &[
        "net", "height", "width", "acc", "batch", "arrays", "grid", "out", "budget", "threads", "artifacts",
        "dataflow", "seed", "energy-model",
    ],
    flags: &["json", "per-layer", "smoke", "help", "quiet", "verbose"],
};

pub fn usage() -> &'static str {
    "camuy — Configurable Accelerator Modeling for Understanding and Analysis

USAGE: camuy <command> [options]

COMMANDS:
  zoo                 list registered networks
  emulate             run one network on one array configuration
  sweep               Fig 2: heatmaps for one network over the grid
  pareto              Fig 3: NSGA-II Pareto sets for one network
  heatmaps            Fig 4: data-movement heatmaps for all paper models
  robust              Fig 5: robust Pareto across all paper models
  equal-pe            Fig 6: equal-PE-count aspect-ratio study
  figures             regenerate every paper figure into --out
  memory              per-layer UB working sets, spills, DRAM overhead
  verify              three-way check: reference = emulator = PJRT artifact

OPTIONS:
  --net NAME          network (see `camuy zoo`)
  --batch N           inference batch size (emulate; default 1)
  --arrays N          multi-array bank size (emulate; default 1)
  --height H --width W --acc N   array geometry / accumulator entries
  --dataflow ws|os    dataflow concept (default ws)
  --energy-model paper|dally14nm  Equation-1 weights
  --grid paper|smoke  sweep grid (961-point paper grid or 4x4 smoke)
  --budget N          equal-PE budget (repeatable; default 4096 16384 65536)
  --out DIR           output directory for CSV/PGM/TXT (default results/)
  --threads N         sweep parallelism (default: cores)
  --artifacts DIR     AOT artifact directory (default artifacts/)
  --per-layer --json --smoke --quiet --verbose --help
"
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    let args = match Args::parse(argv, &SCHEMA) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return 2;
        }
    };
    crate::util::logging::init(crate::util::logging::level_from_verbosity(
        args.flag("quiet"),
        if args.flag("verbose") { 1 } else { 0 },
    ));
    if args.flag("help") || args.command.is_none() {
        println!("{}", usage());
        return if args.command.is_none() && !args.flag("help") { 2 } else { 0 };
    }
    let cmd = args.command.clone().unwrap();
    let result = match cmd.as_str() {
        "zoo" => cmd_zoo(),
        "emulate" => cmd_emulate(&args),
        "sweep" => cmd_sweep(&args),
        "pareto" => cmd_pareto(&args),
        "heatmaps" => cmd_heatmaps(&args),
        "robust" => cmd_robust(&args),
        "equal-pe" => cmd_equal_pe(&args),
        "figures" => cmd_figures(&args),
        "memory" => cmd_memory(&args),
        "verify" => cmd_verify(&args),
        other => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt("out").unwrap_or("results"))
}

fn context(args: &Args) -> anyhow::Result<FigureContext> {
    let mut ctx = match args.opt("grid").unwrap_or("paper") {
        "paper" => FigureContext::paper(),
        "smoke" => FigureContext::smoke(),
        g => anyhow::bail!("unknown grid '{g}' (paper|smoke)"),
    };
    if args.flag("smoke") {
        ctx.grid = FigureContext::smoke().grid;
    }
    ctx.template = template_config(args, 1, 1)?;
    ctx.threads = args.opt_usize("threads", ctx.threads)?;
    ctx.weights = energy_weights(args)?;
    Ok(ctx)
}

fn energy_weights(args: &Args) -> anyhow::Result<EnergyWeights> {
    Ok(match args.opt("energy-model").unwrap_or("paper") {
        "paper" => EnergyWeights::paper(),
        "dally14nm" => EnergyWeights::dally_14nm(),
        other => anyhow::bail!("unknown energy model '{other}' (paper|dally14nm)"),
    })
}

fn template_config(args: &Args, def_h: usize, def_w: usize) -> anyhow::Result<ArrayConfig> {
    let mut cfg = ArrayConfig::new(
        args.opt_usize("height", def_h)?,
        args.opt_usize("width", def_w)?,
    );
    cfg.acc_capacity = args.opt_usize("acc", cfg.acc_capacity)?;
    if let Some(df) = args.opt("dataflow") {
        cfg.dataflow =
            Dataflow::parse(df).ok_or_else(|| anyhow::anyhow!("unknown dataflow '{df}'"))?;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

fn require_net(args: &Args) -> anyhow::Result<String> {
    args.opt("net")
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("--net is required (see `camuy zoo`)"))
}

fn cmd_zoo() -> anyhow::Result<()> {
    println!(
        "{:<18} {:>10} {:>10} {:>8} {:>15}",
        "network", "params", "MACs", "layers", "distinct GEMMs"
    );
    for name in nets::ALL_MODELS {
        let net = nets::build(name).unwrap();
        println!(
            "{:<18} {:>10} {:>10} {:>8} {:>15}",
            name,
            human_count(net.params()),
            human_count(net.macs()),
            net.layers.len(),
            net.gemm_histogram().len(),
        );
    }
    Ok(())
}

fn cmd_emulate(args: &Args) -> anyhow::Result<()> {
    let name = require_net(args)?;
    let net = nets::build(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown network '{name}'"))?
        .with_batch(args.opt_usize("batch", 1)?);
    let cfg = template_config(args, 128, 128)?;
    let coord = Coordinator::new(cfg.clone())
        .map_err(anyhow::Error::msg)?
        .with_weights(energy_weights(args)?);
    let arrays = args.opt_usize("arrays", 1)?;
    if arrays > 1 {
        let mcfg = crate::model::multi::MultiArrayConfig::new(arrays, cfg.clone());
        let m = crate::model::multi::network_metrics_multi(&net, &mcfg);
        println!(
            "{}",
            kv_block(
                &format!("{name} on {arrays}x [{cfg}]"),
                &[
                    ("makespan cycles", human_count(m.makespan_cycles)),
                    ("busy cycles (sum)", human_count(m.total.cycles)),
                    ("MACs", human_count(m.total.macs)),
                    ("bank utilization", format!("{:.4}", m.utilization(&mcfg))),
                    (
                        "energy (Eq.1)",
                        format!("{:.4e}", m.energy(&energy_weights(args)?))
                    ),
                    ("M_UB", human_count(m.total.movements.m_ub())),
                ]
            )
        );
        return Ok(());
    }
    let run = coord.run_inference(&net);

    if args.flag("json") {
        println!("{}", run.to_json().to_string_pretty());
        return Ok(());
    }
    println!(
        "{}",
        kv_block(
            &format!("{name} on {cfg}"),
            &[
                ("cycles", human_count(run.total.cycles)),
                ("stall cycles", human_count(run.total.stall_cycles)),
                ("MACs", human_count(run.total.macs)),
                ("passes", human_count(run.total.passes)),
                ("utilization", format!("{:.4}", run.utilization())),
                (
                    "energy (Eq.1)",
                    format!("{:.4e}", run.energy(&coord.weights))
                ),
                ("M_UB", human_count(run.total.movements.m_ub())),
                ("M_INTER_PE", human_count(run.total.movements.m_inter_pe())),
                ("M_AA", human_count(run.total.movements.m_aa())),
                ("M_INTRA_PE", human_count(run.total.movements.m_intra_pe())),
                (
                    "UB bandwidth (B/cy)",
                    format!("{:.2}", run.bandwidth.ub_total())
                ),
                (
                    "UB spills",
                    if run.ub_violations.is_empty() {
                        "none".to_string()
                    } else {
                        format!("{} layers exceed the UB", run.ub_violations.len())
                    }
                ),
            ]
        )
    );
    if args.flag("per-layer") {
        let (rooflines, mem_share) = crate::model::roofline::network_roofline(&net, &cfg);
        println!(
            "top layers by cycles (machine balance {:.1} MACs/B; {:.0}% of layers memory-bound):",
            crate::model::roofline::machine_balance(&cfg),
            100.0 * mem_share
        );
        let roofline_of = |name: &str| rooflines.iter().find(|r| r.layer == name);
        for t in run.top_layers_by_cycles(15) {
            let rl = roofline_of(&t.layer);
            println!(
                "  {:<40} {:>12} cycles  util {:.3}  E {:.3e}  {} ({:.1} MACs/B)",
                t.layer,
                human_count(t.metrics.cycles),
                t.utilization,
                t.energy,
                rl.map(|r| match r.bound {
                    crate::model::roofline::Bound::Compute => "compute-bound",
                    crate::model::roofline::Bound::Memory => "memory-bound",
                })
                .unwrap_or("?"),
                rl.map(|r| r.intensity).unwrap_or(0.0),
            );
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let name = require_net(args)?;
    let ctx = context(args)?;
    log::info!("sweeping {name} over {} configs", ctx.grid.len());
    let data = figures::fig2_heatmaps(&name, &ctx);
    let dir = out_dir(args);
    figures::write_fig2(&data, &dir)?;
    println!("{}", data.energy.ascii());
    println!("{}", data.utilization.ascii());
    println!("wrote fig2 outputs to {}", dir.display());
    Ok(())
}

fn cmd_pareto(args: &Args) -> anyhow::Result<()> {
    let name = require_net(args)?;
    let ctx = context(args)?;
    let params = Nsga2Params {
        seed: args.opt_usize("seed", 0xCA_0001)? as u64,
        ..Default::default()
    };
    let data = figures::fig3_pareto(&name, &ctx, &params);
    let dir = out_dir(args);
    figures::write_fig3(&data, &dir)?;
    println!(
        "{}",
        pareto_table(
            &format!("{name}: Pareto set (E, cycles) — NSGA-II"),
            &["energy", "cycles"],
            &data.energy_front
        )
    );
    println!(
        "exhaustive front: {} points; NSGA-II found {}",
        data.exhaustive_energy_front.len(),
        data.energy_front.len()
    );
    println!("wrote fig3 outputs to {}", dir.display());
    Ok(())
}

fn cmd_heatmaps(args: &Args) -> anyhow::Result<()> {
    let ctx = context(args)?;
    let data = figures::fig4_heatmaps(&ctx);
    let dir = out_dir(args);
    figures::write_fig4(&data, &dir)?;
    for d in &data {
        let (h, w, v) = d.energy.min_cell();
        println!("{:<16} min E {v:.3e} at ({h:>3}, {w:>3})", d.network);
    }
    println!("wrote fig4 outputs to {}", dir.display());
    Ok(())
}

fn cmd_robust(args: &Args) -> anyhow::Result<()> {
    let ctx = context(args)?;
    let params = Nsga2Params::default();
    let data = figures::fig5_robust(&ctx, &params);
    let dir = out_dir(args);
    figures::write_fig5(&data, &dir)?;
    println!(
        "{}",
        pareto_table(
            "Robust Pareto (avg normalized E, cycles) — all paper models",
            &["avg_norm_E", "avg_norm_cyc"],
            &data.front
        )
    );
    println!("wrote fig5 outputs to {}", dir.display());
    Ok(())
}

fn cmd_equal_pe(args: &Args) -> anyhow::Result<()> {
    let ctx = context(args)?;
    let budgets: Vec<usize> = {
        let given = args.opt_list("budget");
        if given.is_empty() {
            vec![4096, 16384, 65536]
        } else {
            given
                .iter()
                .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad --budget '{s}'")))
                .collect::<anyhow::Result<_>>()?
        }
    };
    let data: Vec<_> = budgets
        .iter()
        .map(|&b| figures::fig6_equal_pe(b, 8, &ctx))
        .collect();
    let dir = out_dir(args);
    figures::write_fig6(&data, &dir)?;
    for d in &data {
        println!("PE budget {}:", d.pe_budget);
        for (i, &(h, w)) in d.shapes.iter().enumerate() {
            println!("  {h:>5} x {w:<5} avg norm E = {:.4}", d.average[i]);
        }
    }
    println!("wrote fig6 outputs to {}", dir.display());
    Ok(())
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let ctx = context(args)?;
    let dir = out_dir(args);
    let params = Nsga2Params::default();

    log::info!("Fig 2 (ResNet-152 heatmaps)…");
    figures::write_fig2(&figures::fig2_heatmaps("resnet152", &ctx), &dir)?;
    log::info!("Fig 3 (ResNet-152 Pareto)…");
    figures::write_fig3(&figures::fig3_pareto("resnet152", &ctx, &params), &dir)?;
    log::info!("Fig 4 (all-model heatmaps)…");
    figures::write_fig4(&figures::fig4_heatmaps(&ctx), &dir)?;
    log::info!("Fig 5 (robust Pareto)…");
    figures::write_fig5(&figures::fig5_robust(&ctx, &params), &dir)?;
    log::info!("Fig 6 (equal-PE aspect ratios)…");
    let f6: Vec<_> = [4096usize, 16384, 65536]
        .iter()
        .map(|&b| figures::fig6_equal_pe(b, 8, &ctx))
        .collect();
    figures::write_fig6(&f6, &dir)?;
    println!("all figures written to {}", dir.display());
    Ok(())
}

fn cmd_memory(args: &Args) -> anyhow::Result<()> {
    let name = require_net(args)?;
    let net = nets::build(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown network '{name}'"))?
        .with_batch(args.opt_usize("batch", 1)?);
    let cfg = template_config(args, 128, 128)?;
    let analysis = crate::model::memory::MemoryAnalysis::of(&net, &cfg);
    println!(
        "{name} on {cfg} (UB {} MiB):",
        cfg.ub_bytes >> 20
    );
    println!(
        "  peak working set {:.2} MiB; {} of {} layers spill; DRAM words {}",
        analysis.peak_working_set_bytes as f64 / (1 << 20) as f64,
        analysis.spilling_layers,
        analysis.layers.len(),
        human_count(analysis.total_dram_words)
    );
    let w = energy_weights(args)?;
    let base = net.metrics(&cfg).energy(&w);
    let corrected = analysis.corrected_energy(&net, &cfg, &w);
    println!(
        "  Eq.1 energy {base:.4e}; with DRAM spills {corrected:.4e} ({:+.1}%)",
        100.0 * (corrected / base - 1.0)
    );
    let mut spillers: Vec<_> = analysis.layers.iter().filter(|l| !l.fits).collect();
    spillers.sort_by(|a, b| b.working_set_bytes.cmp(&a.working_set_bytes));
    for l in spillers.iter().take(10) {
        println!(
            "    {:<40} {:.2} MiB working set, {} DRAM words",
            l.layer,
            l.working_set_bytes as f64 / (1 << 20) as f64,
            human_count(l.dram_words)
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::default_artifact_dir);
    let manifest = Manifest::load(Path::new(&dir))?;
    let rt = PjrtRuntime::cpu()?;
    let cfg = template_config(args, 32, 32)?;
    println!(
        "PJRT platform: {} | artifacts: {}",
        rt.platform(),
        manifest.artifacts.len()
    );
    let mut failures = 0;
    for entry in manifest.artifacts.iter().filter(|a| a.kind == "gemm") {
        let report = crate::coordinator::verify_gemm_artifact(&rt, entry, &cfg, 42)?;
        println!("{report}");
        if !report.pass {
            failures += 1;
        }
    }
    anyhow::ensure!(failures == 0, "{failures} artifact verification(s) failed");
    println!("verification PASSED");
    Ok(())
}
