//! A small argument parser (the offline environment has no clap):
//! positional subcommand + `--flag` / `--key value` options, with typed
//! accessors and unknown-option rejection.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub command: Option<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ArgError {}

/// Declared option/flag schema for validation.
#[derive(Debug, Clone, Default)]
pub struct Schema {
    /// Options that take a value.
    pub options: &'static [&'static str],
    /// Boolean flags.
    pub flags: &'static [&'static str],
}

impl Args {
    /// Parse `argv[1..]`: first bare word is the subcommand, the rest are
    /// `--opt value`, `--flag`, or positionals.
    pub fn parse(argv: &[String], schema: &Schema) -> Result<Args, ArgError> {
        let mut out = Args {
            command: None,
            options: BTreeMap::new(),
            flags: Vec::new(),
            positionals: Vec::new(),
        };
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if schema.flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if schema.options.contains(&name) {
                    i += 1;
                    let val = argv
                        .get(i)
                        .ok_or_else(|| ArgError(format!("--{name} needs a value")))?;
                    out.options.entry(name.to_string()).or_default().push(val.clone());
                } else {
                    return Err(ArgError(format!("unknown option --{name}")));
                }
            } else if out.command.is_none() {
                out.command = Some(tok.clone());
            } else {
                out.positionals.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| ArgError(format!("--{name} expects an integer, got '{s}'"))),
        }
    }

    pub fn opt_list(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema() -> Schema {
        Schema {
            options: &["net", "height", "out"],
            flags: &["json", "smoke"],
        }
    }

    fn parse(toks: &[&str]) -> Result<Args, ArgError> {
        let v: Vec<String> = toks.iter().map(|s| s.to_string()).collect();
        Args::parse(&v, &schema())
    }

    #[test]
    fn full_parse() {
        let a = parse(&["sweep", "--net", "resnet152", "--json", "--height", "64"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("sweep"));
        assert_eq!(a.opt("net"), Some("resnet152"));
        assert!(a.flag("json"));
        assert_eq!(a.opt_usize("height", 0).unwrap(), 64);
        assert_eq!(a.opt_usize("width", 7).unwrap(), 7); // default
    }

    #[test]
    fn repeated_options_collect() {
        let a = parse(&["x", "--net", "a", "--net", "b"]).unwrap();
        assert_eq!(a.opt_list("net"), vec!["a", "b"]);
        assert_eq!(a.opt("net"), Some("b")); // last wins for scalar access
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["x", "--bogus"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["x", "--net"]).is_err());
    }

    #[test]
    fn bad_integer_rejected() {
        let a = parse(&["x", "--height", "lots"]).unwrap();
        assert!(a.opt_usize("height", 1).is_err());
    }

    #[test]
    fn positionals_after_command() {
        let a = parse(&["emulate", "alexnet", "vgg16"]).unwrap();
        assert_eq!(a.positionals(), &["alexnet".to_string(), "vgg16".to_string()]);
    }
}
