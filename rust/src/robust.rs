//! Operational hardening for the serve tier (DESIGN.md §15): per-request
//! deadlines with cooperative cancellation, and admission control with
//! load shedding.
//!
//! # Cancellation model
//!
//! A [`CancelToken`] is a deadline plus a shared cancelled flag. The serve
//! loop creates one per request that carries a `"deadline_ms"` field and
//! installs it as the *ambient* token ([`with_token`]) for the duration of
//! the dispatch. Compute cores call the free function [`checkpoint`] at
//! their natural work boundaries — pool chunks, sweep dispatch units,
//! NSGA-II generations, graph/sim per-node closures — which is two
//! thread-local loads when no token is installed (the library-caller hot
//! path pays essentially nothing).
//!
//! When the ambient token has fired, `checkpoint` panics with a
//! [`Cancelled`] payload. The panic rides the exact machinery the pool
//! already has for job poisoning: remaining chunks are skipped and the
//! payload is re-raised on the submitting caller ([`crate::runtime::pool`]).
//! [`crate::runtime::pool::Pool::run`] captures the submitter's ambient
//! token into the job so worker threads inherit it across the thread hop.
//! The serve dispatch catches the unwind and downcasts: a `Cancelled`
//! payload becomes a typed `ApiError::DeadlineExceeded` carrying the
//! progress count; anything else is a real panic and becomes an
//! `internal` error (panic isolation). Deliberate cancellation unwinds
//! are silenced in the panic hook so deadlines don't spray backtraces to
//! stderr.
//!
//! Infallible deep APIs (`figures::fig2_heatmaps_planned`, the schedule
//! and sim entry points) need no signature change: cancellation crosses
//! them as an unwind, and because the pool re-raises *before* the
//! result-collection phase, the write-once slot invariants of
//! `parallel_map`/`parallel_scatter` are never observed half-filled.
//!
//! # Admission control
//!
//! An [`Admission`] gate bounds how many compute requests are in flight
//! at once. The serve loop takes one [`Permit`] per compute request at
//! batch-assembly time; requests past the budget are shed immediately
//! with a structured `overloaded` error carrying `retry_after_ms`
//! (estimated from a latency EWMA of recently completed requests), so a
//! client can back off instead of watching a silently dropped socket.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// The panic payload of a cooperative cancellation. The serve layer
/// downcasts unwind payloads to this type to tell a fired deadline apart
/// from a genuine bug.
#[derive(Debug, Clone)]
pub struct Cancelled {
    /// Checkpoints the request passed before the cancellation fired — the
    /// partial-progress figure reported in `ApiError::DeadlineExceeded`.
    pub progress: u64,
    /// The request's deadline, if the token carried one (a manual
    /// [`CancelToken::cancel`] has none).
    pub deadline_ms: Option<u64>,
}

#[derive(Debug)]
struct TokenInner {
    /// Absolute fire time; `None` for manually cancelled tokens.
    deadline: Option<Instant>,
    deadline_ms: Option<u64>,
    cancelled: AtomicBool,
    /// Checkpoints passed so far, across every thread sharing the token.
    progress: AtomicU64,
}

/// A cheap cancellation handle: a deadline plus a shared flag. Clones
/// share state; see the module docs for the propagation model.
#[derive(Debug, Clone)]
pub struct CancelToken {
    inner: Arc<TokenInner>,
}

impl CancelToken {
    /// A token that fires `ms` milliseconds from now.
    pub fn with_deadline_ms(ms: u64) -> CancelToken {
        quiet_cancellation_unwinds();
        CancelToken {
            inner: Arc::new(TokenInner {
                deadline: Some(Instant::now() + Duration::from_millis(ms)),
                deadline_ms: Some(ms),
                cancelled: AtomicBool::new(false),
                progress: AtomicU64::new(0),
            }),
        }
    }

    /// A token with no deadline; fires only on [`CancelToken::cancel`].
    pub fn manual() -> CancelToken {
        quiet_cancellation_unwinds();
        CancelToken {
            inner: Arc::new(TokenInner {
                deadline: None,
                deadline_ms: None,
                cancelled: AtomicBool::new(false),
                progress: AtomicU64::new(0),
            }),
        }
    }

    /// Fire the token now, regardless of its deadline.
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Relaxed);
    }

    /// Whether the token has fired (cancelled, or past its deadline).
    pub fn fired(&self) -> bool {
        if self.inner.cancelled.load(Ordering::Relaxed) {
            return true;
        }
        match self.inner.deadline {
            Some(d) if Instant::now() >= d => {
                // Latch, so later checks skip the clock read.
                self.inner.cancelled.store(true, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// Checkpoints passed so far.
    pub fn progress(&self) -> u64 {
        self.inner.progress.load(Ordering::Relaxed)
    }

    /// The deadline the token was built with, if any.
    pub fn deadline_ms(&self) -> Option<u64> {
        self.inner.deadline_ms
    }

    /// Count one unit of progress, then unwind with [`Cancelled`] if the
    /// token has fired. Compute cores call this through the ambient free
    /// function [`checkpoint`].
    pub fn checkpoint(&self) {
        let progress = self.inner.progress.fetch_add(1, Ordering::Relaxed) + 1;
        if self.fired() {
            std::panic::panic_any(Cancelled {
                progress,
                deadline_ms: self.inner.deadline_ms,
            });
        }
    }
}

thread_local! {
    static CURRENT: RefCell<Option<CancelToken>> = const { RefCell::new(None) };
}

/// Run `f` with `token` installed as this thread's ambient token,
/// restoring the previous one afterwards — including on unwind, so a
/// cancellation cannot leak the token into unrelated later work on a
/// pool worker.
pub fn with_token<T>(token: &CancelToken, f: impl FnOnce() -> T) -> T {
    struct Restore(Option<CancelToken>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prior = self.0.take();
            CURRENT.with(|c| *c.borrow_mut() = prior);
        }
    }
    let prior = CURRENT.with(|c| c.borrow_mut().replace(token.clone()));
    let _restore = Restore(prior);
    f()
}

/// This thread's ambient token, if a deadline-carrying request is in
/// flight on it. [`crate::runtime::pool::Pool::run`] captures this at
/// submit so worker threads inherit the submitter's token.
pub fn current() -> Option<CancelToken> {
    CURRENT.with(|c| c.borrow().clone())
}

/// The cooperative cancellation point: count progress on the ambient
/// token and unwind with [`Cancelled`] if it has fired. With no token
/// installed this is two thread-local reads — cheap enough for per-unit
/// placement in the sweep dispatch and per-chunk placement in the pool.
#[inline]
pub fn checkpoint() {
    let token = CURRENT.with(|c| c.borrow().clone());
    if let Some(t) = token {
        t.checkpoint();
    }
}

/// Install (once) a panic-hook wrapper that suppresses the default
/// backtrace print for [`Cancelled`] payloads: a fired deadline is
/// control flow, not a bug, and a server shedding hundreds of deadlines
/// must not flood stderr. Every other payload still reaches the previous
/// hook unchanged.
fn quiet_cancellation_unwinds() {
    static HOOK: OnceLock<()> = OnceLock::new();
    HOOK.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<Cancelled>().is_none() {
                prev(info);
            }
        }));
    });
}

/// Bounded admission in front of the pool: at most `capacity` compute
/// requests hold a [`Permit`] at once; the rest are shed with a
/// `retry_after_ms` hint derived from recently observed request latency.
#[derive(Debug)]
pub struct Admission {
    capacity: usize,
    inflight: AtomicUsize,
    /// EWMA of completed-request wall time, nanoseconds. Racy updates are
    /// fine — this only shapes the retry hint.
    recent_nanos: AtomicU64,
}

/// Floor/ceiling for the shed `retry_after_ms` hint.
const RETRY_MS_MIN: u64 = 10;
const RETRY_MS_MAX: u64 = 5_000;

impl Admission {
    pub fn new(capacity: usize) -> Admission {
        Admission {
            capacity: capacity.max(1),
            inflight: AtomicUsize::new(0),
            recent_nanos: AtomicU64::new(0),
        }
    }

    /// Admit one request, or shed it: `Err(retry_after_ms)` when
    /// `capacity` permits are already out.
    pub fn try_admit(&self) -> Result<Permit<'_>, u64> {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.capacity {
                return Err(self.retry_after_ms());
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        crate::telemetry::global().admission_depth.inc();
        Ok(Permit {
            gate: self,
            since: Instant::now(),
        })
    }

    /// Permits currently out.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// The backoff hint handed to shed clients: roughly one recent
    /// request latency (time for a slot to free up), clamped to
    /// [[`RETRY_MS_MIN`], [`RETRY_MS_MAX`]].
    fn retry_after_ms(&self) -> u64 {
        let ms = self.recent_nanos.load(Ordering::Relaxed) / 1_000_000;
        ms.clamp(RETRY_MS_MIN, RETRY_MS_MAX)
    }

    fn release(&self, held_for: Duration) {
        let sample = held_for.as_nanos().min(u64::MAX as u128) as u64;
        let old = self.recent_nanos.load(Ordering::Relaxed);
        let next = if old == 0 { sample } else { (3 * (old / 4)) + sample / 4 };
        self.recent_nanos.store(next, Ordering::Relaxed);
        crate::telemetry::global().admission_depth.dec();
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// RAII admission slot: dropping it frees the slot and feeds the held
/// duration into the gate's latency EWMA.
#[derive(Debug)]
pub struct Permit<'a> {
    gate: &'a Admission,
    since: Instant,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.gate.release(self.since.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn manual_cancel_unwinds_with_progress() {
        let t = CancelToken::manual();
        let r = catch_unwind(AssertUnwindSafe(|| {
            with_token(&t, || {
                checkpoint();
                checkpoint();
                t.cancel();
                checkpoint();
            })
        }));
        let payload = r.expect_err("third checkpoint must unwind");
        let c = payload.downcast_ref::<Cancelled>().expect("Cancelled payload");
        assert_eq!(c.progress, 3);
        assert_eq!(c.deadline_ms, None);
        assert!(current().is_none(), "token must not leak past with_token");
    }

    #[test]
    fn deadline_token_fires_after_its_deadline() {
        let t = CancelToken::with_deadline_ms(1);
        assert_eq!(t.deadline_ms(), Some(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.fired());
        let r = catch_unwind(AssertUnwindSafe(|| t.checkpoint()));
        let payload = r.expect_err("fired token must unwind at a checkpoint");
        let c = payload.downcast_ref::<Cancelled>().unwrap();
        assert_eq!(c.deadline_ms, Some(1));
        assert!(c.progress >= 1);
    }

    #[test]
    fn checkpoint_without_a_token_is_a_no_op() {
        assert!(current().is_none());
        checkpoint(); // must not panic
    }

    #[test]
    fn with_token_restores_the_prior_token() {
        let outer = CancelToken::with_deadline_ms(60_000);
        let inner = CancelToken::with_deadline_ms(60_000);
        with_token(&outer, || {
            assert!(current().is_some());
            with_token(&inner, || {
                assert_eq!(current().unwrap().deadline_ms(), Some(60_000));
            });
            // Outer token back in place.
            assert!(Arc::ptr_eq(&current().unwrap().inner, &outer.inner));
        });
        assert!(current().is_none());
    }

    #[test]
    fn admission_sheds_past_capacity_and_frees_on_drop() {
        let gate = Admission::new(2);
        let a = gate.try_admit().expect("first admit");
        let _b = gate.try_admit().expect("second admit");
        let shed = gate.try_admit().expect_err("third must shed");
        assert!((RETRY_MS_MIN..=RETRY_MS_MAX).contains(&shed));
        assert_eq!(gate.inflight(), 2);
        drop(a);
        assert_eq!(gate.inflight(), 1);
        let _c = gate.try_admit().expect("slot freed by drop");
    }

    #[test]
    fn retry_hint_tracks_recent_latency_and_stays_clamped() {
        let gate = Admission::new(1);
        gate.release_sample(Duration::from_millis(120));
        let _held = gate.try_admit().unwrap();
        let hint = gate.try_admit().expect_err("full");
        assert!(hint >= RETRY_MS_MIN && hint <= RETRY_MS_MAX);
        assert!(hint >= 25, "EWMA of 120ms must push the hint up, got {hint}");
        gate.release_sample(Duration::from_secs(3600));
        let hint = gate.try_admit().expect_err("still full");
        assert_eq!(hint, RETRY_MS_MAX);
    }

    impl Admission {
        /// Test helper: feed a latency sample without holding a permit.
        fn release_sample(&self, d: Duration) {
            self.inflight.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::global().admission_depth.inc();
            self.release(d);
        }
    }
}
