//! Process-wide runtimes: the persistent work-stealing compute pool every
//! CAMUY fan-out routes through ([`pool`], DESIGN.md §11), the epoll
//! readiness wrapper behind the event-loop serve front end ([`netpoll`],
//! Linux only, DESIGN.md §16), and the PJRT runtime that loads and
//! executes the HLO-text artifacts produced by `python/compile/aot.py`
//! (`make artifacts`; Python never runs here).

pub mod artifact;
pub mod client;
#[cfg(target_os = "linux")]
pub mod netpoll;
pub mod pool;

pub use artifact::{default_artifact_dir, ArtifactEntry, Manifest};
pub use client::{CompiledArtifact, PjrtRuntime};
pub use pool::{default_threads, parallel_map, parallel_map_chunked, Pool};
