//! PJRT runtime: loads and executes the HLO-text artifacts produced by
//! `python/compile/aot.py` (`make artifacts`). Python never runs here.

pub mod artifact;
pub mod client;

pub use artifact::{default_artifact_dir, ArtifactEntry, Manifest};
pub use client::{CompiledArtifact, PjrtRuntime};
