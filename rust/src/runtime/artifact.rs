//! The artifact manifest written by `python/compile/aot.py`: names, files,
//! kinds and operand shapes of every AOT-compiled computation.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    /// Input shapes, outermost first.
    pub inputs: Vec<Vec<usize>>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let format = v
            .get("format")
            .and_then(Json::as_str)
            .context("manifest missing 'format'")?;
        anyhow::ensure!(format == "hlo-text", "unsupported artifact format '{format}'");
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .context("artifact missing file")?;
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact missing inputs")?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .context("shape not an array")?
                        .iter()
                        .map(|d| d.as_usize().context("bad dim"))
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactEntry {
                name,
                file: dir.join(file),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                inputs,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// Default artifact directory: `$CAMUY_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("CAMUY_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": [
        {"name": "gemm_quickstart", "file": "gemm_quickstart.hlo.txt",
         "kind": "gemm", "dims": {"m": 128, "k": 128, "n": 128},
         "inputs": [[128, 128], [128, 128]], "hlo_bytes": 1234}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("gemm_quickstart").unwrap();
        assert_eq!(a.kind, "gemm");
        assert_eq!(a.inputs, vec![vec![128, 128], vec![128, 128]]);
        assert_eq!(a.file, Path::new("/tmp/a/gemm_quickstart.hlo.txt"));
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
    }
}
