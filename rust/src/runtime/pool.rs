//! The shared compute runtime: a long-lived work-stealing thread pool
//! (DESIGN.md §11).
//!
//! Every fan-out in CAMUY — the sweep cores, `Engine::eval_batch`, the
//! serve loop's per-batch dispatch, the graph scheduler's node metrics,
//! NSGA-II generation probes — used to spawn OS threads per call through
//! `std::thread::scope`. Under serving traffic that is thousands of
//! spawn/join cycles per second for jobs whose useful work is often
//! microseconds. This module replaces all of them with one process-wide
//! pool of **persistent parked workers**:
//!
//! * **Job model** — a job is a half-open index range `0..n` split into
//!   fixed-size chunks. Executors claim chunks from a shared atomic
//!   cursor (the same chunked work-stealing the scoped pool used, so a
//!   straggler chunk can never idle the pool), run `f(i)` for each index
//!   of the chunk, and the last finished chunk signals completion.
//! * **Caller participation** — the submitting thread is always the
//!   job's first executor: it pushes the job on the queue, wakes
//!   workers, then claims chunks itself until the cursor is exhausted
//!   and only parks for in-flight stragglers. A *nested* submission
//!   (serve request → sweep inside → pool again) therefore always makes
//!   progress on the calling thread even if every worker is busy —
//!   nested jobs cannot deadlock, they only lose parallelism.
//! * **Per-job caps** — `run(n, chunk, cap, f)` bounds how many
//!   executors (caller included) may work one job, preserving the
//!   `threads` semantics of the old per-call pools: `threads = 1` is
//!   exactly serial on the caller.
//! * **Sizing** — the pool spawns `default_threads() - 1` workers (the
//!   caller supplies the remaining executor). `CAMUY_THREADS` overrides
//!   the size; `CAMUY_THREADS=1` spawns no workers at all and every
//!   fan-out in the process degenerates to the serial path, which CI
//!   runs as a separate determinism step.
//!
//! Panics in a job closure poison only that job: remaining chunks are
//! skipped (not left pending — completion still signals) and the payload
//! is re-raised on the submitting thread, matching the scoped-pool
//! behavior where `thread::scope` re-raised on join.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// The hardware parallelism, read once per process (the
/// `available_parallelism` syscall used to run on every sweep and every
/// serve-batch default).
fn hardware_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    })
}

/// Ceiling for `CAMUY_THREADS`: far above any real machine, small enough
/// that a typo cannot ask the pool for a million workers.
const MAX_THREADS: usize = 1024;

/// Default parallelism: `CAMUY_THREADS` if set to a positive integer
/// (clamped to [`MAX_THREADS`]), otherwise the hardware parallelism.
/// Cached in a `OnceLock` — both the env lookup and the syscall happen
/// once per process.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        match std::env::var("CAMUY_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
        {
            Some(n) if n >= 1 => n.min(MAX_THREADS),
            _ => hardware_threads(),
        }
    })
}

/// The submitted closure with its lifetime erased to a raw pointer — a
/// worker-held `Arc<Job>` may outlive the closure's stack frame, so the
/// type deliberately does NOT claim a live reference. Dereferencing is
/// sound only under `Job::execute`'s guard: a chunk index `c < chunks`
/// implies the submitting caller is still blocked in [`Pool::run`]
/// (completion cannot have signaled), so the frame is alive.
struct RawFn(*const (dyn Fn(usize) + Sync));

unsafe impl Send for RawFn {}
unsafe impl Sync for RawFn {}

/// One submitted job: an index range, a chunk cursor, and completion
/// accounting. Lives on the queue behind an `Arc`; the closure behind
/// `f` lives on the submitting caller's stack (see [`RawFn`]).
struct Job {
    /// Total indices.
    n: usize,
    /// Indices per claimed chunk.
    chunk: usize,
    /// Total chunks (`ceil(n / chunk)`).
    chunks: usize,
    /// Next chunk to claim. Exhausted when `>= chunks`.
    next: AtomicUsize,
    /// Chunks fully executed. The executor completing the last chunk
    /// signals `complete` (AcqRel so every executor's writes — including
    /// result-slot publication — happen-before the caller's wakeup).
    done: AtomicUsize,
    /// Executors currently inside the job, caller included.
    executors: AtomicUsize,
    /// Most executors allowed (the job's `threads` bound).
    cap: usize,
    /// Set when a chunk panicked: the remaining chunks are skipped.
    poisoned: AtomicBool,
    /// First panic payload, re-raised by the submitting caller.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    complete: Mutex<bool>,
    complete_cv: Condvar,
    f: RawFn,
    /// The submitter's ambient cancellation token (DESIGN.md §15),
    /// captured at submit so worker threads inherit it across the thread
    /// hop: every chunk re-installs it and checkpoints, so a fired
    /// deadline poisons the job through the existing panic machinery and
    /// re-raises on the submitting caller.
    token: Option<crate::robust::CancelToken>,
}

impl Job {
    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.chunks
    }

    /// Try to become an executor; fails once `cap` executors are inside.
    fn try_join(&self) -> bool {
        let mut e = self.executors.load(Ordering::Relaxed);
        loop {
            if e >= self.cap {
                return false;
            }
            match self.executors.compare_exchange_weak(
                e,
                e + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => e = now,
            }
        }
    }

    fn leave(&self) {
        self.executors.fetch_sub(1, Ordering::Relaxed);
    }

    /// Claim and execute chunks until the cursor is exhausted. Called by
    /// workers and by the submitting caller alike.
    fn execute(&self) {
        loop {
            let c = self.next.fetch_add(1, Ordering::Relaxed);
            if c >= self.chunks {
                return;
            }
            crate::telemetry::global().pool_chunks.add(1);
            if !self.poisoned.load(Ordering::Relaxed) {
                let lo = c * self.chunk;
                let hi = (lo + self.chunk).min(self.n);
                // Safety: `c < chunks` implies the submitting caller is
                // still blocked in `Pool::run`, so the closure's frame is
                // alive (see `RawFn`).
                let f = unsafe { &*self.f.0 };
                let run_chunk = || {
                    crate::robust::checkpoint();
                    for i in lo..hi {
                        f(i);
                    }
                };
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| match &self.token {
                    Some(t) => crate::robust::with_token(t, run_chunk),
                    None => run_chunk(),
                })) {
                    self.poisoned.store(true, Ordering::Relaxed);
                    let mut slot = self.panic.lock().expect("job panic slot");
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
            }
            // AcqRel: chains every executor's prior writes into the final
            // increment, which the completion mutex publishes to the
            // caller.
            if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.chunks {
                let mut g = self.complete.lock().expect("job completion flag");
                *g = true;
                self.complete_cv.notify_all();
            }
        }
    }
}

struct Shared {
    /// Active jobs with unclaimed chunks. Submission order; executors
    /// scan front to back, so earlier jobs drain first.
    queue: Mutex<Vec<Arc<Job>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

/// A work-stealing pool of persistent parked workers. One process-wide
/// instance ([`global`]) backs every CAMUY fan-out; independent instances
/// exist only in tests and benchmarks.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("workers", &self.workers).finish()
    }
}

impl Pool {
    /// Spawn a pool with `workers` persistent worker threads (0 is valid:
    /// every job then runs serially on its submitting caller).
    pub fn new(workers: usize) -> Pool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("camuy-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool {
            shared,
            handles,
            workers,
        }
    }

    /// Persistent worker threads (executors beyond the caller).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(i)` for every `i in 0..n`, blocking until all have
    /// completed. Indices are claimed `chunk` at a time; at most `cap`
    /// executors (the caller plus up to `cap - 1` pool workers) run the
    /// job. `cap <= 1` — or a pool without workers — is exactly the
    /// serial loop on the caller.
    pub fn run(&self, n: usize, chunk: usize, cap: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        let chunks = crate::util::ceil_div(n, chunk);
        let cap = cap.max(1).min(chunks);
        if cap <= 1 || self.workers == 0 {
            // The serial fast path checkpoints per index so deadlines
            // behave identically at `CAMUY_THREADS=1` (a no-op without an
            // ambient token).
            for i in 0..n {
                crate::robust::checkpoint();
                f(i);
            }
            return;
        }
        // Safety: lifetime erasure into a raw pointer (`RawFn`); it is
        // dereferenced exclusively while this frame is alive (`run`
        // blocks on the completion latch below before returning).
        let raw = RawFn(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
        });
        let job = Arc::new(Job {
            n,
            chunk,
            chunks,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            executors: AtomicUsize::new(1), // the caller
            cap,
            poisoned: AtomicBool::new(false),
            panic: Mutex::new(None),
            complete: Mutex::new(false),
            complete_cv: Condvar::new(),
            f: raw,
            token: crate::robust::current(),
        });
        // Telemetry (DESIGN.md §14): the job counter and latency
        // histogram cover the pooled path only — the serial fast path
        // above never queues. The timer starts before the push so the
        // recorded latency is submit-to-completion, queueing included.
        let tel = crate::telemetry::global();
        let timer = crate::telemetry::Timer::start();
        tel.pool_jobs.add(1);
        tel.pool_queue_depth.inc();
        {
            let mut q = self.shared.queue.lock().expect("pool queue");
            q.push(Arc::clone(&job));
        }
        // Wake only as many workers as the job can seat (the caller fills
        // one slot itself) — `notify_all` would stampede a big pool for a
        // 2-executor job, and every woken worker rescans the whole queue
        // anyway, so undershooting on a race only costs parallelism, not
        // progress (the caller always drives its own job).
        for _ in 0..(cap - 1).min(self.workers) {
            self.shared.work_cv.notify_one();
        }
        // Participate: the caller is executor #1. With every chunk
        // claimed, park for the in-flight stragglers only.
        job.execute();
        {
            let mut done = job.complete.lock().expect("job completion flag");
            while !*done {
                done = job.complete_cv.wait(done).expect("job completion wait");
            }
        }
        // Workers prune exhausted jobs opportunistically; make sure this
        // one is gone before the closure's frame unwinds.
        {
            let mut q = self.shared.queue.lock().expect("pool queue");
            q.retain(|j| !Arc::ptr_eq(j, &job));
        }
        tel.pool_queue_depth.dec();
        timer.observe_into(&tel.pool_job_latency);
        if let Some(payload) = job.panic.lock().expect("job panic slot").take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // The shutdown flag must flip while holding the queue mutex:
        // workers check it and park under one continuous hold of that
        // lock, so an unlocked store+notify could land entirely inside a
        // worker's check-to-wait window and strand it on a notification
        // that already fired (deadlocking the join below).
        {
            let _q = self.shared.queue.lock().expect("pool queue");
            self.shared.shutdown.store(true, Ordering::Relaxed);
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut q = shared.queue.lock().expect("pool queue");
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            return;
        }
        // Prune exhausted jobs (their stragglers finish on the executors
        // already inside), then join the first job with open chunks and
        // executor headroom.
        q.retain(|j| !j.exhausted());
        let mut picked = None;
        for j in q.iter() {
            if !j.exhausted() && j.try_join() {
                picked = Some(Arc::clone(j));
                break;
            }
        }
        match picked {
            Some(job) => {
                drop(q);
                crate::telemetry::global().pool_steals.add(1);
                job.execute();
                job.leave();
                q = shared.queue.lock().expect("pool queue");
            }
            None => {
                // The parked gauge is inc/dec-paired around the wait
                // (never flag-gated), so it reads true even across
                // enable toggles.
                let tel = crate::telemetry::global();
                tel.pool_workers_parked.inc();
                q = shared.work_cv.wait(q).expect("pool wait");
                tel.pool_workers_parked.dec();
            }
        }
    }
}

/// The process-wide pool: `default_threads() - 1` persistent workers
/// (the submitting caller is always the remaining executor).
pub fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool::new(default_threads().saturating_sub(1)))
}

/// Run `f(i)` for `0..n` on the global pool with up to `threads`
/// executors, collecting results in index order. Chunk size 1 — each
/// index is stolen individually (jobs whose per-index work is heavy:
/// serve requests, graph nodes, NSGA-II probes).
pub fn parallel_map<T: Send + Sync>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    parallel_map_chunked(n, threads, 1, f)
}

/// [`parallel_map`] claiming `chunk` consecutive indices per steal — the
/// sweep cores' dispatch shape, where a cell is a few hundred
/// nanoseconds and per-index stealing overhead would be visible.
pub fn parallel_map_chunked<T: Send + Sync>(
    n: usize,
    threads: usize,
    chunk: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let cap = threads.max(1).min(n);
    if cap <= 1 || global().workers() == 0 {
        return (0..n)
            .map(|i| {
                crate::robust::checkpoint();
                f(i)
            })
            .collect();
    }
    let slots: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    global().run(n, chunk, cap, &|i| {
        let _ = slots[i].set(f(i));
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("all slots filled"))
        .collect()
}

/// A write-once scatter view over a result buffer: the work units of
/// [`parallel_scatter`] publish each result under its own index, so a
/// unit may produce results for an arbitrary subset of `0..n` (the
/// blocked sweep dispatch reorders cells block-major but must return
/// them in request order).
pub struct Scatter<'a, T> {
    slots: &'a [OnceLock<T>],
}

impl<T> Scatter<'_, T> {
    /// Publish the result for index `i`. Writing an index twice is a bug
    /// in the caller's unit decomposition and panics.
    pub fn set(&self, i: usize, value: T) {
        if self.slots[i].set(value).is_err() {
            panic!("scatter index {i} written twice");
        }
    }
}

/// Run `f(u, &scatter)` for every unit `u in 0..units` on the global
/// pool with up to `threads` executors, where the units collectively
/// publish exactly one result per index in `0..n`; returns the results
/// in index order. This is [`parallel_map_chunked`] with the
/// index-to-unit mapping inverted: the *caller* decides how indices
/// group into stealable units (the blocked sweep dispatch makes one
/// unit per cache block run), instead of the pool slicing `0..n` into
/// fixed-size chunks. Panics if a unit leaves an index unwritten.
pub fn parallel_scatter<T: Send + Sync>(
    n: usize,
    threads: usize,
    units: usize,
    f: impl Fn(usize, &Scatter<'_, T>) + Sync,
) -> Vec<T> {
    let slots: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    let scatter = Scatter { slots: &slots };
    let cap = threads.max(1).min(units);
    if cap <= 1 || global().workers() == 0 {
        for u in 0..units {
            crate::robust::checkpoint();
            f(u, &scatter);
        }
    } else {
        global().run(units, 1, cap, &|u| f(u, &scatter));
    }
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("every index scattered"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let pool = Pool::new(3);
        for n in [0usize, 1, 2, 63, 64, 65, 1000] {
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, 7, 4, &|i| {
                counts[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "index {i} of n={n}");
            }
        }
    }

    #[test]
    fn zero_worker_pool_runs_serially_on_the_caller() {
        let pool = Pool::new(0);
        let caller = std::thread::current().id();
        let sum = AtomicUsize::new(0);
        pool.run(100, 8, 16, &|i| {
            assert_eq!(std::thread::current().id(), caller);
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn nested_submission_completes_without_deadlock() {
        // Outer job saturates the pool; each outer index submits an inner
        // job. The inner callers participate in their own jobs, so this
        // terminates even with a single worker.
        let pool = Pool::new(1);
        let total = AtomicUsize::new(0);
        pool.run(8, 1, 4, &|_| {
            pool.run(16, 2, 4, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 16);
    }

    #[test]
    fn concurrent_jobs_share_the_pool() {
        let pool = Arc::new(Pool::new(2));
        let hits = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let hits = Arc::clone(&hits);
                s.spawn(move || {
                    pool.run(50, 4, 3, &|_| {
                        hits.fetch_add(1, Ordering::Relaxed);
                    });
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn parallel_map_matches_serial_in_order() {
        let serial: Vec<usize> = (0..500).map(|i| i * i).collect();
        assert_eq!(parallel_map(500, 8, |i| i * i), serial);
        assert_eq!(parallel_map_chunked(500, 8, 32, |i| i * i), serial);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn parallel_scatter_returns_index_order_for_unit_major_writes() {
        // 10 units of 50 indices each, written in a unit-local order that
        // differs from the index order — the result must still come back
        // index-major, for both the serial and the pooled path.
        for threads in [1usize, 8] {
            let out = parallel_scatter(500, threads, 10, |u, s| {
                for j in (0..50).rev() {
                    let i = u * 50 + j;
                    s.set(i, i * i);
                }
            });
            assert_eq!(out, (0..500).map(|i| i * i).collect::<Vec<usize>>());
        }
        // Degenerate shapes: no indices, and more units than indices.
        assert_eq!(parallel_scatter(0, 4, 0, |_, _: &Scatter<usize>| {}), vec![]);
        let one = parallel_scatter(1, 4, 3, |u, s: &Scatter<usize>| {
            if u == 2 {
                s.set(0, 7);
            }
        });
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn parallel_scatter_panics_on_a_double_write() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            parallel_scatter(2, 1, 2, |_, s: &Scatter<usize>| s.set(0, 1))
        }));
        assert!(r.is_err(), "double write must panic");
    }

    #[test]
    fn cap_one_is_exactly_serial() {
        let caller = std::thread::current().id();
        let out = parallel_map(64, 1, |i| {
            assert_eq!(std::thread::current().id(), caller);
            i + 1
        });
        assert_eq!(out, (1..=64).collect::<Vec<usize>>());
    }

    #[test]
    fn job_panic_propagates_to_the_caller_and_pool_survives() {
        let pool = Pool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(100, 1, 3, &|i| {
                if i == 17 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the submitting caller");
        // The pool still works afterwards.
        let sum = AtomicUsize::new(0);
        pool.run(10, 2, 3, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn cancelled_token_poisons_the_job_and_reaches_the_caller() {
        // Workers inherit the submitter's ambient token: once the token
        // fires, the next chunk checkpoint unwinds with `Cancelled`, the
        // job poisons (remaining chunks skipped), and the payload
        // re-raises on the submitting caller — on any thread.
        let pool = Pool::new(2);
        let token = crate::robust::CancelToken::manual();
        let executed = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            crate::robust::with_token(&token, || {
                pool.run(1000, 1, 3, &|i| {
                    if i == 5 {
                        token.cancel();
                    }
                    executed.fetch_add(1, Ordering::Relaxed);
                });
            })
        }));
        let payload = r.expect_err("cancellation must reach the caller");
        assert!(
            payload.downcast_ref::<crate::robust::Cancelled>().is_some(),
            "payload must be Cancelled"
        );
        assert!(
            executed.load(Ordering::Relaxed) < 1000,
            "poisoning must skip chunks after the cancel"
        );
        // The pool survives and the worker's ambient token was restored.
        let sum = AtomicUsize::new(0);
        pool.run(10, 2, 3, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn default_threads_is_cached_and_positive() {
        let a = default_threads();
        let b = default_threads();
        assert_eq!(a, b);
        assert!(a >= 1);
        assert!(a <= MAX_THREADS);
    }
}
