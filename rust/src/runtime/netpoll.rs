//! A thin readiness-notification wrapper over raw `epoll(7)` syscalls —
//! the I/O substrate of the event-loop serve front end (DESIGN.md §16).
//!
//! Like every other OS touchpoint in this crate, the binding is a raw
//! `extern "C"` shim rather than a `libc` dependency (DESIGN.md §6): the
//! offline image ships no crates, and the four calls needed here —
//! `epoll_create1`, `epoll_ctl`, `epoll_wait`, `eventfd` — have stable
//! kernel ABIs. The wrapper is deliberately small: register a file
//! descriptor under a caller-chosen `u64` token with a level-triggered
//! interest mask, wait for readiness, read the tokens back. Everything
//! stateful (connection tables, buffers, timers) lives in the caller.
//!
//! [`Waker`] wraps an `eventfd(2)`: worker threads that finish a batch
//! call [`Waker::wake`] so the poller returns immediately instead of
//! riding out its timeout. The eventfd is nonblocking and the counter
//! saturates, so waking is cheap, lock-free and never blocks the waker.

use std::io;
use std::os::fd::RawFd;

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

const EPOLL_CLOEXEC: i32 = 0o2000000;
const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000;

/// Readiness: data to read (or a listener with a pending accept).
pub const EPOLLIN: u32 = 0x001;
/// Readiness: the socket's send buffer has room.
pub const EPOLLOUT: u32 = 0x004;
/// Error condition (always reported; no need to register it).
pub const EPOLLERR: u32 = 0x008;
/// Hangup — both directions closed (always reported).
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its write side (half-close); must be registered.
pub const EPOLLRDHUP: u32 = 0x2000;

/// The kernel's `struct epoll_event`. On x86 the kernel ABI packs the
/// 12-byte struct (no padding between `events` and `data`); everywhere
/// else it is naturally aligned — get this wrong and `epoll_wait` writes
/// tokens into the wrong offsets.
#[repr(C)]
#[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
#[derive(Debug, Clone, Copy)]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

impl EpollEvent {
    /// An empty slot for the wait buffer.
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }

    /// The token the file descriptor was registered under. (Returned by
    /// value: the struct may be packed, so a reference to the field would
    /// be unaligned.)
    pub fn token(&self) -> u64 {
        self.data
    }

    /// The raw readiness bits.
    pub fn bits(&self) -> u32 {
        self.events
    }

    /// Data (or a pending accept) is available, or the peer half-closed —
    /// either way a read will not block.
    pub fn readable(&self) -> bool {
        self.bits() & (EPOLLIN | EPOLLRDHUP) != 0
    }

    /// The send buffer has room.
    pub fn writable(&self) -> bool {
        self.bits() & EPOLLOUT != 0
    }

    /// The descriptor is in an error or fully-hung-up state; the owner
    /// should tear the connection down.
    pub fn failed(&self) -> bool {
        self.bits() & (EPOLLERR | EPOLLHUP) != 0
    }

    /// The peer closed its write side (half-close): drain what remains,
    /// expect EOF.
    pub fn peer_closed(&self) -> bool {
        self.bits() & EPOLLRDHUP != 0
    }
}

/// A level-triggered epoll instance. Level-triggered (the default) keeps
/// the state machine simple: a readiness condition the owner did not
/// fully service is simply reported again on the next wait, so partial
/// reads and deferred writes need no re-arming bookkeeping.
#[derive(Debug)]
pub struct Poller {
    epfd: RawFd,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent {
            events: interest,
            data: token,
        };
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` under `token` with the given interest mask.
    pub fn add(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Change the interest mask (and token) of a registered descriptor.
    pub fn modify(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Deregister a descriptor. Closing the fd deregisters it too, but an
    /// explicit delete keeps the interest table honest while the fd is
    /// still open.
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        // Pre-2.6.9 kernels demanded a non-null event pointer for DEL;
        // every kernel this crate can run on ignores it.
        let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, std::ptr::null_mut()) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Block until at least one registered descriptor is ready or
    /// `timeout_ms` elapses (`-1` = forever). Fills `events` from the
    /// front and returns how many entries are valid. Interrupted waits
    /// (EINTR — e.g. the SIGTERM that starts a drain) retry with the same
    /// timeout; the caller's loop re-checks its flags every wakeup anyway.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let rc = unsafe {
                epoll_wait(
                    self.epfd,
                    events.as_mut_ptr(),
                    events.len() as i32,
                    timeout_ms,
                )
            };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        unsafe {
            close(self.epfd);
        }
    }
}

/// Cross-thread poller wakeup over an `eventfd(2)`. Register
/// [`Waker::fd`] with the poller under a reserved token; any thread may
/// then call [`wake`](Waker::wake) to make the next (or current)
/// `epoll_wait` return immediately.
#[derive(Debug)]
pub struct Waker {
    fd: RawFd,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Waker { fd })
    }

    /// The descriptor to register for `EPOLLIN`.
    pub fn fd(&self) -> RawFd {
        self.fd
    }

    /// Make the poller's wait return. Failure modes are all benign — a
    /// full counter (EAGAIN) means a wake is already pending — so the
    /// result is ignored.
    pub fn wake(&self) {
        let one: u64 = 1;
        unsafe {
            write(self.fd, &one as *const u64 as *const u8, 8);
        }
    }

    /// Consume pending wakeups so a level-triggered poller stops
    /// reporting the waker readable.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        unsafe {
            read(self.fd, &mut buf as *mut u64 as *mut u8, 8);
        }
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::os::fd::AsRawFd;

    #[test]
    fn listener_readiness_carries_the_registered_token() {
        let poller = Poller::new().unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        poller.add(listener.as_raw_fd(), 7, EPOLLIN).unwrap();

        let mut events = vec![EpollEvent::zeroed(); 8];
        // Nothing pending: the wait times out empty.
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);

        let addr = listener.local_addr().unwrap();
        let _client = std::net::TcpStream::connect(addr).unwrap();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 7);
        assert!(events[0].readable());
        assert!(!events[0].failed());
    }

    #[test]
    fn modify_switches_interest_between_read_and_write() {
        let poller = Poller::new().unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        // An idle connected socket with write interest is immediately
        // writable; with read interest it is quiet until bytes arrive.
        poller.add(server.as_raw_fd(), 3, EPOLLOUT).unwrap();
        let mut events = vec![EpollEvent::zeroed(); 8];
        let n = poller.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert!(events[0].writable());

        poller.modify(server.as_raw_fd(), 3, EPOLLIN).unwrap();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);
        client.write_all(b"ping\n").unwrap();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 3);
        assert!(events[0].readable());

        poller.delete(server.as_raw_fd()).unwrap();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn waker_wakes_and_drains() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        poller.add(waker.fd(), 1, EPOLLIN).unwrap();

        let mut events = vec![EpollEvent::zeroed(); 8];
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);

        // Wakes coalesce: two wakes, one readable event, one drain.
        waker.wake();
        waker.wake();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 1);
        waker.drain();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn waker_works_across_threads() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        poller.add(waker.fd(), 9, EPOLLIN).unwrap();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                waker.wake();
            });
            let mut events = vec![EpollEvent::zeroed(); 8];
            let n = poller.wait(&mut events, 5000).unwrap();
            assert_eq!(n, 1);
            assert_eq!(events[0].token(), 9);
            waker.drain();
        });
    }
}
