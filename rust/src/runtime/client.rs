//! PJRT bridge: load AOT-compiled HLO-text artifacts, compile them once on
//! the CPU PJRT client, and execute them from the Rust hot path. Python is
//! never invoked here — the artifacts are self-contained.
//!
//! The bridge needs the vendored `xla` crate, which the offline build image
//! does not ship; it is therefore gated behind the `pjrt` cargo feature.
//! Without the feature the same public API compiles to an explicit stub
//! whose constructor reports the missing backend, so every caller (CLI
//! `verify`, examples, the e2e bench) degrades gracefully instead of
//! breaking the build (DESIGN.md §6).
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! (text interchange — the 0.5.1 xla_extension rejects jax>=0.5 serialized
//! protos) → `XlaComputation::from_proto` → `client.compile` → `execute`,
//! unwrapping the 1-tuple the exporter emits.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::tensor::Matrix;
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A PJRT CPU runtime holding compiled executables.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    /// One compiled artifact ready to run.
    pub struct CompiledArtifact {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtRuntime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact.
        pub fn load(&self, name: &str, path: &Path) -> Result<CompiledArtifact> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            Ok(CompiledArtifact {
                exe,
                name: name.to_string(),
            })
        }
    }

    impl CompiledArtifact {
        /// Execute with rank-N f32 inputs given as (shape, data) pairs; returns
        /// the flat f32 payload of the single tuple output.
        pub fn run_raw(&self, inputs: &[(&[i64], &[f32])]) -> Result<Vec<f32>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(shape, data)| {
                    let lit = xla::Literal::vec1(data);
                    lit.reshape(shape)
                        .with_context(|| format!("reshape input to {shape:?}"))
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing '{}'", self.name))?[0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True.
            let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Execute a 2-input GEMM-shaped artifact on matrices.
        pub fn run_gemm(&self, a: &Matrix, w: &Matrix) -> Result<Matrix> {
            let out = self.run_raw(&[
                (&[a.rows as i64, a.cols as i64], a.data()),
                (&[w.rows as i64, w.cols as i64], w.data()),
            ])?;
            anyhow::ensure!(
                out.len() == a.rows * w.cols,
                "output length {} != {}x{}",
                out.len(),
                a.rows,
                w.cols
            );
            Ok(Matrix::from_vec(a.rows, w.cols, out))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::tensor::Matrix;
    use anyhow::Result;
    use std::path::Path;

    const UNAVAILABLE: &str = "CAMUY was built without the `pjrt` feature; rebuild with \
         `--features pjrt` in an environment that vendors the `xla` crate to execute \
         AOT artifacts";

    /// Stub runtime: same API surface, constructor reports the missing
    /// backend.
    pub struct PjrtRuntime {
        _private: (),
    }

    /// Stub compiled artifact (never constructed — `load` always errors).
    pub struct CompiledArtifact {
        pub name: String,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            anyhow::bail!("{}", UNAVAILABLE)
        }

        pub fn platform(&self) -> String {
            "pjrt-unavailable".to_string()
        }

        pub fn load(&self, _name: &str, _path: &Path) -> Result<CompiledArtifact> {
            anyhow::bail!("{}", UNAVAILABLE)
        }
    }

    impl CompiledArtifact {
        pub fn run_raw(&self, _inputs: &[(&[i64], &[f32])]) -> Result<Vec<f32>> {
            anyhow::bail!("{}", UNAVAILABLE)
        }

        pub fn run_gemm(&self, _a: &Matrix, _w: &Matrix) -> Result<Matrix> {
            anyhow::bail!("{}", UNAVAILABLE)
        }
    }
}

pub use imp::{CompiledArtifact, PjrtRuntime};
