//! The Accumulator Array: buffers partial sums produced by the bottom PE
//! row across row-tile passes, and drains finished chunks back to the
//! Unified Buffer. Capacity is a single shared budget of entries
//! (DESIGN.md §3.1) — the knob whose interaction with array width drives
//! the paper's tall-narrow recommendation.

/// Accumulator state for one (col-tile, M-chunk) window.
#[derive(Debug)]
pub struct AccumulatorArray {
    capacity: usize,
    /// Current window geometry.
    rows: usize,
    cols: usize,
    buf: Vec<f32>,
    pub writes: u64,
    pub reads: u64,
}

impl AccumulatorArray {
    pub fn new(capacity: usize) -> AccumulatorArray {
        assert!(capacity > 0);
        AccumulatorArray {
            capacity,
            rows: 0,
            cols: 0,
            buf: Vec::new(),
            writes: 0,
            reads: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Row budget for an active tile width, `max(1, capacity / n_t)`.
    pub fn row_budget(&self, n_t: usize) -> usize {
        (self.capacity / n_t).max(1)
    }

    /// Open a fresh accumulation window of `rows x cols` zeroed entries.
    /// Panics if the window exceeds capacity (the control unit must chunk),
    /// except for the degenerate 1-row window that a too-small capacity
    /// still has to admit.
    pub fn open(&mut self, rows: usize, cols: usize) {
        assert!(
            rows * cols <= self.capacity || rows == 1,
            "accumulator window {rows}x{cols} exceeds capacity {}",
            self.capacity
        );
        self.rows = rows;
        self.cols = cols;
        self.buf.clear();
        self.buf.resize(rows * cols, 0.0);
    }

    /// Accumulate one partial sum arriving from the array's bottom row.
    #[inline]
    pub fn accumulate(&mut self, row: usize, col: usize, psum: f32) {
        debug_assert!(row < self.rows && col < self.cols);
        self.writes += 1;
        self.buf[row * self.cols + col] += psum;
    }

    /// Drain the window; calls `sink(row, col, value)` for each entry.
    pub fn drain(&mut self, mut sink: impl FnMut(usize, usize, f32)) {
        for r in 0..self.rows {
            for c in 0..self.cols {
                self.reads += 1;
                sink(r, c, self.buf[r * self.cols + c]);
            }
        }
        self.rows = 0;
        self.cols = 0;
        self.buf.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_passes() {
        let mut aa = AccumulatorArray::new(16);
        aa.open(2, 2);
        aa.accumulate(0, 0, 1.0);
        aa.accumulate(0, 0, 2.5);
        aa.accumulate(1, 1, -1.0);
        let mut out = vec![];
        aa.drain(|r, c, v| out.push((r, c, v)));
        assert_eq!(out, vec![(0, 0, 3.5), (0, 1, 0.0), (1, 0, 0.0), (1, 1, -1.0)]);
        assert_eq!(aa.writes, 3);
        assert_eq!(aa.reads, 4);
    }

    #[test]
    fn row_budget_math() {
        let aa = AccumulatorArray::new(4096);
        assert_eq!(aa.row_budget(256), 16);
        assert_eq!(aa.row_budget(16), 256);
        assert_eq!(aa.row_budget(8192), 1); // clamp
    }

    #[test]
    fn reopen_zeroes() {
        let mut aa = AccumulatorArray::new(8);
        aa.open(1, 2);
        aa.accumulate(0, 0, 5.0);
        aa.drain(|_, _, _| {});
        aa.open(1, 2);
        let mut vals = vec![];
        aa.drain(|_, _, v| vals.push(v));
        assert_eq!(vals, vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "exceeds capacity")]
    fn oversized_window_panics() {
        let mut aa = AccumulatorArray::new(4);
        aa.open(2, 4);
    }

    #[test]
    fn degenerate_single_row_allowed() {
        // Capacity smaller than the active width still admits 1-row windows.
        let mut aa = AccumulatorArray::new(2);
        aa.open(1, 8);
        aa.accumulate(0, 7, 1.0);
        let mut n = 0;
        aa.drain(|_, _, _| n += 1);
        assert_eq!(n, 8);
    }
}
