//! The Unified Buffer: the single on-chip memory holding weights, input
//! activations and output activations (the paper's departure from TPUv1,
//! which kept weights off-chip). All traffic through it is counted.

use crate::tensor::Matrix;

/// Counted storage for one GEMM's operands.
#[derive(Debug)]
pub struct UnifiedBuffer {
    a: Matrix, // activations  M x K
    w: Matrix, // weights      K x N
    c: Matrix, // outputs      M x N
    pub act_reads: u64,
    pub weight_reads: u64,
    pub out_writes: u64,
}

impl UnifiedBuffer {
    pub fn new(a: Matrix, w: Matrix) -> UnifiedBuffer {
        let c = Matrix::zeros(a.rows, w.cols);
        UnifiedBuffer {
            a,
            w,
            c,
            act_reads: 0,
            weight_reads: 0,
            out_writes: 0,
        }
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.a.rows, self.a.cols, self.w.cols)
    }

    /// Read one activation (SDS fetch).
    #[inline]
    pub fn read_act(&mut self, row: usize, k: usize) -> f32 {
        self.act_reads += 1;
        self.a[(row, k)]
    }

    /// Read one weight (Weight Fetcher fetch).
    #[inline]
    pub fn read_weight(&mut self, k: usize, n: usize) -> f32 {
        self.weight_reads += 1;
        self.w[(k, n)]
    }

    /// Write one final output activation.
    #[inline]
    pub fn write_out(&mut self, row: usize, n: usize, v: f32) {
        self.out_writes += 1;
        self.c[(row, n)] = v;
    }

    /// Finished output matrix (consumes the buffer).
    pub fn into_output(self) -> Matrix {
        self.c
    }

    /// Bytes resident: operands + outputs at the configured widths. Used
    /// for UB sizing reports.
    pub fn footprint_bytes(&self, act_bits: u32, weight_bits: u32, out_bits: u32) -> u64 {
        let a = (self.a.rows * self.a.cols) as u64 * act_bits as u64;
        let w = (self.w.rows * self.w.cols) as u64 * weight_bits as u64;
        let c = (self.c.rows * self.c.cols) as u64 * out_bits as u64;
        (a + w + c) / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_every_access() {
        let a = Matrix::from_fn(2, 3, |r, c| (r + c) as f32);
        let w = Matrix::from_fn(3, 2, |r, c| (r * c) as f32);
        let mut ub = UnifiedBuffer::new(a, w);
        assert_eq!(ub.dims(), (2, 3, 2));
        let v = ub.read_act(1, 2);
        assert_eq!(v, 3.0);
        ub.read_weight(2, 1);
        ub.write_out(0, 0, 7.0);
        assert_eq!((ub.act_reads, ub.weight_reads, ub.out_writes), (1, 1, 1));
        let c = ub.into_output();
        assert_eq!(c[(0, 0)], 7.0);
    }

    #[test]
    fn footprint_bytes_uses_bitwidths() {
        let ub = UnifiedBuffer::new(Matrix::zeros(4, 4), Matrix::zeros(4, 4));
        // 16 acts * 8b + 16 weights * 8b + 16 outs * 32b = 16+16+64 bytes.
        assert_eq!(ub.footprint_bytes(8, 8, 32), 96);
    }
}
