//! The functional, cycle-level emulator of the CAMUY processor (Fig. 1 of
//! the paper): PE array, Systolic Data Setup FIFOs, Weight Fetcher,
//! Accumulator Array, Unified Buffer, Main Control Unit.
//!
//! It computes real GEMMs (validating numerics against plain matmul and
//! the AOT-compiled XLA artifacts) while counting every buffer and
//! register access; the analytic model in `crate::model` must agree with
//! it counter-for-counter, cycle-for-cycle (property-tested).

pub mod accumulator;
pub mod array;
pub mod control;
pub mod fifo;
pub mod pe;
pub mod unified_buffer;
pub mod weight_fetcher;

pub use control::{EmulationMode, EmulationResult, Emulator};
