//! A single processing element: the 4-register arrangement of the paper
//! (a variant of Kung/Mead-Conway): two weight registers for double
//! buffering, one activation register, one partial-sum register.
//!
//! The hot emulation loop in `array.rs` operates on struct-of-arrays for
//! speed; this module is the authoritative register-level semantics that
//! the array code mirrors, and it is unit-tested on its own.

/// Register file of one PE.
#[derive(Debug, Clone, Copy, Default)]
pub struct Pe {
    /// Active weight register (read by the MAC).
    pub weight: f32,
    /// Shadow weight register (written by loads; swapped at pass start).
    pub weight_shadow: f32,
    /// Activation register (written from the left neighbour / FIFO).
    pub act: f32,
    /// Partial-sum register (written from the upper neighbour, then MAC).
    pub psum: f32,
}

/// Register access counts of one PE operation, so the array can account
/// intra-PE movement exactly as DESIGN.md §3 defines it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeAccessCounts {
    pub intra_reads: u32,
    pub intra_writes: u32,
}

impl Pe {
    /// Latch a new shadow weight (during a tile load). 1 intra write.
    pub fn load_shadow(&mut self, w: f32) -> PeAccessCounts {
        self.weight_shadow = w;
        PeAccessCounts {
            intra_reads: 0,
            intra_writes: 1,
        }
    }

    /// Swap shadow into active at pass start. 1 intra write (the active
    /// register is rewritten; the shadow read is free in a flip-flop swap).
    pub fn activate_weight(&mut self) -> PeAccessCounts {
        self.weight = self.weight_shadow;
        PeAccessCounts {
            intra_reads: 0,
            intra_writes: 1,
        }
    }

    /// One MAC step: latch the incoming activation, read the weight,
    /// combine with the incoming partial sum, latch the result.
    ///
    /// Access accounting (5 per MAC): act write + act read + weight read +
    /// psum read(in) + psum write. The *inter*-PE hops (reading the left
    /// neighbour's act register / the upper neighbour's psum register) are
    /// counted by the array, which knows the topology.
    pub fn mac(&mut self, act_in: f32, psum_in: f32) -> (f32, PeAccessCounts) {
        self.act = act_in; // act reg write
        let a = self.act; // act reg read
        let w = self.weight; // weight reg read
        self.psum = psum_in + w * a; // psum read (in) + psum write
        (
            self.psum,
            PeAccessCounts {
                intra_reads: 3,
                intra_writes: 2,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_computes_and_counts() {
        let mut pe = Pe::default();
        pe.load_shadow(3.0);
        pe.activate_weight();
        let (out, counts) = pe.mac(2.0, 10.0);
        assert_eq!(out, 16.0);
        assert_eq!(counts.intra_reads + counts.intra_writes, 5);
    }

    #[test]
    fn double_buffering_isolates_active_weight() {
        let mut pe = Pe::default();
        pe.load_shadow(1.0);
        pe.activate_weight();
        // Loading the next tile must not disturb the active weight.
        pe.load_shadow(99.0);
        let (out, _) = pe.mac(1.0, 0.0);
        assert_eq!(out, 1.0);
        pe.activate_weight();
        let (out, _) = pe.mac(1.0, 0.0);
        assert_eq!(out, 99.0);
    }

    #[test]
    fn load_and_swap_cost_one_write_each() {
        let mut pe = Pe::default();
        assert_eq!(pe.load_shadow(5.0).intra_writes, 1);
        assert_eq!(pe.activate_weight().intra_writes, 1);
    }
}
