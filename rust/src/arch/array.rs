//! The PE grid itself. Two execution engines produce identical results and
//! identical counters:
//!
//! * [`SystolicArray::stream_pass_cycle`] — literal cycle-stepped emulation:
//!   every cycle, every PE holding valid data fires, reading its left
//!   neighbour's activation register and its upper neighbour's partial-sum
//!   register as of the previous cycle (enforced by update order).
//! * [`SystolicArray::stream_pass_wavefront`] — the fast engine: iterates
//!   MAC events in wavefront order without scanning idle PEs. This is what
//!   `camuy emulate` runs; the cycle engine validates it in tests.
//!
//! Both count movements identically: 5 intra-PE register accesses per MAC,
//! one inter-PE activation hop per MAC with c > 0, one inter-PE psum hop
//! per MAC with d > 0, and d shift-down hops for a weight landing in row d.

use crate::arch::accumulator::AccumulatorArray;
use crate::arch::fifo::SystolicDataSetup;
use crate::arch::pe::Pe;
use crate::arch::weight_fetcher::WeightTile;

/// Movement counters owned by the grid.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArrayCounters {
    pub inter_act: u64,
    pub inter_psum: u64,
    pub inter_weight: u64,
    pub intra: u64,
    pub macs: u64,
}

#[derive(Debug)]
pub struct SystolicArray {
    pub height: usize,
    pub width: usize,
    pes: Vec<Pe>,
    /// Active extent of the currently loaded tile.
    k_t: usize,
    n_t: usize,
    pub counters: ArrayCounters,
}

impl SystolicArray {
    pub fn new(height: usize, width: usize) -> SystolicArray {
        assert!(height > 0 && width > 0);
        SystolicArray {
            height,
            width,
            pes: vec![Pe::default(); height * width],
            k_t: 0,
            n_t: 0,
            counters: ArrayCounters::default(),
        }
    }

    /// PE storage is column-major (`pes[c * height + d]`): the fast
    /// engine's inner loop walks a column (d ascending) contiguously
    /// (§Perf iteration 4).
    #[inline]
    fn pe(&mut self, d: usize, c: usize) -> &mut Pe {
        &mut self.pes[c * self.height + d]
    }

    /// Push a staged tile into the shadow registers: weight for row d
    /// shifts down through d PEs (inter-PE weight hops), then latches
    /// (1 intra write).
    pub fn load_shadow_tile(&mut self, tile: &WeightTile) {
        assert!(tile.k_t <= self.height && tile.n_t <= self.width);
        for d in 0..tile.k_t {
            for c in 0..tile.n_t {
                let counts = self.pe(d, c).load_shadow(tile.at(d, c));
                self.counters.intra += counts.intra_writes as u64;
                self.counters.inter_weight += d as u64;
            }
        }
    }

    /// Swap shadow -> active over the tile extent (1 intra write per PE)
    /// and record the live extent for the coming pass.
    pub fn activate_tile(&mut self, k_t: usize, n_t: usize) {
        assert!(k_t <= self.height && n_t <= self.width);
        for d in 0..k_t {
            for c in 0..n_t {
                let counts = self.pe(d, c).activate_weight();
                self.counters.intra += counts.intra_writes as u64;
            }
        }
        self.k_t = k_t;
        self.n_t = n_t;
    }

    /// Fast engine: stream `rows` activation rows (each `k_t` long, already
    /// fetched by the SDS) through the active tile, emitting bottom-row
    /// partial sums into the accumulator.
    ///
    /// `acts[r]` is the r-th activation row restricted to the tile's K
    /// window. Emits `aa.accumulate(r, c, psum)` exactly once per (r, c).
    pub fn stream_pass_wavefront(&mut self, acts: &[Vec<f32>], aa: &mut AccumulatorArray) {
        let (k_t, n_t) = (self.k_t, self.n_t);
        assert!(k_t > 0 && n_t > 0, "no active tile");
        for (r, row) in acts.iter().enumerate() {
            assert_eq!(row.len(), k_t);
            for c in 0..n_t {
                // Inlined Pe::mac register semantics (act latch, weight
                // read, psum chain) — the hot loop of the fast engine.
                // §Perf iteration 1: per-event counter increments hoisted
                // to the exact bulk equivalents below; the cycle-accurate
                // engine still counts every event individually and the
                // property tests keep the two engines equal.
                let mut psum = 0.0f32;
                let col = &mut self.pes[c * self.height..c * self.height + k_t];
                for (pe, &a) in col.iter_mut().zip(row.iter()) {
                    pe.act = a;
                    psum += pe.weight * pe.act;
                    pe.psum = psum;
                }
                aa.accumulate(r, c, psum);
            }
        }
        let rows = acts.len() as u64;
        let (k, n) = (k_t as u64, n_t as u64);
        let macs = rows * k * n;
        self.counters.macs += macs;
        self.counters.intra += 5 * macs; // act w+r, weight r, psum r+w
        self.counters.inter_act += rows * k * (n - 1); // active hops
        self.counters.inter_psum += rows * n * (k - 1);
        self.add_passthrough_hops(acts.len());
    }

    /// Propagation beyond the active extent — the array has no clock
    /// gating, so activations continue rightward through the idle columns
    /// and partial sums descend through the idle rows below the tile
    /// before reaching the accumulators (DESIGN.md §3). Counted in bulk;
    /// values are unchanged by pass-through so numerics are unaffected.
    fn add_passthrough_hops(&mut self, rows: usize) {
        let (k_t, n_t) = (self.k_t, self.n_t);
        self.counters.inter_act += (rows * k_t * (self.width - n_t)) as u64;
        self.counters.inter_psum += (rows * n_t * (self.height - k_t)) as u64;
    }

    /// Literal cycle-stepped engine. Activations are staged in the SDS
    /// (row r begins entering at cycle r); PEs update in decreasing (d, c)
    /// order so neighbour reads observe previous-cycle register state.
    /// Returns the number of cycles stepped, which must equal the pass
    /// duration formula `Mc + k_t + n_t - 2`.
    pub fn stream_pass_cycle(
        &mut self,
        sds: &mut SystolicDataSetup,
        rows: usize,
        aa: &mut AccumulatorArray,
    ) -> u64 {
        let (k_t, n_t) = (self.k_t, self.n_t);
        assert!(k_t > 0 && n_t > 0, "no active tile");
        let total_cycles = (rows + k_t + n_t - 2) as u64;
        // psum wires between rows: psums[d][c] = psum reg of PE(d, c).
        // Processed in decreasing order per cycle, single-buffered regs
        // behave like previous-cycle reads.
        for t in 0..total_cycles {
            for d in (0..k_t).rev() {
                for c in (0..n_t).rev() {
                    // PE (d, c) fires at cycle t iff it holds row
                    // r = t - d - c with 0 <= r < rows.
                    let Some(r) = (t as i64 - d as i64 - c as i64)
                        .try_into()
                        .ok()
                        .filter(|r: &u64| (*r as usize) < rows)
                    else {
                        continue;
                    };
                    let r = r as usize;
                    // Activation input: FIFO for column 0, left neighbour
                    // otherwise (previous-cycle value, guaranteed by the
                    // descending-c update order).
                    let act_in = if c == 0 {
                        sds.pop_if_due(d, t).expect("SDS waveform violated")
                    } else {
                        self.counters.inter_act += 1;
                        self.pes[(c - 1) * self.height + d].act
                    };
                    let psum_in = if d == 0 {
                        0.0
                    } else {
                        self.counters.inter_psum += 1;
                        self.pes[c * self.height + (d - 1)].psum
                    };
                    let (out, counts) = self.pe(d, c).mac(act_in, psum_in);
                    self.counters.intra += (counts.intra_reads + counts.intra_writes) as u64;
                    self.counters.macs += 1;
                    if d == k_t - 1 {
                        aa.accumulate(r, c, out);
                    }
                }
            }
        }
        self.add_passthrough_hops(rows);
        total_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::weight_fetcher::WeightTile;

    fn tile(k_t: usize, n_t: usize, f: impl Fn(usize, usize) -> f32) -> WeightTile {
        let mut values = Vec::new();
        for d in 0..k_t {
            for c in 0..n_t {
                values.push(f(d, c));
            }
        }
        WeightTile { k_t, n_t, values }
    }

    /// Both engines on the same tiny GEMM; compare outputs, counters,
    /// and cycle count against hand math.
    #[test]
    fn engines_agree_and_match_hand_math() {
        let k_t = 3;
        let n_t = 2;
        let rows = 4;
        let w = tile(k_t, n_t, |d, c| (d + 1) as f32 * if c == 0 { 1.0 } else { -1.0 });
        let acts: Vec<Vec<f32>> = (0..rows)
            .map(|r| (0..k_t).map(|d| (r * k_t + d) as f32).collect())
            .collect();

        // Wavefront engine.
        let mut arr_w = SystolicArray::new(4, 4);
        arr_w.load_shadow_tile(&w);
        arr_w.activate_tile(k_t, n_t);
        let mut aa_w = AccumulatorArray::new(64);
        aa_w.open(rows, n_t);
        arr_w.stream_pass_wavefront(&acts, &mut aa_w);
        let mut out_w = vec![0.0; rows * n_t];
        aa_w.drain(|r, c, v| out_w[r * n_t + c] = v);

        // Cycle engine.
        let mut arr_c = SystolicArray::new(4, 4);
        arr_c.load_shadow_tile(&w);
        arr_c.activate_tile(k_t, n_t);
        let mut aa_c = AccumulatorArray::new(64);
        aa_c.open(rows, n_t);
        let mut sds = SystolicDataSetup::new(4);
        for (r, row) in acts.iter().enumerate() {
            sds.stage_row(r as u64, row);
        }
        let cycles = arr_c.stream_pass_cycle(&mut sds, rows, &mut aa_c);
        let mut out_c = vec![0.0; rows * n_t];
        aa_c.drain(|r, c, v| out_c[r * n_t + c] = v);

        assert_eq!(cycles, (rows + k_t + n_t - 2) as u64);
        assert_eq!(out_w, out_c);
        assert_eq!(arr_w.counters, arr_c.counters);
        assert!(sds.is_empty());

        // Hand check one output: row 1 = [3,4,5], col 0 weights [1,2,3]:
        // 3*1 + 4*2 + 5*3 = 26.
        assert_eq!(out_w[1 * n_t], 26.0);
        // Counter identities for one pass on the 4x4 array: full-width
        // activation propagation and full-height psum descent.
        assert_eq!(arr_w.counters.macs, (rows * k_t * n_t) as u64);
        assert_eq!(arr_w.counters.inter_act, (rows * k_t * (4 - 1)) as u64);
        assert_eq!(arr_w.counters.inter_psum, (rows * n_t * (4 - 1)) as u64);
        assert_eq!(
            arr_w.counters.inter_weight,
            (n_t * k_t * (k_t - 1) / 2) as u64
        );
        assert_eq!(
            arr_w.counters.intra,
            (5 * rows * k_t * n_t + 2 * k_t * n_t) as u64
        );
    }

    #[test]
    fn single_pe_pass() {
        let mut arr = SystolicArray::new(1, 1);
        arr.load_shadow_tile(&tile(1, 1, |_, _| 4.0));
        arr.activate_tile(1, 1);
        let mut aa = AccumulatorArray::new(4);
        aa.open(1, 1);
        let mut sds = SystolicDataSetup::new(1);
        sds.stage_row(0, &[3.0]);
        let cycles = arr.stream_pass_cycle(&mut sds, 1, &mut aa);
        assert_eq!(cycles, 1);
        let mut v = 0.0;
        aa.drain(|_, _, x| v = x);
        assert_eq!(v, 12.0);
    }

    #[test]
    fn shadow_load_does_not_disturb_running_weights() {
        let mut arr = SystolicArray::new(2, 2);
        arr.load_shadow_tile(&tile(2, 2, |_, _| 1.0));
        arr.activate_tile(2, 2);
        // Load the next tile mid-flight.
        arr.load_shadow_tile(&tile(2, 2, |_, _| 100.0));
        let mut aa = AccumulatorArray::new(8);
        aa.open(1, 2);
        arr.stream_pass_wavefront(&[vec![1.0, 1.0]], &mut aa);
        let mut out = vec![];
        aa.drain(|_, _, v| out.push(v));
        // Still the old weights: 1*1 + 1*1 = 2 per column.
        assert_eq!(out, vec![2.0, 2.0]);
    }
}
